"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON artifacts.  Invoked manually; output pasted/included into
EXPERIMENTS.md (kept as a script so the tables are regenerable).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.roofline import model_flops  # noqa: E402
from repro.configs import SHAPES, ASSIGNED_ARCHS  # noqa: E402

ARCHS = ASSIGNED_ARCHS + ["paper-solar-102b"]


def load(variant):
    p = RESULTS / f"dryrun_{variant}.json"
    return json.loads(p.read_text()) if p.exists() else {}


def fmt_bytes(n):
    if n is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def dryrun_table():
    base = load("baseline")
    out = ["| arch | shape | 16x16 | 2x16x16 | bytes/device (args) | "
           "gate collectives (16x16) |",
           "|---|---|---|---|---|---|"]
    for arch in ARCHS:
        for shape in SHAPES:
            k1 = f"{arch}|{shape}|16x16"
            k2 = f"{arch}|{shape}|2x16x16"
            r1, r2 = base.get(k1, {}), base.get(k2, {})
            s1, s2 = r1.get("status", "—"), r2.get("status", "—")
            if s1 == "SKIP":
                out.append(f"| {arch} | {shape} | SKIP | SKIP | — | "
                           f"{r1.get('reason','')[:60]} |")
                continue
            mem = r1.get("memory_analysis", {})
            args = mem.get("argument_size_in_bytes")
            colls = r1.get("gate_collective_ops", {})
            coll_s = " ".join(f"{k}:{v}" for k, v in sorted(colls.items()))
            out.append(f"| {arch} | {shape} | {s1} "
                       f"({r1.get('gate_compile_s','?')}s) | {s2} "
                       f"({r2.get('gate_compile_s','?')}s) | "
                       f"{fmt_bytes(args)} | {coll_s} |")
    return "\n".join(out)


def roofline_table(variant="baseline"):
    base = load(variant)
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MODEL_FLOPS | useful ratio | fraction |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCHS:
        for shape in SHAPES:
            rec = base.get(f"{arch}|{shape}|16x16", {})
            if rec.get("status") == "SKIP":
                out.append(f"| {arch} | {shape} | SKIP | | | | | | |")
                continue
            r = rec.get("roofline")
            if not r:
                continue
            mf = model_flops(arch, shape)
            ratio = mf / max(r["flops"], 1)
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            out.append(
                f"| {arch} | {shape} | {r['compute_s']:.4g} | "
                f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
                f"**{r['dominant']}** | {mf:.3g} | {ratio:.3f} | "
                f"{r['compute_s']/bound:.3f} |")
    return "\n".join(out)


def variant_comparison(arch, shape, variants):
    out = [f"**{arch} × {shape}** (16x16)", "",
           "| variant | compute (s) | memory (s) | collective (s) | "
           "temp bytes/dev | dominant |",
           "|---|---|---|---|---|---|"]
    for v in variants:
        rec = load(v).get(f"{arch}|{shape}|16x16", {})
        r = rec.get("roofline")
        if not r:
            out.append(f"| {v} | (not measured) | | | | |")
            continue
        temp = rec.get("memory_analysis", {}).get("temp_size_in_bytes")
        out.append(f"| {v} | {r['compute_s']:.4g} | {r['memory_s']:.4g} | "
                   f"{r['collective_s']:.4g} | {fmt_bytes(temp)} | "
                   f"{r['dominant']} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### §Dry-run table\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n### §Roofline table (baseline, single pod)\n")
        print(roofline_table())
    if which in ("all", "perf"):
        print("\n### §Perf variant comparisons\n")
        print(variant_comparison("paper-solar-102b", "train_4k",
                                 ["naive-port", "baseline", "moe-shard", "loss-chunk", "opt", "opt2"]))
        print()
        print(variant_comparison("granite-moe-1b-a400m", "train_4k",
                                 ["baseline", "moe-shard", "loss-chunk", "opt", "opt2"]))
        print()
        print(variant_comparison("mistral-large-123b", "prefill_32k",
                                 ["naive-attn", "baseline", "bf16-attn", "opt", "opt2"]))
