"""CI benchmark regression gate.

    python -m benchmarks.check_regression CURRENT.json BASELINE.json \
        [--factor 2.0] [--require GROUP]... [--envelope GROUP=FACTOR]...

Compares the ``us_per_call`` of every benchmark row present in BOTH files
(the ``--json`` output of ``benchmarks.run``) and fails when any current
timing exceeds ``factor`` x its baseline.  Rows with missing or
non-positive timings (derived-only rows, errored benches) are skipped;
benches new since the baseline are reported but do not fail the gate —
regenerate the baseline to start tracking them:

    REPRO_BENCH_FAST=1 PYTHONPATH=src python -m benchmarks.run \
        --only cluster_engine --only storage_fabric \
        --only control_plane --only mc_batch --only mc_wavefront \
        --only detector_backend --only fault_taxonomy \
        --only fault_topology --only sweep_service \
        --json benchmarks/baselines/ci_baseline.json

``--require GROUP`` (repeatable) declares a gated group: at least one row
whose name contains GROUP must exist in BOTH files, otherwise the gate
fails with exit 2 instead of silently passing.  Without it, a gated
benchmark whose baseline entry was never committed (or whose bench was
renamed away) would sail through as "new"/"missing" forever.

``--envelope GROUP=FACTOR`` (repeatable) overrides the global ``--factor``
for rows whose name contains GROUP — compiled device passes swing harder
on shared runners than pure-numpy rows (JIT warm-up, thread contention),
so one global factor is either too loose for the stable groups or too
trigger-happy for the jittery ones.  The longest matching GROUP wins when
several apply.

The committed baseline (`benchmarks/baselines/ci_baseline.json`) seeds the
BENCH_* perf trajectory: the 2x headroom absorbs runner-to-runner noise
while still catching the order-of-magnitude regressions that matter (a
batched path silently degrading to its per-tick reference).
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        us = row.get("us_per_call")
        if isinstance(us, (int, float)) and us > 0:
            out[row["name"]] = float(us)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh benchmarks.run --json output")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="fail when current > factor x baseline")
    ap.add_argument("--min-us", type=float, default=1000.0,
                    help="ignore rows whose baseline is below this "
                         "(microsecond rows are timer noise on shared "
                         "runners)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="GROUP",
                    help="fail (exit 2) unless a row whose name contains "
                         "GROUP exists in both files — a gated group "
                         "missing its baseline entry must not silently "
                         "pass; repeatable")
    ap.add_argument("--envelope", action="append", default=[],
                    metavar="GROUP=FACTOR",
                    help="per-group tolerance override: rows whose name "
                         "contains GROUP gate at FACTOR x baseline "
                         "instead of --factor (longest matching GROUP "
                         "wins); repeatable")
    args = ap.parse_args()

    envelopes = {}
    for spec in args.envelope:
        group, sep, val = spec.partition("=")
        try:
            factor = float(val)
            if not group or not sep or factor <= 0:
                raise ValueError
        except ValueError:
            print(f"error: bad --envelope {spec!r} (want GROUP=FACTOR "
                  "with FACTOR > 0)", file=sys.stderr)
            sys.exit(2)
        envelopes[group] = factor

    def row_factor(name: str) -> float:
        hits = [g for g in envelopes if g in name]
        if not hits:
            return args.factor
        return envelopes[max(hits, key=len)]

    cur = load_rows(args.current)
    base = load_rows(args.baseline)

    missing_base = [g for g in args.require
                    if not any(g in name for name in base)]
    missing_cur = [g for g in args.require
                   if not any(g in name for name in cur)]
    if missing_base or missing_cur:
        for g in missing_base:
            print(f"error: required group {g!r} has no baseline row — "
                  f"add it to {args.baseline}", file=sys.stderr)
        for g in missing_cur:
            print(f"error: required group {g!r} produced no current row "
                  f"(bench renamed, filtered out, or errored?)",
                  file=sys.stderr)
        sys.exit(2)
    skipped = sorted(name for name in set(cur) & set(base)
                     if base[name] < args.min_us)
    shared = sorted(name for name in set(cur) & set(base)
                    if base[name] >= args.min_us)
    new = sorted(set(cur) - set(base))
    gone = sorted(set(base) - set(cur))

    failures = []
    print(f"{'benchmark':<34} {'baseline':>12} {'current':>12} {'ratio':>7}")
    for name in shared:
        factor = row_factor(name)
        ratio = cur[name] / base[name]
        delta_pct = (ratio - 1.0) * 100.0
        flag = f" <-- REGRESSION ({delta_pct:+.0f}% vs baseline, " \
               f"allowed {factor:.1f}x)" if ratio > factor else ""
        print(f"{name:<34} {base[name]:>10.0f}us {cur[name]:>10.0f}us "
              f"{ratio:>6.2f}x{flag}")
        if ratio > factor:
            failures.append((name, ratio, factor))
    for name in skipped:
        print(f"{name:<34} {base[name]:>10.0f}us {cur[name]:>10.0f}us "
              f"  (below --min-us, not gated)")
    for name in new:
        print(f"{name:<34} {'(new)':>12} {cur[name]:>10.0f}us       -")
    for name in gone:
        print(f"{name:<34} {base[name]:>10.0f}us {'(missing)':>12}       -")

    if not shared:
        print("error: no overlapping benchmark rows between current and "
              "baseline", file=sys.stderr)
        sys.exit(2)
    if failures:
        worst = max(failures, key=lambda kv: kv[1] / kv[2])
        print(f"\nFAIL: {len(failures)} benchmark(s) regressed past their "
              f"tolerance envelope (worst: {worst[0]} at {worst[1]:.2f}x "
              f"= {(worst[1]-1)*100:+.0f}% vs baseline, allowed "
              f"{worst[2]:.1f}x)", file=sys.stderr)
        sys.exit(1)
    env = "".join(f", {g}<={f:.1f}x" for g, f in sorted(envelopes.items()))
    print(f"\nOK: {len(shared)} benchmarks within tolerance "
          f"(default {args.factor:.1f}x{env})")


if __name__ == "__main__":
    main()
