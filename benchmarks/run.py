"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substr]... [--json path]

``--only`` is repeatable; a bench runs when ANY given substring matches its
name (CI: ``--only cluster_engine --only storage_fabric --only
control_plane --only mc_batch --only mc_wavefront --only
detector_backend --only fault_taxonomy --only fault_topology --only
sweep_service``).  Prints
``name,us_per_call,derived`` CSV; ``--json`` additionally writes the rows
as a JSON document (the CI artifact, which ``benchmarks.check_regression``
gates against the committed baseline) stamped with the git SHA, an
ISO-8601 UTC timestamp, the best-of-K setting, and — where a bench
declares one — the backend each row ran on, so the archived
``BENCH_*.json`` perf trajectory stays attributable across PRs.
``--repeat K`` makes every default-configured timing best-of-K.  Set
REPRO_BENCH_FAST=1 for the abbreviated suite (CI).  The roofline table
(from the dry-run artifacts) is appended when
benchmarks/results/dryrun_baseline.json exists.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import traceback
from datetime import datetime, timezone


def git_sha() -> str:
    """HEAD commit of the repo this benchmark file lives in ("unknown"
    outside a git checkout — the payload is still valid)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="run benches whose name contains this substring; "
                         "repeatable (any match runs the bench)")
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--repeat", type=int, default=None, metavar="K",
                    help="best-of-K timing for every `timed` call that "
                         "does not set its own best_of (the min over K "
                         "rounds strips runner noise; the gated CI "
                         "groups already run their measured paths at "
                         "best-of-3)")
    args = ap.parse_args()

    from benchmarks import bench_kernels, bench_ops, common
    from benchmarks.common import FAST

    if args.repeat is not None:
        common.BEST_OF = max(args.repeat, 1)

    benches = bench_ops.all_benches() + bench_kernels.all_benches()
    print("name,us_per_call,derived")
    failures = 0
    rows = []
    for bench in benches:
        if args.only and not any(o in bench.__name__ for o in args.only):
            continue
        try:
            for row in bench():
                # rows are (name, us, derived[, backend[, n_seeds]]) —
                # the 4th element records which detection/kernel backend
                # produced the timing, the 5th how many Monte Carlo
                # seeds the timing covers (so per-seed cost stays
                # computable from the archived JSON trajectory)
                name, us, derived = row[:3]
                backend = row[3] if len(row) > 3 else None
                n_seeds = row[4] if len(row) > 4 else None
                rows.append({"name": name, "us_per_call": us,
                             "derived": derived, "backend": backend,
                             "n_seeds": n_seeds})
                print(f"{name},{us:.1f},\"{derived}\"", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            rows.append({"name": bench.__name__, "us_per_call": None,
                         "derived": f"ERROR: {e}", "backend": None,
                         "n_seeds": None})
            print(f"{bench.__name__},nan,\"ERROR: {e}\"", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"fast": FAST, "only": args.only,
                       "best_of": common.BEST_OF,
                       "git_sha": git_sha(),
                       "generated_at": datetime.now(
                           timezone.utc).isoformat(timespec="seconds"),
                       "failures": failures, "rows": rows}, f, indent=2)
        print(f"json written to {args.json}", file=sys.stderr)

    # roofline summary (if the dry-run has produced artifacts)
    try:
        from benchmarks import roofline
        rs = [r for r in roofline.rows() if r.get("status") == "OK"
              and "dominant" in r]
        if rs and not args.only:
            worst = min(rs, key=lambda r: r["roofline_fraction"])
            best = max(rs, key=lambda r: r["roofline_fraction"])
            print(f"roofline_cells,{len(rs)},\"best={best['arch']}/"
                  f"{best['shape']}={best['roofline_fraction']:.2f} "
                  f"worst={worst['arch']}/{worst['shape']}="
                  f"{worst['roofline_fraction']:.2f} "
                  f"(full table: EXPERIMENTS.md §Roofline)\"")
    except Exception:
        pass

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
