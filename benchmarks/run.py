"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only substr]

Prints ``name,us_per_call,derived`` CSV.  Set REPRO_BENCH_FAST=1 for the
abbreviated suite (CI).  The roofline table (from the dry-run artifacts) is
appended when benchmarks/results/dryrun_baseline.json exists.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import bench_kernels, bench_ops

    benches = bench_ops.all_benches() + bench_kernels.all_benches()
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},\"{derived}\"", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{bench.__name__},nan,\"ERROR: {e}\"", flush=True)

    # roofline summary (if the dry-run has produced artifacts)
    try:
        from benchmarks import roofline
        rs = [r for r in roofline.rows() if r.get("status") == "OK"
              and "dominant" in r]
        if rs and not args.only:
            worst = min(rs, key=lambda r: r["roofline_fraction"])
            best = max(rs, key=lambda r: r["roofline_fraction"])
            print(f"roofline_cells,{len(rs)},\"best={best['arch']}/"
                  f"{best['shape']}={best['roofline_fraction']:.2f} "
                  f"worst={worst['arch']}/{worst['shape']}="
                  f"{worst['roofline_fraction']:.2f} "
                  f"(full table: EXPERIMENTS.md §Roofline)\"")
    except Exception:
        pass

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
