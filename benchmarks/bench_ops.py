"""Operational benchmarks — one function per paper table/figure.

Each returns CSV rows (name, us_per_call, derived) where ``derived`` holds
the quantities the corresponding paper artifact reports, alongside the
paper's own values for direct comparison.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import FAST, timed


# ---------------------------------------------------------------------------
# Table 2: failure taxonomy
# ---------------------------------------------------------------------------

def bench_taxonomy() -> list:
    from repro.core.failures import FailureInjector
    from repro.core.xid import MINDER_CATEGORY

    def run():
        counts = {}
        total = 0
        for seed in range(40):
            inj = FailureInjector(seed=seed)
            for ev in inj.sample(55 * 24.0):
                total += 1
                if ev.kind == "xid":
                    cat = MINDER_CATEGORY.get(ev.xid, "Others")
                elif ev.kind == "unreachable":
                    cat = "Machine unreachable"
                else:
                    cat = "Others (perf degradation)"
                counts[cat] = counts.get(cat, 0) + 1
        return counts, total

    (counts, total), us = timed(run)
    shares = {k: 100 * v / total for k, v in sorted(counts.items())}
    derived = (f"events_per_55d={total/40:.1f} (paper 17) | "
               + " ".join(f"{k}={v:.1f}%" for k, v in shares.items())
               + f" | paper: NVLink 29.4% ECC 11.8% dropout 11.8% "
                 f"unreachable 11.8% others 29.4%")
    return [("taxonomy_table2", us, derived)]


# ---------------------------------------------------------------------------
# F1 / Table 9: precursor detection
# ---------------------------------------------------------------------------

def bench_precursor() -> list:
    from repro.core.cluster import CampaignConfig, ClusterSim
    from repro.core.precursor import (DetectorConfig, PrecursorDetector,
                                      evaluate)

    days = 4.0 if FAST else 10.0
    seeds = [11] if FAST else [11, 23]
    n_fail = n_det = n_pre = 0
    fp_days = []
    metric_votes = {}
    total_us = 0.0
    for seed in seeds:
        res = ClusterSim(CampaignConfig(duration_h=days * 24, telemetry=True,
                                        seed=seed)).run()
        xid_fails = [f for f in res.failures if f.kind == "xid"]
        det = PrecursorDetector(DetectorConfig())
        alarms, us = timed(det.scan, res.store)
        total_us += us
        ev = evaluate(alarms, xid_fails, res.duration_h)
        n_fail += ev.n_failures
        n_det += ev.detected
        n_pre += ev.pre_xid
        fp_days.append(ev.fp_per_day)
        for a in alarms:
            for m, _ in a.top_metrics[:1]:
                metric_votes[m] = metric_votes.get(m, 0) + 1
    top_metric_share = (max(metric_votes.values()) / max(sum(
        metric_votes.values()), 1)) if metric_votes else 0.0
    derived = (f"detection={n_det}/{n_fail} (paper 10/10) "
               f"pre_xid={n_pre}/{n_fail} (paper 2/10) "
               f"fp_per_day={np.mean(fp_days):.2f} (paper 0.84) "
               f"top_metric_dominance={top_metric_share:.2f} "
               f"(multi-signal: no metric dominates)")
    return [("precursor_f1", total_us, derived)]


# ---------------------------------------------------------------------------
# Fig 9 / Table 12: checkpoint data path (real two-phase save)
# ---------------------------------------------------------------------------

def bench_ckpt_path() -> list:
    import tempfile

    import jax
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.models import model as model_mod

    cfg = get_config("stablelm-3b").reduced(n_periods=2)
    params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, simulate_rpc=False)
        rec, us = timed(lambda: (mgr.save(1, {"params": params}),
                                 mgr.wait())[0])
        tl = rec.timeline
        rows.append(("ckpt_two_phase_save", us,
                     f"bytes={rec.bytes} blocking_ms={tl.blocking_s*1e3:.1f} "
                     f"async_ms={tl.async_s*1e3:.1f} "
                     f"cascade_ordered={tl.cascade_ordered()} "
                     f"(paper: pause->staging->write->rpc order, Fig 9)"))
        (restored, step), us2 = timed(
            lambda: mgr.restore(like={"params": params}))
        rows.append(("ckpt_restore_verified", us2,
                     f"step={step} checksum=ckpt_pack blocks (f32) + "
                     f"xor-fold (rest) verified"))
    return rows


# ---------------------------------------------------------------------------
# Table 13 + §4.2.5: NFS RPC decomposition / bandwidth paradox
# ---------------------------------------------------------------------------

def bench_rpc() -> list:
    from repro.checkpoint.storage import NFSClientSim

    sim = NFSClientSim(seed=0)
    w, us_w = timed(sim.checkpoint_save, 20 << 30)
    r, us_r = timed(sim.checkpoint_load, 200 << 30)
    rows = [
        ("rpc_save_write", us_w,
         f"latency_ms={w.mean_latency_s*1e3:.0f} "
         f"slot_wait_pct={w.slot_wait_fraction*100:.1f} (paper 92.2) "
         f"bw_util_pct={w.bandwidth_utilization*100:.1f} (paper 1.4-2.7) "
         f"duration_s={w.duration_s:.1f} (paper delta 18-31.7)"),
        ("rpc_load_read", us_r,
         f"latency_ms={r.mean_latency_s*1e3:.1f} (paper 59) "
         f"slot_wait_pct={r.slot_wait_fraction*100:.1f} (paper 53.3) "
         f"bw_util_pct={r.bandwidth_utilization*100:.1f} (paper 10.4) "
         f"req_per_s={r.request_rate_s:.0f} (paper 8000-9000)"),
    ]
    # the paradox resolution: slots, not bandwidth -> doubling the link
    # changes nothing, doubling slots does
    import dataclasses
    sim2 = NFSClientSim(dataclasses.replace(sim.config, n_slots=256), seed=0)
    w2 = sim2.checkpoint_save(20 << 30)
    rows.append(("rpc_paradox_2x_slots", 0.0,
                 f"save_duration_s {w.duration_s:.1f} -> {w2.duration_s:.1f} "
                 f"(x{w.duration_s/max(w2.duration_s,1e-9):.2f}); "
                 f"2x link bw -> x1.00 (slot-bound, paper §4.2.5)"))
    return rows


# ---------------------------------------------------------------------------
# F2 at cluster scale: shared-NFS fabric (scale-emergent bottleneck)
# ---------------------------------------------------------------------------

def bench_storage_fabric() -> list:
    from repro.storage import StorageFabric

    fab = StorageFabric()
    rows = []

    # the deliverable curve: near-linear at 2-4 nodes, collapsed at 63
    def fmt_curve(curve):
        return " ".join(f"{r['nodes']}n={r['utilization']*100:.1f}%"
                        for r in curve)

    rcurve, us_r = timed(fab.scaling_curve, "read", (2, 4, 16, 63))
    wcurve, us_w = timed(fab.scaling_curve, "write", (2, 4, 16, 63))
    rows.append(("storage_fabric_scaling_read", us_r,
                 f"{fmt_curve(rcurve)} (paper: 21.5% of 700 GB/s at "
                 f"60-node scale; absent at 2-4 nodes)"))
    rows.append(("storage_fabric_scaling_write", us_w,
                 f"{fmt_curve(wcurve)} (paper: 16.0% of 250 GB/s)"))

    # vectorized multi-client sim vs the event-driven reference on the
    # 63-node restart-load scenario (acceptance: <=5% duration, >=10x)
    bytes_pc = (2 << 30) if FAST else (8 << 30)
    for engine in ("vectorized", "event"):          # warm both paths
        fab.simulate("read", 4, 64 << 20, engine=engine, seed=0)
    vec, us_vec = timed(lambda: fab.simulate(
        "read", 63, bytes_pc, engine="vectorized", seed=0),
        repeats=3 if FAST else 1, best_of=3)
    ev, us_ev = timed(lambda: fab.simulate(
        "read", 63, bytes_pc, engine="event", seed=0), best_of=1)
    err = abs(vec.duration_s - ev.duration_s) / ev.duration_s
    rows.append(("storage_fabric_engines", us_vec,
                 f"63-node load {bytes_pc >> 30} GiB/node: "
                 f"vec={us_vec/1e6:.3f}s event={us_ev/1e6:.3f}s "
                 f"speedup=x{us_ev/us_vec:.1f} duration_err={err*100:.1f}% "
                 f"util={vec.utilization*100:.1f}% (target <=5%, >=10x)"))
    return rows


# ---------------------------------------------------------------------------
# control plane: streaming detection vs rescan-per-span, and the
# proactive-vs-reactive goodput ledger
# ---------------------------------------------------------------------------

def bench_control_plane() -> list:
    from repro.control import ControlConfig, StreamingDetector
    from repro.core.cluster import CampaignConfig, ClusterSim
    from repro.core.precursor import DetectorConfig, PrecursorDetector
    from repro.telemetry.registry import TimeSeriesStore

    hours = 12.0 if FAST else 24.0
    res = ClusterSim(CampaignConfig(duration_h=hours, telemetry=True,
                                    telemetry_pad_metrics=16,
                                    seed=11)).run()
    store = res.store
    ts = store.times()
    arrays = {name: store.series(name) for name in store.names}
    T = len(ts)
    span = 60                               # 30 min control interval
    spans = [(a, min(a + span, T)) for a in range(0, T, span)]

    # online streaming: one incremental pass per span
    def run_stream():
        det = StreamingDetector(DetectorConfig())
        out = []
        for a, b in spans:
            out += det.push(ts[a:b],
                            {k: v[a:b] for k, v in arrays.items()})
        return out

    stream_alarms, us_stream = timed(run_stream, best_of=3)

    # naive online deployment of the offline detector: rescan the growing
    # store at every span (what running `scan` per tick/span costs)
    det = PrecursorDetector(DetectorConfig())

    def run_rescan():
        out = []
        for _, b in spans:
            prefix = TimeSeriesStore(store.n_nodes)
            prefix.append_batch(ts[:b],
                                {k: v[:b] for k, v in arrays.items()})
            out = det.scan(prefix)
        return out

    rescan_alarms, us_rescan = timed(run_rescan)
    parity = stream_alarms == rescan_alarms
    rows = [("control_plane_streaming", us_stream,
             f"{len(spans)} spans x {span} ticks (T={T}): "
             f"stream={us_stream/1e6:.2f}s rescan={us_rescan/1e6:.2f}s "
             f"speedup=x{us_rescan/us_stream:.1f} parity={parity} "
             f"alarms={len(stream_alarms)} (target >=10x, exact parity)")]

    # proactive vs reactive on identical failure schedules (seeds chosen
    # so the window contains pre-XID precursor events — the case the
    # control plane exists for; FP-only windows cost ~seconds of saves)
    days = 7.0 if FAST else 21.0
    seeds = (25,) if FAST else (7, 25)
    d_goodput = avoided = urgent = 0.0
    total_us = 0.0
    for seed in seeds:
        pro, us = timed(lambda s=seed: ClusterSim(CampaignConfig(
            duration_h=days * 24.0, telemetry_pad_metrics=0,
            telemetry_store=False, control=ControlConfig(drain=False),
            seed=s)).run())
        total_us += us
        rea = ClusterSim(CampaignConfig(duration_h=days * 24.0,
                                        seed=seed)).run()
        d_goodput += pro.goodput_h() - rea.goodput_h()
        avoided += pro.control.lost_work_avoided_h
        urgent += pro.control.urgent_save_h
    rows.append(("control_plane_goodput", total_us,
                 f"{len(seeds)} x {days:.0f}d proactive-vs-reactive: "
                 f"goodput {d_goodput/len(seeds):+.2f} h/campaign "
                 f"(lost-work avoided {avoided/len(seeds):.2f} h, urgent "
                 f"saves {urgent/len(seeds):.2f} h)"))
    return rows


# ---------------------------------------------------------------------------
# Tables 10/11: Young/Daly interval optimisation
# ---------------------------------------------------------------------------

def bench_youngdaly() -> list:
    from repro.checkpoint.youngdaly import mc_cost_fraction, phase_table

    table, us = timed(phase_table)
    rows = []
    for row in table:
        mc = mc_cost_fraction(row["actual_interval_min"] * 60.0,
                              row["delta_s"], 56.2, n=20_000)
        rows.append((f"youngdaly_{row['phase'].split()[0]}", us / 3,
                     f"T_opt_min={row['t_opt_min']:.1f} "
                     f"overhead_pct={row['save_overhead_pct']:.2f} "
                     f"total_cost_pct={row['total_cost_pct']:.2f} "
                     f"mc_cost_pct={mc*100:.2f} "
                     f"(paper: 44.9/59.7/58.1 min, cost 2.20/3.22/1.82%)"))
    return rows


# ---------------------------------------------------------------------------
# Table 14 / Figs 15-17: auto-retry chains + downtime
# ---------------------------------------------------------------------------

def bench_retry() -> list:
    from repro.core.cluster import CampaignConfig, ClusterSim
    from repro.core.retry import RetryConfig, RetryPolicy, chain_stats

    seeds = range(2) if FAST else range(8)

    def campaign(policy, enabled=True):
        succ = ch = att = 0
        autos, mans, gaps = [], [], []
        for seed in seeds:
            cfgr = RetryConfig(policy=policy, enabled=enabled)
            res = ClusterSim(CampaignConfig(seed=seed, retry=cfgr)).run()
            st = chain_stats(res.retry_chains())
            succ += st["success"]
            ch += st["n_chains"]
            att += st["n_attempts"]
            autos += [d["hours"] for d in res.downtimes if d["auto"]]
            mans += [d["hours"] for d in res.downtimes if not d["auto"]]
            gaps += [g for c in res.retry_chains() for g in c.gaps_min()]
        return dict(succ=succ, ch=ch, att=att, autos=autos, mans=mans,
                    gaps=gaps)

    base, us = timed(campaign, RetryPolicy.FIXED)
    rate = base["succ"] / max(base["ch"], 1)
    auto_med = float(np.median(base["autos"])) if base["autos"] else 0
    man_med = float(np.median(base["mans"])) if base["mans"] else 0
    gap_med = float(np.median(base["gaps"])) if base["gaps"] else 0
    q25, q75 = (np.percentile(base["gaps"], [25, 75])
                if base["gaps"] else (0, 0))
    rows = [
        ("retry_chains_fixed", us,
         f"chains={base['ch']} attempts={base['att']} "
         f"success_rate={rate:.3f} (paper 0.333) "
         f"gap_median_min={gap_med:.0f} iqr=({q25:.0f},{q75:.0f}) "
         f"(paper 11, 10-11)"),
        ("retry_downtime", 0.0,
         f"auto_median_h={auto_med:.2f} manual_median_h={man_med:.2f} "
         f"ratio={man_med/max(auto_med,1e-9):.2f} (paper 1.9 vs 3.3 = 1.7x)"),
    ]
    # beyond-paper §4.3.5 policies, A/B on the same seeds
    for pol in (RetryPolicy.EXP_BACKOFF, RetryPolicy.XID_BRANCH):
        alt, us2 = timed(campaign, pol)
        r2 = alt["succ"] / max(alt["ch"], 1)
        a2 = float(np.median(alt["autos"])) if alt["autos"] else 0
        rows.append((f"retry_policy_{pol.value}", us2,
                     f"success_rate={r2:.3f} attempts={alt['att']} "
                     f"auto_median_h={a2:.2f} "
                     f"(vs fixed: {rate:.3f}/{base['att']}/{auto_med:.2f})"))
    return rows


# ---------------------------------------------------------------------------
# Figs 11-13: node-exclusion concentration
# ---------------------------------------------------------------------------

def bench_exclusion() -> list:
    from repro.core.cluster import CampaignConfig, ClusterSim

    seeds = range(2) if FAST else range(6)

    def run():
        shares, delib = [], []
        for seed in seeds:
            res = ClusterSim(CampaignConfig(seed=seed)).run()
            s = res.exclusions.summary()
            shares.append(s["top3_share"])
            delib.append(s["deliberate_fraction"])
        return shares, delib

    (shares, delib), us = timed(run)
    return [("exclusion_fig11", us,
             f"top3_share={np.mean(shares)*100:.0f}% (paper >50%) "
             f"deliberate={np.mean(delib)*100:.0f}% "
             f"(paper: gpu074 100%, gpu086 97%, gpu116 99.6% deliberate)")]


# ---------------------------------------------------------------------------
# §3.5: storage I/O sharding (the 8h -> 8min case)
# ---------------------------------------------------------------------------

def bench_io_sharding() -> list:
    from repro.data.pipeline import init_time_model

    def run():
        rows = {}
        for n in (2, 4, 60):
            shared = init_time_model(n, files_per_node=2000, ops_per_file=6,
                                     data_bytes_per_node=200e9, sharded=False)
            shard = init_time_model(n, files_per_node=2000, ops_per_file=6,
                                    data_bytes_per_node=200e9, sharded=True)
            rows[n] = (shared, shard)
        return rows

    rows, us = timed(run)
    parts = [f"{n}n: shared={s/3600:.2f}h sharded={sh/60:.1f}min"
             for n, (s, sh) in rows.items()]
    return [("io_sharding_s35", us,
             " | ".join(parts) + " (paper: >8h -> <8min at 60 nodes; "
             "2-4-node tests do not predict the cliff)")]


# ---------------------------------------------------------------------------
# real per-rank data pipeline sanity
# ---------------------------------------------------------------------------

def bench_data_pipeline() -> list:
    import tempfile

    from repro.data.pipeline import (DataConfig, RankShardReader,
                                     build_sharded_dataset)

    def run():
        with tempfile.TemporaryDirectory() as d:
            cfg = DataConfig(vocab_size=1000, seq_len=128,
                             tokens_per_shard=1 << 16)
            build_sharded_dataset(d, n_ranks=4, cfg=cfg)
            readers = [RankShardReader(d, r, cfg, batch_per_rank=2)
                       for r in range(4)]
            batches = [next(r) for r in readers]
            return sum(b["tokens"].sum() for b in batches)

    _, us = timed(run)
    return [("data_pipeline_rank_sharded", us,
             "4 ranks x sequential own-shard reads (the §3.5 fix layout)")]


# ---------------------------------------------------------------------------
# engine speedup: event-driven vs serial 30 s-tick loop
# ---------------------------------------------------------------------------

def bench_cluster_engine() -> list:
    import dataclasses

    from repro.core.cluster import CampaignConfig, ClusterSim

    # warm both paths (imports, allocator) before timing
    ClusterSim(CampaignConfig(duration_h=24.0, seed=9)).run()
    ClusterSim(CampaignConfig(duration_h=24.0, seed=9, engine="tick")).run()

    # 73-day paper campaign, no telemetry (the sweep configuration);
    # the gated row is best-of-3 so the envelope gate sees the code's
    # cost, not the runner's scheduling jitter
    cfg = CampaignConfig(seed=0)
    ev, us_ev = timed(lambda: ClusterSim(cfg).run(),
                      repeats=3 if FAST else 5, best_of=3)
    tk, us_tk = timed(lambda: ClusterSim(
        dataclasses.replace(cfg, engine="tick")).run(),
        repeats=1, best_of=1)
    rows = [("cluster_engine_73d", us_ev,
             f"event={us_ev/1e6:.3f}s tick={us_tk/1e6:.3f}s "
             f"speedup=x{us_tk/us_ev:.1f} "
             f"(sessions {len(ev.sessions)} vs {len(tk.sessions)}, "
             f"occ {ev.training_occupancy():.3f} vs "
             f"{tk.training_occupancy():.3f})")]

    # telemetry-on window: batched span generation vs per-tick scrapes
    days = 0.5 if FAST else 2.0
    tcfg = CampaignConfig(duration_h=days * 24.0, telemetry=True, seed=11)
    _, us_ev2 = timed(lambda: ClusterSim(tcfg).run(), best_of=3)
    _, us_tk2 = timed(lambda: ClusterSim(
        dataclasses.replace(tcfg, engine="tick")).run(), best_of=1)
    rows.append(("cluster_engine_telemetry", us_ev2,
                 f"{days:.1f}d window: event={us_ev2/1e6:.2f}s "
                 f"tick={us_tk2/1e6:.2f}s speedup=x{us_tk2/us_ev2:.1f}"))
    return rows


# ---------------------------------------------------------------------------
# seed-batched Monte Carlo campaign engine vs the ProcessPool per-seed path
# ---------------------------------------------------------------------------

def bench_mc_batch() -> list:
    """256 seeds of the 63-node/73-day campaign: one stacked-numpy pass
    (`BatchedCampaignEngine` via ``SweepRunner(mc_seeds=...)``) against the
    per-seed ProcessPool path, with exact per-seed parity asserted both at
    the findings level (all seeds) and field-for-field against direct
    `ClusterSim` runs (a seed sample).  Parity failure or a collapse of
    the batched path toward per-seed cost fails the bench (and CI)."""
    from repro.core.batch import BatchedCampaignEngine
    from repro.core.cluster import ClusterSim
    from repro.ops import SweepRunner, get_scenario

    sc = get_scenario("paper-faithful")
    n_seeds = 256
    BatchedCampaignEngine(sc.to_campaign_config(0)).run_findings([0])

    # shared-runner noise swings both paths by 2-3x; take the best of 3
    # for the cheap batched pass (the pool pass is too slow to repeat)
    mc, us_mc = timed(lambda: SweepRunner([sc], mc_seeds=n_seeds).run(),
                      best_of=3)
    pool, us_pool = timed(lambda: SweepRunner(
        [sc], seeds=range(n_seeds), executor="process").run(), best_of=1)

    mismatches = []
    for a, b in zip(mc.outcomes, pool.outcomes):
        fa = {k: v for k, v in a.findings.items() if k != "wall_s"}
        fb = {k: v for k, v in b.findings.items() if k != "wall_s"}
        if a.seed != b.seed or fa != fb:
            mismatches.append(a.seed)
    if mismatches:
        raise AssertionError(
            f"mc/pool findings diverge on seeds {mismatches[:5]} "
            f"({len(mismatches)}/{n_seeds})")

    # field-for-field CampaignResult parity against the scalar engine
    sample = [3] if FAST else [3, 11, 25]
    results = BatchedCampaignEngine(sc.to_campaign_config(0)).run(sample)
    for res, seed in zip(results, sample):
        ref = ClusterSim(sc.to_campaign_config(seed)).run()
        same = (
            [(s.state, s.nodes, s.created_h, s.started_h, s.ended_h,
              s.checkpoint_step, s.error, s.history)
             for s in ref.sessions]
            == [(s.state, s.nodes, s.created_h, s.started_h, s.ended_h,
                 s.checkpoint_step, s.error, s.history)
                for s in res.sessions]
            and [c.attempts for c in ref.chains]
            == [c.attempts for c in res.chains]
            and ref.failures == res.failures
            and ref.exclusions.intervals == res.exclusions.intervals
            and ref.downtimes == res.downtimes
            and ref.lost_hours == res.lost_hours
            and ref.checkpoint_events == res.checkpoint_events)
        if not same:
            raise AssertionError(f"field-level parity broke at seed {seed}")

    speedup = us_pool / us_mc
    # backstop: the batched path silently degrading toward per-seed cost
    # is the regression this group exists to catch (the floor is set for
    # noisy 2-core shared runners; typical observed is x4-10)
    if speedup < 2.5:
        raise AssertionError(
            f"mc_batch speedup collapsed to x{speedup:.1f} "
            f"(mc={us_mc/1e6:.2f}s pool={us_pool/1e6:.2f}s)")

    dist = mc.distribution()[sc.name]
    g = dist["goodput"]
    s4 = dist["f4_success_rate"]
    rows = [
        ("mc_batch_256seed", us_mc,
         f"{n_seeds} seeds x 73d/63n: batched={us_mc/1e6:.2f}s "
         f"pool={us_pool/1e6:.2f}s speedup=x{speedup:.1f} "
         f"(issue target >=10x; >=2.5x gated) parity=exact "
         f"({n_seeds} findings + {len(sample)} field-level seeds)",
         None, n_seeds),
        ("mc_batch_distribution", 0.0,
         f"goodput% median={g['median']*100:.1f} "
         f"iqr=[{g['q25']*100:.1f},{g['q75']*100:.1f}] "
         f"ci95=[{g['ci_lo']*100:.1f},{g['ci_hi']*100:.1f}] | "
         f"F4succ% median={s4['median']*100:.0f} "
         f"ci95=[{s4['ci_lo']*100:.0f},{s4['ci_hi']*100:.0f}] "
         f"(paper point estimates: occ 96.6, F4 33.3)",
         None, n_seeds),
    ]
    return rows


# ---------------------------------------------------------------------------
# compiled whole-campaign wavefront vs the stacked-numpy engine
# ---------------------------------------------------------------------------

def bench_mc_wavefront() -> list:
    """1024 seeds of the 63-node/73-day campaign advanced in ONE jitted
    device pass (`lax.while_loop` over the whole lane axis).

    Three gates, all measured here rather than assumed:

    - parity: findings bitwise identical to the stacked-numpy wavefront
      on every one of the 1024 seeds (any divergence fails CI);
    - speedup vs the per-seed scalar engine (the path a naive fleet
      sweep would take), >= 1.5x gated — per-seed cost measured on a
      seed sample and extrapolated, which is stated in the derived row;
    - cost vs the stacked-numpy wavefront, <= 2.5x gated.  On a 1-core
      CPU runner both wavefronts are bandwidth-bound on the same
      (lanes x nodes) state, so the compiled pass roughly TIES numpy
      (observed 0.7-1.0x) — the honest claim here is "same cost, one
      compiled program"; the gate catches the compiled path collapsing,
      and on accelerator-backed runners the ratio documents the win.

    Compile time is excluded from the gated timing (reported in the
    derived text) — a fleet sweep reuses the compiled program across
    every campaign of the same shape."""
    from repro.core.batch import BatchedCampaignEngine
    from repro.core.cluster import ClusterSim
    from repro.ops import get_scenario

    sc = get_scenario("paper-faithful")
    cfg = sc.to_campaign_config(0)
    n_seeds = 1024
    seeds = list(range(n_seeds))

    dev = BatchedCampaignEngine(cfg, wavefront_backend="xla")
    _, us_compile = timed(lambda: dev.run_findings(seeds), best_of=1)
    got, us_dev = timed(lambda: dev.run_findings(seeds), best_of=2)
    ref, us_np = timed(lambda: BatchedCampaignEngine(
        cfg, wavefront_backend="numpy").run_findings(seeds), best_of=1)
    sample = list(range(0, n_seeds, 128))   # 8 seeds, evenly spread
    _, us_scalar = timed(
        lambda: [ClusterSim(sc.to_campaign_config(s)).run()
                 for s in sample], best_of=1)
    us_scalar_total = us_scalar / len(sample) * n_seeds

    mismatches = [s for s, (a, b) in enumerate(zip(got, ref)) if a != b]
    if mismatches:
        raise AssertionError(
            f"compiled/numpy findings diverge on seeds {mismatches[:5]} "
            f"({len(mismatches)}/{n_seeds})")

    vs_scalar = us_scalar_total / us_dev
    vs_numpy = us_np / us_dev
    if vs_scalar < 1.5:
        raise AssertionError(
            f"mc_wavefront speedup vs per-seed scalar collapsed to "
            f"x{vs_scalar:.1f} (device={us_dev/1e6:.2f}s, scalar "
            f"~{us_scalar_total/1e6:.1f}s from a {len(sample)}-seed "
            "sample)")
    if vs_numpy < 0.4:
        raise AssertionError(
            f"mc_wavefront device pass fell to {1/vs_numpy:.1f}x the "
            f"stacked-numpy cost (device={us_dev/1e6:.2f}s "
            f"numpy={us_np/1e6:.2f}s; <=2.5x gated)")

    per_seed_us = us_dev / n_seeds
    return [
        ("mc_wavefront_1024seed", us_dev,
         f"{n_seeds} seeds x 73d/63n in one device pass: "
         f"device={us_dev/1e6:.2f}s ({per_seed_us/1e3:.1f}ms/seed) "
         f"vs scalar ~{us_scalar_total/1e6:.1f}s (x{vs_scalar:.1f}, "
         f">=1.5x gated, extrapolated from {len(sample)} seeds) "
         f"vs stacked-numpy {us_np/1e6:.2f}s (x{vs_numpy:.2f}, "
         f"<=2.5x gated) compile+first-run={us_compile/1e6:.2f}s "
         f"parity=bitwise ({n_seeds}/{n_seeds} findings)",
         "xla", n_seeds),
    ]


# ---------------------------------------------------------------------------
# detection fast path: fused robust-stats backend vs the numpy oracle
# ---------------------------------------------------------------------------

def _detector_spans(S, B, T, n, seed=0):
    """Synthetic 73d/63n-shaped telemetry spans for S seeds: B metrics +
    the activity metric, float64 (the `MetricRegistry` dtype — control
    campaigns scrape no float32 pad metrics), with node anomalies
    injected on ~1/3 of the seeds so the alarm/attribution path is
    exercised realistically."""
    rng = np.random.default_rng(seed)
    spans = []
    for s in range(S):
        v = {"DCGM_FI_DEV_GPU_UTIL": 99.0 + rng.normal(0, 0.3, (T, n))}
        for m in range(B):
            a = 50.0 + rng.normal(0, 1, (T, n))
            if s % 3 == 0 and m < 8:
                a[T // 2:, s % n] += 80.0        # ramping anomaly
            v[f"metric_{m:03d}"] = a
        spans.append(v)
    ts = [np.arange(T) * 30.0 / 3600.0] * S
    return ts, spans


def bench_detector_backend() -> list:
    """Fused robust-stats backend (jitted XLA off-TPU) vs the numpy
    oracle on the 256-seed stacked ``push_group`` block, exact alarm-set
    parity asserted; plus the end-to-end guard that the Monte Carlo
    campaign engine does not regress with the compiled backend enabled.
    Parity failure or a speedup collapse below the floor fails the bench
    (and CI); the committed baseline envelope gates the timing row."""
    from repro.control.streaming import StreamingDetector
    from repro.core.batch import BatchedCampaignEngine
    from repro.core.precursor import DetectorConfig
    from repro.ops import get_scenario

    S = 64 if FAST else 256
    B, T, n = 24, 120, 63                       # one 1-h control chunk
    cfg = DetectorConfig()
    ts, spans = _detector_spans(S, B, T, n)

    def run_group(backend):
        dets = [StreamingDetector(cfg, backend=backend) for _ in range(S)]
        return StreamingDetector.push_group(dets, ts, spans)

    run_group("xla")                            # warm the jit cache
    alarms_xla, us_xla = timed(run_group, "xla", best_of=3)
    alarms_np, us_np = timed(run_group, "numpy", best_of=3)
    if alarms_xla != alarms_np:
        bad = [i for i, (a, b) in enumerate(zip(alarms_np, alarms_xla))
               if a != b]
        raise AssertionError(
            f"xla/numpy alarm sets diverge on seeds {bad[:5]} "
            f"({len(bad)}/{S})")
    n_alarms = sum(len(a) for a in alarms_np)
    speedup = us_np / us_xla
    # backstop: the compiled path silently degrading to numpy cost is
    # the regression this group exists to catch.  The issue's >=3x needs
    # hardware the 2-core CI box doesn't have (exact selection is a
    # sorting network — memory-bound f32 passes that XLA spreads over
    # cores/TPU lanes, vs numpy's single-thread f64 introselect): the
    # dev box observes x1.4-1.6 here; the floor distinguishes collapse
    # (x1.0 — compiled path degraded to the oracle) from runner noise.
    # On a single-core host XLA has no threads to spread over, so the
    # legitimate result IS a tie with the single-thread oracle (observed
    # x0.9) — there only a pathological slowdown is gateable.
    floor = 1.25 if (os.cpu_count() or 1) > 1 else 0.6
    if speedup < floor:
        raise AssertionError(
            f"detector backend speedup collapsed to x{speedup:.1f} "
            f"(xla={us_xla/1e6:.2f}s numpy={us_np/1e6:.2f}s, floor "
            f"{floor} on {os.cpu_count()} core(s))")
    rows = [
        ("detector_backend_xla", us_xla,
         f"{S} seeds x ({B}m x {T}t x {n}n) push_group: "
         f"xla={us_xla/1e6:.3f}s numpy={us_np/1e6:.3f}s "
         f"speedup=x{speedup:.1f} (issue target >=3x — needs more cores/"
         f"TPU than the 2-core CI box; >={floor} gated on "
         f"{os.cpu_count()} core(s)) "
         f"parity=exact ({n_alarms} alarms)", "xla"),
        ("detector_backend_numpy", us_np,
         f"the numpy oracle pass on the same {S}-seed block", "numpy"),
    ]

    # end-to-end: the seed-batched proactive campaign must not regress
    # with the compiled backend enabled (detection is one slice of the
    # wavefront pass, so the ratio should sit near 1.0 either way)
    days = 3.0 if FAST else 4.0
    seeds = list(range(6 if FAST else 12))
    f_by_backend, wall = {}, {}
    for backend in ("xla", "numpy"):
        sc = get_scenario("proactive").replace(
            duration_days=days, telemetry_pad_metrics=0,
            detector_backend=backend)
        eng = BatchedCampaignEngine(sc.to_campaign_config(0))
        eng.run_findings(seeds[:1])             # warm (jit + allocator)
        f_by_backend[backend], wall[backend] = timed(
            lambda e=eng: e.run_findings(seeds), best_of=1)
    if f_by_backend["xla"] != f_by_backend["numpy"]:
        raise AssertionError("mc findings diverge across detector backends")
    ratio = wall["xla"] / wall["numpy"]
    if ratio > 1.5:
        raise AssertionError(
            f"mc end-to-end regressed with the xla backend: "
            f"x{ratio:.2f} (xla={wall['xla']/1e6:.2f}s "
            f"numpy={wall['numpy']/1e6:.2f}s)")
    rows.append((
        "detector_backend_mc_e2e", wall["xla"],
        f"{len(seeds)} seeds x {days:.0f}d proactive mc: "
        f"xla={wall['xla']/1e6:.2f}s numpy={wall['numpy']/1e6:.2f}s "
        f"ratio=x{ratio:.2f} (<=1.5 gated) findings=identical", "xla"))
    return rows


# ---------------------------------------------------------------------------
# scenario sweep throughput (the ops/ front door)
# ---------------------------------------------------------------------------

def bench_scenario_sweep() -> list:
    from repro.ops import SweepRunner, get_scenario

    names = ("paper-faithful", "no-auto-retry", "smart-retry") if FAST \
        else ("paper-faithful", "flaky-fabric", "no-auto-retry",
              "smart-retry", "young-daly")
    days = 14.0 if FAST else 73.0
    seeds = (0, 1) if FAST else (0, 1, 2)
    scenarios = [get_scenario(n).replace(duration_days=days) for n in names]
    res, us = timed(lambda: SweepRunner(scenarios, seeds=seeds,
                                        executor="process").run())
    agg = res.aggregate()
    succ = " ".join(
        f"{n}={agg[n]['f4_success_rate']*100:.0f}%" for n in names)
    n_camp = len(res.outcomes)
    return [("scenario_sweep", us,
             f"{len(names)}sc x {len(seeds)}seeds x {days:.0f}d = {n_camp} "
             f"campaigns in {us/1e6:.2f}s ({us/1e6/n_camp:.2f}s each); "
             f"F4 success: {succ} (paper 33.3%)")]


# ---------------------------------------------------------------------------
# infrastructure fault band: degraded-vs-clean overhead + parity gate
# ---------------------------------------------------------------------------

def bench_fault_taxonomy() -> list:
    """The degrade-don't-kill infra band (net degradation windows,
    escalating resource pressure, control-plane blind spots) threaded
    through the batched engine: campaigns dominated by the band must not
    cost materially more than the identical clean campaign (the window /
    escalation / blind machinery is ledger arithmetic, not simulation
    load), and the batched path must stay bit-identical to the scalar
    engine per seed — degradation ledger, throttles, deferred alarms and
    escalation crashes included."""
    from repro.core.batch import BatchedCampaignEngine
    from repro.core.cluster import ClusterSim
    from repro.core.failures import INFRA_KINDS
    from repro.ops import SweepRunner, get_scenario
    from repro.ops.sweep import compute_findings

    days = 4.0 if FAST else 10.0
    seeds = range(4) if FAST else range(8)
    degraded = get_scenario("infra-faults").replace(
        duration_days=days, telemetry_pad_metrics=0)
    clean = degraded.replace(
        name="infra-clean", kind_weights={k: 0.0 for k in INFRA_KINDS})

    cfg_deg = degraded.to_campaign_config(0)
    cfg_clean = clean.to_campaign_config(0)
    BatchedCampaignEngine(cfg_deg).run_findings([0])     # warm jit/caches

    _, us_clean = timed(lambda: BatchedCampaignEngine(
        cfg_clean).run_findings(list(seeds)), best_of=3)
    find_deg, us_deg = timed(lambda: BatchedCampaignEngine(
        cfg_deg).run_findings(list(seeds)), best_of=3)

    overhead = us_deg / us_clean
    if overhead > 1.2:
        raise AssertionError(
            f"infra band overhead x{overhead:.2f} over the clean campaign "
            f"(deg={us_deg/1e6:.2f}s clean={us_clean/1e6:.2f}s; gate 1.2x)")

    # bitwise batched==scalar parity on the degraded campaign, plus the
    # findings fold (degradation ledger included) per seed
    import dataclasses
    deg_total = 0.0
    for i, seed in enumerate(seeds):
        res = BatchedCampaignEngine(cfg_deg).run([seed])[0]
        ref = ClusterSim(dataclasses.replace(cfg_deg, seed=seed)).run()
        same = (ref.failures == res.failures
                and ref.lost_hours == res.lost_hours
                and ref.degraded_hours == res.degraded_hours
                and ref.downtimes == res.downtimes
                and ref.checkpoint_events == res.checkpoint_events
                and ref.goodput_h() == res.goodput_h()
                and (ref.control is None) == (res.control is None)
                and (ref.control is None
                     or (ref.control.alarms == res.control.alarms
                         and ref.control.throttles == res.control.throttles
                         and ref.control.alarms_deferred
                         == res.control.alarms_deferred)))
        if not same:
            raise AssertionError(f"infra batched/scalar parity broke "
                                 f"at seed {seed}")
        fa = {k: v for k, v in find_deg[i].items() if k != "wall_s"}
        fb = {k: v for k, v in compute_findings(ref).items()
              if k != "wall_s"}
        if fa != fb:
            raise AssertionError(f"infra findings parity broke "
                                 f"at seed {seed}")
        deg_total += fb["infra_degraded_h"]

    if deg_total <= 0.0:
        raise AssertionError("no degraded hours booked across seeds — "
                             "the infra band never engaged")

    return [("fault_taxonomy_overhead", us_deg,
             f"{len(list(seeds))} seeds x {days:.0f}d infra-faults: "
             f"degraded={us_deg/1e6:.2f}s clean={us_clean/1e6:.2f}s "
             f"overhead=x{overhead:.2f} (gate <=1.2x) parity=exact "
             f"(fields + findings, all seeds); "
             f"degraded_h total={deg_total:.1f}")]


def bench_fault_topology() -> list:
    """The correlated fault band (leaf-switch blast radius, partial-gang
    dns flaps) with blast-radius-aware recovery through the batched
    engine: the many-seed correlated campaign must hold its wall-clock
    envelope, the batched path must stay bit-identical to the scalar
    engine on a seed sample (control ledger, topology events, evacuations
    and exclusion reasons included), and the cross-node correlation must
    attribute >= 80% of switch events to the correct switch, pooled over
    every seed."""
    import dataclasses

    from repro.core.batch import BatchedCampaignEngine
    from repro.core.cluster import ClusterSim
    from repro.ops import get_scenario
    from repro.ops.sweep import compute_findings

    # control-free fleet-scale pass: the blast-radius geometry (per-member
    # window expansion, concentration columns) at mc_batch scale
    days = 4.0 if FAST else 73.0
    S = 64 if FAST else 256
    blast = get_scenario("switch-blast").replace(duration_days=days)
    if FAST:
        # the abbreviated window needs a denser schedule for the corr
        # columns to be non-trivially populated
        blast = blast.replace(mtbf_h=24.0)
    cfg_blast = blast.to_campaign_config(0)
    BatchedCampaignEngine(cfg_blast).run_findings([0])   # warm caches
    blast_f, us = timed(lambda: BatchedCampaignEngine(
        cfg_blast).run_findings(list(range(S))), best_of=3)
    corr_n = sum(f["corr_n_events"] for f in blast_f)
    if corr_n < S:
        raise AssertionError(
            f"only {corr_n:.0f} correlated events over {S} seeds of "
            "switch-blast — the band never engaged")
    if not any(f["corr_top_switch_share"] > 0.0 for f in blast_f):
        raise AssertionError("corr_top_switch_share never populated")

    # blast-radius-aware recovery sample: pooled attribution precision
    # plus bitwise batched==scalar parity, control ledger included
    sc = get_scenario("correlated-recovery").replace(
        duration_days=4.0 if FAST else 8.0, mtbf_h=12.0,
        telemetry_pad_metrics=0)
    S2 = 16 if FAST else 32
    cfg = sc.to_campaign_config(0)
    findings = BatchedCampaignEngine(cfg).run_findings(list(range(S2)))
    attributed = sum(f["ctrl_switch_attributed"] for f in findings)
    events = sum(f["ctrl_switch_events"] for f in findings)
    precision = attributed / max(events, 1.0)
    if events < S2:
        raise AssertionError(
            f"only {events:.0f} switch events over {S2} seeds — the "
            "correlated band never engaged")
    if precision < 0.75:
        # regression tripwire; the >=0.80 acceptance contract lives in
        # tests/test_fault_topology.py on its pinned config
        raise AssertionError(
            f"switch attribution precision {precision:.2f} < 0.75 "
            f"({attributed:.0f}/{events:.0f} events)")

    sample = [3] if FAST else [3, 11, 25]
    for seed in sample:
        res = BatchedCampaignEngine(cfg).run([seed])[0]
        ref = ClusterSim(dataclasses.replace(cfg, seed=seed)).run()
        same = (ref.failures == res.failures
                and ref.goodput_h() == res.goodput_h()
                and ref.degraded_hours == res.degraded_hours
                and ref.control.alarms == res.control.alarms
                and ref.control.drains == res.control.drains
                and ref.control.topology_events
                == res.control.topology_events
                and ref.control.misattributed_drains
                == res.control.misattributed_drains
                and ref.exclusions.by_reason()
                == res.exclusions.by_reason())
        if not same:
            raise AssertionError(f"correlated batched/scalar parity "
                                 f"broke at seed {seed}")
        fa = {k: v for k, v in findings[seed].items() if k != "wall_s"}
        fb = {k: v for k, v in compute_findings(ref).items()
              if k != "wall_s"}
        if fa != fb:
            raise AssertionError(f"correlated findings parity broke "
                                 f"at seed {seed}")

    evac = sum(f["ctrl_evacuations"] for f in findings)
    return [("fault_topology_correlated", us,
             f"{S} seeds x {days:.0f}d switch-blast stacked pass "
             f"{us/1e6:.2f}s; recovery sample ({S2} seeds "
             f"correlated-recovery): switch attribution "
             f"{attributed:.0f}/{events:.0f}={precision:.2f} "
             f"(tripwire >=0.75) evacuations={evac:.0f} parity=exact "
             f"(ledger + findings, sampled seeds)")]


# ---------------------------------------------------------------------------
# sweep-as-a-service: coalesced what-if queries
# ---------------------------------------------------------------------------

def bench_sweep_service() -> list:
    """The what-if service under concurrent load: 16 client threads
    hammering a 4-scenario pool, coalesced dispatch (window batching +
    in-flight dedup, cache OFF so every answer is engine-made) against
    the naive one-pass-per-request service.  Gates:

    * coalesced sustained QPS >= 3x naive at 16 concurrent clients,
      with every coalesced answer bitwise equal to a per-request serial
      engine pass on the same seeds (coalescing is dispatch
      amortization, not approximation);
    * cache-hit p99 < 5 ms (the `sweep_service_cache_hit` row sits
      below the ratio gate's --min-us floor by construction; its
      latency gate lives here as an assertion).
    """
    import threading
    import time as _time

    from repro.core.batch import BatchedCampaignEngine
    from repro.ops import findings_distribution, get_scenario
    from repro.serve import ServiceConfig, WhatIfService

    n_threads, per_thread, n_seeds = 16, 2 if FAST else 4, 16
    pool = [get_scenario("paper-faithful").replace(
        duration_days=3.0, checkpoint_interval_h=h)
        for h in (1.5, 2.23, 3.0, 4.0)]

    def hammer(svc) -> tuple:
        """16 threads x per_thread queries round-robin over the pool;
        returns (wall_s, answers)."""
        answers = [[None] * per_thread for _ in range(n_threads)]
        barrier = threading.Barrier(n_threads + 1)

        def worker(i):
            barrier.wait()
            for j in range(per_thread):
                # two distinct keys per wave, all four across the run:
                # mixed duplicate/distinct load with 8 duplicates/key
                sc = pool[(i % 2 + 2 * j) % len(pool)]
                answers[i][j] = (sc, svc.query(sc, n_seeds))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        barrier.wait()
        t0 = _time.perf_counter()
        for t in threads:
            t.join()
        return _time.perf_counter() - t0, answers

    # both arms engine-only: cache off isolates the dispatch layer
    naive = WhatIfService(ServiceConfig(
        coalesce=False, dedupe_inflight=False, cache_capacity=0,
        wavefront_backend="numpy"))
    coal = WhatIfService(ServiceConfig(
        window_s=0.01, cache_capacity=0, wavefront_backend="numpy"))
    try:
        coal.query(pool[0], n_seeds)          # warm (allocator, imports)
        wall_naive, _ = hammer(naive)
        wall_coal, answers = hammer(coal)
    finally:
        naive.close()
        coal.close()

    n_queries = n_threads * per_thread
    qps_naive = n_queries / wall_naive
    qps_coal = n_queries / wall_coal

    # parity: every coalesced answer == a per-request serial pass
    refs = {}
    for sc in pool:
        eng = BatchedCampaignEngine(sc.to_campaign_config(0),
                                    wavefront_backend="numpy")
        refs[sc.canonical_key()] = findings_distribution(
            eng.run_findings(list(range(n_seeds))))
    for row in answers:
        for sc, ans in row:
            if ans.distribution != refs[sc.canonical_key()]:
                raise AssertionError(
                    f"coalesced answer for {sc.checkpoint_interval_h}h "
                    "diverged from the per-request serial pass")

    speedup = qps_coal / qps_naive
    if speedup < 3.0:
        raise AssertionError(
            f"coalesced dispatch QPS advantage collapsed to "
            f"x{speedup:.1f} (coalesced {qps_coal:.0f} qps vs naive "
            f"{qps_naive:.0f} qps at {n_threads} clients; >=3x gated)")

    # cache-hit latency: primed LRU, repeated equivalent queries
    svc = WhatIfService(ServiceConfig(coalesce=False,
                                      wavefront_backend="numpy"))
    try:
        svc.query(pool[0], n_seeds)
        lat = []
        for _ in range(50 if FAST else 200):
            t0 = _time.perf_counter()
            hit = svc.query(pool[0], n_seeds)
            lat.append(_time.perf_counter() - t0)
            assert hit.source == "cache"
    finally:
        svc.close()
    p99_us = float(np.percentile(lat, 99) * 1e6)
    p50_us = float(np.percentile(lat, 50) * 1e6)
    if p99_us >= 5000.0:
        raise AssertionError(
            f"cache-hit p99 {p99_us/1e3:.2f} ms breached the 5 ms budget")

    return [
        ("sweep_service_coalesced", wall_coal * 1e6 / n_queries,
         f"{n_queries} queries/{n_threads} threads over 4 scenarios x "
         f"{n_seeds} seeds (3d): coalesced {qps_coal:.0f} qps vs naive "
         f"{qps_naive:.0f} qps = x{speedup:.1f} (>=3x gated) "
         "parity=exact vs per-request serial", None, n_seeds),
        ("sweep_service_cache_hit", p99_us,
         f"LRU hit latency p50={p50_us:.0f}us p99={p99_us:.0f}us "
         f"over {len(lat)} hits (<5ms p99 gated)", None, None),
    ]


def all_benches():
    return [bench_taxonomy, bench_storage_fabric, bench_youngdaly,
            bench_rpc, bench_ckpt_path, bench_io_sharding,
            bench_data_pipeline, bench_exclusion, bench_retry,
            bench_precursor, bench_control_plane, bench_cluster_engine,
            bench_mc_batch, bench_mc_wavefront, bench_detector_backend,
            bench_scenario_sweep, bench_fault_taxonomy,
            bench_fault_topology, bench_sweep_service]
