"""Roofline table from the dry-run artifacts (§Roofline deliverable).

Reads benchmarks/results/dryrun_<variant>.json (written by
``python -m repro.launch.dryrun``) and emits, per (arch x shape) cell on the
single-pod mesh: the three roofline terms, the dominant bottleneck,
MODEL_FLOPS = 6*N(_active)*D vs compiled HLO flops, and a one-line lever.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config

RESULTS = Path(__file__).resolve().parent / "results"

LEVERS = {
    "compute": "raise arithmetic efficiency: larger per-device batch, "
               "fused attention kernel, drop remat recompute",
    "memory": "cut HBM traffic: chunked loss, fp32->bf16 intermediates, "
              "flash attention (no S^2 materialisation), better fusion",
    "collective": "cut comms: 2D-sharded all-gathers, overlap FSDP gather "
                  "with compute, HSDP pod-replication, larger TP blocks",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch          # decode: one token/request


def load(variant: str = "baseline") -> dict:
    p = RESULTS / f"dryrun_{variant}.json"
    if not p.exists():
        return {}
    return json.loads(p.read_text())


def rows(variant: str = "baseline", mesh: str = "16x16"):
    out = []
    for key, rec in sorted(load(variant).items()):
        if rec.get("mesh") != mesh:
            continue
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "status": rec["status"]}
        if rec["status"] == "OK" and "roofline" in rec:
            r = rec["roofline"]
            mf = model_flops(rec["arch"], rec["shape"])
            row.update({
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "collective_s": r["collective_s"], "dominant": r["dominant"],
                "model_flops": mf,
                "useful_ratio": mf / max(r["flops"], 1.0),
                "bound_s": max(r["compute_s"], r["memory_s"],
                               r["collective_s"]),
                "roofline_fraction": r["compute_s"] / max(
                    r["compute_s"], r["memory_s"], r["collective_s"]),
                "lever": LEVERS[r["dominant"]],
                "hlo_flops": r["flops"],
                "coll_breakdown": r.get("coll_breakdown", {}),
                "mem_bytes_per_dev": rec.get("memory_analysis", {}).get(
                    "temp_size_in_bytes"),
            })
        elif rec["status"] == "SKIP":
            row["reason"] = rec.get("reason", "")
        else:
            row["error"] = rec.get("error", "")[:120]
        out.append(row)
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rs = rows(args.variant, args.mesh)
    print("arch,shape,status,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for r in rs:
        if r["status"] == "OK" and "dominant" in r:
            print(f"{r['arch']},{r['shape']},OK,{r['compute_s']:.4f},"
                  f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
                  f"{r['dominant']},{r['useful_ratio']:.3f},"
                  f"{r['roofline_fraction']:.3f}")
        else:
            print(f"{r['arch']},{r['shape']},{r['status']},,,,,,")


if __name__ == "__main__":
    main()
