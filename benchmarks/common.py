"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time
from typing import Callable, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)

FAST = os.environ.get("REPRO_BENCH_FAST", "") not in ("", "0", "false")


def timed(fn: Callable, *args, repeats: int = 1, **kwargs):
    """Run fn, return (result, us_per_call)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


def fmt(x, nd=2):
    if x is None:
        return "na"
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)
