"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time
from typing import Callable, Tuple

# (name, us_per_call, derived[, backend[, n_seeds]]) — backend records
# which compute backend produced the timing; n_seeds how many Monte Carlo
# seeds it covers (per-seed cost stays computable from archived JSON)
Row = Tuple[str, float, str]

FAST = os.environ.get("REPRO_BENCH_FAST", "") not in ("", "0", "false")

# best-of-k default for `timed`: each timing is the MINIMUM over k rounds,
# which strips scheduler noise on small shared CI boxes (the min is the
# honest estimate of the code's cost; the mean smears preemption into it).
# Set per call via ``best_of=``, globally via REPRO_BENCH_BEST_OF or
# ``benchmarks.run --repeat K``.  The gated regression groups run their
# cheap measured paths at best-of-3 so the `check_regression` envelope
# gate fires on real slowdowns, not runner jitter.
BEST_OF = int(os.environ.get("REPRO_BENCH_BEST_OF", "1") or "1")


def timed(fn: Callable, *args, repeats: int = 1, best_of: int = None,
          **kwargs):
    """Run fn, return (result, us_per_call).

    ``repeats`` averages within one timing round (amortizes per-call
    overhead of microsecond-scale fns); ``best_of`` repeats the whole
    round k times and keeps the fastest (noise rejection).  ``best_of``
    defaults to the module-level ``BEST_OF`` (env / --repeat override).
    """
    k = max(BEST_OF if best_of is None else best_of, 1)
    out = None
    best = float("inf")
    for _ in range(k):
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(*args, **kwargs)
        dt = (time.perf_counter() - t0) / repeats
        best = min(best, dt)
    return out, best * 1e6


def fmt(x, nd=2):
    if x is None:
        return "na"
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)
