"""Kernel benchmarks: Pallas (interpret on CPU / compiled on TPU) vs the
pure-jnp oracle — correctness + us/call at validation shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed


def bench_flash_attention() -> list:
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.ops import flash_attention

    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)

    out, us_k = timed(lambda: flash_attention(
        q, k, v, block_q=64, block_k=64, interpret=True)
        .block_until_ready(), repeats=2)
    exp, us_r = timed(lambda: ref.attention_bhsd(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2))
        .block_until_ready(), repeats=2)
    err = float(jnp.max(jnp.abs(out.swapaxes(1, 2) - exp)))
    return [("kernel_flash_attention", us_k,
             f"ref_us={us_r:.0f} max_err={err:.2e} shape=B{B}xS{S}xH{H}x{D} "
             f"(TPU target: pl.pallas_call, VMEM q/kv blocks 128x128)")]


def bench_rwkv6_scan() -> list:
    from repro.kernels.rwkv6_scan import ref
    from repro.kernels.rwkv6_scan.ops import wkv6

    rng = np.random.default_rng(1)
    B, S, H, D = 2, 256, 2, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32) * 0.5
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32) * 0.5
    w = jnp.asarray(rng.uniform(0.9, 0.999, size=(B, S, H, D)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)), jnp.float32) * 0.3
    s0 = jnp.zeros((B, H, D, D), jnp.float32)

    (y, s_f), us_k = timed(lambda: jax.block_until_ready(
        wkv6(r, k, v, w, u, s0, chunk=64, interpret=True)), repeats=2)
    (y_r, s_r), us_r = timed(lambda: jax.block_until_ready(
        ref.wkv6_sequential(r, k, v, w, u, s0)), repeats=2)
    err = float(jnp.max(jnp.abs(y - y_r)))
    return [("kernel_rwkv6_scan", us_k,
             f"seq_ref_us={us_r:.0f} max_err={err:.2e} "
             f"(chunked matmul form; state carried in VMEM scratch)")]


def bench_ckpt_pack() -> list:
    from repro.kernels.ckpt_pack.ops import ckpt_pack
    from repro.kernels.ckpt_pack.ref import ckpt_pack_blocks_ref

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)
    (y, chk), us_k = timed(lambda: jax.block_until_ready(
        ckpt_pack(x, block=2048, interpret=True)), repeats=2)
    (y_r, chk_r), us_r = timed(lambda: jax.block_until_ready(
        ckpt_pack_blocks_ref(x.reshape(-1, 2048))), repeats=2)
    ok = bool(jnp.all(y.reshape(-1, 2048) == y_r)) and \
        bool(jnp.all(chk == chk_r.reshape(-1)))
    return [("kernel_ckpt_pack", us_k,
             f"ref_us={us_r:.0f} exact_match={ok} "
             f"(fp32->bf16 cast + u32 block checksum, one VMEM pass; "
             f"halves the NFS WRITE volume through the 128-slot layer)")]


def all_benches():
    return [bench_flash_attention, bench_rwkv6_scan, bench_ckpt_pack]
