#!/usr/bin/env python
"""Docs-drift check: README quickstart blocks must stay runnable.

Extracts fenced ``bash`` and ``python`` blocks from README.md and
validates them against the actual CLI surface, so renaming a flag or a
module without updating the docs fails CI:

* ``python`` blocks must parse (`ast.parse`);
* every ``python <script>.py`` / ``python -m <module>`` invocation in a
  ``bash`` block must reference an existing script/module, and every
  ``--flag`` it passes must appear in that entry point's ``--help``
  output (one ``--help`` subprocess per entry point, cached);
* module paths named in the README module-map table must exist under
  ``src/repro``.

Run from the repo root: ``python scripts/check_docs.py`` (CI does).
"""
from __future__ import annotations

import ast
import os
import re
import shlex
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(ROOT, "README.md")

_FENCE = re.compile(r"^```(\w*)\s*$")
# flags whose value we never validate, plus flags argparse always has
_SKIP_CMDS = ("pip", "cd", "export", "echo")


def fenced_blocks(path):
    """(language, text, first_line_no) for every fenced block."""
    blocks, lang, buf, start = [], None, [], 0
    with open(path) as f:
        for i, line in enumerate(f, 1):
            m = _FENCE.match(line)
            if m and lang is None:
                lang, buf, start = m.group(1), [], i
            elif line.rstrip() == "```" and lang is not None:
                blocks.append((lang, "".join(buf), start))
                lang = None
            elif lang is not None:
                buf.append(line)
    return blocks


def bash_commands(text):
    """Logical commands: continuation-joined, comments stripped."""
    joined, acc = [], ""
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.endswith("\\"):
            acc += line[:-1] + " "
            continue
        joined.append(acc + line)
        acc = ""
    if acc:
        joined.append(acc)
    return joined


class HelpCache:
    def __init__(self):
        self._cache = {}

    def help_text(self, argv):
        key = tuple(argv)
        if key not in self._cache:
            env = dict(os.environ)
            env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
                os.pathsep + env["PYTHONPATH"]
                if env.get("PYTHONPATH") else "")
            try:
                proc = subprocess.run(
                    [sys.executable, *argv, "--help"], cwd=ROOT, env=env,
                    capture_output=True, text=True, timeout=120)
            except subprocess.TimeoutExpired:
                self._cache[key] = None
                return None
            out = proc.stdout + proc.stderr
            self._cache[key] = out if proc.returncode == 0 else None
        return self._cache[key]


def check_bash_block(text, line_no, helps, errors):
    for cmd in bash_commands(text):
        # tolerate VAR=val prefixes (PYTHONPATH=src ...)
        toks = shlex.split(cmd)
        while toks and "=" in toks[0] and not toks[0].startswith("-"):
            toks.pop(0)
        if not toks or os.path.basename(toks[0]) not in (
                "python", "python3") or toks[0] in _SKIP_CMDS:
            continue
        toks = toks[1:]
        if toks[:1] == ["-m"]:
            module = toks[1]
            if module == "pytest":
                continue
            mod_path = os.path.join(ROOT, *module.split(".")) + ".py"
            pkg_path = os.path.join(ROOT, *module.split("."),
                                    "__main__.py")
            src_mod = os.path.join(ROOT, "src", *module.split(".")) + ".py"
            if not any(os.path.exists(p)
                       for p in (mod_path, pkg_path, src_mod)):
                errors.append(f"README.md:{line_no}: module `{module}` "
                              f"does not exist")
                continue
            entry, args = ["-m", module], toks[2:]
        else:
            script = toks[0]
            if not script.endswith(".py"):
                continue
            if not os.path.exists(os.path.join(ROOT, script)):
                errors.append(f"README.md:{line_no}: script `{script}` "
                              f"does not exist")
                continue
            entry, args = [script], toks[1:]
        flags = [a.split("=", 1)[0] for a in args if a.startswith("--")]
        if not flags:
            continue
        help_text = helps.help_text(entry)
        if help_text is None:
            errors.append(f"README.md:{line_no}: `{' '.join(entry)} "
                          f"--help` failed")
            continue
        for flag in flags:
            if flag not in help_text:
                errors.append(f"README.md:{line_no}: flag `{flag}` not in "
                              f"`{' '.join(entry)} --help`")


def check_module_map(errors):
    """Module paths in the README module-map table must exist."""
    row = re.compile(r"^\|\s*`([^`]+)`")
    with open(README) as f:
        for i, line in enumerate(f, 1):
            m = row.match(line)
            if not m:
                continue
            for part in m.group(1).split("`, `"):
                part = part.strip()
                if "/" not in part and "." not in part:
                    continue        # a preset/flag name, not a path
                rel = part.rstrip("/")
                if not re.fullmatch(r"[\w./-]+", rel):
                    continue
                candidates = [os.path.join(ROOT, "src", "repro", rel),
                              os.path.join(ROOT, rel)]
                if not any(os.path.exists(c) for c in candidates):
                    errors.append(f"README.md:{i}: module-map path "
                                  f"`{rel}` does not exist")


def main():
    errors = []
    helps = HelpCache()
    n_bash = n_py = 0
    for lang, text, line_no in fenced_blocks(README):
        if lang == "python":
            n_py += 1
            try:
                ast.parse(text)
            except SyntaxError as e:
                errors.append(f"README.md:{line_no}: python block does "
                              f"not parse: {e}")
        elif lang == "bash":
            n_bash += 1
            check_bash_block(text, line_no, helps, errors)
    check_module_map(errors)
    if errors:
        print(f"check_docs: {len(errors)} problem(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_docs: OK ({n_bash} bash blocks, {n_py} python blocks, "
          f"module map verified)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
