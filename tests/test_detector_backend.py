"""Detection fast path: fused robust-stats backends vs the numpy oracle.

The contract under test is exact alarm-set parity: the compiled backends
("xla" jitted reference, "pallas" TPU kernel — interpreted off-TPU) must
produce the identical alarms (same (tick, node) pairs, same vote counts,
same attribution) and identical carry state as the numpy path, so every
parity contract built on the numpy detector (PR-3 streaming==scan, PR-4
batched==scalar) survives a backend switch untouched.  Plus the
``_nanmedian_rows`` edge paths and the shared-mutable-default fixes that
ride along with this layer.
"""
import warnings

import numpy as np
import pytest

from repro.control.streaming import StreamingDetector, _nanmedian_rows
from repro.core.precursor import DetectorConfig, PrecursorDetector
from repro.kernels.robust_stats.ops import detect_block, validate_backend


# ---------------------------------------------------------------------------
# _nanmedian_rows edge paths (satellite)
# ---------------------------------------------------------------------------

def _np_nanmedian(a):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return np.nanmedian(a, axis=-1, keepdims=True)


def test_nanmedian_rows_matches_numpy_baseline():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(40, 63))
    a[rng.random((40, 63)) < 0.2] = np.nan
    got = _nanmedian_rows(a)
    np.testing.assert_array_equal(got, _np_nanmedian(a))


def test_nanmedian_rows_sort_fallback_pathological_cohorts():
    """> 8 distinct (k_lo, k_hi) ranks trips the full-sort fallback; the
    selected order statistics must match the partition path bit-for-bit
    (np.nanmedian is the external referee for both)."""
    rng = np.random.default_rng(1)
    rows, n = 24, 40
    a = rng.normal(size=(rows, n))
    # row i keeps i+1 valid entries -> cohort sizes 1..24, >8 distinct ks
    for i in range(rows):
        a[i, i + 1:] = np.nan
    ks = np.unique([(m - 1) // 2 for m in range(1, rows + 1)]
                   + [m // 2 for m in range(1, rows + 1)])
    assert len(ks) > 8                       # the fallback is actually hit
    np.testing.assert_array_equal(_nanmedian_rows(a), _np_nanmedian(a))


def test_nanmedian_rows_all_nan_rows():
    a = np.full((3, 7), np.nan)
    a[1, :] = [1.0, np.nan, 3.0, np.nan, 2.0, np.nan, np.nan]
    got = _nanmedian_rows(a)
    assert np.isnan(got[0, 0]) and np.isnan(got[2, 0])
    assert got[1, 0] == 2.0
    np.testing.assert_array_equal(np.isnan(got), np.isnan(_np_nanmedian(a)))


def test_nanmedian_rows_single_active_peer():
    a = np.full((4, 9), np.nan)
    for i in range(4):
        a[i, 2 * i] = 10.0 * i - 5.0
    got = _nanmedian_rows(a)
    np.testing.assert_array_equal(got, _np_nanmedian(a))
    assert got[2, 0] == 15.0


# ---------------------------------------------------------------------------
# fused detect_block vs the numpy oracle
# ---------------------------------------------------------------------------

def _numpy_oracle(block, active, carry, zt, ms):
    from repro.control.streaming import robust_peer_z_block
    S, B, T, n = block.shape
    hit = np.zeros((S, T, n), np.int32)
    for s in range(S):
        z = robust_peer_z_block(block[s], active[s])
        hit[s] = ((z > zt) & active[s]).sum(axis=0, dtype=np.int32)
    over = hit >= ms
    idx = np.arange(1, T + 1, dtype=np.int64)[None, :, None]
    last_reset = np.maximum.accumulate(np.where(over, 0, idx), axis=1)
    streak = np.where(over, idx - last_reset, 0)
    streak += np.where(over & (last_reset == 0), carry[:, None, :], 0)
    return hit, streak


@pytest.fixture(scope="module")
def awkward_block():
    """Odd shapes (bucketing pads S and T), NaN columns, all-inactive and
    single-active rows, carried streaks — every edge the oracle handles."""
    rng = np.random.default_rng(7)
    S, B, T, n = 5, 9, 51, 63
    block = rng.normal(50, 1, (S, B, T, n))
    block[1, 2, 10:30, 5] += 80.0            # genuine anomaly
    block[0, :, :, 7] = np.nan               # NaN node column
    block[3, 4, 20, :] = np.nan              # all-NaN row for one metric
    active = rng.random((S, T, n)) > 0.1
    active[2, 5] = False                     # all-inactive tick
    active[2, 6, :62] = False                # single active peer
    carry = rng.integers(0, 4, (S, n)).astype(np.int64)
    return block, active, carry


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_detect_block_matches_oracle(awkward_block, backend):
    block, active, carry = awkward_block
    zt, ms = 6.0, 4
    hit_ref, streak_ref = _numpy_oracle(block, active, carry, zt, ms)
    hit, streak = detect_block(block, active, carry, z_threshold=zt,
                               min_signals=ms, backend=backend)
    np.testing.assert_array_equal(hit, hit_ref)
    np.testing.assert_array_equal(streak, streak_ref)


def test_detect_block_rejects_numpy_and_unknown():
    blk = np.zeros((1, 1, 4, 4))
    act = np.ones((1, 4, 4), bool)
    car = np.zeros((1, 4), np.int32)
    with pytest.raises(ValueError, match="numpy oracle"):
        detect_block(blk, act, car, z_threshold=6.0, min_signals=4,
                     backend="numpy")
    with pytest.raises(ValueError, match="unknown detector backend"):
        validate_backend("cuda")


# ---------------------------------------------------------------------------
# StreamingDetector backend switch: alarm parity through push / push_group
# ---------------------------------------------------------------------------

def _mk_spans(S, T, n, n_metrics=8, seed=40):
    vals, ts = [], []
    for i in range(S):
        r = np.random.default_rng(seed + i)
        v = {"DCGM_FI_DEV_GPU_UTIL": np.full((T, n), 99.0)}
        for m in range(n_metrics):
            a = 50 + r.normal(0, 1, (T, n))
            if r.random() < 0.7:
                a[T // 2:, 3] += 80.0
            v[f"m{m}"] = a
        vals.append(v)
        ts.append(np.arange(T) * 30 / 3600 + i)
    return ts, vals


@pytest.fixture
def force_compiled(monkeypatch):
    """Spans below COMPILED_MIN_ELEMS dispatch back to the numpy pass
    (device round trips lose at small sizes); the parity tests force the
    compiled route so they actually exercise it at test-sized spans."""
    import repro.kernels.robust_stats.ops as rs_ops
    monkeypatch.setattr(rs_ops, "COMPILED_MIN_ELEMS", 0)


def test_small_spans_dispatch_back_to_numpy():
    from repro.control.streaming import _worth_compiling
    from repro.kernels.robust_stats.ops import COMPILED_MIN_ELEMS
    assert not _worth_compiling(1, 9, 41, 16)          # test-sized span
    assert _worth_compiling(256, 25, 120, 63)          # the mc block
    assert COMPILED_MIN_ELEMS > 0


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_push_chunked_matches_numpy_backend(backend, force_compiled):
    T, n = 41, 16
    cfg = DetectorConfig(z_threshold=4.0, min_signals=3, persistence=2)
    ts, vals = _mk_spans(1, T, n)
    ref_det = StreamingDetector(cfg)
    got_det = StreamingDetector(cfg, backend=backend)
    ref, got = [], []
    for a in range(0, T, 13):                # chunk boundaries mid-streak
        sl = {k: v[a:a + 13] for k, v in vals[0].items()}
        ref += ref_det.push(ts[0][a:a + 13], sl)
        got += got_det.push(ts[0][a:a + 13], sl)
    assert len(ref) > 0
    assert got == ref                        # ticks, nodes, votes, metrics
    assert np.array_equal(got_det._streak, ref_det._streak)
    assert got_det._tick_offset == ref_det._tick_offset
    assert got_det.n_alarms == ref_det.n_alarms


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_push_group_matches_numpy_backend(backend, force_compiled):
    S, T, n = 4, 30, 12
    cfg = DetectorConfig(z_threshold=4.0, min_signals=3)
    ts, vals = _mk_spans(S, T, n)

    def run(bk):
        dets = [StreamingDetector(cfg, backend=bk) for _ in range(S)]
        outs = [[] for _ in range(S)]
        for a in range(0, T, 7):
            got = StreamingDetector.push_group(
                dets, [t[a:a + 7] for t in ts],
                [{k: v[a:a + 7] for k, v in val.items()} for val in vals])
            for i in range(S):
                outs[i] += got[i]
        return outs, dets

    ref, _ = run("numpy")
    got, dets = run(backend)
    assert sum(len(o) for o in ref) > 0
    assert got == ref


def test_push_group_rejects_mixed_backends():
    cfg = DetectorConfig()
    dets = [StreamingDetector(cfg), StreamingDetector(cfg, backend="xla")]
    ts, vals = _mk_spans(2, 4, 4, n_metrics=2)
    with pytest.raises(ValueError, match="shared backend"):
        StreamingDetector.push_group(dets, ts, vals)


def test_unknown_backend_rejected_everywhere():
    with pytest.raises(ValueError, match="unknown detector backend"):
        StreamingDetector(backend="fortran")
    from repro.ops import get_scenario
    with pytest.raises(ValueError, match="unknown detector backend"):
        get_scenario("proactive").replace(detector_backend="fortran")


def test_precursor_scan_backend_parity(force_compiled):
    """The offline scan path through the compiled backend reproduces the
    numpy scan on simulated telemetry (a real store, ~40 metrics)."""
    from repro.core.cluster import CampaignConfig, ClusterSim
    res = ClusterSim(CampaignConfig(duration_h=6.0, telemetry=True,
                                    telemetry_pad_metrics=12,
                                    seed=11)).run()
    ref = PrecursorDetector(DetectorConfig()).scan(res.store)
    got = PrecursorDetector(DetectorConfig(), backend="xla").scan(res.store)
    assert len(ref) > 0
    assert got == ref


# ---------------------------------------------------------------------------
# control plane + scenario wiring
# ---------------------------------------------------------------------------

def test_scenario_backend_reaches_control_plane():
    from repro.ops import get_scenario
    sc = get_scenario("proactive").replace(detector_backend="xla")
    cfg = sc.to_campaign_config(0)
    assert cfg.control.detector_backend == "xla"
    rt = type(sc).from_dict(sc.to_dict())    # serialization round-trip
    assert rt.detector_backend == "xla"
    from repro.control.policy import ControlPlane
    plane = ControlPlane(cfg.control, urgent_save_s=18.0)
    assert plane.detector.backend == "xla"


def test_proactive_campaign_backend_invariant():
    """End to end: the proactive campaign's control ledger and goodput are
    identical under the compiled backend (alarm parity => identical
    recovery actions => identical trajectory)."""
    from repro.core.cluster import ClusterSim
    from repro.ops import get_scenario
    runs = {}
    for backend in ("numpy", "xla"):
        sc = get_scenario("proactive").replace(
            duration_days=2.5, telemetry_pad_metrics=0,
            detector_backend=backend)
        runs[backend] = ClusterSim(sc.to_campaign_config(25)).run()
    a, b = runs["numpy"], runs["xla"]
    assert len(a.control.alarms) > 0
    assert a.control.alarms == b.control.alarms
    assert a.goodput_h() == b.goodput_h()
    assert a.lost_hours == b.lost_hours


# ---------------------------------------------------------------------------
# infra fault band: backend invariance per degrade-don't-kill kind
# ---------------------------------------------------------------------------

_INFRA_SPANS = {
    # kind -> how the exporter learns about the window (campaign setup hook)
    "net_degrade": lambda e: e.begin_degradation(
        3, 0.2, 0.45, 1.6, "net_degrade", "spike"),
    "resource_exhaust": lambda e: e.begin_degradation(
        3, 0.1, 0.5, 1.8, "resource_exhaust", "gradual"),
    "ctrl_blind": lambda e: e.begin_outage(0.2, 0.4),
}


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("kind", sorted(_INFRA_SPANS))
def test_infra_overlay_alarm_parity(kind, backend, force_compiled):
    """Each infra fault kind's telemetry overlay produces the identical
    alarm set on the compiled backends as on the numpy oracle — and the
    degrade kinds alarm on the degraded node with the right net/resource
    classification.  Blind windows are deliberately gang-wide (every peer
    shifts together), so the peer detector stays silent and the control
    plane catches them via its blind-window registry instead."""
    from repro.control.policy import classify_alarm
    from repro.telemetry.exporters import ExporterSuite, NodeStateBatch

    n, T = 16, 60
    outs = {}
    for bk in ("numpy", backend):
        exp = ExporterSuite(n, seed=5, n_pad=4)
        _INFRA_SPANS[kind](exp)
        ts = np.arange(T) * 30 / 3600
        vals = exp.tick_batch(ts, NodeStateBatch.constant(T, n,
                                                          training=1.0))
        det = StreamingDetector(
            DetectorConfig(z_threshold=6.0, min_signals=4, persistence=2),
            backend=bk)
        alarms = []
        for a in range(0, T, 17):            # chunk boundaries mid-window
            alarms += det.push(ts[a:a + 17],
                               {k: v[a:a + 17] for k, v in vals.items()})
        outs[bk] = alarms
    assert outs[backend] == outs["numpy"]
    if kind == "ctrl_blind":
        assert outs["numpy"] == []
    else:
        assert len(outs["numpy"]) > 0
        assert {a.node for a in outs["numpy"]} == {3}
        expect = "net" if kind == "net_degrade" else "resource"
        assert {classify_alarm(a) for a in outs["numpy"]} == {expect}


@pytest.mark.parametrize("preset,seed", [("degraded-network", 25),
                                         ("resource-pressure", 25),
                                         ("ops-blind-spots", 12)])
def test_infra_campaign_backend_invariant(preset, seed):
    """End to end per infra kind: campaigns dominated by each fault kind
    keep an identical control ledger, degradation ledger and goodput under
    the compiled backend (alarm parity => identical throttle/drain/blind
    decisions => identical trajectory)."""
    from repro.core.cluster import ClusterSim
    from repro.ops import get_scenario
    runs = {}
    for backend in ("numpy", "xla"):
        sc = get_scenario(preset).replace(duration_days=2.5,
                                          telemetry_pad_metrics=0,
                                          detector_backend=backend)
        runs[backend] = ClusterSim(sc.to_campaign_config(seed)).run()
    a, b = runs["numpy"], runs["xla"]
    assert len(a.control.alarms) > 0
    assert a.control.alarms == b.control.alarms
    assert a.goodput_h() == b.goodput_h()
    assert a.lost_hours == b.lost_hours
    assert a.degraded_hours == b.degraded_hours
    sa = a.control.summarize(a.failures, 2.5 * 24.0)
    assert sa == b.control.summarize(b.failures, 2.5 * 24.0)
    if preset == "ops-blind-spots":
        assert sa["n_blind_windows"] > 0     # the blind machinery engaged
    else:
        assert sum(np.asarray(a.degraded_hours)) > 0.0


# ---------------------------------------------------------------------------
# shared-mutable-default fixes (satellite)
# ---------------------------------------------------------------------------

def test_default_configs_are_per_instance():
    from repro.control.policy import ControlConfig
    from repro.core.cluster import ClusterSim
    from repro.core.straggler import StragglerDetector
    from repro.storage.fabric import StorageFabric
    assert StreamingDetector().config is not StreamingDetector().config
    assert PrecursorDetector().config is not PrecursorDetector().config
    assert ClusterSim().cfg is not ClusterSim().cfg
    assert StorageFabric().config is not StorageFabric().config
    assert StragglerDetector(4).cfg is not StragglerDetector(4).cfg
    assert ControlConfig().detector is not ControlConfig().detector
