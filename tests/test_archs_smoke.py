"""Per-architecture smoke tests (deliverable (f)).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward/train step and one decode step on CPU, asserting output
shapes and finite values.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.steps import (make_serve_step, make_train_step,
                                synthetic_batch, synthetic_decode_inputs)
from repro.models import model as model_mod
from repro.models.model import RunOptions
from repro.optim import AdamW

ALL = ASSIGNED_ARCHS + ["paper-solar-102b"]
OPTS = RunOptions(q_chunk=16, kv_chunk=16)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    params = model_mod.init_params(rng, cfg)
    optimizer = AdamW()
    opt_state = optimizer.init(params)
    batch = synthetic_batch(rng, cfg, batch=2, seq=32)
    step = jax.jit(make_train_step(cfg, OPTS, optimizer))
    params2, opt2, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    # params updated in place (same tree structure, changed values)
    l1 = jax.tree.leaves(params)
    l2 = jax.tree.leaves(params2)
    assert len(l1) == len(l2)
    assert any(bool(jnp.any(a != b)) for a, b in zip(l1, l2))


@pytest.mark.parametrize("arch", ALL)
def test_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = model_mod.init_params(rng, cfg)
    cache, tokens, pos = synthetic_decode_inputs(rng, cfg, batch=2, seq=32,
                                                 pos=5)
    step = jax.jit(make_serve_step(cfg, OPTS))
    logits, new_cache = step(params, cache, tokens, pos)
    assert logits.shape == (2, 1, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure is preserved (required for the decode loop)
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ALL)
def test_full_config_geometry(arch):
    """The FULL config matches the assignment card (no allocation)."""
    cfg = get_config(arch)
    assigned = {
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "rwkv6-3b": (32, 2560, None, None, 8960, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }
    if arch not in assigned:
        return
    nl, d, h, kv, ff, v = assigned[arch]
    assert cfg.n_layers == nl, (arch, cfg.n_layers)
    assert cfg.d_model == d
    if h is not None and not cfg.is_attention_free:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_param_counts_match_families():
    """Analytic n_params ~ the advertised scale for key archs."""
    approx = {
        "mistral-large-123b": 123e9,
        "gemma3-27b": 27e9,
        "rwkv6-3b": 3e9,
        "jamba-v0.1-52b": 52e9,
        "deepseek-moe-16b": 16e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).n_params()
        assert 0.5 * target < n < 1.8 * target, (arch, n, target)


def test_paper_solar_budget():
    """Solar Open: ~102B total / ~12B active (paper §1.1)."""
    cfg = get_config("paper-solar-102b")
    assert 85e9 < cfg.n_params() < 120e9, cfg.n_params()
    assert 8e9 < cfg.n_active_params() < 12 * 1.6e9 + 8e9, cfg.n_active_params()


def test_moe_active_params_less_than_total():
    for arch in ("deepseek-moe-16b", "granite-moe-1b-a400m",
                 "jamba-v0.1-52b", "paper-solar-102b"):
        cfg = get_config(arch)
        assert cfg.n_active_params() < cfg.n_params(), arch


def test_long_context_support_flags():
    runs = {a for a in ALL if get_config(a).supports_long_context}
    assert runs == {"gemma3-27b", "gemma2-2b", "rwkv6-3b", "jamba-v0.1-52b"}
