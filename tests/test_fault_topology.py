"""Correlated fault band: topology, blast radius, recovery, parity.

Covers the PR-9 contracts:

* leaf-switch topology partition units (deterministic, draw-free);
* injector invariants for the two correlated kinds — a switch event's
  blast radius is exactly the topology's rack, a dns flap's mask is a
  symmetric pairwise cut that never contains the peer itself;
* the off-gate, twice over: with zero correlated weight the schedule is
  byte-identical to one sampled without the correlated entries at all
  (property-tested), and with ``blast_radius_aware=False`` (every
  pre-existing preset) the topology object is never even constructed;
* 8-seed bitwise batch==scalar parity on the correlated-recovery
  campaign (control ledger, findings, exclusion reasons included);
* the acceptance deltas: >= 80% of switch events are attributed to the
  correct switch, and blast-radius-aware retry placement beats the
  naive twin on summed goodput over identical schedules;
* zero-event schedules round-trip through every window helper and both
  engines without special-casing.
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.batch import BatchedCampaignEngine
from repro.core.cluster import ClusterSim
from repro.core.failures import (CORRELATED_KINDS, FailureInjector,
                                 blast_radius_windows, blind_windows,
                                 degradation_windows, escalation_events,
                                 flap_pairs, has_correlated_band)
from repro.core.topology import ClusterTopology
from repro.ops.scenario import PRESETS, get_scenario
from repro.ops.sweep import SweepRunner, compute_findings


# ---------------------------------------------------------------- topology

def test_topology_partitions_nodes():
    topo = ClusterTopology(63, 8)
    assert topo.n_switches == 8
    seen = []
    for sw in range(topo.n_switches):
        members = topo.members(sw)
        assert all(topo.switch_of(n) == sw for n in members)
        seen.extend(members)
    assert seen == list(range(63))          # exact partition, no overlap
    assert len(topo.members(7)) == 7        # the ragged tail rack


def test_topology_switch_map_matches_switch_of():
    topo = ClusterTopology(63, 8)
    assert topo.switch_map().tolist() == \
        [topo.switch_of(n) for n in range(63)]


def test_topology_bounds_checked():
    topo = ClusterTopology(8, 4)
    with pytest.raises(ValueError):
        topo.switch_of(8)
    with pytest.raises(ValueError):
        topo.switch_of(-1)
    with pytest.raises(ValueError):
        topo.members(2)
    with pytest.raises(ValueError):
        ClusterTopology(0, 4)
    with pytest.raises(ValueError):
        ClusterTopology(8, 0)


@given(n_nodes=st.integers(1, 300), fanout=st.integers(1, 32))
@settings(max_examples=80, deadline=None)
def test_topology_partition_property(n_nodes, fanout):
    topo = ClusterTopology(n_nodes, fanout)
    covered = [n for sw in range(topo.n_switches)
               for n in topo.members(sw)]
    assert covered == list(range(n_nodes))
    assert all(1 <= len(topo.members(sw)) <= fanout
               for sw in range(topo.n_switches))


# ---------------------------------------------- injector: blast radius

def _corr_injector(seed=0, fanout=8):
    return FailureInjector(n_nodes=63, mtbf_h=6.0, seed=seed,
                           kind_weights={"switch_degrade": 6.0,
                                         "dns_flap": 6.0},
                           topology_fanout=fanout)


def test_switch_events_carry_the_rack():
    topo = ClusterTopology(63, 8)
    evs = _corr_injector().sample(10 * 24.0)
    sw_evs = [ev for ev in evs if ev.kind == "switch_degrade"]
    assert sw_evs, "config must actually draw switch events"
    for ev in sw_evs:
        assert ev.switch == topo.switch_of(ev.node)
        assert ev.members == topo.members(ev.switch)
        assert ev.node in ev.members
        assert ev.window_h > 0.0 and ev.slow_factor > 1.0
        assert ev.peers == ()


def test_dns_flaps_are_partial_gang_masks():
    evs = _corr_injector(seed=3).sample(10 * 24.0)
    flaps = [ev for ev in evs if ev.kind == "dns_flap"]
    assert flaps, "config must actually draw dns flaps"
    for ev in flaps:
        assert ev.peers == (ev.node,)
        assert ev.members and ev.node not in ev.members
        assert all(0 <= m < 63 for m in ev.members)
        assert ev.switch == -1
        assert 1.0 < ev.slow_factor < 1.31


@given(seed=st.integers(0, 2 ** 16), days=st.floats(1.0, 12.0))
@settings(max_examples=25, deadline=None)
def test_flap_masks_symmetric_property(seed, days):
    """Every dns_flap mask is a symmetric pairwise cut over live nodes
    that never isolates the peer from itself."""
    for ev in _corr_injector(seed=seed).sample(days * 24.0):
        pairs = flap_pairs(ev)
        if ev.kind != "dns_flap":
            assert pairs == frozenset()
            continue
        assert pairs
        assert all((b, a) in pairs for a, b in pairs)
        assert all(a != b for a, b in pairs)
        touched = {n for pair in pairs for n in pair}
        assert touched == set(ev.members) | set(ev.peers)


# -------------------------------------------------- off-gate: bit-identity

@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_zero_weight_band_is_byte_identical(seed):
    """Appending the correlated kinds at zero mass consumes no draws:
    the full schedule (times, nodes, kinds, geometry) is byte-identical
    with and without the correlated entries in ``kind_weights``."""
    base = dict(n_nodes=63, mtbf_h=8.0, seed=seed)
    a = FailureInjector(kind_weights={"net_degrade": 2.0}, **base) \
        .sample_batch(6 * 24.0, [seed])
    b = FailureInjector(kind_weights={"net_degrade": 2.0,
                                      "switch_degrade": 0.0,
                                      "dns_flap": 0.0}, **base) \
        .sample_batch(6 * 24.0, [seed])
    for fld in ("times", "nodes", "kind", "xid", "leads", "slows",
                "windows", "onset", "escalate", "switch"):
        assert getattr(a, fld).tobytes() == getattr(b, fld).tobytes(), fld
    assert a.members == b.members and a.peers == b.peers


def test_has_correlated_band_gate():
    assert not has_correlated_band(None)
    assert not has_correlated_band({"net_degrade": 3.0})
    assert not has_correlated_band({"switch_degrade": 0.0})
    assert has_correlated_band({"dns_flap": 0.1})


def test_blast_radius_off_never_constructs_topology(monkeypatch):
    """With ``blast_radius_aware=False`` (every pre-band preset) the
    control plane never constructs a topology — pre-existing campaigns
    cannot be perturbed, enforced by making construction explode."""
    def boom(*a, **kw):
        raise AssertionError("topology constructed with gate off")
    monkeypatch.setattr("repro.control.policy.ClusterTopology", boom)
    for name in ("proactive", "infra-faults"):
        sc = dataclasses.replace(get_scenario(name), duration_days=2.0,
                                 telemetry_pad_metrics=16)
        res = ClusterSim(sc.to_campaign_config(seed=3)).run()
        assert res.control is not None
        assert res.control.topology_events == []
        assert res.control.misattributed_drains == 0


def test_only_correlated_presets_enable_the_band():
    on = {name for name, sc in PRESETS.items()
          if has_correlated_band(sc.kind_weights)}
    assert on == {"switch-blast", "dns-flaps", "correlated-recovery"}
    aware = {name for name, sc in PRESETS.items() if sc.blast_radius_aware}
    assert aware == {"correlated-recovery"}


def test_blast_radius_aware_requires_control_plane():
    with pytest.raises(ValueError, match="blast_radius_aware"):
        dataclasses.replace(get_scenario("reactive"),
                            blast_radius_aware=True)


# ------------------------------------------------------- batch == scalar

def _parity_cfg():
    sc = dataclasses.replace(get_scenario("correlated-recovery"),
                             duration_days=3.0, mtbf_h=10.0,
                             telemetry_pad_metrics=24)
    return sc.to_campaign_config(seed=0)


def test_batch_scalar_parity_8_seeds():
    cfg = _parity_cfg()
    seeds = list(range(8))
    batch = BatchedCampaignEngine(cfg).run(seeds)
    saw_corr = saw_topo = saw_switch_reason = False
    for i, s in enumerate(seeds):
        ref = ClusterSim(dataclasses.replace(cfg, seed=s)).run()
        got = batch[i]
        assert ref.goodput() == got.goodput()
        rs = ref.control.summarize(ref.failures, cfg.duration_h)
        gs = got.control.summarize(got.failures, cfg.duration_h)
        assert rs == gs
        assert compute_findings(ref) == compute_findings(got)
        assert ref.exclusions.summary() == got.exclusions.summary()
        assert ref.exclusions.by_reason() == got.exclusions.by_reason()
        saw_corr |= rs["corr_events"] > 0
        saw_topo |= rs["n_topology_events"] > 0
        saw_switch_reason |= "switch" in ref.exclusions.by_reason()
    # the parity claim is vacuous unless the band actually fired
    assert saw_corr and saw_topo and saw_switch_reason


# -------------------------------------------------- acceptance: the deltas

@pytest.mark.slow
def test_switch_attribution_precision():
    """>= 80% of switch_degrade events are attributed to the correct
    switch by the cross-node correlation, pooled over 6 seeds."""
    cfg = _parity_cfg()
    hits = total = 0
    for res in BatchedCampaignEngine(cfg).run(list(range(6))):
        s = res.control.summarize(res.failures, cfg.duration_h)
        hits += s["switch_attributed"]
        total += s["switch_events"]
    assert total >= 5
    assert hits / total >= 0.8


@pytest.mark.slow
def test_aware_beats_naive_on_goodput():
    """Blast-radius-aware recovery beats the naive twin on summed
    goodput over identical 8-seed schedules: suppressed member drains
    and rack-avoiding retry placement keep the gang off the degraded
    switch."""
    days, mtbf, pad = 6.0, 9.0, 24
    aware = dataclasses.replace(get_scenario("correlated-recovery"),
                                duration_days=days, mtbf_h=mtbf,
                                telemetry_pad_metrics=pad)
    naive = dataclasses.replace(aware, name="correlated-naive",
                                blast_radius_aware=False)
    result = SweepRunner([naive, aware], mc_seeds=8).run()
    agg = result.aggregate()
    assert agg["correlated-recovery"]["goodput"] > \
        agg["correlated-naive"]["goodput"]
    # the aware plane actually exercised its machinery
    assert agg["correlated-recovery"]["ctrl_n_topology_events"] > 0
    assert agg["correlated-naive"]["ctrl_n_topology_events"] == 0


# ------------------------------------------- zero-event round-trip (edge)

def test_zero_event_schedule_round_trips():
    """A seed that draws no failures flows through every window helper
    and both engines without special-casing."""
    assert degradation_windows([]) == []
    assert blast_radius_windows([]) == []
    assert escalation_events([]) == []
    assert blind_windows([]) == []
    sc = dataclasses.replace(get_scenario("correlated-recovery"),
                             duration_days=0.02, mtbf_h=1e9,
                             telemetry_pad_metrics=16)
    cfg = sc.to_campaign_config(seed=0)
    inj = FailureInjector(n_nodes=cfg.n_nodes, mtbf_h=cfg.mtbf_h,
                          seed=0, kind_weights=cfg.kind_weights)
    batch = inj.sample_batch(cfg.duration_h, [0, 1])
    assert batch.count(0) == 0 and batch.events(1) == []
    ref = ClusterSim(cfg).run()
    got = BatchedCampaignEngine(cfg).run([0])[0]
    assert ref.failures == [] == got.failures
    assert ref.goodput() == got.goodput()
    assert compute_findings(ref) == compute_findings(got)
    assert ref.control.summarize([], cfg.duration_h)["corr_events"] == 0


def test_corr_findings_columns_present():
    sc = dataclasses.replace(get_scenario("switch-blast"),
                             duration_days=3.0, mtbf_h=10.0)
    res = ClusterSim(sc.to_campaign_config(seed=1)).run()
    f = compute_findings(res)
    assert f["corr_n_events"] >= 1
    assert 0.0 < f["corr_top_switch_share"] <= 1.0
    kinds = {ev.kind for ev in res.failures}
    assert kinds & CORRELATED_KINDS
