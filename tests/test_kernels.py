"""Per-kernel validation: shape/dtype sweeps vs the ref.py pure-jnp oracle
(interpret=True executes the Pallas kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # (B, S, H, Hkv, D, block)
    (1, 128, 2, 2, 16, 64),
    (2, 128, 4, 2, 32, 64),
    (1, 256, 4, 1, 16, 128),
    (2, 64, 2, 2, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.ops import flash_attention

    b, s, h, hkv, d, blk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    out = flash_attention(q, k, v, block_q=blk, block_k=blk, interpret=True)
    exp = ref.attention_bhsd(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2)).swapaxes(1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.ops import flash_attention

    rng = np.random.default_rng(0)
    q, k, v = [jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
               for _ in range(3)]
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32,
                          interpret=True)
    exp = ref.attention_bhsd(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), window=window).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_flash_attention_softcap():
    from repro.kernels.flash_attention import ref
    from repro.kernels.flash_attention.ops import flash_attention

    rng = np.random.default_rng(1)
    q, k, v = [jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
               for _ in range(3)]
    out = flash_attention(q, k, v, attn_softcap=30.0, block_q=32, block_k=32,
                          interpret=True)
    exp = ref.attention_bhsd(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), softcap=30.0).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_flash_matches_model_backends():
    """pallas == chunked == naive at the model layer."""
    from repro.models.attention import self_attention

    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 16)), jnp.float32)
    o_naive = self_attention(q, k, v, backend="naive")
    o_chunk = self_attention(q, k, v, backend="chunked", q_chunk=32,
                             kv_chunk=32)
    o_pallas = self_attention(q, k, v, backend="pallas")
    np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_chunk),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_pallas),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

def _wkv_inputs(b, s, h, d, seed=0):
    rng = np.random.default_rng(seed)
    r, k, v = [jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32) * 0.5
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.85, 0.999, size=(b, s, h, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32) * 0.3
    s0 = jnp.asarray(rng.normal(size=(b, h, d, d)), jnp.float32) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("shape", [(1, 64, 2, 8), (2, 128, 3, 16),
                                   (1, 96, 1, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_kernel_sweep(shape, chunk):
    from repro.kernels.rwkv6_scan import ref
    from repro.kernels.rwkv6_scan.ops import wkv6

    b, s, h, d = shape
    r, k, v, w, u, s0 = _wkv_inputs(b, s, h, d, seed=hash(shape) % 997)
    y, s_f = wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y_r, s_r = ref.wkv6_sequential(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_r),
                               atol=2e-4, rtol=2e-4)


def test_wkv6_chunked_ref_matches_sequential():
    from repro.kernels.rwkv6_scan import ref

    r, k, v, w, u, s0 = _wkv_inputs(2, 128, 2, 16, seed=5)
    y_c, s_c = ref.wkv6_chunked(r, k, v, w, u, s0, chunk_size=32)
    y_r, s_r = ref.wkv6_sequential(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), atol=2e-4)


def test_wkv6_state_continuation():
    """Processing [a;b] == processing a then b with carried state."""
    from repro.kernels.rwkv6_scan import ref

    r, k, v, w, u, s0 = _wkv_inputs(1, 64, 2, 8, seed=9)
    y_all, s_all = ref.wkv6_sequential(r, k, v, w, u, s0)
    y1, s_mid = ref.wkv6_sequential(r[:, :32], k[:, :32], v[:, :32],
                                    w[:, :32], u, s0)
    y2, s_end = ref.wkv6_sequential(r[:, 32:], k[:, 32:], v[:, 32:],
                                    w[:, 32:], u, s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_all),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# ckpt pack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,block", [(4096, 512), (5000, 512), (1 << 14, 2048)])
def test_ckpt_pack_sweep(n, block):
    from repro.kernels.ckpt_pack.ops import ckpt_pack
    from repro.kernels.ckpt_pack.ref import ckpt_pack_blocks_ref

    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    y, chk = ckpt_pack(x, block=block, interpret=True)
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).reshape(-1, block)
    y_r, chk_r = ckpt_pack_blocks_ref(xp)
    assert bool(jnp.all(y.reshape(-1, block) == y_r))
    assert bool(jnp.all(chk == chk_r.reshape(-1)))


def test_ckpt_pack_detects_corruption():
    from repro.kernels.ckpt_pack.ops import ckpt_pack

    x = jnp.arange(2048, dtype=jnp.float32)
    _, chk0 = ckpt_pack(x, block=512, interpret=True)
    x2 = x.at[100].set(123.0)
    _, chk1 = ckpt_pack(x2, block=512, interpret=True)
    assert chk0[0] != chk1[0]
    assert bool(jnp.all(chk0[1:] == chk1[1:]))
