"""Seed-batched Monte Carlo campaign engine: the parity contract.

`BatchedCampaignEngine.run(seeds)[i]` must reproduce
`ClusterSim(replace(cfg, seed=seeds[i])).run()` field-for-field (sessions,
chains, failures, exclusion intervals, downtimes, lost-work hours,
checkpoint counts, control-plane ledger — everything except the
process-global ``session_id`` counter), and `run_findings` must match
`compute_findings` of the scalar results value-for-value.  The property
is exercised across retry policies, the proactive control plane
(urgent saves + counterfactual ledger) and executed predictive drains.
"""
import dataclasses

import numpy as np
import pytest

from repro.control.policy import ControlConfig
from repro.control.streaming import StreamingDetector
from repro.core.batch import BatchedCampaignEngine
from repro.core.cluster import CampaignConfig, ClusterSim
from repro.core.failures import FailureInjector
from repro.core.precursor import DetectorConfig
from repro.core.retry import chain_stats
from repro.ops import SweepRunner, get_scenario
from repro.ops.sweep import compute_findings


def assert_result_parity(ref, got, tag=""):
    """Field-for-field CampaignResult comparison (session_id exempt)."""
    assert len(ref.sessions) == len(got.sessions), tag
    for i, (a, b) in enumerate(zip(ref.sessions, got.sessions)):
        for f in ("task_name", "n_nodes", "state", "nodes", "created_h",
                  "started_h", "ended_h", "checkpoint_step", "error",
                  "history"):
            assert getattr(a, f) == getattr(b, f), (tag, i, f)
    assert len(ref.chains) == len(got.chains), tag
    for i, (a, b) in enumerate(zip(ref.chains, got.chains)):
        assert a.task_name == b.task_name, (tag, i)
        assert a.stopped_reason == b.stopped_reason, (tag, i)
        assert a.attempts == b.attempts, (tag, i)
    assert ref.failures == got.failures, tag
    assert ref.exclusions.intervals == got.exclusions.intervals, tag
    assert ref.downtimes == got.downtimes, tag
    assert ref.checkpoint_events == got.checkpoint_events, tag
    assert ref.lost_hours == got.lost_hours, tag
    assert ref.degraded_hours == got.degraded_hours, tag
    assert ref.duration_h == got.duration_h, tag
    assert ref.checkpoint_save_s == got.checkpoint_save_s, tag
    assert (ref.control is None) == (got.control is None), tag
    if ref.control is not None:
        a, b = ref.control, got.control
        assert a.alarms == b.alarms, tag
        assert a.urgent_saves == b.urgent_saves, tag
        assert a.drains == b.drains, tag
        assert a.urgent_save_h == b.urgent_save_h, tag
        assert a.lost_work_avoided_h == b.lost_work_avoided_h, tag
        assert a.failures_on_drained_node == b.failures_on_drained_node, tag
        assert a.throttles == b.throttles, tag
        assert a.alarms_deferred == b.alarms_deferred, tag


def scalar_results(cfg, seeds):
    return [ClusterSim(dataclasses.replace(cfg, seed=s)).run()
            for s in seeds]


# ---------------------------------------------------------------------------
# failure schedule batching
# ---------------------------------------------------------------------------

def test_sample_batch_matches_per_seed_sample():
    inj = FailureInjector(mtbf_h=40.0, kind_weights={"nvlink": 2.0})
    seeds = [0, 3, 11, 42]
    batch = inj.sample_batch(30 * 24.0, seeds)
    for i, seed in enumerate(seeds):
        solo = dataclasses.replace(inj, seed=seed).sample(30 * 24.0)
        assert batch.events(i) == solo, seed
        assert batch.count(i) == len(solo)
        hw = batch.hardware[batch.offsets[i]:batch.offsets[i + 1]]
        assert [bool(h) for h in hw] == [e.is_hardware for e in solo]


def test_sample_batch_empty_horizon():
    inj = FailureInjector()
    batch = inj.sample_batch(0.01, [0, 1])
    assert batch.count(0) == 0 and batch.events(1) == []


# ---------------------------------------------------------------------------
# reactive parity (the benchmark's configuration), >= 8 seeds
# ---------------------------------------------------------------------------

def test_reactive_parity_8_seeds():
    cfg = CampaignConfig(duration_h=15 * 24.0)
    seeds = list(range(8))
    batched = BatchedCampaignEngine(cfg).run(seeds)
    findings = BatchedCampaignEngine(cfg).run_findings(seeds)
    for i, (seed, ref) in enumerate(zip(seeds, scalar_results(cfg, seeds))):
        assert_result_parity(ref, batched[i], f"seed{seed}")
        # retry-chain stats are identical down to the float
        assert chain_stats(ref.retry_chains()) == \
            chain_stats(batched[i].retry_chains()), seed
        assert findings[i] == compute_findings(ref), seed


def test_parity_across_retry_policies():
    """Non-FIXED retry paths (exp backoff, structural stop) stay exact."""
    seeds = [1, 5, 9]
    for preset in ("exp-backoff", "smart-retry", "no-auto-retry"):
        sc = get_scenario(preset).replace(duration_days=12.0)
        cfg = sc.to_campaign_config(0)
        batched = BatchedCampaignEngine(cfg).run(seeds)
        for i, seed in enumerate(seeds):
            ref = ClusterSim(sc.to_campaign_config(seed)).run()
            assert_result_parity(ref, batched[i], f"{preset}-seed{seed}")


def test_parity_storage_fabric_resolution():
    """Fabric-resolved checkpoint timing flows through the batched path."""
    sc = get_scenario("storage-fabric").replace(duration_days=10.0)
    cfg = sc.to_campaign_config(0)
    seeds = [0, 4]
    batched = BatchedCampaignEngine(cfg).run(seeds)
    for i, seed in enumerate(seeds):
        ref = ClusterSim(sc.to_campaign_config(seed)).run()
        assert_result_parity(ref, batched[i], f"fabric-seed{seed}")


# ---------------------------------------------------------------------------
# proactive parity: urgent saves, ledger, drains (>= 8 seeds combined)
# ---------------------------------------------------------------------------

def test_proactive_parity_with_ledger():
    sc = get_scenario("proactive").replace(duration_days=2.0,
                                           telemetry_pad_metrics=0)
    cfg = sc.to_campaign_config(0)
    seeds = list(range(8))
    batched = BatchedCampaignEngine(cfg).run(seeds)
    findings = BatchedCampaignEngine(cfg).run_findings(seeds)
    n_alarms = 0
    for i, seed in enumerate(seeds):
        ref = ClusterSim(sc.to_campaign_config(seed)).run()
        assert_result_parity(ref, batched[i], f"proactive-seed{seed}")
        # the counterfactual ledger summarizes identically
        assert ref.control.summarize(ref.failures, ref.duration_h) == \
            batched[i].control.summarize(batched[i].failures,
                                         batched[i].duration_h), seed
        assert findings[i] == compute_findings(ref), seed
        n_alarms += len(ref.control.alarms)
    assert n_alarms > 0, "window produced no alarms — parity untested"


def test_drain_parity():
    """Executed predictive drains (span truncation, graceful handoff,
    exclusion attribution) reproduce exactly."""
    cfg = CampaignConfig(duration_h=7 * 24.0, telemetry_pad_metrics=0,
                         telemetry_store=False,
                         control=ControlConfig(drain=True))
    seeds = [25, 7]
    batched = BatchedCampaignEngine(cfg).run(seeds)
    n_drains = 0
    for i, seed in enumerate(seeds):
        ref = ClusterSim(dataclasses.replace(cfg, seed=seed)).run()
        assert_result_parity(ref, batched[i], f"drain-seed{seed}")
        n_drains += ref.control.n_drains
    assert n_drains > 0, "window executed no drains — parity untested"


def test_infra_band_parity_8_seeds():
    """The infra fault band (degradation windows + ledger, escalation
    crashes, blind-window deferral and replay, net throttles, predictive
    drains) reproduces field-for-field across 8 seeds — the weights are
    tilted so every new mechanism actually fires somewhere in the batch."""
    cfg = CampaignConfig(
        duration_h=5 * 24.0, mtbf_h=30.0,
        kind_weights={"resource_exhaust": 12.0, "ctrl_blind": 30.0},
        telemetry_pad_metrics=0, telemetry_store=False,
        control=ControlConfig(drain=True))
    seeds = list(range(8))
    batched = BatchedCampaignEngine(cfg).run(seeds)
    findings = BatchedCampaignEngine(cfg).run_findings(seeds)
    cov = dict(deferred=0, degraded=0, esc_fails=0, drains=0)
    for i, seed in enumerate(seeds):
        ref = ClusterSim(dataclasses.replace(cfg, seed=seed)).run()
        assert_result_parity(ref, batched[i], f"infra-seed{seed}")
        assert ref.control.summarize(ref.failures, ref.duration_h) == \
            batched[i].control.summarize(batched[i].failures,
                                         batched[i].duration_h), seed
        assert findings[i] == compute_findings(ref), seed
        cov["deferred"] += ref.control.alarms_deferred
        cov["degraded"] += len(ref.degraded_hours)
        cov["esc_fails"] += sum(
            1 for s in ref.sessions
            if s.error and "resource_exhaust" in s.error)
        cov["drains"] += ref.control.n_drains
    # the parity claim is only as strong as what the batch exercised
    for k, v in cov.items():
        assert v > 0, f"no {k} in any seed — infra parity untested"


def test_degraded_hours_reduce_goodput():
    """A degrade-band window overlapping a RUNNING span must show up in
    the ledger and be charged against goodput exactly once, after every
    other deduction (the documented fold order)."""
    kw = {"net_degrade": 8.0, "resource_exhaust": 8.0}
    infra = CampaignConfig(duration_h=4 * 24.0, seed=2, kind_weights=kw)
    b = ClusterSim(infra).run()
    assert b.degraded_hours, "no degradation window landed on the gang"
    assert all(d > 0 for d in b.degraded_hours)
    assert b.goodput_h() == pytest.approx(
        sum(s.elapsed_running_h(b.duration_h) for s in b.sessions
            if s.n_nodes > 1)
        - float(np.sum(b.lost_hours))
        - b.checkpoint_events * b.checkpoint_save_s / 3600.0
        - float(np.sum(b.degraded_hours)))


def test_engine_rejects_tick_engine():
    with pytest.raises(ValueError, match="event engine"):
        BatchedCampaignEngine(CampaignConfig(engine="tick"))


# ---------------------------------------------------------------------------
# detector seed axis
# ---------------------------------------------------------------------------

def test_push_group_matches_per_seed_push():
    rng0 = np.random.default_rng(7)
    T, n, S = 30, 12, 4
    cfg = DetectorConfig()

    def span(r):
        v = {"DCGM_FI_DEV_GPU_UTIL": 99.0 + r.normal(0, 0.3, (T, n))}
        for m in range(10):
            a = 50 + r.normal(0, 1, (T, n))
            if r.random() < 0.6:
                a[T // 2:, 2] += 80.0
            v[f"m{m}"] = a
        return v

    vals = [span(np.random.default_rng(100 + i)) for i in range(S)]
    ts = [np.arange(T) * 30 / 3600 + i for i in range(S)]
    ref = []
    for i in range(S):
        det = StreamingDetector(cfg)
        out = []
        for a in range(0, T, 7):
            out += det.push(ts[i][a:a + 7],
                            {k: v[a:a + 7] for k, v in vals[i].items()})
        ref.append((out, det._streak.copy(), det._tick_offset))
    dets = [StreamingDetector(cfg) for _ in range(S)]
    outs = [[] for _ in range(S)]
    for a in range(0, T, 7):
        got = StreamingDetector.push_group(
            dets, [ts[i][a:a + 7] for i in range(S)],
            [{k: v[a:a + 7] for k, v in vals[i].items()}
             for i in range(S)])
        for i in range(S):
            outs[i] += got[i]
    assert sum(len(o) for o in outs) > 0
    for i in range(S):
        assert outs[i] == ref[i][0], i
        assert np.array_equal(dets[i]._streak, ref[i][1])
        assert dets[i]._tick_offset == ref[i][2]
        assert dets[i].n_alarms == len(ref[i][0])


# ---------------------------------------------------------------------------
# SweepRunner Monte Carlo mode (the tier-1 batched-path selection)
# ---------------------------------------------------------------------------

def test_sweep_runner_mc_mode_matches_serial():
    sc = get_scenario("paper-faithful").replace(duration_days=10.0)
    mc = SweepRunner([sc], mc_seeds=10).run()
    serial = SweepRunner([sc], seeds=range(10), executor="serial").run()
    assert mc.seeds == list(range(10))
    for a, b in zip(mc.outcomes, serial.outcomes):
        fa = {k: v for k, v in a.findings.items() if k != "wall_s"}
        fb = {k: v for k, v in b.findings.items() if k != "wall_s"}
        assert a.seed == b.seed and fa == fb, a.seed


def test_sweep_runner_mc_distribution_report():
    sc = get_scenario("paper-faithful").replace(duration_days=8.0)
    res = SweepRunner([sc], mc_seeds=10).run()
    dist = res.distribution()[sc.name]
    g = dist["goodput"]
    assert g["n"] == 10
    assert g["q25"] <= g["median"] <= g["q75"]
    assert g["ci_lo"] <= g["mean"] <= g["ci_hi"]
    md = res.to_markdown()
    assert "## Distributional findings (10 seeds)" in md
    assert "±" in md and "F4 succ %" in md
    # below the threshold the section stays out of the report
    few = SweepRunner([sc], seeds=(0, 1), executor="serial").run()
    assert "Distributional findings" not in few.to_markdown()


# ---------------------------------------------------------------------------
# compiled wavefront (XLA/Pallas device core): bitwise findings parity
# ---------------------------------------------------------------------------

def _wavefront_ops():
    pytest.importorskip("jax")
    from repro.kernels.wavefront import ops
    return ops


def test_compiled_wavefront_reactive_parity_8_seeds():
    """The jitted while-loop core reproduces the scalar findings dict
    bitwise (every float, every median, every None) on both compiled
    backends — the benchmark configuration, 8 seeds."""
    _wavefront_ops()
    cfg = CampaignConfig(duration_h=15 * 24.0)
    seeds = list(range(8))
    ref = [compute_findings(r) for r in scalar_results(cfg, seeds)]
    for backend in ("xla", "pallas"):
        eng = BatchedCampaignEngine(cfg, wavefront_backend=backend)
        got = eng.run_findings(seeds)
        for i, seed in enumerate(seeds):
            assert got[i] == ref[i], (backend, seed)


def test_compiled_wavefront_retry_presets_parity():
    """Non-FIXED retry paths (exp backoff, structural stop, no-retry)
    stay exact through the device core."""
    _wavefront_ops()
    seeds = [1, 5, 9, 13]
    for preset in ("exp-backoff", "smart-retry", "no-auto-retry"):
        sc = get_scenario(preset).replace(duration_days=12.0)
        cfg = sc.to_campaign_config(0)
        got = BatchedCampaignEngine(
            cfg, wavefront_backend="xla").run_findings(seeds)
        for i, seed in enumerate(seeds):
            ref = ClusterSim(sc.to_campaign_config(seed)).run()
            assert got[i] == compute_findings(ref), (preset, seed)


def test_compiled_wavefront_infra_band_parity():
    """Control-free infra fault band: degradation windows, escalation
    crashes and fail-slow isolation all fold identically on device."""
    _wavefront_ops()
    cfg = CampaignConfig(
        duration_h=5 * 24.0, mtbf_h=30.0,
        kind_weights={"resource_exhaust": 10.0, "net_degrade": 8.0})
    seeds = list(range(8))
    got = BatchedCampaignEngine(
        cfg, wavefront_backend="xla").run_findings(seeds)
    refs = scalar_results(cfg, seeds)
    for i, seed in enumerate(seeds):
        assert got[i] == compute_findings(refs[i]), seed
    # the claim is only as strong as what the band exercised
    assert any(r.degraded_hours for r in refs), "no degradation landed"
    assert any("resource_exhaust" in (s.error or "")
               for r in refs for s in r.sessions), "no escalation crash"


def test_compiled_backend_rejects_ineligible_config():
    """Explicitly forcing the device core on a control-plane config is a
    hard error; auto silently stays on the numpy wavefront."""
    ops = _wavefront_ops()
    sc = get_scenario("proactive").replace(duration_days=2.0,
                                           telemetry_pad_metrics=0)
    cfg = sc.to_campaign_config(0)
    assert not ops.compiled_eligible(cfg)
    with pytest.raises(ValueError, match="control-free campaign"):
        BatchedCampaignEngine(
            cfg, wavefront_backend="xla").run_findings([0, 1])
    assert ops.resolve_wavefront_backend("auto", cfg, 512) == "numpy"
    with pytest.raises(ValueError, match="unknown wavefront backend"):
        BatchedCampaignEngine(cfg, wavefront_backend="cuda")


def test_compiled_auto_floor():
    """auto routes small batches to numpy (compile cost dominates) and
    large eligible batches to the device core; explicit backends ignore
    the floor."""
    ops = _wavefront_ops()
    from repro.kernels.common import WAVEFRONT_MIN_SEEDS
    cfg = CampaignConfig(duration_h=24.0)
    assert ops.compiled_eligible(cfg)
    assert ops.resolve_wavefront_backend(
        "auto", cfg, WAVEFRONT_MIN_SEEDS - 1) == "numpy"
    assert ops.resolve_wavefront_backend(
        "auto", cfg, WAVEFRONT_MIN_SEEDS) == "xla"
    assert ops.resolve_wavefront_backend("xla", cfg, 2) == "xla"
    assert ops.resolve_wavefront_backend("numpy", cfg, 4096) == "numpy"


def test_run_findings_grid_matches_single_config_runs():
    """The dense grid pass (every config x seed as one lane axis) returns
    exactly what per-config compiled runs return."""
    ops = _wavefront_ops()
    cfgs = [CampaignConfig(duration_h=6 * 24.0),
            CampaignConfig(duration_h=6 * 24.0, mtbf_h=30.0,
                           kind_weights={"net_degrade": 6.0})]
    seeds = [0, 1, 2, 3]
    grid = ops.run_findings_grid(cfgs, seeds, backend="xla")
    for g, cfg in enumerate(cfgs):
        solo = ops.run_findings_compiled(cfg, seeds, backend="xla")
        for i, seed in enumerate(seeds):
            assert grid[g][i] == solo[i], (g, seed)
            assert grid[g][i] == compute_findings(
                ClusterSim(dataclasses.replace(cfg, seed=seed)).run()), \
                (g, seed)


def test_sweep_runner_grid_pass_matches_numpy():
    """SweepRunner's whole-sweep grid pass feeds the same findings into
    the outcome rows as the pure-numpy path (control scenarios fall back
    transparently)."""
    _wavefront_ops()
    scs = [get_scenario("paper-faithful").replace(duration_days=6.0),
           get_scenario("smart-retry").replace(duration_days=6.0)]
    dev = SweepRunner(scs, mc_seeds=8, wavefront_backend="xla").run()
    ref = SweepRunner(scs, mc_seeds=8, wavefront_backend="numpy").run()
    assert len(dev.outcomes) == len(ref.outcomes) == 16
    for a, b in zip(dev.outcomes, ref.outcomes):
        fa = {k: v for k, v in a.findings.items() if k != "wall_s"}
        fb = {k: v for k, v in b.findings.items() if k != "wall_s"}
        assert a.seed == b.seed and fa == fb, (a.scenario, a.seed)


def test_sweep_runner_mc_storage_fabric_f2_columns():
    sc = get_scenario("storage-fabric").replace(duration_days=5.0)
    res = SweepRunner([sc], mc_seeds=8).run()
    for o in res.outcomes:
        assert o.findings["f2_load_util"] == pytest.approx(0.215, abs=0.01)
        assert o.findings["f2_save_util"] == pytest.approx(0.160, abs=0.01)
