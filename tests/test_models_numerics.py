"""Model-layer numerics: backend equivalences, decode==forward consistency,
MoE routing invariants, mamba/rwkv state continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LayerSpec, MoESpec
from repro.launch.steps import synthetic_batch
from repro.models import model as model_mod
from repro.models.mamba import (causal_conv1d, init_mamba, mamba_mixer,
                                selective_scan)
from repro.models.model import RunOptions
from repro.models.moe import init_moe, moe_ffn


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(7)


def _pick_cross(path, dst, prefill_cache):
    """Copy static cross-attn KV from the prefill cache into a decode cache
    (identified by the CROSS period position, pos4 for llama-vision)."""
    keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
    if "pos4" in keys:
        src = prefill_cache
        for k in keys:
            src = src[k]
        return src
    return dst


# ---------------------------------------------------------------------------
# decode == sliced forward (the serving correctness contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gemma2-2b", "rwkv6-3b", "jamba-v0.1-52b",
                                  "llama-3.2-vision-90b"])
def test_decode_matches_forward(arch, rng):
    """Prefill(x[:t]) then decode x[t] must equal forward(x[:t+1]) logits."""
    cfg = get_config(arch).reduced()
    opts = RunOptions(q_chunk=8, kv_chunk=8)
    params = model_mod.init_params(rng, cfg)
    b, s = 2, 16
    batch = synthetic_batch(rng, cfg, b, s)
    inputs = batch.get("tokens", batch.get("embeds"))
    img = batch.get("img_embeds")

    # full forward logits at every position
    x, _ = model_mod.forward(params, cfg, opts, inputs, img_embeds=img)
    full_logits = model_mod.unembed(params, cfg, x)

    # decode replay against a fresh cache; cross-attn caches (static image
    # KV) are seeded from prefill — they are inputs to the decode step
    cache2 = model_mod.init_cache(cfg, b, s)
    if cfg.n_img_tokens:
        _, pcache = model_mod.prefill(params, cfg, opts, inputs,
                                      img_embeds=img)
        cache2 = jax.tree_util.tree_map_with_path(
            lambda path, dst: _pick_cross(path, dst, pcache), cache2)
    logits = None
    for t in range(s):
        tok = inputs[:, t:t + 1]
        logits, cache2 = model_mod.decode_step(params, cfg, opts, tok,
                                               cache2, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3)


def test_vlm_decode_uses_cross_cache(rng):
    """VLM decode must attend the image embeddings via the cross cache."""
    cfg = get_config("llama-3.2-vision-90b").reduced()
    opts = RunOptions(q_chunk=8, kv_chunk=8)
    params = model_mod.init_params(rng, cfg)
    b, s = 1, 6
    batch = synthetic_batch(rng, cfg, b, s)
    img = batch["img_embeds"]
    # llama-3.2 gated cross-attn inits at tanh(0)=0 — open the gates so the
    # image pathway is live, as after training
    params["period"]["pos4"]["gate_attn"] = \
        jnp.ones_like(params["period"]["pos4"]["gate_attn"])
    logits_p, cache = model_mod.prefill(params, cfg, opts,
                                        batch["tokens"], img_embeds=img)
    assert cache is not None
    # zeroing the cross cache must change decode logits
    def zero_cross(path, leaf):
        return jnp.zeros_like(leaf)
    tok = batch["tokens"][:, -1:]
    l1, _ = model_mod.decode_step(params, cfg, opts, tok, cache,
                                  jnp.int32(s - 1))
    # cross caches sit at period pos4 (CROSS layer)
    c2 = jax.tree_util.tree_map_with_path(
        lambda p, l: jnp.zeros_like(l) if "pos4" in str(p) else l, cache)
    l2, _ = model_mod.decode_step(params, cfg, opts, tok, c2,
                                  jnp.int32(s - 1))
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

def test_moe_capacity_and_weights(rng):
    spec = MoESpec(n_experts=8, top_k=2, d_expert=16, n_shared=1)
    p = init_moe(rng, 32, spec, jnp.float32)
    x = jax.random.normal(rng, (2, 16, 32))
    out, aux = moe_ffn(x, p, spec)
    assert out.shape == x.shape
    assert float(aux["lb_loss"]) > 0
    # lb loss is ~1 for perfectly uniform routing, >=1 in general
    assert 0.5 < float(aux["lb_loss"]) < 8.0


def test_moe_dropped_tokens_bounded(rng):
    """With capacity_factor>=1, most tokens keep their top-1 expert."""
    spec = MoESpec(n_experts=4, top_k=1, d_expert=8, capacity_factor=2.0)
    p = init_moe(rng, 16, spec, jnp.float32)
    x = jax.random.normal(rng, (1, 64, 16))
    out, _ = moe_ffn(x, p, spec)
    # zero rows = dropped tokens; with cf=2 they should be rare
    zeros = int(jnp.sum(jnp.all(out == 0, axis=-1)))
    assert zeros <= 8


def test_moe_constraints_noop_without_mesh(rng):
    spec = MoESpec(n_experts=4, top_k=2, d_expert=8)
    p = init_moe(rng, 16, spec, jnp.float32)
    x = jax.random.normal(rng, (1, 8, 16))
    a, _ = moe_ffn(x, p, spec, constraints=False)
    b, _ = moe_ffn(x, p, spec, constraints=True)   # dist ctx unset -> same
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# mamba
# ---------------------------------------------------------------------------

def test_selective_scan_chunked_matches_sequential(rng):
    b, s, din, n = 2, 64, 8, 4
    ks = jax.random.split(rng, 5)
    u = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)) - 1)
    a = -jnp.exp(jax.random.normal(ks[2], (din, n)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y1, h1 = selective_scan(u, dt, a, bm, cm, chunk_size=1)
    y2, h2 = selective_scan(u, dt, a, bm, cm, chunk_size=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=3e-4, rtol=3e-4)


def test_mamba_state_continuation(rng):
    spec = LayerSpec(kind="mamba", d_state=4, d_conv=4, expand=2)
    p = init_mamba(rng, 16, spec, jnp.float32)
    x = jax.random.normal(rng, (1, 32, 16))
    y_full, st_full = mamba_mixer(x, p, spec,
                                  state={"conv": jnp.zeros((1, 3, 32)),
                                         "ssm": jnp.zeros((1, 32, 4))})
    # split processing
    st = {"conv": jnp.zeros((1, 3, 32)), "ssm": jnp.zeros((1, 32, 4))}
    y1, st = mamba_mixer(x[:, :16], p, spec, state=st)
    y2, st = mamba_mixer(x[:, 16:], p, spec, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st["ssm"]),
                               np.asarray(st_full["ssm"]), atol=2e-4)


def test_causal_conv_matches_numpy(rng):
    x = jax.random.normal(rng, (2, 10, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 3))
    b = jnp.zeros((3,))
    y, _ = causal_conv1d(x, w, b)
    xn = np.asarray(x)
    wn = np.asarray(w)
    for t in range(10):
        acc = np.zeros((2, 3))
        for i in range(4):
            ti = t - 3 + i
            if ti >= 0:
                acc += xn[:, ti] * wn[i]
        np.testing.assert_allclose(np.asarray(y[:, t]), acc, atol=1e-5)


# ---------------------------------------------------------------------------
# attention backends at the model layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 16])
def test_chunked_equals_naive_with_window(window, rng):
    from repro.models.attention import self_attention
    q = jax.random.normal(rng, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
    a = self_attention(q, k, v, window=window, backend="naive")
    b = self_attention(q, k, v, window=window, backend="chunked",
                       q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_loss_chunked_equals_full(rng):
    cfg = get_config("stablelm-3b").reduced()
    params = model_mod.init_params(rng, cfg)
    batch = synthetic_batch(rng, cfg, 2, 32)
    l1, _ = model_mod.loss_fn(params, cfg, RunOptions(q_chunk=8, kv_chunk=8),
                              batch)
    l2, _ = model_mod.loss_fn(params, cfg,
                              RunOptions(q_chunk=8, kv_chunk=8, loss_chunk=8),
                              batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


def test_unroll_periods_equals_scan(rng):
    cfg = get_config("gemma2-2b").reduced()
    params = model_mod.init_params(rng, cfg)
    batch = synthetic_batch(rng, cfg, 2, 16)
    o1 = RunOptions(q_chunk=8, kv_chunk=8, unroll_periods=False)
    o2 = RunOptions(q_chunk=8, kv_chunk=8, unroll_periods=True)
    l1, _ = model_mod.loss_fn(params, cfg, o1, batch)
    l2, _ = model_mod.loss_fn(params, cfg, o2, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
