"""What-if service: canonical keys, cache, coalescer, surface, HTTP.

The load-bearing contracts:

* `Scenario.canonical_key` collapses every spelling of the same campaign
  (dict order, to_dict/from_dict round trips through `run_campaign`'s
  wire format, preset-vs-explicit construction, int-vs-float, identity
  tilts) to one key — the cache's correctness hinges on it;
* the coalescer under concurrency: N threads submitting mixed
  duplicate/distinct queries produce exactly one engine pass per
  distinct canonical key, and every caller's answer is bitwise equal to
  a per-request serial pass on the same seeds;
* the surface answers only surface-shaped queries inside its error
  bound, exactly on grid nodes, and never bleeds into the engine
  parity path (``source`` labels stay honest);
* the distributional cutoff (`MIN_DIST_SEEDS`) gates the report section
  and the service's ``distributional`` flag at the same threshold.
"""
import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core.batch import BatchedCampaignEngine, run_findings_stacked
from repro.ops import (MIN_DIST_SEEDS, Scenario, SweepOutcome, SweepResult,
                       findings_distribution, get_scenario, run_campaign)
from repro.serve import (Coalescer, DistributionCache, ServiceConfig,
                         SurfaceSpec, SweepSurface, WhatIfService,
                         scenario_from_request)
from repro.serve.http import make_server

from tests._hypothesis_support import given, settings, st

DAYS = 3.0          # all engine passes here run short campaigns


def short(name="paper-faithful", **kw):
    return get_scenario(name).replace(duration_days=DAYS, **kw)


def numpy_service(**cfg_kw):
    cfg_kw.setdefault("wavefront_backend", "numpy")
    cfg_kw.setdefault("default_seeds", 8)
    return WhatIfService(ServiceConfig(**cfg_kw))


def serial_reference(scenario, n_seeds):
    """Per-request answer with no service in the loop: one numpy engine
    pass + the shared distribution extraction."""
    eng = BatchedCampaignEngine(scenario.to_campaign_config(0),
                                wavefront_backend="numpy")
    return findings_distribution(eng.run_findings(list(range(n_seeds))))


# ---------------------------------------------------------------------------
# canonical key
# ---------------------------------------------------------------------------

def test_canonical_key_round_trip_all_presets():
    """Scenario -> to_dict -> from_dict (the `run_campaign` wire format)
    preserves the canonical key for every preset."""
    from repro.ops import list_scenarios
    for name in list_scenarios():
        sc = get_scenario(name)
        assert Scenario.from_dict(sc.to_dict()).canonical_key() \
            == sc.canonical_key(), name


def test_canonical_key_ignores_labels_and_spelling():
    sc = get_scenario("paper-faithful")
    assert sc.canonical_key() == Scenario(name="explicit-twin").canonical_key()
    assert sc.replace(description="renamed").canonical_key() \
        == sc.canonical_key()
    # int-vs-float spelling of the same campaign
    assert sc.replace(duration_days=73).canonical_key() \
        == sc.replace(duration_days=73.0).canonical_key()
    # identity tilts multiply a weight by one: the same mix
    assert sc.replace(kind_weights={"nvlink": 1.0}).canonical_key() \
        == sc.canonical_key()
    assert sc.replace(kind_weights={}).canonical_key() \
        == sc.canonical_key()
    # different campaigns stay distinct
    assert sc.replace(mtbf_h=28.0).canonical_key() != sc.canonical_key()
    assert sc.replace(kind_weights={"nvlink": 2.0}).canonical_key() \
        != sc.canonical_key()


def test_canonical_key_dict_order_insensitive():
    a = Scenario(name="a", kind_weights={"nvlink": 2.0, "ecc": 3.0})
    b = Scenario(name="b", kind_weights={"ecc": 3.0, "nvlink": 2.0})
    assert a.canonical_key() == b.canonical_key()
    # shuffled top-level dict order through from_dict
    d = a.to_dict()
    shuffled = dict(reversed(list(d.items())))
    assert Scenario.from_dict(shuffled).canonical_key() == a.canonical_key()


def test_run_campaign_key_stable_across_wire_format():
    """The sweep's process-pool worker consumes `to_dict` payloads; the
    reconstructed scenario must hit the same cache line as the original
    (and still produce the same findings)."""
    sc = short()
    wire = sc.to_dict()
    assert Scenario.from_dict(wire).canonical_key() == sc.canonical_key()
    out = run_campaign(wire, seed=0)["findings"]
    ref = run_campaign(sc.to_dict(), seed=0)["findings"]
    out.pop("wall_s", None), ref.pop("wall_s", None)
    assert out == ref


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_canonical_key_property(data):
    """Property: random label edits, kind-weight orderings/identity
    tilts and int-vs-float spellings never change the key; a real tilt
    change always does."""
    weights = data.draw(st.dictionaries(
        st.sampled_from(["nvlink", "ecc", "dropout", "exec"]),
        st.floats(0.5, 4.0, allow_nan=False), max_size=3))
    sc = Scenario(name=data.draw(st.text(max_size=8)),
                  description=data.draw(st.text(max_size=8)),
                  duration_days=data.draw(st.sampled_from([3, 3.0])),
                  kind_weights=weights or None)
    twin = Scenario(
        name="twin", description="other label",
        duration_days=float(sc.duration_days),
        kind_weights=dict(reversed(list(weights.items()))) if weights
        else None)
    assert sc.canonical_key() == twin.canonical_key()
    assert Scenario.from_dict(sc.to_dict()).canonical_key() \
        == sc.canonical_key()
    tilted = sc.replace(kind_weights={**(weights or {}), "app": 2.5})
    assert tilted.canonical_key() != sc.canonical_key()


# ---------------------------------------------------------------------------
# distributional cutoff (MIN_DIST_SEEDS)
# ---------------------------------------------------------------------------

def _fake_sweep(n_seeds):
    sc = get_scenario("paper-faithful")
    outcomes = [SweepOutcome(sc.name, s, {"goodput": 0.9 + 0.001 * s,
                                          "occupancy": 0.95})
                for s in range(n_seeds)]
    return SweepResult(scenarios=[sc], seeds=list(range(n_seeds)),
                       outcomes=outcomes)


def test_distribution_section_cutoff():
    """The report's distributional section renders exactly from
    MIN_DIST_SEEDS up — the named constant, not a drifting literal."""
    assert SweepResult.MIN_SEEDS_FOR_DISTRIBUTION == MIN_DIST_SEEDS
    below = _fake_sweep(MIN_DIST_SEEDS - 1).to_markdown()
    at = _fake_sweep(MIN_DIST_SEEDS).to_markdown()
    assert "## Distributional findings" not in below
    assert f"## Distributional findings ({MIN_DIST_SEEDS} seeds)" in at


def test_service_distributional_flag_cutoff():
    svc = numpy_service(coalesce=False)
    try:
        lo = svc.query(short(), n_seeds=MIN_DIST_SEEDS - 1)
        hi = svc.query(short(), n_seeds=MIN_DIST_SEEDS)
        assert not lo.distributional and hi.distributional
        assert lo.distribution["goodput"]["n"] == MIN_DIST_SEEDS - 1
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# cache layer
# ---------------------------------------------------------------------------

def test_cache_lru_eviction_and_stats():
    c = DistributionCache(capacity=2)
    c.put("a", 1), c.put("b", 2)
    assert c.get("a") == 1          # refreshes a
    c.put("c", 3)                   # evicts b (LRU)
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
    s = c.stats()
    assert (s["size"], s["evictions"]) == (2, 1)
    assert DistributionCache(capacity=0).get("x") is None


def test_cache_hit_equivalent_specs_and_latency():
    """Equivalent spellings of one campaign share a cache line; hits
    answer without an engine pass in well under the 5 ms budget."""
    svc = numpy_service()
    try:
        cold = svc.query(short())
        assert cold.source == "engine"
        # a differently-spelled equivalent spec
        twin = short().replace(name="respelled", duration_days=int(DAYS),
                               kind_weights={"nvlink": 1.0})
        lat = []
        for _ in range(50):
            t0 = time.perf_counter()
            hit = svc.query(twin)
            lat.append(time.perf_counter() - t0)
            assert hit.source == "cache"
            assert hit.distribution == cold.distribution
        assert svc.stats()["engine_configs"] == 1
        assert np.percentile(lat, 99) < 0.005
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# coalescer
# ---------------------------------------------------------------------------

def test_coalescer_windows_and_dedup():
    calls = []

    def runner(batch):
        calls.append([k for k, _ in batch])
        return {k: f"r:{k}" for k, _ in batch}

    co = Coalescer(runner, window_s=0.05)
    futs = [co.submit(k, None) for k in ("a", "b", "a", "a", "b")]
    assert [f.result(timeout=5) for f in futs] \
        == ["r:a", "r:b", "r:a", "r:a", "r:b"]
    co.close()
    # one window, deduped to the two distinct keys (first-come order)
    assert calls == [["a", "b"]]
    s = co.stats()
    assert (s["requests"], s["dispatched"], s["deduped"]) == (5, 2, 3)


def test_coalescer_runner_error_fails_all_futures():
    def runner(batch):
        raise RuntimeError("engine exploded")
    co = Coalescer(runner, window_s=0.01)
    futs = [co.submit("k", None), co.submit("k2", None)]
    for f in futs:
        with pytest.raises(RuntimeError, match="engine exploded"):
            f.result(timeout=5)
    co.close()
    with pytest.raises(RuntimeError, match="closed"):
        co.submit("late", None)


def test_coalesced_concurrency_one_pass_per_key_bitwise_parity():
    """The satellite contract: 16 threads x mixed duplicate/distinct
    queries -> exactly one engine pass per distinct canonical key, and
    every caller's slice is bitwise equal to its per-request serial
    answer.

    Concurrent duplicates attach to the in-flight pass (or coalesce in
    the same window); once a key's pass has finished, repeats hit the
    cache — so across all 48 queries the engine sees each of the 4
    distinct keys exactly once, with no timing assumptions."""
    distinct = [short(checkpoint_interval_h=h)
                for h in (1.5, 2.23, 3.0, 4.0)]
    n_seeds, n_threads, per_thread = 8, 16, 3

    passes = []

    def counting_engine(cfgs, seeds):
        passes.append(len(cfgs))
        return run_findings_stacked(cfgs, seeds,
                                    wavefront_backend="numpy")

    svc = WhatIfService(
        ServiceConfig(window_s=0.05, default_seeds=n_seeds,
                      wavefront_backend="numpy"),
        engine_fn=counting_engine)
    results = [[None] * per_thread for _ in range(n_threads)]
    barrier = threading.Barrier(n_threads)

    def worker(i):
        barrier.wait()
        for j in range(per_thread):
            sc = distinct[(i + j) % len(distinct)]
            results[i][j] = (sc, svc.query(sc))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads)
        # exactly one engine pass per distinct canonical key despite
        # 48 queries: concurrent duplicates rode the in-flight pass or
        # a shared coalescer window, later repeats the cache
        assert sum(passes) == len(distinct), (passes, svc.stats())
        refs = {sc.canonical_key(): serial_reference(sc, n_seeds)
                for sc in distinct}
        sources = set()
        for row in results:
            for sc, ans in row:
                sources.add(ans.source)
                assert ans.n_seeds == n_seeds
                assert ans.distribution == refs[sc.canonical_key()], \
                    "coalesced answer diverged from serial reference"
        assert "engine" in sources
    finally:
        svc.close()


def test_grouped_stacked_pass_matches_per_config():
    """`run_findings_stacked` on a mixed config bag returns, per config,
    exactly what a solo pass returns (lanes never interact)."""
    scs = [short(), short(checkpoint_interval_h=1.5),
           # correlated fault band: host-only, never grid-able
           short(kind_weights={"switch_degrade": 1.5})]
    cfgs = [sc.to_campaign_config(0) for sc in scs]
    seeds = list(range(4))
    stacked = run_findings_stacked(cfgs, seeds, wavefront_backend="numpy")
    for cfg, by_seed in zip(cfgs, stacked):
        solo = BatchedCampaignEngine(
            cfg, wavefront_backend="numpy").run_findings(seeds)
        assert by_seed == dict(zip(seeds, solo))


# ---------------------------------------------------------------------------
# surface layer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_surface():
    base = get_scenario("paper-faithful").replace(duration_days=2.0)
    spec = SurfaceSpec(base=base, n_nodes=(31, 63, 95),
                       tilts=(1.0, 2.0, 4.0), ckpt_hours=(1.0, 2.23, 4.0),
                       seeds=8)
    return SweepSurface(spec, wavefront_backend="numpy").build()


def test_surface_exact_on_grid(small_surface):
    """A query landing on a grid node reproduces the precomputed
    distribution exactly (interpolation weights collapse to one corner),
    with a zero error estimate."""
    surf = small_surface
    sc = surf.spec.point(63, 2.0, 1.0)
    hit = surf.lookup(sc)
    assert hit is not None and hit["interp_err_goodput"] == 0.0
    ref = serial_reference(sc, surf.spec.seeds)
    g = hit["distribution"]["goodput"]
    assert g["median"] == ref["goodput"]["median"]
    assert g["q25"] == ref["goodput"]["q25"]


def test_surface_near_miss_interpolates_between_neighbors(small_surface):
    surf = small_surface
    lo = surf.lookup(surf.spec.point(63, 2.0, 1.0))
    hi = surf.lookup(surf.spec.point(63, 2.0, 2.23))
    mid_sc = surf.spec.point(63, 2.0, 1.6)
    mid = surf.lookup(mid_sc)
    assert mid is not None
    a, b = sorted([lo["distribution"]["goodput"]["median"],
                   hi["distribution"]["goodput"]["median"]])
    assert a <= mid["distribution"]["goodput"]["median"] <= b


def test_surface_rejects_off_grid_and_out_of_hull(small_surface):
    surf = small_surface
    base = surf.spec.base
    # off-axis field change: not surface-shaped
    assert surf.lookup(base.replace(retry_policy="exp_backoff")) is None
    assert surf.lookup(base.replace(mtbf_h=28.0)) is None
    # outside the hull
    assert surf.lookup(base.replace(n_nodes=200, job_nodes=197)) is None
    assert surf.lookup(base.replace(checkpoint_interval_h=9.0)) is None
    # gang size breaking the base's spare count
    assert surf.lookup(base.replace(n_nodes=63, job_nodes=50)) is None


def test_surface_error_bound_falls_back_to_engine(small_surface):
    """Mid-cell queries fall back to a live pass when the curvature
    bound exceeds the spec tolerance (here: forced to 0), while grid
    nodes still serve (their interpolation is exact)."""
    surf = small_surface
    old = surf.spec.max_goodput_err
    surf.spec.max_goodput_err = 0.0
    try:
        mid = surf.spec.point(63, 2.0, 1.6)
        if surf.error_estimate(surf.coords(mid)) > 0.0:
            assert surf.lookup(mid) is None
        assert surf.lookup(surf.spec.point(63, 2.0, 1.0)) is not None
    finally:
        surf.spec.max_goodput_err = old
    svc = WhatIfService(ServiceConfig(coalesce=False, default_seeds=8,
                                      wavefront_backend="numpy"),
                        surface=surf)
    try:
        assert svc.query(surf.spec.point(63, 2.0, 1.0)).source == "surface"
        off = surf.spec.base.replace(retry_policy="exp_backoff")
        assert svc.query(off).source == "engine"
    finally:
        svc.close()


def test_surface_spec_validation():
    base = get_scenario("paper-faithful")
    with pytest.raises(ValueError, match="ascending"):
        SurfaceSpec(base=base, n_nodes=(63,))
    with pytest.raises(ValueError, match="fixed"):
        SurfaceSpec(base=base.replace(checkpoint_strategy="young_daly"))
    with pytest.raises(ValueError, match="spares"):
        SurfaceSpec(base=base, n_nodes=(2, 63))


# ---------------------------------------------------------------------------
# request parsing + HTTP transport
# ---------------------------------------------------------------------------

def test_scenario_from_request():
    sc = scenario_from_request({"preset": "flaky-fabric"})
    assert sc.canonical_key() == get_scenario("flaky-fabric").canonical_key()
    sc = scenario_from_request({"scenario": {"mtbf_h": 28.0}})
    assert sc.name == "adhoc" and sc.mtbf_h == 28.0
    sc = scenario_from_request({"preset": "paper-faithful",
                                "overrides": {"duration_days": 7.0}})
    assert sc.duration_days == 7.0
    for bad in ({}, {"preset": "x", "scenario": {}},
                {"scenario": {"not_a_field": 1}},
                {"preset": "paper-faithful", "overrides": {"nope": 1}}):
        with pytest.raises((ValueError, KeyError)):
            scenario_from_request(bad)


@pytest.fixture()
def http_service():
    svc = numpy_service()
    server = make_server(svc)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, f"http://127.0.0.1:{server.server_address[1]}"
    server.shutdown()
    svc.close()


def _post(url, payload):
    req = urllib.request.Request(url, data=json.dumps(payload).encode(),
                                 headers={"Content-Type":
                                          "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(url):
    try:
        with urllib.request.urlopen(url) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_http_endpoints(http_service):
    svc, root = http_service
    assert _get(f"{root}/healthz") == (200, {"ok": True})
    code, ans = _post(f"{root}/whatif", {
        "preset": "paper-faithful", "seeds": 8,
        "overrides": {"duration_days": DAYS}})
    assert code == 200 and ans["source"] == "engine"
    assert ans["n_seeds"] == 8 and "goodput" in ans["distribution"]
    ref = serial_reference(short(), 8)
    assert ans["distribution"]["goodput"]["median"] \
        == ref["goodput"]["median"]
    # the HTTP layer shares the one service: repeat hits the cache
    code, again = _post(f"{root}/whatif", {
        "preset": "paper-faithful", "seeds": 8,
        "overrides": {"duration_days": DAYS}})
    assert code == 200 and again["source"] == "cache"
    code, stats = _get(f"{root}/stats")
    assert code == 200 and stats["queries"] == 2
    assert stats["cache"]["hits"] == 1
    code, surf = _get(f"{root}/surface")
    assert code == 200 and surf["surface"] is None


def test_http_errors(http_service):
    _, root = http_service
    assert _get(f"{root}/nope")[0] == 404
    code, err = _post(f"{root}/whatif", {"preset": "no-such-preset"})
    assert code == 400 and "unknown scenario" in err["error"]
    code, err = _post(f"{root}/whatif", {"scenario": {"bogus_field": 1}})
    assert code == 400
    code, err = _post(f"{root}/whatif",
                      {"preset": "paper-faithful", "seeds": 0})
    assert code == 400 and "n_seeds" in err["error"]
