"""Operational pipeline: precursor detection, cluster sim, exclusion,
data pipeline, health checks, telemetry."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.exclusion import ExclusionTracker
from repro.core.failures import FailureInjector
from repro.core.precursor import (DetectorConfig, PrecursorDetector,
                                  evaluate, robust_peer_z)
from repro.telemetry.exporters import ExporterSuite, NodeState
from repro.telemetry.registry import TimeSeriesStore


# ---------------------------------------------------------------------------
# robust z / detector
# ---------------------------------------------------------------------------

@given(st.integers(8, 64), st.floats(10.0, 1e6))
@settings(max_examples=40, deadline=None)
def test_robust_z_flags_outlier(n, scale):
    rng = np.random.default_rng(int(scale) % 7919)
    vals = rng.normal(100.0, 1.0, n)
    vals[3] += 50 * scale / scale * 50   # gross outlier
    z = robust_peer_z(vals)
    assert abs(z[3]) > 6
    # small samples can throw 1-2 extra tails past 6 MAD-sigmas; the vote
    # (min_signals metrics) is what suppresses these in the detector
    assert np.sum(np.abs(z) > 6) <= max(3, n // 4)


def test_robust_z_constant_series_no_alarm():
    z = robust_peer_z(np.full(63, 42.0))
    assert np.all(np.abs(z) < 1e-3)


def _make_store(n_ticks=200, n_nodes=16, fail_node=None, fail_tick=None,
                seed=0):
    rng = np.random.default_rng(seed)
    store = TimeSeriesStore(n_nodes)
    for t in range(n_ticks):
        snap = {
            "DCGM_FI_DEV_GPU_UTIL": np.full(n_nodes, 99.0)
            + rng.normal(0, 0.3, n_nodes),
            "m1": rng.normal(100, 1, n_nodes),
            "m2": rng.normal(50, 2, n_nodes),
            "m3": rng.normal(10, 0.5, n_nodes),
            "m4": rng.normal(5, 0.2, n_nodes),
        }
        if fail_node is not None and t == fail_tick:
            for m in ("m1", "m2", "m3", "m4"):
                snap[m][fail_node] += 500
            snap["DCGM_FI_DEV_GPU_UTIL"][fail_node] = 0.0
        store.append(t * 30 / 3600.0, snap)
    return store


def test_detector_finds_injected_anomaly():
    store = _make_store(fail_node=5, fail_tick=120)
    alarms = PrecursorDetector(DetectorConfig(min_signals=3)).scan(store)
    assert any(a.node == 5 and a.tick == 120 for a in alarms)


def test_detector_low_fp_on_pure_noise():
    store = _make_store()
    alarms = PrecursorDetector(DetectorConfig(min_signals=3)).scan(store)
    # 200 ticks x 16 nodes of well-behaved noise: no multi-signal alarms
    assert len(alarms) <= 2


@given(st.integers(0, 15))
@settings(max_examples=10, deadline=None)
def test_detector_node_identification(node):
    store = _make_store(fail_node=node, fail_tick=77, seed=node)
    alarms = PrecursorDetector(DetectorConfig(min_signals=3)).scan(store)
    hits = [a for a in alarms if a.tick == 77]
    assert hits and hits[0].node == node


def test_evaluate_pre_xid_and_fp_accounting():
    from repro.core.failures import FailureEvent
    store = _make_store(fail_node=2, fail_tick=100)
    alarms = PrecursorDetector(DetectorConfig(min_signals=3)).scan(store)
    ev_time = 100 * 30 / 3600.0
    failures = [FailureEvent(time_h=ev_time, node=2, kind="xid", xid=94)]
    res = evaluate(alarms, failures, duration_h=200 * 30 / 3600.0)
    assert res.detected == 1
    assert res.pre_xid == 0          # abrupt signature -> at-XID detection


# ---------------------------------------------------------------------------
# failure injector
# ---------------------------------------------------------------------------

def test_injector_mtbf_statistics():
    inj = FailureInjector(mtbf_h=56.2, seed=0)
    events = inj.sample(3000 * 24.0)
    gaps = np.diff([0.0] + [e.time_h for e in events])
    assert abs(np.mean(gaps) - 56.2) < 6.0


def test_injector_hot_node_concentration():
    inj = FailureInjector(seed=1)
    events = inj.sample(2000 * 24.0)
    counts = np.bincount([e.node for e in events], minlength=63)
    top3 = np.sort(counts)[::-1][:3].sum()
    assert top3 / counts.sum() > 0.35    # concentrated (paper: >50% of excl.)


def test_injector_mix_covers_paper_categories():
    inj = FailureInjector(seed=2)
    events = inj.sample(3000 * 24.0)
    kinds = {e.kind for e in events}
    assert kinds == {"xid", "unreachable", "fail_slow"}
    xids = {e.xid for e in events if e.kind == "xid"}
    assert {145, 94, 79}.issubset(xids)


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_exporter_metric_count_realistic():
    suite = ExporterSuite(4, seed=0)
    assert suite.reg.n_metrics >= 300    # ~305 analysis-active in the paper


def test_exporter_nvlink_signature():
    from repro.core.failures import FailureEvent
    suite = ExporterSuite(8, seed=0)
    states = [NodeState(training=True) for _ in range(8)]
    ev = FailureEvent(time_h=1.0, node=3, kind="xid", xid=145)
    snap = suite.tick(1.0, states, [ev])
    # paper Fig 2: interrupts collapse ~300K -> 70-100K; procs_running -> 0
    assert snap["node_intr_total"][3] < 150e3
    assert snap["node_procs_running"][3] == 0
    healthy = np.delete(snap["node_intr_total"], 3)
    assert np.all(healthy > 250e3)


def test_exporter_ecc_signature():
    from repro.core.failures import FailureEvent
    suite = ExporterSuite(8, seed=0)
    states = [NodeState(training=True) for _ in range(8)]
    ev = FailureEvent(time_h=1.0, node=2, kind="xid", xid=94)
    snap = suite.tick(1.0, states, [ev])
    getattr_m = "node_mountstats_nfs_operations_response_time_seconds_total:GETATTR"
    assert snap[getattr_m][2] > 10 * np.median(np.delete(snap[getattr_m], 2))
    assert snap["node_vmstat_pgpgout"][2] > 5 * np.median(
        np.delete(snap["node_vmstat_pgpgout"], 2))
    assert suite.remap_uncorr[2] >= 1


# ---------------------------------------------------------------------------
# exclusion tracker
# ---------------------------------------------------------------------------

def test_exclusion_concentration_math():
    tr = ExclusionTracker(n_nodes=10)
    # node 9 always excluded deliberately; others excluded once each
    for i in range(8):
        tr.record_session(i, i + 1.0, [n for n in range(10)
                                       if n not in (9, i)],
                          {9: "slow"})
    s = tr.summary()
    assert 9 in s["top3_nodes"]
    assert s["top3_share"] > 0.5
    overlap = tr.deliberate_overlap()
    assert overlap[9] == 1.0
    assert overlap.get(0, 0.0) == 0.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_rank_sharded_pipeline_roundtrip(tmp_path):
    from repro.data.pipeline import (DataConfig, RankShardReader,
                                     build_sharded_dataset)
    cfg = DataConfig(vocab_size=512, seq_len=32, tokens_per_shard=1 << 12)
    build_sharded_dataset(tmp_path, n_ranks=3, cfg=cfg)
    readers = [RankShardReader(tmp_path, r, cfg, batch_per_rank=2)
               for r in range(3)]
    b0 = next(readers[0])
    assert b0["tokens"].shape == (2, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
    # ranks see disjoint streams
    b1 = next(readers[1])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # unknown rank -> clear error
    with pytest.raises(KeyError):
        RankShardReader(tmp_path, 7, cfg, 1)


def test_io_sharding_cliff():
    """§3.5: the contention cliff exists at 60 nodes but NOT at 2-4 nodes."""
    from repro.data.pipeline import init_time_model
    shared_60 = init_time_model(60, 2000, 6, 200e9, sharded=False)
    shard_60 = init_time_model(60, 2000, 6, 200e9, sharded=True)
    shared_4 = init_time_model(4, 2000, 6, 200e9, sharded=False)
    assert shared_60 > 8 * 3600          # >8h (paper)
    assert shard_60 < 10 * 60            # <10min (paper: ~8min)
    assert shared_4 < 0.25 * shared_60 / 15   # small-scale tests mislead


# ---------------------------------------------------------------------------
# health checks
# ---------------------------------------------------------------------------

def test_health_monitor_layers():
    from repro.core.health import (HealthLayer, HealthMonitor, Probe,
                                   device_liveness_probe)
    mon = HealthMonitor()
    mon.register(0, Probe(HealthLayer.DEVICE, device_liveness_probe))
    mon.register(0, Probe(HealthLayer.AGENT_RPC, lambda: True))
    mon.register(1, Probe(HealthLayer.AGENT_RPC, lambda: False))
    reports = mon.sweep()
    assert reports[0].healthy
    assert not reports[1].healthy
    assert reports[1].failing_layers == [HealthLayer.AGENT_RPC]
