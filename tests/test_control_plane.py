"""Detection->recovery control plane: streaming/offline detector parity,
policy actions (urgent checkpoints, predictive drains, alarm-informed
placement), counterfactual accounting, scenario presets, and the
acceptance check that a proactive 73-day paper campaign beats the
reactive baseline on goodput with identical failure schedules."""
import numpy as np
import pytest

from repro.control import ControlConfig, ControlStats, StreamingDetector
from repro.core.cluster import CampaignConfig, ClusterSim
from repro.core.precursor import Alarm, DetectorConfig, PrecursorDetector
from repro.core.scheduler import GangScheduler
from repro.core.session import Session
from repro.ops import get_scenario


# ---------------------------------------------------------------------------
# streaming detector: exact parity with the offline scan
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def telemetry_store():
    res = ClusterSim(CampaignConfig(duration_h=12.0, telemetry=True,
                                    telemetry_pad_metrics=24,
                                    seed=11)).run()
    return res.store


def _chunked_alarms(store, chunk):
    ts = store.times()
    arrays = {name: store.series(name) for name in store.names}
    det = StreamingDetector(DetectorConfig())
    out = []
    for a in range(0, len(ts), chunk):
        b = min(a + chunk, len(ts))
        out += det.push(ts[a:b], {k: v[a:b] for k, v in arrays.items()})
    return out


@pytest.mark.parametrize("chunk", [37, 120, 2048])
def test_streaming_reproduces_scan_exactly(telemetry_store, chunk):
    """Acceptance: chunked online pushes == one offline scan, exactly —
    alarm ticks, nodes, vote counts, and attribution lists all equal."""
    scan = PrecursorDetector(DetectorConfig()).scan(telemetry_store)
    assert len(scan) > 0                      # seed 11 raises alarms
    assert _chunked_alarms(telemetry_store, chunk) == scan


def test_scan_is_single_push(telemetry_store):
    """PrecursorDetector.scan delegates to the streaming core: one push of
    the whole store is the same code path."""
    store = telemetry_store
    det = StreamingDetector(DetectorConfig())
    one = det.push(store.times(),
                   {n: store.series(n) for n in store.names})
    assert one == PrecursorDetector(DetectorConfig()).scan(store)


def test_streak_carries_across_chunk_boundary():
    """A persistence streak spanning a push boundary alarms exactly once,
    at the tick where the streak completes."""
    cfg = DetectorConfig(z_threshold=3.0, min_signals=2, persistence=3,
                         activity_metric="act")
    rng = np.random.default_rng(0)
    T, n = 10, 8
    vals = {f"m{i}": rng.normal(50.0, 1.0, (T, n)) for i in range(2)}
    vals["act"] = np.full((T, n), 100.0)
    for name in ("m0", "m1"):
        vals[name][4:8, 3] = 90.0            # 4-tick deviation on node 3
    ts = np.arange(T) * (30.0 / 3600.0)

    whole = StreamingDetector(cfg).push(ts, vals)
    det = StreamingDetector(cfg)
    split = det.push(ts[:6], {k: v[:6] for k, v in vals.items()})
    split += det.push(ts[6:], {k: v[6:] for k, v in vals.items()})
    assert whole == split
    assert [a.tick for a in whole] == [6]    # streak of 3 completes at t=6
    assert whole[0].node == 3
    assert {m for m, _ in whole[0].top_metrics} == {"m0", "m1"}


# ---------------------------------------------------------------------------
# policy actions
# ---------------------------------------------------------------------------

# seed 25's first week contains three pre-XID precursor failures — the
# case the control plane exists for
PROACTIVE_SEED = 25


def _campaign(control=None, seed=PROACTIVE_SEED, days=7.0):
    return ClusterSim(CampaignConfig(
        duration_h=days * 24.0, telemetry_pad_metrics=0,
        telemetry_store=False, control=control, seed=seed)).run()


def test_urgent_checkpoints_shrink_lost_work():
    pro = _campaign(ControlConfig(drain=False))
    rea = ClusterSim(CampaignConfig(duration_h=7 * 24.0,
                                    seed=PROACTIVE_SEED)).run()
    assert pro.control is not None and rea.control is None
    assert len(pro.control.alarms) > 0
    assert len(pro.control.urgent_saves) > 0
    assert pro.control.lost_work_avoided_h > 0
    # identical failure schedules, less total lost work
    assert [f.time_h for f in pro.failures] == \
        [f.time_h for f in rea.failures]
    assert sum(pro.lost_hours) < sum(rea.lost_hours)


def test_predictive_drain_dodges_failure():
    pro = _campaign(ControlConfig(drain=True))
    cs = pro.control
    assert cs.n_drains >= 1
    assert cs.failures_on_drained_node >= 1
    # the drain feeds F3: exclusion intervals tagged with the detector's
    # reason, so concentration emerges from alarms rather than injection
    reasons = pro.exclusions.by_reason()
    assert "predictive drain" in reasons
    assert reasons["predictive drain"]["count"] > 0
    # drained chains close gracefully, not as failures
    assert any(c.stopped_reason == "predictive drain" for c in pro.chains)
    # drain downtime episodes are tagged so F4 medians stay reactive-only
    assert any(d.get("kind") == "drain" for d in pro.downtimes)


def test_control_stats_summarize_ledger():
    pro = _campaign(ControlConfig(drain=False))
    s = pro.control.summarize(pro.failures, pro.duration_h)
    assert s["n_alarms"] == len(pro.control.alarms)
    assert s["urgent_save_h"] == pytest.approx(pro.control.urgent_save_h)
    assert s["urgent_wasted_h"] <= s["urgent_save_h"] + 1e-12
    assert s["tp"] >= 1                      # the precursors are caught
    assert s["avoided_per_tp_h"] > 0


def test_tick_engine_rejects_control():
    cfg = CampaignConfig(duration_h=24.0, engine="tick",
                         control=ControlConfig())
    with pytest.raises(ValueError, match="event engine"):
        ClusterSim(cfg).run()


def test_scheduler_avoid_orders_alarmed_nodes_last():
    sched = GangScheduler(6, spares=2)
    s = Session(task_name="t", n_nodes=4)
    assert sched.try_allocate(s, 0.0, avoid={0, 1})
    assert s.nodes == [2, 3, 4, 5]
    sched.release(s, 1.0)
    # gang requirement wins when the pool is tight: avoided nodes are used
    s2 = Session(task_name="t2", n_nodes=5)
    assert sched.try_allocate(s2, 2.0, avoid={0, 1})
    assert set(s2.nodes) == {2, 3, 4, 5, 0}


# ---------------------------------------------------------------------------
# scenario presets + sweep integration
# ---------------------------------------------------------------------------

def test_control_presets_resolve():
    rea = get_scenario("reactive").to_campaign_config()
    assert rea.control is None and not rea.telemetry
    pro = get_scenario("proactive").to_campaign_config()
    assert pro.control is not None
    assert pro.telemetry and not pro.telemetry_store
    assert pro.control.urgent_checkpoint and not pro.control.drain
    agg = get_scenario("proactive-aggressive").to_campaign_config()
    assert agg.control.drain
    assert agg.control.drain_confirm_alarms == 3


def test_sweep_reports_control_ledger():
    from repro.ops import SweepRunner
    scs = [get_scenario("reactive").replace(duration_days=5.0),
           get_scenario("proactive").replace(duration_days=5.0,
                                             telemetry_pad_metrics=0)]
    res = SweepRunner(scs, seeds=(PROACTIVE_SEED,), executor="serial").run()
    agg = res.aggregate()
    assert agg["proactive"]["ctrl_n_alarms"] is not None
    assert agg["reactive"].get("ctrl_n_alarms") is None
    assert agg["proactive"]["goodput"] is not None
    md = res.to_markdown()
    assert "Detection -> recovery (control plane)" in md
    assert "proactive" in md


def test_summarize_splits_tp_fp_spend():
    stats = ControlStats()
    stats.alarms = [Alarm(tick=10, time_h=1.0, node=3, n_signals=5,
                          top_metrics=[]),
                    Alarm(tick=99, time_h=9.0, node=7, n_signals=4,
                          top_metrics=[])]
    from repro.control.policy import UrgentSave
    stats.urgent_saves = [UrgentSave(1.0, 3, 0, 0.01),
                          UrgentSave(9.0, 7, 1, 0.01)]
    stats.urgent_save_h = 0.02

    class Ev:
        def __init__(self, t, node):
            self.time_h, self.node = t, node
            self.kind, self.xid = "xid", 145
            self.precursor_lead_h = 0.5

    s = stats.summarize([Ev(1.2, 3)], duration_h=24.0)
    assert s["tp"] == 1 and s["fp"] == 1
    # the node-7 save was a false positive: its cost is the wasted half
    assert s["urgent_wasted_h"] == pytest.approx(0.01)
    assert s["wasted_per_fp_h"] == pytest.approx(0.01)


# ---------------------------------------------------------------------------
# acceptance: proactive beats reactive on the paper-default campaign
# ---------------------------------------------------------------------------

def test_proactive_beats_reactive_73d_identical_schedule():
    """The paper-default 63-node/73-day campaign: the proactive preset
    shows strictly higher goodput than the reactive baseline under the
    identical failure schedule (same seed)."""
    seed = 3
    pro_sc = get_scenario("proactive").replace(telemetry_pad_metrics=0)
    rea_sc = get_scenario("reactive")
    pro = ClusterSim(pro_sc.to_campaign_config(seed)).run()
    rea = ClusterSim(rea_sc.to_campaign_config(seed)).run()
    assert (pro.duration_h, rea.duration_h) == (73 * 24.0, 73 * 24.0)
    assert [f.time_h for f in pro.failures] == \
        [f.time_h for f in rea.failures]
    assert pro.goodput() > rea.goodput()
    # and the margin is what the ledger says it is: lost work avoided
    # minus urgent save spend (trajectory-preserving actions only)
    assert pro.control.lost_work_avoided_h > pro.control.urgent_save_h
