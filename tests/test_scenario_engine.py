"""Scenario engine + sweep runner: preset round-trips, config resolution,
sweep determinism, engine parity, batched telemetry, and the golden check
that the paper-faithful scenario still reproduces the seed's F3/F4
headline numbers."""
import numpy as np
import pytest

from repro.core.cluster import CampaignConfig, ClusterSim
from repro.core.retry import RetryPolicy, chain_stats
from repro.ops import (PRESETS, Scenario, SweepRunner, get_scenario,
                       list_scenarios, run_campaign)


# ---------------------------------------------------------------------------
# scenario spec
# ---------------------------------------------------------------------------

def test_presets_round_trip():
    for name, sc in PRESETS.items():
        assert sc.name == name
        rt = Scenario.from_dict(sc.to_dict())
        assert rt == sc, name


def test_get_scenario_isolated_and_presets_run_smoke():
    """Every preset survives canonicalize -> construct -> run without
    mutating the shared registry: get_scenario hands out an isolated
    deep copy (serialization round-trip), so callers tweaking nested
    config (kind_weights, control, storage) cannot corrupt PRESETS."""
    snapshot = {name: sc.to_dict() for name, sc in PRESETS.items()}
    for name in list_scenarios():
        sc = get_scenario(name)
        assert sc is not PRESETS[name], name
        smoke = sc.replace(duration_days=1.0, telemetry_pad_metrics=0)
        res = ClusterSim(smoke.to_campaign_config(seed=0)).run()
        assert res.goodput_h() >= 0.0, name
        if sc.kind_weights is not None:
            assert sc.kind_weights is not PRESETS[name].kind_weights, name
            sc.kind_weights["nvlink"] = 1e9          # poison the copy
    assert {n: sc.to_dict() for n, sc in PRESETS.items()} == snapshot


def test_preset_registry():
    assert "paper-faithful" in list_scenarios()
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("definitely-not-a-scenario")


def test_invalid_specs_rejected():
    with pytest.raises(ValueError):
        Scenario(name="bad", retry_policy="coin_flip")
    with pytest.raises(ValueError):
        Scenario(name="bad", checkpoint_strategy="hourly")


def test_paper_faithful_resolution():
    cfg = get_scenario("paper-faithful").to_campaign_config(seed=3)
    assert isinstance(cfg, CampaignConfig)
    assert (cfg.n_nodes, cfg.job_nodes) == (63, 60)
    assert cfg.duration_h == 73 * 24.0
    assert cfg.checkpoint_interval_h == pytest.approx(2.23)
    assert cfg.retry.policy is RetryPolicy.FIXED and cfg.retry.enabled
    assert cfg.seed == 3


def test_policy_and_scale_presets_resolve():
    assert not get_scenario("no-auto-retry").to_campaign_config().retry.enabled
    assert get_scenario("xid-branch").to_campaign_config().retry.policy \
        is RetryPolicy.XID_BRANCH
    assert get_scenario("smart-retry").to_campaign_config() \
        .retry.structural_stop
    big = get_scenario("big-cluster-252").to_campaign_config()
    assert (big.n_nodes, big.job_nodes) == (252, 240)
    assert big.mtbf_h == pytest.approx(56.2 * 63 / 252)


def test_young_daly_strategy_sets_optimal_interval():
    cfg = get_scenario("young-daly").to_campaign_config()
    assert cfg.checkpoint_interval_h == pytest.approx(44.9 / 60.0, rel=0.01)


def test_storage_model_drives_checkpoint_delta():
    sc = get_scenario("storage-degraded")
    base = sc.replace(storage_degradation=1.0)
    assert sc.resolve_delta_s() > 2 * base.resolve_delta_s()
    cfg = sc.to_campaign_config()
    assert cfg.checkpoint_save_s == pytest.approx(sc.resolve_delta_s())
    assert cfg.loading_time_h == pytest.approx(4.0 * 31.0 / 60.0)
    # Young-Daly stretches the interval to match the slower saves
    # (T_opt ~ sqrt(delta): 4x the service time -> ~2x the interval)
    assert cfg.checkpoint_interval_h > 1.5 * base.resolve_interval_h()


def test_kind_weights_tilt_mix():
    sc = get_scenario("flaky-fabric")
    evs = ClusterSim(sc.replace(duration_days=600)
                     .to_campaign_config(seed=0)).run().failures
    xids = [e.xid for e in evs if e.kind == "xid"]
    nvlink = sum(1 for x in xids if x in (145, 149))
    assert nvlink / max(len(xids), 1) > 0.5      # baseline mix: ~45%


# ---------------------------------------------------------------------------
# sweep runner
# ---------------------------------------------------------------------------

def _strip_wall(outcomes):
    return [(o.scenario, o.seed,
             {k: v for k, v in o.findings.items() if k != "wall_s"})
            for o in outcomes]


def test_sweep_deterministic_across_runs_and_executors():
    scs = [get_scenario(n).replace(duration_days=7.0)
           for n in ("paper-faithful", "no-auto-retry")]
    a = SweepRunner(scs, seeds=(0, 1), executor="serial").run()
    b = SweepRunner(scs, seeds=(0, 1), executor="serial").run()
    c = SweepRunner(scs, seeds=(0, 1), executor="thread").run()
    assert _strip_wall(a.outcomes) == _strip_wall(b.outcomes)
    assert _strip_wall(a.outcomes) == _strip_wall(c.outcomes)
    assert len(a.outcomes) == 4


def test_sweep_aggregate_and_report(tmp_path):
    scs = [get_scenario(n).replace(duration_days=5.0)
           for n in ("paper-faithful", "smart-retry")]
    res = SweepRunner(scs, seeds=(0,), executor="serial").run()
    agg = res.aggregate()
    assert set(agg) == {"paper-faithful", "smart-retry"}
    assert 0.0 <= agg["paper-faithful"]["occupancy"] <= 1.0
    table = res.comparison_table()
    assert "paper-faithful" in table and "| paper" in table
    md = res.write(tmp_path / "sweep.md")
    assert (tmp_path / "sweep.md").read_text() == md
    assert "F1-F4 comparison" in md


def test_run_campaign_f1_subcampaign():
    sc = get_scenario("paper-faithful").replace(
        duration_days=2.0, telemetry_days=1.0, telemetry_pad_metrics=8)
    out = run_campaign(sc.to_dict(), seed=11)
    f = out["findings"]
    assert {"f1_detection_rate", "f1_fp_per_day"} <= set(f)
    assert f["f1_fp_per_day"] >= 0.0


def test_sweep_rejects_bad_inputs():
    with pytest.raises(ValueError, match="duplicate"):
        SweepRunner(["paper-faithful", "paper-faithful"])
    with pytest.raises(ValueError, match="executor"):
        SweepRunner(["paper-faithful"], executor="gpu")


# ---------------------------------------------------------------------------
# engines: parity + golden headline numbers
# ---------------------------------------------------------------------------

def test_event_and_tick_engines_agree():
    """Same seed -> identical failure schedule; campaign aggregates land
    within statistical tolerance of each other (the engines quantize event
    times differently but share the state machine)."""
    cfg = CampaignConfig(duration_h=14 * 24.0, seed=4)
    ev = ClusterSim(cfg).run()
    tk = ClusterSim(CampaignConfig(duration_h=14 * 24.0, seed=4,
                                   engine="tick")).run()
    assert [f.time_h for f in ev.failures] == [f.time_h for f in tk.failures]
    assert abs(ev.training_occupancy() - tk.training_occupancy()) < 0.05
    assert abs(ev.checkpoint_events - tk.checkpoint_events) \
        <= max(3, 0.1 * tk.checkpoint_events)
    assert len(ev.chains) == len(tk.chains)


def test_event_engine_campaign_invariants():
    res = ClusterSim(CampaignConfig(duration_h=21 * 24.0, seed=7)).run()
    for s in res.sessions:
        assert s.is_terminal and len(s.nodes) == 60
    for c in res.chains:
        for a in c.attempts[:-1]:
            assert a.end_h is not None
        for prev, nxt in zip(c.attempts, c.attempts[1:]):
            assert nxt.start_h >= (prev.end_h or prev.start_h) - 1e-9
    assert all(d["hours"] >= 0 for d in res.downtimes)
    assert res.checkpoint_events > 0


def test_golden_paper_faithful_f3_f4():
    """The refactored engine still reproduces the seed's F3/F4 headline
    numbers on the paper-faithful scenario (same bounds as the seed's
    system test, plus the F3 concentration check)."""
    sc = get_scenario("paper-faithful")
    succ = ch = 0
    gaps, top3 = [], []
    for seed in (0, 5):
        res = ClusterSim(sc.to_campaign_config(seed)).run()
        st = chain_stats(res.retry_chains())
        succ += st["success"]
        ch += st["n_chains"]
        gaps += [g for c in res.retry_chains() for g in c.gaps_min()]
        top3.append(res.exclusions.summary()["top3_share"])
    assert 0.1 < succ / max(ch, 1) < 0.8        # paper: 0.333
    assert abs(np.median(gaps) - 11.0) < 2.0    # paper: 11 min (IQR 10-11)
    assert np.mean(top3) > 0.4                  # paper F3: >50% on 3 nodes


# ---------------------------------------------------------------------------
# batched telemetry building blocks
# ---------------------------------------------------------------------------

def test_tick_batch_matches_signature_semantics():
    from repro.core.failures import FailureEvent
    from repro.telemetry.exporters import ExporterSuite, NodeStateBatch

    suite = ExporterSuite(8, seed=0, n_pad=4)
    T = 16
    ts = np.arange(T) * (30.0 / 3600.0)
    batch = NodeStateBatch.constant(T, 8, training=np.ones(8))
    ev = FailureEvent(time_h=float(ts[5]), node=3, kind="xid", xid=145)
    snap = suite.tick_batch(ts, batch, [(5, ev)])
    assert snap["node_intr_total"].shape == (T, 8)
    # NVLink signature only on the pinned tick (paper Fig 2)
    assert snap["node_intr_total"][5, 3] < 150e3
    assert snap["node_procs_running"][5, 3] == 0
    assert snap["DCGM_FI_DEV_XID_ERRORS"][5, 3] == 145
    assert np.all(snap["DCGM_FI_DEV_XID_ERRORS"][:5] == 0)
    healthy = np.delete(snap["node_intr_total"][5], 3)
    assert np.all(healthy > 250e3)
    # persistent counters are monotone within the batch and persist across
    # calls
    corr = snap["DCGM_FI_DEV_ROW_REMAP_CORRECTABLE"]
    assert np.all(np.diff(corr, axis=0) >= 0)
    snap2 = suite.tick_batch(ts + 1.0, batch)
    assert np.all(snap2["DCGM_FI_DEV_ROW_REMAP_CORRECTABLE"][0]
                  >= corr[-1])


def test_tick_batch_unreachable_zeroes_node():
    from repro.core.failures import FailureEvent
    from repro.telemetry.exporters import ExporterSuite, NodeStateBatch

    suite = ExporterSuite(4, seed=1, n_pad=0)
    batch = NodeStateBatch.constant(3, 4, training=np.ones(4))
    ev = FailureEvent(time_h=0.0, node=2, kind="unreachable")
    snap = suite.tick_batch(np.array([0.0, 0.01, 0.02]), batch, [(0, ev)])
    assert snap["DCGM_FI_DEV_GPU_UTIL"][0, 2] == 0.0
    assert snap["backendai_agent_heartbeat_age_s"][0, 2] == 600.0


def test_store_batch_and_single_append_interleave():
    from repro.telemetry.registry import TimeSeriesStore

    store = TimeSeriesStore(4)
    store.append(0.0, {"m": np.arange(4.0)})
    store.append_batch(np.array([1.0, 2.0]),
                       {"m": np.arange(8.0).reshape(2, 4)})
    store.append(3.0, {"m": np.full(4, 9.0)})
    s = store.series("m")
    assert s.shape == (4, 4)
    np.testing.assert_array_equal(s[0], np.arange(4.0))
    np.testing.assert_array_equal(s[3], np.full(4, 9.0))
    w = store.window("m", 1.0, 3.0)
    assert w.shape == (2, 4)
    np.testing.assert_array_equal(store.times(), [0.0, 1.0, 2.0, 3.0])
    assert store.nbytes() > 0


def test_event_engine_telemetry_feeds_detector():
    """End-to-end: batched telemetry from the event engine is scannable and
    the injected XID signatures alarm on the right node."""
    from repro.core.precursor import DetectorConfig, PrecursorDetector

    res = ClusterSim(CampaignConfig(duration_h=36.0, telemetry=True,
                                    telemetry_pad_metrics=16,
                                    seed=11)).run()
    assert len(res.store.ticks) == int(36.0 * 3600 / 30)
    alarms = PrecursorDetector(DetectorConfig()).scan(res.store)
    xid_fails = [f for f in res.failures if f.kind == "xid"]
    if xid_fails:                                  # seed 11: present
        hit_nodes = {a.node for a in alarms}
        assert any(f.node in hit_nodes for f in xid_fails)
