"""End-to-end behaviour tests for the paper's system (detect -> recover).

The paper's pipeline: telemetry -> precursor/XID detection -> classification
-> isolation/retry -> checkpoint resume.  This test drives the whole chain
on a small simulated campaign plus a real training session.
"""
import numpy as np


def test_detect_to_recover_pipeline(tmp_path):
    """The titular pipeline, end to end, on real training state."""
    from repro.core.xid import classify, requires_isolation
    from repro.core.retry import RetryConfig, RetryEngine, RetryPolicy
    from repro.core.scheduler import GangScheduler
    from repro.core.session import Session
    from repro.launch.train import run_training

    # 1. DETECT + CLASSIFY: an NVLink XID arrives
    assert classify(145) is not None
    assert requires_isolation(145)

    # 2. ISOLATE: the scheduler pulls the node, spares keep the gang whole
    sched = GangScheduler(n_nodes=63)
    s = Session(task_name="t", n_nodes=60)
    assert sched.try_allocate(s, 0.0)
    victim = s.nodes[0]
    sched.release(s, 1.0)
    sched.mark_down(victim, 1.0, "xid=145")
    s2 = Session(task_name="t", n_nodes=60)
    assert sched.try_allocate(s2, 1.1)           # 62 healthy >= 60
    assert victim not in s2.nodes

    # 3. RETRY policy fires per Table 3
    eng = RetryEngine(RetryConfig(policy=RetryPolicy.XID_BRANCH))
    assert eng.next_delay_min(1, xid=145) is not None

    # 4. RECOVER: real training resumes from the checkpoint and completes
    rep = run_training("stablelm-3b", steps=20, batch=2, seq=32,
                       ckpt_dir=str(tmp_path), fail_at=(9,), fail_xid=145,
                       verbose=False)
    assert rep.steps_done == 20
    assert rep.n_restarts == 1
    assert np.isfinite(rep.final_loss)


def test_campaign_reproduces_paper_headline_numbers():
    """Four findings, one campaign (abbreviated seeds; the benchmark suite
    runs the full version)."""
    from repro.core.cluster import CampaignConfig, ClusterSim
    from repro.core.retry import chain_stats

    succ = ch = 0
    gaps = []
    for seed in (0, 5):
        res = ClusterSim(CampaignConfig(seed=seed)).run()
        st = chain_stats(res.retry_chains())
        succ += st["success"]
        ch += st["n_chains"]
        gaps += [g for c in res.retry_chains() for g in c.gaps_min()]
    rate = succ / max(ch, 1)
    assert 0.1 < rate < 0.8                      # paper: 0.333
    assert abs(np.median(gaps) - 11.0) < 2.0     # paper: 11 min (IQR 10-11)
