"""StorageFabric: the scale-emergent F2 bottleneck (acceptance criteria),
engine parity, fabric-derived per-client/campaign/scenario integration,
and the storage-anomaly telemetry vote."""
import numpy as np
import pytest

from repro.storage import (FabricConfig, StorageFabric, STD_READ_SLOTS,
                           STD_WRITE_SLOTS)


# ---------------------------------------------------------------------------
# the paper's F2 numbers, derived
# ---------------------------------------------------------------------------

def test_utilization_collapse_at_63_clients():
    """Acceptance: 63-client aggregate utilization within +-5 points of the
    paper's 21.5% (read) / 16.0% (write)."""
    fab = StorageFabric()
    assert fab.utilization("read", 63) == pytest.approx(0.215, abs=0.05)
    assert fab.utilization("write", 63) == pytest.approx(0.160, abs=0.05)


def test_small_scale_near_linear():
    """Acceptance: 2-4-client runs achieve >=3x the 63-client utilization
    fraction, and aggregate bandwidth scales ~linearly 2 -> 4."""
    fab = StorageFabric()
    for op in ("read", "write"):
        u63 = fab.utilization(op, 63)
        assert fab.utilization(op, 2) >= 3 * u63
        assert fab.utilization(op, 4) >= 3 * u63
        agg2 = 2 * fab.per_client_bandwidth_bytes_s(op, 2)
        agg4 = 4 * fab.per_client_bandwidth_bytes_s(op, 4)
        assert agg4 == pytest.approx(2 * agg2, rel=0.1)


def test_table13_service_times_emerge():
    """The paper's Table 13 per-RPC service times are the fabric's
    effective values at the campaign fanins, not free constants."""
    fab = StorageFabric()
    read = fab.service_time_s("read", 60, STD_READ_SLOTS)
    write = fab.service_time_s("write", 39, STD_WRITE_SLOTS)
    assert read == pytest.approx(0.0273, rel=0.05)
    assert write == pytest.approx(0.126, rel=0.05)


def test_scaling_curve_shape():
    fab = StorageFabric()
    curve = fab.scaling_curve("read", (2, 4, 8, 16, 32, 63))
    utils = [r["utilization"] for r in curve]
    # monotone-nonincreasing utilization; big drop between 4 and 63 nodes
    assert all(a >= b - 1e-12 for a, b in zip(utils, utils[1:]))
    assert utils[1] > 3 * utils[-1]
    # service time inflates with fanin
    svcs = [r["service_ms"] for r in curve]
    assert svcs[-1] > 5 * svcs[0]


def test_degradation_scales_service_not_ceiling():
    base = StorageFabric()
    bad = StorageFabric(FabricConfig(degradation=4.0))
    assert bad.service_time_s("write", 60) == pytest.approx(
        4.0 * base.service_time_s("write", 60), rel=1e-6)
    # the nominal server maxima (utilization denominators) are untouched
    assert bad.ceiling_bytes_s("read", 63) == base.ceiling_bytes_s("read", 63)
    assert bad.utilization("read", 63) < base.utilization("read", 63)


def test_client_link_floor():
    """A single unloaded client is bounded by its own link, never above."""
    fab = StorageFabric()
    for op in ("read", "write"):
        bw = fab.per_client_bandwidth_bytes_s(op, 1)
        assert bw <= fab.config.client_link_bw * (1 + 1e-9)


# ---------------------------------------------------------------------------
# simulation engines
# ---------------------------------------------------------------------------

def test_vectorized_matches_event_reference():
    """Acceptance: vectorized sim within 5% of the event-driven reference
    on the 63-node load scenario (and on a write burst)."""
    fab = StorageFabric()
    vec = fab.simulate("read", 63, 2 << 30, engine="vectorized", seed=0)
    ev = fab.simulate("read", 63, 2 << 30, engine="event", seed=0)
    assert vec.duration_s == pytest.approx(ev.duration_s, rel=0.05)
    assert vec.mean_service_s == pytest.approx(ev.mean_service_s, rel=0.05)

    vecw = fab.simulate("write", 16, 4 << 30, engine="vectorized", seed=1)
    evw = fab.simulate("write", 16, 4 << 30, engine="event", seed=1)
    assert vecw.duration_s == pytest.approx(evw.duration_s, rel=0.05)


def test_simulation_matches_analytic_utilization():
    fab = StorageFabric()
    sim = fab.simulate("read", 63, 4 << 30, engine="vectorized", seed=2)
    assert sim.utilization == pytest.approx(
        fab.utilization("read", 63), rel=0.10)
    assert sim.n_rpcs_per_client == (4 << 30) // (256 << 10)
    assert len(sim.per_client_duration_s) == 63
    assert sim.duration_s == sim.per_client_duration_s.max()


def test_expected_duration_floor_for_sub_wave_transfers():
    """A transfer smaller than one slot-table wave still costs at least a
    full RPC service round — the analytic query must agree with the
    simulation engines at small sizes too."""
    fab = StorageFabric()
    t_svc = fab.service_time_s("write", 60)
    est = fab.expected_duration_s("write", 60, 16 << 20)   # 16 RPCs < slots
    # pre-fix this returned n_rpcs/slots * t_svc ~ t_svc/8, physically
    # impossible; the estimate is a mean, so the jittered makespan across
    # 60 clients sits somewhat above it (extreme-value tail), never 8x
    assert t_svc <= est < 2 * t_svc
    sim = fab.simulate("write", 60, 16 << 20, engine="event", seed=0)
    assert est <= sim.duration_s < 3 * est


def test_simulate_deterministic_and_validates():
    fab = StorageFabric()
    a = fab.simulate("read", 8, 256 << 20, seed=5)
    b = fab.simulate("read", 8, 256 << 20, seed=5)
    assert a.duration_s == b.duration_s
    with pytest.raises(ValueError, match="engine"):
        fab.simulate("read", 4, 1 << 20, engine="gpu")
    with pytest.raises(ValueError, match="unknown op"):
        fab.service_time_s("append", 4)


def test_telemetry_levels_rise_with_fanin_and_degradation():
    fab = StorageFabric()
    lo = fab.telemetry_levels(4)
    hi = fab.telemetry_levels(60)
    for k in ("save_queue_depth", "load_queue_depth",
              "save_backlog_bytes", "load_backlog_bytes"):
        assert hi[k] >= lo[k] > 0
    # a degraded server holds requests in queue longer: the exported
    # levels must deviate from a healthy campaign's
    bad = StorageFabric(FabricConfig(degradation=4.0)).telemetry_levels(60)
    assert bad["save_queue_depth"] > 2 * hi["save_queue_depth"]
    assert bad["load_backlog_bytes"] > 2 * hi["load_backlog_bytes"]


# ---------------------------------------------------------------------------
# per-client view (checkpoint/storage.py)
# ---------------------------------------------------------------------------

def test_nfs_client_service_times_derived_from_fabric():
    from repro.checkpoint.storage import NFSClientSim, NFSConfig

    sim = NFSClientSim(seed=0)
    # defaults resolve to the fabric's Table-13-effective values
    assert sim.config.read_service_s == pytest.approx(0.0273, rel=0.05)
    assert sim.config.write_service_s == pytest.approx(0.126, rel=0.05)
    # explicit values (degraded scenarios) bypass the derivation
    pinned = NFSClientSim(NFSConfig(write_service_s=0.5, read_service_s=0.1))
    assert pinned.config.write_service_s == 0.5
    # a degraded fabric propagates into the per-client view
    slow = NFSClientSim(fabric=StorageFabric(FabricConfig(degradation=2.0)))
    assert slow.config.write_service_s == pytest.approx(
        2 * sim.config.write_service_s, rel=1e-6)


def test_checkpoint_load_does_not_mutate_shared_config():
    """The nconnect=2 load path must be a per-call override: a concurrent
    save from the manager's flush thread reads the same config."""
    from repro.checkpoint.storage import NFSClientSim

    sim = NFSClientSim(seed=0)
    before = sim.config
    res = sim.checkpoint_load(bytes_per_node=1 << 30)
    assert sim.config is before            # literally untouched
    assert sim.config.n_connections == 1
    assert res.n_rpcs == (1 << 30) // (256 << 10)


def test_transfer_accepts_raw_config_override():
    """A per-call config built from scratch (service times unresolved)
    must resolve against the fabric, not crash on None."""
    from repro.checkpoint.storage import NFSClientSim, NFSConfig

    sim = NFSClientSim(seed=0)
    res = sim.transfer("write", 8 << 20, config=NFSConfig(n_slots=256))
    assert res.n_rpcs == 8
    assert res.duration_s > 0


# ---------------------------------------------------------------------------
# campaign + scenario integration
# ---------------------------------------------------------------------------

def test_campaign_derives_checkpoint_timing_from_fabric():
    from repro.core.cluster import CampaignConfig, ClusterSim

    sim = ClusterSim(CampaignConfig(duration_h=24.0, seed=0,
                                    storage=FabricConfig()))
    # gang-fanin fabric queries land near the paper's observed constants
    assert 10.0 < sim.cfg.checkpoint_save_s < 25.0        # paper 18-31.7 s
    assert sim.cfg.loading_time_h == pytest.approx(31.0 / 60.0, rel=0.05)
    assert sim.cfg.loading_cold_h == pytest.approx(58.0 / 60.0, rel=0.05)
    res = sim.run()
    assert res.duration_h == 24.0


def test_campaign_fabric_telemetry_exports_storage_series():
    from repro.core.cluster import CampaignConfig, ClusterSim

    res = ClusterSim(CampaignConfig(duration_h=12.0, seed=3, telemetry=True,
                                    telemetry_pad_metrics=4,
                                    storage=FabricConfig())).run()
    names = res.store.names
    assert "node_mountstats_nfs_rpc_queue_depth" in names
    assert "node_netstat_Tcp_transport_backlog_bytes" in names
    q = res.store.series("node_mountstats_nfs_rpc_queue_depth")
    b = res.store.series("node_netstat_Tcp_transport_backlog_bytes")
    # queueing and transport backlog rise TOGETHER during save bursts
    # (paper F2): ticks where queue depth spikes see backlog spike too
    spikes = q > 100.0
    if spikes.any():
        assert (b[spikes] > 1e7).mean() > 0.9


def test_scenario_storage_fabric_resolution():
    from repro.ops import Scenario, get_scenario

    sc = get_scenario("storage-fabric")
    rt = Scenario.from_dict(sc.to_dict())
    assert rt == sc
    cfg = sc.to_campaign_config(seed=1)
    assert cfg.storage is not None
    # fabric-derived save duration: the ckpt_pack bf16 wire volume (10 GiB)
    # bursting from 60 writers
    assert sc.resolve_delta_s() == pytest.approx(
        sc.fabric().expected_duration_s("write", 60, 10 << 30))
    deg = get_scenario("storage-fabric-degraded")
    assert deg.resolve_delta_s() > 2 * sc.resolve_delta_s()


def test_storage_slots_lever_works_in_fabric_mode():
    """The F2 'doubling slots' lever must reach the fabric queries, not
    just the legacy per-client path."""
    from repro.core.cluster import ClusterSim
    from repro.ops import get_scenario

    sc = get_scenario("storage-fabric")
    wide = sc.replace(storage_slots=256)
    # at 60-writer fanin the server is contended: more slots per client
    # deepens the queue, so the save does NOT speed up linearly — but the
    # timing must respond to the knob
    assert wide.resolve_delta_s() != sc.resolve_delta_s()
    cs = ClusterSim(sc.to_campaign_config(0))
    cw = ClusterSim(wide.to_campaign_config(0))
    assert cw.cfg.checkpoint_save_s == pytest.approx(
        wide.resolve_delta_s(), rel=1e-6)
    assert cw.cfg.checkpoint_save_s != cs.cfg.checkpoint_save_s


def test_sweep_reports_f2_for_fabric_scenarios():
    from repro.ops import SweepRunner, get_scenario

    scs = [get_scenario("storage-fabric").replace(duration_days=2.0)]
    res = SweepRunner(scs, seeds=(0,), executor="serial").run()
    agg = res.aggregate()["storage-fabric"]
    assert agg["f2_load_util"] == pytest.approx(0.215, abs=0.05)
    assert agg["f2_save_util"] == pytest.approx(0.160, abs=0.05)
    md = res.to_markdown()
    assert "F2 storage fabric" in md
    assert "21.5" in md


# ---------------------------------------------------------------------------
# detector votes on storage anomalies
# ---------------------------------------------------------------------------

def test_precursor_detector_votes_on_storage_metrics():
    """A node whose RPC queue depth and transport backlog deviate from the
    peer cohort alarms through the standard multi-signal vote."""
    from repro.core.precursor import DetectorConfig, PrecursorDetector
    from repro.telemetry.registry import TimeSeriesStore

    n_nodes, n_ticks, bad = 8, 12, 3
    store = TimeSeriesStore(n_nodes)
    rng = np.random.default_rng(0)
    for t in range(n_ticks):
        util = np.full(n_nodes, 95.0) + rng.normal(0, 0.3, n_nodes)
        q = 2.0 + rng.normal(0, 0.1, n_nodes)
        b = 1e4 + rng.normal(0, 300.0, n_nodes)
        if t >= 6:
            q[bad] = 250.0                  # fabric-level queueing
            b[bad] = 2.6e8                  # transport backlog, together
        store.append(t * 30.0 / 3600.0, {
            "DCGM_FI_DEV_GPU_UTIL": util,
            "node_mountstats_nfs_rpc_queue_depth": q,
            "node_netstat_Tcp_transport_backlog_bytes": b,
        })
    det = PrecursorDetector(DetectorConfig(min_signals=2))
    alarms = det.scan(store)
    assert any(a.node == bad for a in alarms)
    top = {m for a in alarms if a.node == bad for m, _ in a.top_metrics}
    assert "node_mountstats_nfs_rpc_queue_depth" in top
