"""Straggler detection + elastic allocation (beyond-paper features)."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.scheduler import GangScheduler
from repro.core.session import Session
from repro.core.straggler import StragglerConfig, StragglerDetector


def test_straggler_flags_sustained_slow_node():
    det = StragglerDetector(8, StragglerConfig(sustain=4))
    rng = np.random.default_rng(0)
    reports = []
    for step in range(40):
        t = rng.normal(1.0, 0.02, 8)
        if step >= 20:
            t[5] *= 1.4                 # node 5 degrades at step 20
        reports += det.observe(t)
    assert reports and reports[0].node == 5
    assert 20 < reports[0].step <= 20 + 10
    assert det.job_slowdown() > 1.1


def test_straggler_no_false_flags_on_noise():
    det = StragglerDetector(16, StragglerConfig(sustain=4))
    rng = np.random.default_rng(1)
    reports = []
    for _ in range(60):
        reports += det.observe(rng.normal(1.0, 0.03, 16))
    assert reports == []


def test_straggler_transient_blip_not_flagged():
    det = StragglerDetector(8, StragglerConfig(sustain=6))
    rng = np.random.default_rng(2)
    reports = []
    for step in range(40):
        t = rng.normal(1.0, 0.02, 8)
        if step in (15, 16):            # 2-step GC pause, not sustained
            t[3] *= 1.5
        reports += det.observe(t)
    assert reports == []


@given(degrade=st.floats(1.2, 3.0))
@settings(max_examples=20, deadline=None)
def test_job_slowdown_tracks_worst_node(degrade):
    det = StragglerDetector(8)
    for _ in range(20):
        t = np.ones(8)
        t[0] = degrade
        det.observe(t)
    assert det.job_slowdown() == pytest.approx(degrade, rel=0.05)


# ---------------------------------------------------------------------------
# elastic allocation
# ---------------------------------------------------------------------------

def test_elastic_allocation_degrades_width():
    sched = GangScheduler(n_nodes=63)
    for i in range(6):                   # 6 nodes down -> 57 free < 60
        sched.mark_down(i, 0.0, "x")
    s = Session(task_name="t", n_nodes=60)
    assert not sched.try_allocate(s, 0.0)          # strict gang fails
    s2 = Session(task_name="t", n_nodes=60)
    assert sched.try_allocate_elastic(s2, 0.0, min_nodes=48)
    assert len(s2.nodes) == 57                     # got everything available
    assert s2.n_nodes == 57


def test_elastic_respects_minimum():
    sched = GangScheduler(n_nodes=10)
    for i in range(8):
        sched.mark_down(i, 0.0, "x")
    s = Session(task_name="t", n_nodes=8)
    assert not sched.try_allocate_elastic(s, 0.0, min_nodes=4)
    assert s.nodes == []
