"""a2a MoE dispatch: exactness vs the dense dispatch and differentiability
(8 fake devices in a subprocess — the main test process keeps 1 device)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs.base import MoESpec
from repro.models.moe import init_moe, moe_ffn
from repro.models.moe_a2a import moe_ffn_a2a

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = MoESpec(n_experts=8, top_k=2, d_expert=16, n_shared=1)
p = init_moe(jax.random.PRNGKey(0), 32, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
with mesh:
    ref, _ = moe_ffn(x, p, spec)
    out, _ = jax.jit(lambda x, p: moe_ffn_a2a(x, p, spec, mesh,
                                              slack=8.0))(x, p)
    def loss(p):
        o, _ = moe_ffn_a2a(x, p, spec, mesh, slack=8.0)
        return jnp.sum(o ** 2)
    g = jax.jit(jax.grad(loss))(p)
err = float(jnp.max(jnp.abs(out - ref)))
gnorm = float(jnp.linalg.norm(g["w_gate"]))
print(json.dumps({"err": err, "gnorm": gnorm}))
"""


@pytest.mark.slow
def test_moe_a2a_exact_and_differentiable():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5
    assert res["gnorm"] > 0
