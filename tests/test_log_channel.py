"""Log channel (L4-style diagnosis): emitter, analyzer, fusion, parity.

Covers the PR-8 contracts:

* template extraction / burst-rarity scoring / cross-node attribution
  unit behaviour;
* the off-gate: with ``log_channel=False`` (every pre-existing preset)
  the log subsystem is never even constructed — bit-identity with
  pre-log-channel campaigns by construction;
* 8-seed bitwise batch==scalar parity for log-fusion campaigns (alarm
  streams, control ledger, findings);
* the acceptance delta: across >= 8 Monte Carlo seeds, fusing the log
  channel improves median time-to-detection and does not increase false
  drains vs the metric-only twin on identical schedules.
"""
import dataclasses

import pytest

from repro.core.batch import BatchedCampaignEngine
from repro.core.cluster import ClusterSim
from repro.core.failures import FailureEvent
from repro.logs.analysis import LogAnalyzer, LogChannelConfig
from repro.logs.emitter import LogEmitter, LogLine
from repro.ops.scenario import PRESETS, get_scenario
from repro.ops.sweep import SweepRunner, compute_findings


# ---------------------------------------------------------------- analyzer

def test_template_masking_interns_variables():
    an = LogAnalyzer()
    a = an.template("ERROR NVRM: Xid (PCI:0000:b1:00): 79, pid=4242")
    b = an.template("ERROR NVRM: Xid (PCI:0000:a0:00): 145, pid=17")
    c = an.template("WARN rpc: retransmit threshold exceeded, 30 ops")
    assert a is b                       # digits/hex masked to one template
    assert c is not a
    assert an.n_templates == 2
    assert a.level_w == 3.0 and c.level_w == 1.0
    assert c.name.startswith("log:net:")
    assert a.name.startswith("log:node:")


def test_root_cause_attribution_via_references():
    """58 peers shouting about node-7 indict node 7, not the peers."""
    an = LogAnalyzer(LogChannelConfig(warmup_h=0.0))
    lines = [LogLine(0.1 + 1e-4 * i, peer,
                     "ERROR NCCL: connect to node-7 failed: timeout")
             for i, peer in enumerate(range(8, 20))]
    verdicts = an.ingest(lines, t1=0.25)
    assert len(verdicts) == 1
    v = verdicts[0]
    assert v.node == 7
    assert v.top and v.top[0][0].startswith("log:node:")
    assert abs(v.time_h - 0.1) < 1e-6   # earliest referencing line


def test_noise_never_verdicts_after_warmup():
    em = LogEmitter(n_nodes=63, seed=5, noise_per_node_h=2.0)
    an = LogAnalyzer()
    verdicts = []
    t = 0.0
    while t < 12.0:
        lines = em.emit_window(t, t + 1.0, gang=range(60))
        verdicts += an.ingest(lines, t + 1.0)
        t += 1.0
    assert verdicts == []               # INFO/WARN chatter stays silent


def test_window_buffering_across_chunk_boundaries():
    """A window straddling two ingests scores once, identically."""
    cfg = LogChannelConfig(warmup_h=0.0)
    lines = [LogLine(0.25 + 1e-3 * i, 3,
                     "ERROR kernel: page allocation stall for 900 ms")
             for i in range(4)]
    whole = LogAnalyzer(cfg).ingest(list(lines), t1=0.5)
    an = LogAnalyzer(cfg)
    split = an.ingest(lines[:2], t1=0.3)    # window [0.25, 0.5) incomplete
    assert split == []
    split = an.ingest(lines[2:], t1=0.5)
    assert [(v.node, v.time_h, v.score, v.top) for v in split] == \
           [(v.node, v.time_h, v.score, v.top) for v in whole]


# ----------------------------------------------------------------- emitter

def _ev(**kw):
    base = dict(time_h=2.0, node=4, kind="xid", xid=79)
    base.update(kw)
    return FailureEvent(**base)


def test_emitter_deterministic_per_seed():
    def lines_for(seed):
        em = LogEmitter(n_nodes=16, seed=seed)
        em.register_failure(_ev())
        em.register_failure(_ev(time_h=3.0, node=7, kind="net_degrade",
                                xid=None, window_h=1.0))
        out = []
        for k in range(8):
            out += em.emit_window(k * 0.5, (k + 1) * 0.5, gang=range(12))
        return out
    a, b, c = lines_for(1), lines_for(1), lines_for(2)
    assert a == b
    assert a != c


def test_emitter_fault_programs_and_gang_expansion():
    em = LogEmitter(n_nodes=16, seed=0, noise_per_node_h=0.0)
    em.register_failure(_ev(kind="unreachable", xid=None))
    lines = em.emit_window(0.0, 4.0, gang=[1, 2, 4, 9])
    peer_lines = [ln for ln in lines if "node-4" in ln.text
                  and ln.node != -1]
    # every gang member except the dead node reports it
    assert sorted({ln.node for ln in peer_lines}) == [1, 2, 9]
    assert any(ln.node == -1 for ln in lines)        # controller line
    assert all(ln.node != 4 for ln in peer_lines)    # the node is silent


def test_emitter_registration_after_emit_rejected():
    em = LogEmitter(n_nodes=4, seed=0)
    em.emit_window(0.0, 1.0, gang=[])
    with pytest.raises(RuntimeError):
        em.register_failure(_ev())


# ---------------------------------------------------------------- off gate

def test_log_channel_off_never_constructs_subsystem(monkeypatch):
    """With the gate off the emitter/analyzer are never constructed, so
    pre-existing campaigns cannot be perturbed — enforced by making
    construction explode."""
    def boom(*a, **kw):
        raise AssertionError("log subsystem constructed with gate off")
    monkeypatch.setattr("repro.control.policy.LogEmitter", boom)
    monkeypatch.setattr("repro.control.policy.LogAnalyzer", boom)
    for name in ("proactive", "infra-faults"):
        sc = dataclasses.replace(get_scenario(name), duration_days=2.0,
                                 telemetry_pad_metrics=16)
        res = ClusterSim(sc.to_campaign_config(seed=3)).run()
        assert res.control is not None


def test_only_log_fusion_presets_enable_the_gate():
    on = {name for name, sc in PRESETS.items() if sc.log_channel}
    assert on == {"log-fusion", "correlated-recovery"}
    assert PRESETS["log-fusion-off"].control_plane
    # the twin differs from log-fusion only on the gate (and naming)
    a = PRESETS["log-fusion-off"].to_dict()
    b = PRESETS["log-fusion"].to_dict()
    diff = {k for k in a if a[k] != b[k]}
    assert diff == {"name", "description", "log_channel"}


def test_log_channel_requires_control_plane():
    with pytest.raises(ValueError, match="log_channel"):
        dataclasses.replace(get_scenario("reactive"), log_channel=True)


# ------------------------------------------------------- batch == scalar

def _parity_cfg():
    sc = dataclasses.replace(get_scenario("log-fusion"), duration_days=2.0,
                             mtbf_h=12.0, telemetry_pad_metrics=24)
    return sc.to_campaign_config(seed=0)


def test_batch_scalar_parity_8_seeds():
    cfg = _parity_cfg()
    seeds = list(range(8))
    batch = BatchedCampaignEngine(cfg).run(seeds)
    saw_log_alarm = saw_drain = False
    for i, s in enumerate(seeds):
        ref = ClusterSim(dataclasses.replace(cfg, seed=s)).run()
        got = batch[i]
        ra, ga = ref.control.alarms, got.control.alarms
        assert len(ra) == len(ga)
        for x, y in zip(ra, ga):
            assert (x.tick, x.time_h, x.node, x.n_signals,
                    x.top_metrics) == \
                   (y.tick, y.time_h, y.node, y.n_signals, y.top_metrics)
        rs = ref.control.summarize(ref.failures, cfg.duration_h)
        gs = got.control.summarize(got.failures, cfg.duration_h)
        assert rs == gs
        assert compute_findings(ref) == compute_findings(got)
        saw_log_alarm |= rs["n_log_alarms"] > 0
        saw_drain |= rs["n_drains"] > 0
    # the parity claim is vacuous unless the log path actually fired
    assert saw_log_alarm


# -------------------------------------------------- acceptance: the delta

@pytest.mark.slow
def test_ttd_improves_false_drains_flat_over_8_seeds():
    """Across >= 8 MC seeds on identical schedules, fusing the log
    channel improves median time-to-detection and does not increase
    false drains vs the metric-only twin (SweepRunner-reported)."""
    days, mtbf, pad = 4.0, 15.0, 24
    off = dataclasses.replace(get_scenario("log-fusion-off"),
                              duration_days=days, mtbf_h=mtbf,
                              telemetry_pad_metrics=pad)
    on = dataclasses.replace(get_scenario("log-fusion"),
                             duration_days=days, mtbf_h=mtbf,
                             telemetry_pad_metrics=pad)
    result = SweepRunner([off, on], mc_seeds=8).run()
    agg = result.aggregate()
    dist = result.distribution()
    ttd_off = dist["log-fusion-off"]["ctrl_ttd_h"]
    ttd_on = dist["log-fusion"]["ctrl_ttd_h"]
    assert ttd_on["median"] < ttd_off["median"]
    assert agg["log-fusion"]["ctrl_false_drains"] <= \
        agg["log-fusion-off"]["ctrl_false_drains"]
    # the channel actually contributed alarms
    assert agg["log-fusion"]["ctrl_n_log_alarms"] > 0
    assert agg["log-fusion-off"]["ctrl_n_log_alarms"] == 0
    # and the report renders the new columns
    md = result.to_markdown()
    assert "log alarms" in md and "TTD h" in md and "false drains" in md
