"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; only launch/dryrun.py forces the 512-device host platform."""
import sys
from pathlib import Path

# allow `pytest tests/` without PYTHONPATH=src
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
