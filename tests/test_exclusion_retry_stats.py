"""Direct unit coverage for the F3/F4 statistics the sweep reports assert:
ExclusionTracker concentration (top-3 > 50% share, deliberate overlap,
per-reason breakdown) and chain_stats (33.3%-vs-12.5% success rates, the
11-minute median / IQR 10-11 retry gap) — exact on synthetic inputs,
banded on paper-faithful campaigns."""
import numpy as np
import pytest

from repro.core.cluster import CampaignConfig, ClusterSim
from repro.core.exclusion import ExclusionTracker
from repro.core.retry import Attempt, Chain, chain_stats


# ---------------------------------------------------------------------------
# ExclusionTracker: exact synthetic checks
# ---------------------------------------------------------------------------

def _tracker_with_hot_nodes():
    """8 sessions on a 10-node pool: nodes 7/8/9 are never selected (two of
    them deliberately isolated), so they collect all exclusion events."""
    tr = ExclusionTracker(n_nodes=10)
    isolated = {8: "performance degradation", 9: "predictive drain"}
    for k in range(8):
        tr.record_session(t0_h=2.0 * k, t1_h=2.0 * k + 2.0,
                          participating=[0, 1, 2, 3, 4, 5, 6],
                          isolated=isolated)
    return tr


def test_exclusion_counts_hours_exact():
    tr = _tracker_with_hot_nodes()
    counts = tr.exclusion_counts()
    hours = tr.exclusion_hours()
    np.testing.assert_array_equal(counts[:7], np.zeros(7, dtype=int))
    np.testing.assert_array_equal(counts[7:], np.full(3, 8, dtype=int))
    np.testing.assert_allclose(hours[7:], np.full(3, 16.0))
    assert len(tr.intervals) == 24


def test_top3_share_concentration_exact():
    tr = _tracker_with_hot_nodes()
    # all 24 events sit on nodes 7/8/9 -> top-3 share is exactly 1.0,
    # beyond the paper's ">50% on 3 of 63 nodes" bar
    assert tr.top_k_share(3) == pytest.approx(1.0)
    assert tr.top_k_share(1) == pytest.approx(8 / 24)
    s = tr.summary()
    assert sorted(s["top3_nodes"]) == [7, 8, 9]
    assert s["top3_share"] > 0.5
    assert s["n_intervals"] == 24
    # 2 of 3 excluded nodes are deliberate -> 16/24 of the events
    assert s["deliberate_fraction"] == pytest.approx(16 / 24)


def test_deliberate_overlap_and_reasons():
    tr = _tracker_with_hot_nodes()
    overlap = tr.deliberate_overlap()
    assert overlap[8] == pytest.approx(1.0)   # gpu086-style: ~100% overlap
    assert overlap[9] == pytest.approx(1.0)
    assert overlap[7] == pytest.approx(0.0)   # natural non-selection
    reasons = tr.by_reason()
    assert reasons["not selected"]["count"] == 8
    assert reasons["not selected"]["nodes"] == [7]
    assert reasons["predictive drain"]["nodes"] == [9]
    assert reasons["performance degradation"]["hours"] == pytest.approx(16.0)


def test_empty_tracker_degenerate_stats():
    tr = ExclusionTracker(n_nodes=4)
    assert tr.top_k_share() == 0.0
    assert tr.by_reason() == {}
    assert tr.summary()["n_intervals"] == 0


# ---------------------------------------------------------------------------
# chain_stats: exact synthetic checks (paper Table 14 / Fig 16)
# ---------------------------------------------------------------------------

def _chain(gaps_min, reached=(), first_reached=False):
    """A chain whose consecutive attempts are separated by ``gaps_min``."""
    c = Chain(task_name="t")
    t = 0.0
    n = len(gaps_min) + 1
    for i in range(n):
        a = Attempt(start_h=t,
                    reached_training=(i in reached)
                    or (i == 0 and first_reached))
        a.end_h = t + 0.05
        c.attempts.append(a)
        if i < len(gaps_min):
            t = a.end_h + gaps_min[i] / 60.0
    return c


def test_chain_stats_success_rates_exact():
    """3 retried chains with 1 success = the paper's 33.3% auto-retry rate;
    the 12.5% manual rate is 1 success in 8 one-shot restarts."""
    auto = [_chain([10.0, 11.0], reached={2}),      # SUCCESS after retries
            _chain([11.0], first_reached=True),     # failed after training
            _chain([10.5])]                         # never reached training
    st = chain_stats(auto)
    assert st["n_chains"] == 3
    assert st["success"] == 1
    assert st["chain_success_rate"] == pytest.approx(1 / 3, abs=1e-9)
    assert st["fail_after_training"] == 1
    assert st["fail_start"] == 1
    assert st["n_attempts"] == 7 and st["n_retries"] == 4

    manual = [_chain([], first_reached=(i == 0)) for i in range(8)]
    st_manual = chain_stats(manual)
    assert st_manual["chain_success_rate"] == 0.0   # no retry -> no success
    one_shot_rate = sum(c.first_reached for c in manual) / len(manual)
    assert one_shot_rate == pytest.approx(0.125)    # paper's 12.5%


def test_chain_gap_median_and_iqr_exact():
    """Fixed 10-min delay + ~1-min teardown -> 11-min median, IQR 10-11."""
    chains = [_chain([10.0, 11.0, 11.0]), _chain([10.0, 11.0])]
    st = chain_stats(chains)
    assert st["gap_median_min"] == pytest.approx(11.0)
    q25, q75 = st["gap_iqr_min"]
    assert (q25, q75) == (pytest.approx(10.0), pytest.approx(11.0))
    assert chain_stats([])["gap_median_min"] is None


def test_chain_classify_buckets():
    assert _chain([10.0], reached={1}).classify() == "SUCCESS"
    assert _chain([10.0], first_reached=True).classify() \
        == "FAIL_AFTER_TRAINING"
    assert _chain([10.0]).classify() == "FAIL_START"


# ---------------------------------------------------------------------------
# campaign-backed bands: the paper numbers emerge from the simulation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paper_campaigns():
    return [ClusterSim(CampaignConfig(seed=s)).run() for s in (0, 5, 9)]


def test_campaign_f3_top3_share_above_half(paper_campaigns):
    shares = [r.exclusions.summary()["top3_share"]
              for r in paper_campaigns]
    assert np.mean(shares) > 0.5              # paper F3: >50% on 3 nodes


def test_campaign_f4_gap_median_and_iqr(paper_campaigns):
    gaps = [g for r in paper_campaigns
            for c in r.retry_chains() for g in c.gaps_min()]
    assert abs(np.median(gaps) - 11.0) < 1.5  # paper: 11 min
    q25, q75 = np.percentile(gaps, [25, 75])
    assert 9.0 <= q25 <= 11.5                 # paper IQR: 10-11
    assert 10.0 <= q75 <= 12.5


def test_campaign_f4_auto_vs_manual_success(paper_campaigns):
    succ = ch = 0
    for r in paper_campaigns:
        st = chain_stats(r.retry_chains())
        succ += st["success"]
        ch += st["n_chains"]
    auto_rate = succ / max(ch, 1)
    assert 0.15 < auto_rate < 0.65            # paper: 33.3%
    # manual baseline: same seeds, retries disabled -> one-shot restarts
    from repro.core.retry import RetryConfig
    msucc = mch = 0
    for seed in (0, 5, 9):
        r = ClusterSim(CampaignConfig(
            seed=seed, retry=RetryConfig(enabled=False))).run()
        chains = [c for c in r.chains if c.attempts]
        mch += len(chains)
        msucc += sum(c.first_reached for c in chains)
    manual_rate = msucc / max(mch, 1)
    assert manual_rate < auto_rate            # paper: 12.5% vs 33.3%
