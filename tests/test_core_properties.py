"""Hypothesis property tests on the system's invariants."""
import math

import pytest
from _hypothesis_support import given, settings, st

from repro.checkpoint.youngdaly import (cost_fraction, mc_cost_fraction,
                                        t_opt_s)
from repro.core.retry import RetryConfig, RetryEngine, RetryPolicy
from repro.core.scheduler import GangScheduler
from repro.core.session import Session, SessionState
from repro.core.xid import XID_TABLE, Resolution, requires_isolation


# ---------------------------------------------------------------------------
# Young/Daly
# ---------------------------------------------------------------------------

@given(delta=st.floats(1.0, 300.0), mtbf=st.floats(1.0, 1000.0))
@settings(max_examples=60, deadline=None)
def test_t_opt_minimizes_cost(delta, mtbf):
    t = t_opt_s(delta, mtbf)
    c0 = cost_fraction(t, delta, mtbf)
    for factor in (0.5, 0.8, 1.25, 2.0):
        assert c0 <= cost_fraction(t * factor, delta, mtbf) + 1e-12


@given(delta=st.floats(5.0, 60.0), mtbf=st.floats(10.0, 200.0))
@settings(max_examples=10, deadline=None)
def test_analytic_cost_matches_monte_carlo(delta, mtbf):
    t = t_opt_s(delta, mtbf)
    analytic = cost_fraction(t, delta, mtbf)
    mc = mc_cost_fraction(t, delta, mtbf, n=40_000, seed=1)
    assert abs(analytic - mc) < 0.35 * analytic + 0.003


@given(delta=st.floats(1.0, 100.0), mtbf=st.floats(1.0, 500.0))
@settings(max_examples=50, deadline=None)
def test_t_opt_formula(delta, mtbf):
    assert math.isclose(t_opt_s(delta, mtbf),
                        math.sqrt(2 * delta * mtbf * 3600), rel_tol=1e-9)


# ---------------------------------------------------------------------------
# gang scheduler: all-or-nothing
# ---------------------------------------------------------------------------

@given(n_nodes=st.integers(4, 80), job=st.integers(1, 90),
       n_down=st.integers(0, 20))
@settings(max_examples=80, deadline=None)
def test_gang_all_or_nothing(n_nodes, job, n_down):
    sched = GangScheduler(n_nodes=n_nodes)
    n_down = min(n_down, n_nodes)
    for i in range(n_down):
        sched.mark_down(i, 0.0, "test")
    s = Session(task_name="t", n_nodes=job)
    ok = sched.try_allocate(s, 0.0)
    allocated = sum(1 for n in sched.nodes if n.allocated_to == s.session_id)
    if ok:
        assert allocated == job == len(s.nodes)
        # no double allocation, no unhealthy node allocated
        assert all(sched.nodes[i].healthy for i in s.nodes)
    else:
        assert allocated == 0 and s.nodes == []
        assert n_nodes - n_down < job


@given(n_jobs=st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_gang_release_restores_pool(n_jobs):
    sched = GangScheduler(n_nodes=30)
    sessions = []
    for i in range(n_jobs):
        s = Session(task_name=f"t{i}", n_nodes=7)
        if sched.try_allocate(s, 0.0):
            sessions.append(s)
    for s in sessions:
        sched.release(s, 1.0)
    assert len(sched.free_nodes()) == 30


# ---------------------------------------------------------------------------
# session FSM
# ---------------------------------------------------------------------------

def test_session_legal_lifecycle():
    s = Session(task_name="t", n_nodes=60)
    s.transition(SessionState.SCHEDULED, 0.0)
    s.transition(SessionState.PREPARING, 0.1)
    s.transition(SessionState.RUNNING, 0.6)
    assert s.reached_training
    s.transition(SessionState.TERMINATING, 5.0)
    s.transition(SessionState.TERMINATED, 5.2)
    assert s.is_terminal and s.elapsed_running_h() == pytest.approx(4.6)


@given(st.sampled_from(list(SessionState)))
@settings(max_examples=20, deadline=None)
def test_session_illegal_transitions_raise(target):
    s = Session(task_name="t", n_nodes=1)   # PENDING
    legal = {SessionState.SCHEDULED, SessionState.CANCELLED,
             SessionState.ERROR}
    if target in legal:
        s.transition(target, 0.0)
    else:
        with pytest.raises(ValueError):
            s.transition(target, 0.0)


def test_session_hang_detection():
    s = Session(task_name="t", n_nodes=60)
    s.transition(SessionState.SCHEDULED, 0.0)
    s.transition(SessionState.PREPARING, 0.0)
    assert not s.hang_check(0.5)
    assert s.hang_check(1.5)       # PREPARING limit is 1 h


# ---------------------------------------------------------------------------
# retry policies
# ---------------------------------------------------------------------------

@given(attempt=st.integers(1, 29))
@settings(max_examples=40, deadline=None)
def test_fixed_policy_constant_delay(attempt):
    eng = RetryEngine(RetryConfig(policy=RetryPolicy.FIXED))
    d = eng.next_delay_min(attempt)
    assert d == pytest.approx(11.0)   # 10 min delay + 1 min teardown


@given(attempt=st.integers(1, 29))
@settings(max_examples=40, deadline=None)
def test_backoff_monotone_and_capped(attempt):
    eng = RetryEngine(RetryConfig(policy=RetryPolicy.EXP_BACKOFF))
    d1 = eng.next_delay_min(attempt)
    d2 = eng.next_delay_min(attempt + 1)
    assert d2 >= d1
    assert d1 <= 80.0 + 1.0


def test_xid_branching_matches_table3():
    eng = RetryEngine(RetryConfig(policy=RetryPolicy.XID_BRANCH))
    # RESTART_APP -> immediate (teardown only)
    for xid in (31, 43, 94):
        assert eng.next_delay_min(1, xid=xid) == pytest.approx(1.0)
    # RESET_GPU -> device reset first
    for xid in (119, 145, 149):
        assert eng.next_delay_min(1, xid=xid) == pytest.approx(7.0)
    # RESTART_BM -> stop and page operators
    assert eng.next_delay_min(1, xid=79) is None


def test_max_retries_stops():
    eng = RetryEngine(RetryConfig(policy=RetryPolicy.FIXED, max_retries=5))
    assert eng.next_delay_min(5) is not None
    assert eng.next_delay_min(6) is None


def test_xid_table_consistency():
    for code, info in XID_TABLE.items():
        assert info.code == code
        assert requires_isolation(code) == info.hardware
    assert XID_TABLE[79].resolution is Resolution.RESTART_BM
    assert XID_TABLE[94].resolution is Resolution.RESTART_APP


# ---------------------------------------------------------------------------
# NFS RPC simulator invariants
# ---------------------------------------------------------------------------

@given(total_mb=st.integers(1, 2048), slots=st.integers(1, 256))
@settings(max_examples=30, deadline=None)
def test_rpc_conservation_and_slot_bound(total_mb, slots):
    import dataclasses

    from repro.checkpoint.storage import NFSClientSim, NFSConfig

    cfg = dataclasses.replace(NFSConfig(), n_slots=slots, service_jitter=0.0)
    sim = NFSClientSim(cfg, seed=0)
    res = sim.transfer("write", total_mb << 20, keep_results=True)
    # all bytes moved in ceil(bytes/wsize) RPCs
    assert res.n_rpcs == -(-(total_mb << 20) // cfg.wsize)
    # concurrency never exceeds the slot count: at any finish time, the
    # number of in-flight rpcs <= slots  (checked via start/finish ordering)
    events = []
    for r in res.results:
        start = r.arrival_s + r.slot_wait_s
        events.append((start, 1))
        events.append((start + r.service_s, -1))
    events.sort()
    live = peak = 0
    for _, delta in events:
        live += delta
        peak = max(peak, live)
    assert peak <= slots


@given(rate=st.floats(100.0, 20000.0))
@settings(max_examples=15, deadline=None)
def test_rpc_throughput_capped_by_slots(rate):
    from repro.checkpoint.storage import NFSClientSim

    sim = NFSClientSim(seed=0)
    res = sim.transfer("read", 2 << 30, arrival_rate_rpcs_s=rate)
    cap = sim.config.n_slots / sim.config.read_service_s
    assert res.request_rate_s <= max(cap * 1.35, rate * 1.05)


def test_bandwidth_paradox_is_slot_bound():
    """Doubling slots ~halves save time; the link is never the limit."""
    import dataclasses

    from repro.checkpoint.storage import NFSClientSim, NFSConfig, LINK_BW_BYTES

    base = NFSClientSim(NFSConfig(service_jitter=0.0), seed=0)
    w1 = base.checkpoint_save(4 << 30)
    dbl = NFSClientSim(dataclasses.replace(NFSConfig(service_jitter=0.0),
                                           n_slots=256), seed=0)
    w2 = dbl.checkpoint_save(4 << 30)
    assert w2.duration_s < 0.6 * w1.duration_s
    assert w1.bandwidth_bytes_s < 0.2 * LINK_BW_BYTES   # the paradox


# ---------------------------------------------------------------------------
# Infrastructure fault band: degrade-don't-kill window geometry
# ---------------------------------------------------------------------------

_INFRA_WEIGHTS = {"net_degrade": 8.0, "resource_exhaust": 8.0,
                  "ctrl_blind": 8.0}


@given(seed=st.integers(0, 10_000), duration=st.floats(24.0, 24.0 * 14),
       mtbf=st.floats(10.0, 80.0))
@settings(max_examples=25, deadline=None)
def test_infra_windows_bounded_and_non_overlapping(seed, duration, mtbf):
    """_clip_windows guarantees: every window inside the campaign horizon,
    per-node non-overlap for degradation windows, global non-overlap for
    control-plane blind windows, and kind-consistent event fields."""
    from repro.core.failures import (DEGRADE_KINDS, INFRA_KINDS,
                                     FailureInjector, blind_windows,
                                     degradation_windows)

    inj = FailureInjector(mtbf_h=mtbf, seed=seed,
                          kind_weights=_INFRA_WEIGHTS)
    events = inj.sample(duration)

    for ev in events:
        if ev.kind in INFRA_KINDS:
            assert ev.window_h >= 0.0
            assert ev.time_h + ev.window_h <= duration + 1e-9
        if ev.kind == "net_degrade":
            assert ev.onset == "spike" and not ev.escalate
            assert 1.2 <= ev.slow_factor <= 1.8
        elif ev.kind == "resource_exhaust":
            assert ev.onset in ("gradual", "spike")
            assert 1.3 <= ev.slow_factor <= 2.0
        elif ev.kind == "ctrl_blind":
            assert ev.onset == "" and not ev.escalate

    per_node = {}
    for node, t0, t1, _sev, _kind, _onset in degradation_windows(events):
        per_node.setdefault(node, []).append((t0, t1))
    for spans in per_node.values():
        spans.sort()
        for (_a0, a1), (b0, _b1) in zip(spans, spans[1:]):
            assert a1 <= b0 + 1e-9, "degradation windows overlap on a node"

    bw = sorted(blind_windows(events))
    for (_a0, a1), (b0, _b1) in zip(bw, bw[1:]):
        assert a1 <= b0 + 1e-9, "blind windows overlap globally"


@given(t0=st.floats(0.0, 1000.0), width=st.floats(0.1, 48.0),
       n=st.integers(2, 60))
@settings(max_examples=50, deadline=None)
def test_gradual_onset_monotone_severity(t0, width, n):
    """Gradual onset ramps monotonically to the plateau within the window;
    spike jumps straight to full severity; both are zero outside."""
    import numpy as np

    from repro.core.failures import onset_progress

    t1 = t0 + width
    ts = np.linspace(t0, t1 - width * 1e-6, n)
    prog = onset_progress(ts, t0, t1, "gradual")
    assert np.all(np.diff(prog) >= -1e-12)
    assert np.all((prog >= 0.0) & (prog <= 1.0))
    assert onset_progress([t0 + width * 0.75], t0, t1, "gradual")[0] == 1.0
    assert onset_progress([t0 - width * 0.01], t0, t1, "gradual")[0] == 0.0
    assert onset_progress([t1], t0, t1, "gradual")[0] == 0.0
    assert onset_progress([t0], t0, t1, "spike")[0] == 1.0
    assert onset_progress([t1], t0, t1, "spike")[0] == 0.0


@given(seed=st.integers(0, 5000), w_net=st.floats(0.0, 12.0),
       w_res=st.floats(0.0, 12.0), w_blind=st.floats(0.0, 12.0),
       duration=st.floats(24.0, 24.0 * 10))
@settings(max_examples=15, deadline=None)
def test_infra_sample_batch_draw_order_identity(seed, w_net, w_res, w_blind,
                                                duration):
    """sample_batch over S seeds reproduces each per-seed sample() schedule
    bit-for-bit with the infra band at arbitrary (incl. zero) weights —
    the appended draw order is identical on both paths."""
    import dataclasses

    from repro.core.failures import FailureInjector

    weights = {"net_degrade": w_net, "resource_exhaust": w_res,
               "ctrl_blind": w_blind}
    inj = FailureInjector(mtbf_h=30.0, kind_weights=weights)
    seeds = [seed, seed + 1, seed + 7]
    batch = inj.sample_batch(duration, seeds)
    for i, s in enumerate(seeds):
        solo = dataclasses.replace(inj, seed=s).sample(duration)
        assert batch.events(i) == solo


def _lane_tables_for(seed, duration=48.0):
    import pytest

    pytest.importorskip("jax")   # the wavefront package re-exports the
    from repro.core.cluster import CampaignConfig, ClusterSim  # jitted core
    from repro.core.failures import FailureInjector
    from repro.kernels.wavefront.tapes import build_lane_tables

    cfg = ClusterSim(CampaignConfig(duration_h=duration, seed=seed)).cfg
    inj = FailureInjector(n_nodes=cfg.n_nodes, mtbf_h=cfg.mtbf_h,
                          hot_fraction=cfg.hot_fraction,
                          hot_weight=cfg.hot_weight, seed=cfg.seed)
    fails = inj.sample_batch(cfg.duration_h, [seed])
    return cfg, build_lane_tables(cfg, fails, [seed])


@given(seed=st.integers(0, 10_000), k=st.integers(1, 64))
@settings(max_examples=15, deadline=None)
def test_wavefront_uniform_tape_draw_order_identity(seed, k):
    """The compiled core's main uniform tape is positionally identical to
    k sequential ``rng.random()`` calls on the scalar engine's main
    stream — the single ``u_ptr`` walking the tape sees bit-for-bit the
    draws the scalar chain would consume, in the same order."""
    import numpy as np

    cfg, tables = _lane_tables_for(seed)
    r = np.random.default_rng(seed)
    seq = [r.random() for _ in range(k)]
    assert tables.device["u"][0, :k].tolist() == seq


@given(seed=st.integers(0, 10_000), k=st.integers(1, 32))
@settings(max_examples=15, deadline=None)
def test_wavefront_exponential_tapes_draw_order_identity(seed, k):
    """Manual-repair and structural-fix tapes reproduce sequential
    per-call draws on their dedicated rng streams, pre-multiplied by the
    same means the scalar engine applies — both day/night (and
    half/full) variants transform the SAME underlying draw, so whichever
    branch the replayed chain takes reads the scalar engine's float."""
    import numpy as np

    from repro.core.cluster import RNG_STREAM_MANUAL, RNG_STREAM_STRUCT

    cfg, tables = _lane_tables_for(seed)
    rm = np.random.default_rng([seed, RNG_STREAM_MANUAL])
    std_m = [rm.standard_exponential() for _ in range(k)]
    assert tables.device["man_day"][0, :k].tolist() == \
        [cfg.manual_response_h_day * s for s in std_m]
    assert tables.device["man_night"][0, :k].tolist() == \
        [cfg.manual_response_h_night * s for s in std_m]
    rx = np.random.default_rng([seed, RNG_STREAM_STRUCT])
    std_x = [rx.standard_exponential() for _ in range(k)]
    assert tables.device["x_full"][0, :k].tolist() == \
        [cfg.structural_fix_mean_h * s for s in std_x]
    assert tables.device["x_half"][0, :k].tolist() == \
        [cfg.structural_fix_mean_h / 2 * s for s in std_x]


@given(seed=st.integers(0, 10_000), j=st.integers(0, 63))
@settings(max_examples=15, deadline=None)
def test_wavefront_duration_tapes_match_scalar_uniform_calls(seed, j):
    """The pre-transformed load-duration tapes agree bitwise with the
    scalar engine's ``rng.uniform`` calls at every tape position: a
    scalar chain that consumed j draws and then rolled a load duration
    gets exactly ``dur_*[j]`` (``Generator.uniform`` is ``low +
    (high - low) * random()``, the same three floats in the same
    order)."""
    import numpy as np

    cfg, tables = _lane_tables_for(seed)
    r = np.random.default_rng(seed)
    r.random(j)                       # advance to tape position j
    v = r.uniform(-0.08, 0.3)
    assert tables.device["dur_warm"][0, j] == cfg.loading_time_h + v
    assert tables.device["dur_cold"][0, j] == cfg.loading_cold_h + v
    r2 = np.random.default_rng(seed)
    r2.random(j)
    assert tables.device["dur_fail"][0, j] == r2.uniform(0.05, 0.15)


@given(seed=st.integers(0, 5000))
@settings(max_examples=15, deadline=None)
def test_zero_weight_infra_band_keeps_legacy_schedules(seed):
    """Zero-mass infra entries must not perturb Generator.choice: a
    schedule drawn with the band explicitly zeroed is identical to one
    drawn with no kind_weights at all (pre-band seed stability)."""
    from repro.core.failures import INFRA_KINDS, FailureInjector

    d = 24.0 * 10
    base = FailureInjector(seed=seed).sample(d)
    zeroed = FailureInjector(
        seed=seed, kind_weights={k: 0.0 for k in INFRA_KINDS}).sample(d)
    assert base == zeroed
