"""Checkpoint subsystem: two-phase save semantics, roundtrip integrity,
corruption detection, GC, and restart-from-checkpoint training equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.checkpoint.manager import CheckpointManager, xor_fold_checksum


@pytest.fixture
def state(rng):
    k1, k2 = jax.random.split(rng)
    return {
        "params": {"w": jax.random.normal(k1, (64, 32)),
                   "b": jnp.zeros((32,), jnp.bfloat16)},
        "opt": (jax.random.normal(k2, (64, 32)),
                jnp.asarray(3, jnp.int32)),
    }


def test_two_phase_roundtrip(tmp_path, state):
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    rec = mgr.save(7, state)
    mgr.wait()
    assert rec.timeline.cascade_ordered()
    restored, step = mgr.restore(like=state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_returns_before_flush_completes(tmp_path, state):
    """Phase 1 blocks; phase 2 runs while 'training' continues."""
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    rec = mgr.save(1, state, blocking=False)
    # phase-1 timeline fields are already populated at return
    assert rec.timeline.t_staged >= rec.timeline.t_pause
    assert rec.bytes > 0
    mgr.wait()
    assert rec.timeline.t_write_done >= rec.timeline.t_staged


def test_corruption_detected(tmp_path, state):
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    mgr.save(3, state, blocking=True)
    # flip bytes in the payload
    f = next((tmp_path / "step_00000003").glob("data.bin"))
    raw = bytearray(f.read_bytes())
    raw[10] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(like=state)


def test_gc_keeps_latest(tmp_path, state):
    mgr = CheckpointManager(tmp_path, keep=2, simulate_rpc=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_restart_training_resumes_identically(tmp_path):
    """Resume-from-checkpoint reproduces the uninterrupted run exactly
    (the session abstraction's core contract, paper Table 6)."""
    from repro.configs import get_config
    from repro.launch.steps import make_train_step, synthetic_batch
    from repro.models import model as model_mod
    from repro.models.model import RunOptions
    from repro.optim import AdamW

    cfg = get_config("stablelm-3b").reduced()
    opts = RunOptions(q_chunk=16, kv_chunk=16)
    optimizer = AdamW()
    rng = jax.random.PRNGKey(0)
    params = model_mod.init_params(rng, cfg)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_train_step(cfg, opts, optimizer))
    batches = [synthetic_batch(jax.random.PRNGKey(i), cfg, 2, 16)
               for i in range(6)]

    # uninterrupted run
    p, o = params, opt_state
    for b in batches:
        p, o, m = step_fn(p, o, b)
    loss_direct = float(m["loss"])

    # interrupted at step 3 + resumed
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    p, o = params, opt_state
    for b in batches[:3]:
        p, o, _ = step_fn(p, o, b)
    mgr.save(3, {"p": p, "o": o}, blocking=True)
    del p, o
    state, step = mgr.restore(like={"p": params, "o": opt_state})
    p, o = state["p"], state["o"]
    for b in batches[step:]:
        p, o, m = step_fn(p, o, b)
    assert float(m["loss"]) == pytest.approx(loss_direct, rel=1e-5)


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_xor_checksum_properties(words):
    arr = np.asarray(words, np.uint32)
    c = xor_fold_checksum(arr)
    # order-insensitivity of xor fold over 64-bit words is NOT guaranteed,
    # but determinism and self-inverse are:
    assert c == xor_fold_checksum(arr)
    doubled = np.concatenate([arr, arr])
    if len(arr) % 2 == 0:
        assert xor_fold_checksum(doubled) == 0  # x ^ x = 0 per 64-bit lane


def test_restore_corrupt_index_raises(tmp_path, state):
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    mgr.save(2, state, blocking=True)
    idx = tmp_path / "step_00000002" / "index.json"
    idx.write_text("{ not json !!")
    with pytest.raises(IOError, match="corrupt or partial"):
        mgr.restore(like=state)


def test_restore_partial_index_raises(tmp_path, state):
    import json
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    mgr.save(2, state, blocking=True)
    idx = tmp_path / "step_00000002" / "index.json"
    meta = json.loads(idx.read_text())
    del meta["tensors"]                       # interrupted writer
    idx.write_text(json.dumps(meta))
    with pytest.raises(IOError, match="corrupt or partial"):
        mgr.restore(like=state)


def test_restore_truncated_payload_raises(tmp_path, state):
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    mgr.save(4, state, blocking=True)
    data = tmp_path / "step_00000004" / "data.bin"
    raw = data.read_bytes()
    data.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(IOError):
        mgr.restore(like=state)


def test_kernel_pack_vs_xor_fold_parity(tmp_path, state):
    """Both checksum paths restore bit-identical state from the same
    input, and the kernel path's block checksums match the numpy oracle."""
    from repro.kernels.ckpt_pack.ref import block_checksums_np

    mk = CheckpointManager(tmp_path / "k", simulate_rpc=False, pack="kernel")
    mx = CheckpointManager(tmp_path / "x", simulate_rpc=False, pack="xor")
    mk.save(1, state, blocking=True)
    mx.save(1, state, blocking=True)
    rk, sk = mk.restore(like=state)
    rx, sx = mx.restore(like=state)
    assert sk == sx == 1
    for a, b in zip(jax.tree.leaves(rk), jax.tree.leaves(rx)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # f32 tensors carry ckpt_pack block checksums equal to the host oracle
    rec = mk.records[-1]
    f32 = np.asarray(state["params"]["w"], np.float32)
    np.testing.assert_array_equal(rec.checksums["params/w"],
                                  block_checksums_np(f32))
    # non-f32 tensors fall back to the xor fold in BOTH modes
    assert isinstance(rec.checksums["params/b"], int)


def test_kernel_pack_detects_corruption(tmp_path):
    f32_state = {"w": jax.numpy.ones((512, 16), jax.numpy.float32)}
    mgr = CheckpointManager(tmp_path, simulate_rpc=False, pack="kernel")
    mgr.save(9, f32_state, blocking=True)
    f = tmp_path / "step_00000009" / "data.bin"
    raw = bytearray(f.read_bytes())
    raw[100] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="ckpt_pack block-checksum"):
        mgr.restore(like=f32_state)


def test_kernel_pack_halves_wire_bytes(tmp_path):
    # deliberately NOT a 2048-block multiple: the kernel's zero padding
    # must not be charged as wire volume
    f32_state = {"w": jax.numpy.ones((100, 3), jax.numpy.float32),
                 "b": jax.numpy.ones((17,), jax.numpy.float32)}
    mgr = CheckpointManager(tmp_path, simulate_rpc=False, pack="kernel")
    rec = mgr.save(1, f32_state, blocking=True)
    assert rec.timeline.bytes_wire == rec.bytes // 2
    mgr2 = CheckpointManager(tmp_path / "x", simulate_rpc=False, pack="xor")
    rec2 = mgr2.save(1, f32_state, blocking=True)
    assert rec2.timeline.bytes_wire == rec2.bytes


def test_last_load_rpc_declared_and_returned(tmp_path, state):
    mgr = CheckpointManager(tmp_path)      # simulate_rpc on
    assert mgr.last_load_rpc is None       # declared before any load
    mgr.save(1, state, blocking=True)
    result = mgr.restore(like=state)
    assert result.step == 1
    assert result.load_rpc is not None
    assert result.load_rpc.total_bytes == \
        sum(np.asarray(x).nbytes for x in jax.tree.leaves(state))
    assert mgr.last_load_rpc is result.load_rpc
    # tuple-unpack compatibility is part of the contract
    restored, step = result
    assert step == 1


def test_invalid_pack_mode_rejected(tmp_path):
    with pytest.raises(ValueError, match="pack"):
        CheckpointManager(tmp_path, pack="zstd")


def test_staging_buffer_reuse(tmp_path, state):
    """The /dev/shm-analogue staging pool is allocated once and reused."""
    mgr = CheckpointManager(tmp_path, simulate_rpc=False)
    mgr.save(1, state, blocking=True)
    bufs1 = {k: id(v) for k, v in mgr._staging.items()}
    mgr.save(2, state, blocking=True)
    bufs2 = {k: id(v) for k, v in mgr._staging.items()}
    assert bufs1 == bufs2
