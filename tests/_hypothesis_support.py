"""Optional-hypothesis shim: property tests skip gracefully when
``hypothesis`` is not installed (it is listed in requirements-dev.txt).

Test modules import ``given`` / ``settings`` / ``st`` from here instead of
from ``hypothesis`` directly, so module collection never fails and all
non-property tests in the same module still run.  With hypothesis present
this re-exports the real API unchanged.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed "
                       "(pip install -r requirements-dev.txt)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stand-in for hypothesis.strategies: any strategy constructor
        returns None (the @given above skips the test before use)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
