"""Integration tests: fault-tolerant trainer end-to-end, cluster campaign,
serving loop, and a subprocess dry-run cell (512 fake devices)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def test_trainer_recovers_from_injected_xid(tmp_path):
    from repro.launch.train import run_training

    rep = run_training("gemma2-2b", steps=24, batch=2, seq=32,
                       ckpt_dir=str(tmp_path), fail_at=(10,), fail_xid=94,
                       verbose=False)
    assert rep.steps_done == 24
    assert rep.n_failures == 1 and rep.n_restarts == 1
    assert np.isfinite(rep.final_loss)
    # resumed strictly from a checkpointed step
    assert all(r % max(24 // 5, 5) == 0 for r in rep.restore_steps)


def test_trainer_xid79_stops_for_operator(tmp_path):
    """RESTART_BM (XID 79) halts auto-retry — operator action required."""
    from repro.launch.train import run_training

    rep = run_training("gemma2-2b", steps=24, batch=2, seq=32,
                       ckpt_dir=str(tmp_path), fail_at=(10,), fail_xid=79,
                       retry_policy="xid_branch", verbose=False)
    assert rep.steps_done < 24
    assert rep.n_failures == 1 and rep.n_restarts == 0


def test_training_learns(tmp_path):
    """The optimizer + model actually learn: overfitting a fixed batch
    drives the loss well below the uniform-distribution entropy ln(V)."""
    import jax
    import math

    from repro.configs import get_config
    from repro.launch.steps import make_train_step, synthetic_batch
    from repro.models import model as model_mod
    from repro.optim import AdamW
    from repro.models.model import RunOptions

    cfg = get_config("stablelm-3b").reduced()
    optimizer = AdamW(lr=3e-3, warmup_steps=2, total_steps=40)
    params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(cfg, RunOptions(q_chunk=16, kv_chunk=16),
                                   optimizer))
    batch = synthetic_batch(jax.random.PRNGKey(1), cfg, 4, 32)
    losses = []
    for _ in range(40):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < math.log(cfg.vocab_size) - 0.5, losses[-5:]
    assert losses[-1] < losses[0]


def test_serving_loop():
    from repro.launch.serve import run_serving

    out = run_serving("gemma2-2b", batch=2, prompt_len=16, gen_len=8,
                      verbose=False)
    assert out["decode_tokens_per_s"] > 0
    assert len(out["sample"]) == 8


def test_cluster_campaign_invariants():
    from repro.core.cluster import CampaignConfig, ClusterSim

    res = ClusterSim(CampaignConfig(duration_h=14 * 24.0, seed=4)).run()
    # every session is terminal and never exceeded the node budget
    for s in res.sessions:
        assert s.is_terminal
        assert len(s.nodes) == 60
    # chain bookkeeping is self-consistent
    for c in res.chains:
        for a in c.attempts[:-1]:
            assert a.end_h is not None
    # downtime episodes are positive
    assert all(d["hours"] >= 0 for d in res.downtimes)
    assert res.checkpoint_events > 0


def test_occupancy_near_paper():
    from repro.core.cluster import CampaignConfig, ClusterSim

    occ = []
    for seed in (0, 1):
        res = ClusterSim(CampaignConfig(duration_h=30 * 24.0,
                                        seed=seed)).run()
        occ.append(res.training_occupancy())
    assert np.mean(occ) > 0.85         # paper: 96.6%


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real dry-run cell in a subprocess (512 host devices, 16x16 mesh +
    2x16x16 multi-pod gate).  Slow (~2 min) but proves the deliverable."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    code = (
        "from repro.launch.dryrun import run_cell;"
        "import json;"
        "r1 = run_cell('gemma2-2b','train_4k',multi_pod=False,verbose=False);"
        "r2 = run_cell('gemma2-2b','decode_32k',multi_pod=True,"
        "skip_cost=True,verbose=False);"
        "print(json.dumps([r1['status'], r2['status'],"
        " r1['roofline']['dominant']]))"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    status1, status2, dominant = json.loads(out.stdout.strip().splitlines()[-1])
    assert status1 == "OK" and status2 == "OK"
    assert dominant in ("compute", "memory", "collective")


def test_dryrun_results_cover_all_cells():
    """The shipped dry-run artifacts cover every (arch x shape x mesh) cell
    with OK or a documented SKIP."""
    p = REPO / "benchmarks" / "results" / "dryrun_baseline.json"
    if not p.exists():
        pytest.skip("dry-run artifacts not generated yet")
    results = json.loads(p.read_text())
    from repro.configs import ASSIGNED_ARCHS, SHAPES
    missing, failed = [], []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                key = f"{arch}|{shape}|{mesh}"
                rec = results.get(key)
                if rec is None:
                    missing.append(key)
                elif rec["status"] == "FAIL":
                    failed.append(key)
    assert not missing, missing
    assert not failed, failed
