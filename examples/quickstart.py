"""Quickstart: build a model from a config, train a few steps, checkpoint,
restore, and decode — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-2b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.launch.steps import (make_serve_step, make_train_step,
                                synthetic_batch, synthetic_decode_inputs)
from repro.models import model as model_mod
from repro.models.model import RunOptions
from repro.optim import AdamW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    # 1. config (reduced for CPU; drop .reduced() on real hardware)
    cfg = get_config(args.arch).reduced()
    opts = RunOptions(q_chunk=64, kv_chunk=64)
    print(f"{cfg.name}: {cfg.n_layers} layers (reduced), "
          f"{cfg.n_params()/1e6:.1f} M params")

    # 2. init + train
    rng = jax.random.PRNGKey(0)
    params = model_mod.init_params(rng, cfg)
    optimizer = AdamW(lr=1e-3, warmup_steps=2, total_steps=args.steps)
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_train_step(cfg, opts, optimizer))
    batch = synthetic_batch(rng, cfg, batch=2, seq=64)
    for i in range(args.steps):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        print(f"  step {i}: loss={float(metrics['loss']):.4f}")

    # 3. two-phase async checkpoint + restore
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, simulate_rpc=False)
        rec = mgr.save(args.steps, {"params": params}, blocking=True)
        print(f"checkpoint: {rec.bytes/1e6:.1f} MB, "
              f"blocking phase {rec.timeline.blocking_s*1e3:.1f} ms, "
              f"async phase {rec.timeline.async_s*1e3:.1f} ms")
        restored, step = mgr.restore(like={"params": params})
        assert step == args.steps

    # 4. decode a few tokens
    serve = jax.jit(make_serve_step(cfg, opts))
    cache, tok, pos = synthetic_decode_inputs(rng, cfg, batch=2, seq=64,
                                              pos=0)
    for i in range(5):
        logits, cache = serve(restored["params"], cache, tok, pos + i)
        if cfg.embed_inputs:
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
    print("decoded ok:", logits.shape)


if __name__ == "__main__":
    main()
