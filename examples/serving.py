"""Batched serving demo over the assigned architectures.

    PYTHONPATH=src python examples/serving.py [--arch rwkv6-3b]
"""
import argparse

from repro.launch.serve import run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    run_serving(args.arch, batch=args.batch, prompt_len=32, gen_len=16)


if __name__ == "__main__":
    main()
