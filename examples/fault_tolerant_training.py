"""End-to-end fault-tolerant training demo (deliverable (b) driver).

Trains a model for a few hundred steps while XID failures are injected at
chosen steps; the runtime classifies each failure (paper Table 3), applies
the retry policy, and resumes from the last two-phase checkpoint.  Compares
the paper-faithful fixed-delay policy against the paper's proposed
XID-branching policy (§4.3.5).

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

from repro.launch.train import run_training


def main():
    for policy in ("fixed", "xid_branch"):
        print(f"\n=== policy: {policy} ===")
        # fresh checkpoint dir per run: restoring a stale step-60
        # checkpoint from a previous invocation would skip the retries
        # this demo exists to show
        with tempfile.TemporaryDirectory(
                prefix=f"repro_ft_{policy}_") as ckpt_dir:
            rep = run_training(
                "stablelm-3b", steps=60, batch=2, seq=64,
                fail_at=(22, 41), fail_xid=94, retry_policy=policy,
                ckpt_dir=ckpt_dir, log_every=20)
        print(f"steps={rep.steps_done} failures={rep.n_failures} "
              f"restarts={rep.n_restarts} saves={rep.checkpoint_saves} "
              f"final_loss={rep.final_loss:.4f} "
              f"tokens/s={rep.tokens_per_s:,.0f}")
        assert rep.steps_done == 60 and rep.n_restarts == 2


if __name__ == "__main__":
    main()
