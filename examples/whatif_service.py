"""What-if service demo: the three answer layers, in process.

Builds a `WhatIfService` (no sockets — the HTTP front door is
``python -m repro.serve.http``), optionally precomputes the preset sweep
surface, then walks one query through each layer and shows the
provenance + latency waterfall:

    PYTHONPATH=src python examples/whatif_service.py
    PYTHONPATH=src python examples/whatif_service.py \
        --days 7 --seeds 32 --surface

With ``--surface``, near-miss queries (a node count / nvlink tilt /
checkpoint cadence inside the grid hull) answer by multilinear
interpolation in microseconds; everything off-grid runs a live stacked
engine pass, and repeats hit the canonical-key LRU.
"""
import argparse
import time

from repro.ops import get_scenario
from repro.serve import (ServiceConfig, SurfaceSpec, SweepSurface,
                         WhatIfService)


def show(label: str, answer) -> None:
    g = answer.distribution.get("goodput")
    dist = (f"goodput median {g['median']*100:.1f}% "
            f"[{g['q25']*100:.1f}, {g['q75']*100:.1f}]"
            if g else "(no goodput metric)")
    print(f"  {label:<34} source={answer.source:<8} "
          f"{answer.wall_s*1e3:>8.2f} ms  {dist}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=3.0,
                    help="campaign length for the demo queries (shorter "
                         "= faster engine passes)")
    ap.add_argument("--seeds", type=int, default=16,
                    help="Monte Carlo seeds per query")
    ap.add_argument("--surface", action="store_true",
                    help="precompute the preset sweep surface first and "
                         "demo the interpolated answer path")
    ap.add_argument("--window-ms", type=float, default=20.0,
                    help="request-coalescing window")
    args = ap.parse_args()

    base = get_scenario("paper-faithful").replace(duration_days=args.days)
    surface = None
    if args.surface:
        spec = SurfaceSpec(base=base, seeds=max(args.seeds, 8))
        print(f"building surface ({len(spec.n_nodes)}x{len(spec.tilts)}x"
              f"{len(spec.ckpt_hours)} grid x {spec.seeds} seeds)…")
        surface = SweepSurface(spec).build()
        print(f"  built in {surface.build_wall_s:.1f} s\n")

    svc = WhatIfService(ServiceConfig(window_s=args.window_ms / 1e3,
                                      default_seeds=args.seeds),
                        surface=surface)
    try:
        print(f"query waterfall ({args.seeds} seeds, "
              f"{args.days:g}-day campaigns):")
        show("first query (cold)", svc.query(base))
        show("repeat (cache or surface)", svc.query(base))
        tilted = base.replace(kind_weights={"nvlink": 2.5})
        show("nvlink x2.5", svc.query(tilted))
        if surface is not None:
            near = base.replace(n_nodes=71, job_nodes=68,
                                checkpoint_interval_h=3.0)
            show("71 nodes / 3.0 h (interpolated)", svc.query(near))
        off = base.replace(retry_policy="exp_backoff")
        show("exp-backoff retry (off-grid)", svc.query(off))

        # a concurrent burst of engine-path queries (mtbf is off every
        # surface axis): duplicates coalesce into shared passes
        burst = [base.replace(mtbf_h=m)
                 for m in (20.0, 20.0, 26.0, 26.0, 20.0, 26.0)]
        t0 = time.perf_counter()
        answers = [svc.query_async(sc) for sc in burst]
        answers = [a.result() for a in answers]
        wall = time.perf_counter() - t0
        n_engine = sum(1 for a in answers if a.source == "engine")
        print(f"\nburst of {len(burst)} concurrent queries "
              f"(2 distinct): {wall*1e3:.0f} ms total, "
              f"{n_engine} engine answers, "
              f"{svc.stats()['engine_configs']} engine passes overall")
        print("\nservice stats:", svc.stats()["cache"],
              svc.stats()["coalescer"])
    finally:
        svc.close()


if __name__ == "__main__":
    main()
