"""Batched what-if campaign sweeps over named operational scenarios.

Runs M scenarios x N seeds through the event-driven cluster simulation and
prints the F1-F4 findings side by side (plus the paper's published numbers
as the reference row).  The default set contrasts the paper's own campaign
with two §4.3.5 retry improvements; ``--scenarios all`` sweeps every preset.

    PYTHONPATH=src python examples/scenario_sweep.py
    PYTHONPATH=src python examples/scenario_sweep.py \
        --scenarios paper-faithful,flaky-fabric,storage-degraded \
        --seeds 0,1,2 --days 73 --telemetry-days 2 --report sweep.md

Distributional (Monte Carlo) sweeps route hundreds of seeds through the
seed-batched campaign engine in one stacked pass and add median/IQR/95%-CI
columns to the report:

    PYTHONPATH=src python examples/scenario_sweep.py \
        --scenarios paper-faithful,smart-retry --mc-seeds 256 \
        --report sweep_mc.md

Fleet-scale dense sweeps stack EVERY control-free (scenario, seed) lane
into one compiled XLA device pass — the whole campaign grid advances
inside a single jitted while-loop, with findings bitwise identical to
the numpy engines:

    PYTHONPATH=src python examples/scenario_sweep.py \
        --scenarios all --mc-seeds 10000 --grid --report sweep_grid.md
"""
import argparse
import warnings

from repro.ops import SweepRunner, get_scenario, list_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios",
                    default="paper-faithful,no-auto-retry,smart-retry",
                    help="comma-separated preset names, or 'all' "
                         f"(available: {', '.join(list_scenarios())})")
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated campaign seeds")
    ap.add_argument("--days", type=float, default=None,
                    help="override campaign length (default: per-scenario, "
                         "73 for the paper campaign)")
    ap.add_argument("--telemetry-days", type=float, default=None,
                    help="run an F1 precursor sub-campaign of this length "
                         "per (scenario, seed); longer windows tighten the "
                         "F1 estimates; 0 skips F1 (fastest; default 2, "
                         "or 0 in --mc-seeds mode)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool width (default: one per campaign, "
                         "capped at the core count)")
    ap.add_argument("--executor", default="process",
                    choices=("process", "thread", "serial"))
    ap.add_argument("--report", default=None,
                    help="also write the full markdown report here")
    ap.add_argument("--preset", default=None,
                    help="proactive-vs-reactive quickstart: sweep the "
                         "reactive baseline against PRESET (e.g. "
                         "'proactive', 'proactive-aggressive' or "
                         "'log-fusion' — the latter also sweeps its "
                         "metric-only twin log-fusion-off) on identical "
                         "seeds; defaults --days to 14 and skips the F1 "
                         "sub-campaign")
    ap.add_argument("--mc-seeds", type=int, default=None,
                    help="Monte Carlo mode: run this many seeds per "
                         "scenario through the seed-batched campaign "
                         "engine (one stacked pass instead of one process "
                         "per seed) and add median/IQR/95%%-CI columns to "
                         "the report; overrides --seeds with range(N) and "
                         "skips the per-seed F1 sub-campaign unless "
                         "--telemetry-days is set explicitly")
    ap.add_argument("--grid", action="store_true",
                    help="whole-sweep wavefront: stack every control-free "
                         "(scenario, seed) lane into one compiled XLA "
                         "device pass (requires --mc-seeds; control-plane "
                         "scenarios fall back to the numpy engine; "
                         "findings are bitwise identical either way)")
    ap.add_argument("--wavefront-backend", default=None,
                    choices=("auto", "numpy", "xla", "pallas"),
                    help="Monte Carlo campaign backend: auto picks the "
                         "compiled device core when the lane count clears "
                         "its floor, numpy forces the stacked-numpy "
                         "wavefront, xla/pallas force the compiled core "
                         "(--grid implies xla unless set)")
    ap.add_argument("--detector-backend", default=None,
                    choices=("numpy", "xla", "pallas"),
                    help="streaming-detector pass-1 backend for control-"
                         "plane scenarios: numpy (reference), xla (fused "
                         "jitted XLA — the fast path off-TPU), pallas "
                         "(TPU kernel).  Alarm sets are identical across "
                         "backends; this trades wall-clock only")
    ap.add_argument("--list-presets", action="store_true",
                    help="print every scenario preset with its one-line "
                         "description and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic CI sweep: paper-faithful + "
                         "storage-fabric + proactive + infra-faults, "
                         "1 seed, 3 days, serial, no F1, plus an mc_seeds "
                         "spot check")
    args = ap.parse_args()

    if args.list_presets:
        width = max(len(n) for n in list_scenarios())
        for name in list_scenarios():
            sc = get_scenario(name)
            print(f"{name:<{width}}  {sc.description}")
        return

    if args.smoke:
        args.scenarios = "paper-faithful,storage-fabric,proactive," \
                         "infra-faults"
        args.seeds = "0"
        args.days = 3.0
        args.telemetry_days = 0.0
        args.executor = "serial"
    elif args.preset:
        if args.preset == "log-fusion":
            # the log channel's deltas (TTD, false drains) are measured
            # against its metric-only twin on identical schedules
            args.scenarios = "reactive,log-fusion-off,log-fusion"
        else:
            args.scenarios = f"reactive,{args.preset}"
        if args.days is None:
            args.days = 14.0
        args.telemetry_days = 0.0
    if args.telemetry_days is None:
        args.telemetry_days = 0.0 if args.mc_seeds else 2.0
    if args.grid and not args.mc_seeds:
        ap.error("--grid needs --mc-seeds (it stacks the Monte Carlo "
                 "seed axis into the device pass)")
    wavefront = args.wavefront_backend or ("xla" if args.grid else "auto")
    if args.mc_seeds and wavefront != "numpy":
        # compiled lanes pad to the next power of two (>= 64): a
        # non-bucketed seed count pays for lanes it never reads
        try:
            from repro.kernels.common import next_pow2
            bucket = max(next_pow2(args.mc_seeds), 64)
            if bucket != args.mc_seeds:
                warnings.warn(
                    f"--mc-seeds {args.mc_seeds} is not a power-of-two "
                    "lane bucket: the compiled pass pads its lane axis "
                    f"to the next bucket, so up to {bucket} seeds cost "
                    "the same device wall clock (and every distinct "
                    "count compiles its own program)", stacklevel=1)
        except ImportError:
            pass

    names = list_scenarios() if args.scenarios == "all" \
        else [s.strip() for s in args.scenarios.split(",") if s.strip()]
    scenarios = []
    for name in names:
        sc = get_scenario(name)
        if args.days is not None:
            sc = sc.replace(duration_days=args.days)
        if args.telemetry_days > 0:
            sc = sc.replace(telemetry_days=args.telemetry_days)
        if args.detector_backend:
            sc = sc.replace(detector_backend=args.detector_backend)
        scenarios.append(sc)
    seeds = [int(s) for s in args.seeds.split(",")]

    n_seeds = args.mc_seeds if args.mc_seeds else len(seeds)
    mode = "seed-batched Monte Carlo engine" if args.mc_seeds \
        else f"{args.executor} executor"
    print(f"sweeping {len(scenarios)} scenarios x {n_seeds} seeds "
          f"({mode})…")
    for sc in scenarios:
        print(f"  - {sc.name}: {sc.duration_days:.0f} d, {sc.n_nodes} nodes"
              + (f", F1 window {sc.telemetry_days:.0f} d"
                 if sc.telemetry_days else ""))

    res = SweepRunner(scenarios, seeds=seeds, max_workers=args.workers,
                      executor=args.executor, mc_seeds=args.mc_seeds,
                      wavefront_backend=wavefront).run()

    n = len(res.outcomes)
    print(f"\n{n} campaigns in {res.wall_s:.1f} s wall "
          f"({res.wall_s / n:.2f} s/campaign)\n")
    print(res.comparison_table())
    print("\n`—` = not applicable (F1 needs --telemetry-days > 0; downtime "
          "columns need at least one episode of that kind).")
    if args.report:
        res.write(args.report)
        print(f"\nfull report written to {args.report}")

    if args.smoke:
        # Monte Carlo spot check: the batched engine's findings must be
        # identical to the serial per-seed path on the same seeds — on the
        # paper mix and on the infra fault band (degradation ledger,
        # escalations and blind-window replay included)
        for name in ("paper-faithful", "infra-faults"):
            sc = get_scenario(name).replace(duration_days=3.0)
            mc = SweepRunner([sc], mc_seeds=4).run()
            ref = SweepRunner([sc], seeds=range(4), executor="serial").run()
            for a, b in zip(mc.outcomes, ref.outcomes):
                fa = {k: v for k, v in a.findings.items() if k != "wall_s"}
                fb = {k: v for k, v in b.findings.items() if k != "wall_s"}
                assert a.seed == b.seed and fa == fb, \
                    f"mc/serial findings diverged: {name} seed {a.seed}"
            print(f"mc_seeds smoke [{name}]: batched findings == per-seed "
                  "findings (4 seeds)")


if __name__ == "__main__":
    main()
