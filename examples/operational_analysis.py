"""Reproduce the paper's §4 operational analyses on a simulated campaign.

Runs the 63-node cluster simulation (failure injection seeded from the
paper's observed distribution), then executes the three analyses:
F1 precursor detection, F3 node-exclusion concentration, F4 auto-retry
chains — and prints them next to the paper's published numbers.

    PYTHONPATH=src python examples/operational_analysis.py [--days 20]
"""
import argparse

import numpy as np

from repro.core.cluster import CampaignConfig, ClusterSim
from repro.core.precursor import DetectorConfig, PrecursorDetector, evaluate
from repro.core.retry import chain_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=15.0,
                    help="campaign length (telemetry on; 73 for paper scale)")
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()

    print(f"simulating {args.days:.0f}-day campaign (63 nodes, telemetry on)…")
    cfg = CampaignConfig(duration_h=args.days * 24.0, telemetry=True,
                         seed=args.seed)
    res = ClusterSim(cfg).run()

    print(f"\n— campaign: {len(res.failures)} failures, "
          f"{len(res.sessions)} sessions, {res.checkpoint_events} checkpoint "
          f"events, occupancy {res.training_occupancy()*100:.1f}% "
          f"(paper: 96.6%)")

    # F1: precursor detection
    xid_fails = [f for f in res.failures if f.kind == "xid"]
    alarms = PrecursorDetector(DetectorConfig()).scan(res.store)
    ev = evaluate(alarms, xid_fails, res.duration_h)
    print(f"\nF1 precursor detection ({ev.n_failures} XID failures):")
    print(f"   detection {ev.detected}/{ev.n_failures} (paper 10/10), "
          f"pre-XID {ev.pre_xid}/{ev.n_failures} (paper 2/10), "
          f"FP/day {ev.fp_per_day:.2f} (paper ~0.84)")

    # F3: exclusion concentration
    summ = res.exclusions.summary()
    print(f"\nF3 node exclusion: top-3 share {summ['top3_share']*100:.0f}% "
          f"(paper >50%), deliberate fraction "
          f"{summ['deliberate_fraction']*100:.0f}%")

    # F4: retry chains
    st = chain_stats(res.retry_chains())
    auto = [d["hours"] for d in res.downtimes if d["auto"]]
    man = [d["hours"] for d in res.downtimes if not d["auto"]]
    print(f"\nF4 auto-retry: {st['n_chains']} chains / {st['n_attempts']} "
          f"attempts; success {st['chain_success_rate']*100:.0f}% "
          f"(paper 33.3%); gap median {st['gap_median_min']:.0f} min "
          f"(paper 11)")
    if auto and man:
        print(f"   downtime median auto {np.median(auto):.1f} h vs manual "
              f"{np.median(man):.1f} h (paper 1.9 vs 3.3)")


if __name__ == "__main__":
    main()
