"""Structured synthetic log emitter driven by the sim's failure schedule.

The paper's operators diagnosed failure clusters from 73 days of
operational logs *jointly* with Prometheus metrics; the repro's telemetry
layer only modelled the metric side.  This emitter produces the log side:
every failure kind in the taxonomy gets a characteristic line mix (XID
bursts, NCCL watchdog timeouts on the peers, NFS/RPC storage-stall spam,
memory-pressure ramps, scheduler-outage markers), interleaved with benign
per-node background noise and session-lifecycle heartbeats.

Determinism contract (the batch==scalar parity hinge):

* the emitter owns a **dedicated rng stream** (``RNG_STREAM_LOGS``) seeded
  as ``default_rng([seed, RNG_STREAM_LOGS])`` — consuming it can never
  perturb the engines' existing draw order, and nothing else consumes it;
* failure-specific draws happen at **registration time**, in schedule
  order (identical in both engines); window-level draws (noise) happen at
  **emission time**, in chunk order (chunk boundaries are mirrored
  chunk-for-chunk between the scalar batcher and the batched engine);
* gang-wide symptom lines ("peer node-K unreachable" on every other gang
  member) are materialised draw-free at emission from the current gang.

Lines are ``(time_h, node, text)``; the first token of ``text`` is the
level (INFO/WARN/ERROR) and node references are spelled ``node-<id>`` so
the analyzer can recover cross-node attribution edges by parsing, not by
privileged access to ground truth.  Controller-scoped lines carry
``node == -1``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

# dedicated rng stream id for the log emitter (see RNG_STREAM_MANUAL /
# RNG_STREAM_STRUCT in core/cluster.py for the pattern; PARITY.md for why
# streams are append-only)
RNG_STREAM_LOGS = 7027

# scrape tick, mirrors core.cluster.TICK_H (policy.py cannot import the
# engine module without a cycle)
_TICK_H = 30.0 / 3600.0

# benign background chatter; {v} is the masked-out variable slot.  Noise is
# INFO/WARN only — ERROR is reserved for genuine fault programs, which is
# what lets the analyzer treat rare ERROR templates as a rarity signal.
NOISE_TEMPLATES = (
    "INFO trainer: dataloader prefetch depth {v} ok",
    "INFO sshd: accepted publickey for ops from 10.0.{v}.7",
    "INFO systemd: run-docker-runtime scope for job {v} succeeded",
    "WARN systemd-journald: missed {v} kernel messages",
    "INFO smartd: device sda SMART ok, temperature {v} C",
    "INFO dcgm: health watch ok on gpu {v}",
    "INFO chronyd: clock offset {v} us from ntp pool",
    "WARN kubelet: image garbage collection freed {v} bytes",
    "INFO launcher: heartbeat ok, retry queue depth {v}",
    "INFO node-exporter: scrape completed in {v} ms",
)

# session-lifecycle heartbeat cadence (rank-0 progress line)
_HEARTBEAT_H = 0.5


@dataclass(frozen=True)
class LogLine:
    """One synthetic log line.  ``node == -1`` is the controller."""
    time_h: float
    node: int
    text: str

    @property
    def level(self) -> str:
        return self.text.split(" ", 1)[0]


class LogEmitter:
    """Turns a failure schedule + chunk windows into a log stream.

    Usage (both engines follow the same order):

    1. construct with the campaign's ``(n_nodes, seed)``;
    2. ``register_failure(ev)`` for every scheduled event, in schedule
       (time) order — all fault-program draws happen here;
    3. ``emit_window(t0, t1, gang)`` once per emitted telemetry chunk,
       with contiguous ``[t0, t1)`` windows — noise draws happen here.
    """

    def __init__(self, n_nodes: int, seed: int,
                 noise_per_node_h: float = 1.0):
        self.n_nodes = n_nodes
        self.noise_per_node_h = noise_per_node_h
        self.rng = np.random.default_rng([seed, RNG_STREAM_LOGS])
        # (time_h, node, text, gang_wide); for gang_wide entries ``node``
        # is the *referenced* root cause and the line materialises on every
        # other current gang member at emission
        self._prog: List[tuple] = []
        self._cursor = 0
        self._sealed = False

    # -- registration (schedule order; all fault draws live here) ----------

    def register_failure(self, ev) -> None:
        if self._sealed:
            raise RuntimeError("register_failure after first emit_window")
        kind = getattr(ev, "kind", "xid")
        handler = getattr(self, f"_reg_{kind}", None)
        if handler is not None:
            handler(ev)

    def _add(self, t: float, node: int, text: str, gang: bool = False):
        self._prog.append((max(float(t), 0.0), int(node), text, gang))

    def _spread(self, t0: float, width: float, rate_h: float) -> np.ndarray:
        """Jittered stall-cluster times across a degradation window, with
        the first cluster pinned near the window's onset."""
        n = max(3, int(round(width * rate_h)))
        ts = t0 + width * np.sort(self.rng.uniform(0.0, 1.0, n))
        ts[0] = t0 + min(0.02, 0.3 * width)
        return ts

    def _reg_xid(self, ev) -> None:
        rng = self.rng
        t, node = float(ev.time_h), int(ev.node)
        lead = max(float(getattr(ev, "precursor_lead_h", 0.0)), 0.0)
        if lead > 0:
            # a couple of *rare* correctable-ECC errors right after onset
            # (the gpu124 row-remap story) — the analyzer's rarity signal
            n_early = 2 + int(rng.integers(0, 2))
            for dt in rng.uniform(0.0, min(0.2 * lead + 0.02, lead),
                                  n_early):
                self._add(t - lead + float(dt), node,
                          "ERROR dcgm: gpu 0: row remap pending, "
                          "correctable ECC error count rising")
            # warn ramp accelerating toward the failure point
            n_ramp = max(4, int(round(lead * 10.0)))
            for u in rng.uniform(0.0, 1.0, n_ramp):
                self._add(t - lead + lead * float(math.sqrt(u)), node,
                          f"WARN dcgm: volatile sbe retired pages "
                          f"{int(rng.integers(1, 64))} on gpu 0")
        xid = int(ev.xid) if getattr(ev, "xid", None) is not None else 79
        for j in range(3 + int(rng.integers(0, 3))):
            self._add(t + 1e-4 * (j + 1), node,
                      f"ERROR NVRM: Xid (PCI:0000:b1:00): {xid}, "
                      f"pid={int(rng.integers(2000, 32768))}, "
                      f"name=trainer, GPU fault detected")
        self._add(t + 8e-4, node,
                  "ERROR trainer: CUDA error: uncorrectable ECC or "
                  "device-side fault, aborting rank")
        self._add(t + 2e-3, node,
                  f"WARN NCCL: watchdog timeout on collective, peer rank "
                  f"on node-{node} unresponsive", gang=True)
        self._add(t + 0.03, -1,
                  f"INFO launcher: session abort attributed to "
                  f"node-{node}, retry chain scheduled")

    def _reg_unreachable(self, ev) -> None:
        t, node = float(ev.time_h), int(ev.node)
        # the node itself goes silent; only the peers speak (the Mycroft
        # setting: attribution must come from cross-node references)
        self._add(t + 1e-3, node,
                  f"ERROR NCCL: connect to node-{node} failed: "
                  f"Connection timed out", gang=True)
        self._add(t + 2e-3, node,
                  f"WARN gang: heartbeat lost for node-{node}, "
                  f"evicting from ring", gang=True)
        self._add(t + 0.03, -1,
                  f"INFO launcher: node-{node} unreachable, "
                  f"session restart queued")

    def _reg_fail_slow(self, ev) -> None:
        rng = self.rng
        t, node = float(ev.time_h), int(ev.node)
        pre = min(0.5, t)
        for u in rng.uniform(0.0, 1.0, 3 + int(rng.poisson(2.0))):
            self._add(t - pre + pre * float(u), node,
                      "WARN trainer: kernel launch latency high on gpu 0, "
                      "step time degraded")
        self._add(t + 1e-3, node,
                  f"WARN NCCL: rank on node-{node} lagging collective, "
                  f"allreduce stalled", gang=True)
        self._add(t + 0.03, -1,
                  f"INFO launcher: slow rank report filed for node-{node}")

    def _reg_net_degrade(self, ev) -> None:
        rng = self.rng
        t, node = float(ev.time_h), int(ev.node)
        w = max(float(getattr(ev, "window_h", 0.0)), 0.1)
        # correlated storage-stall clusters: each RPC stall produces the
        # kernel NFS line plus transport symptoms within milliseconds
        for tt in self._spread(t, w, rate_h=10.0):
            tt = float(tt)
            self._add(tt, node,
                      "ERROR nfs: server storage-0 not responding, "
                      "still trying")
            self._add(tt + 1e-4, node,
                      f"WARN rpc: retransmit threshold exceeded on mount "
                      f"/ckpt, {int(rng.integers(10, 400))} ops queued")
            self._add(tt + 2e-4, node,
                      "WARN net: tcp transport backlog rising on bond0")
        self._add(t + w + 1e-3, node,
                  "INFO nfs: server storage-0 OK, operations resumed")

    def _reg_resource_exhaust(self, ev) -> None:
        rng = self.rng
        t, node = float(ev.time_h), int(ev.node)
        w = max(float(getattr(ev, "window_h", 0.0)), 0.1)
        for tt in self._spread(t, w, rate_h=10.0):
            tt = float(tt)
            self._add(tt, node,
                      f"ERROR kernel: page allocation stall for "
                      f"{int(rng.integers(1000, 30000))} ms in kswapd0")
            self._add(tt + 1e-4, node,
                      "WARN mm: available memory low, "
                      "reclaim pressure rising")
            self._add(tt + 2e-4, node,
                      f"WARN cgroup: memory usage "
                      f"{int(rng.integers(90, 100))} percent of limit "
                      f"on trainer slice")
        if bool(getattr(ev, "escalate", False)):
            for j in range(3):
                self._add(t + w + 1e-4 * (j + 1), node,
                          f"ERROR oom-killer: invoked, killed trainer "
                          f"pid {int(rng.integers(2000, 32768))}")
        else:
            self._add(t + w + 1e-3, node,
                      "INFO mm: memory pressure cleared, reclaim idle")

    def _reg_switch_degrade(self, ev) -> None:
        rng = self.rng
        t = float(ev.time_h)
        w = max(float(getattr(ev, "window_h", 0.0)), 0.1)
        members = [int(m) for m in getattr(ev, "members", ())]
        sw = int(getattr(ev, "switch", -1))
        # the correlated shape a per-node program cannot produce: every
        # member of the rack logs transport symptoms inside the same
        # stall cluster, because the fault lives in the shared leaf
        for tt in self._spread(t, w, rate_h=8.0):
            tt = float(tt)
            for i, node in enumerate(members):
                self._add(tt + 1e-4 * i, node,
                          f"ERROR net: uplink errors via leaf switch, tcp "
                          f"retransmit storm on bond0, "
                          f"{int(rng.integers(50, 900))} segments resent")
        self._add(t + 1e-3, -1,
                  f"WARN fabric: leaf switch {sw} reporting degraded "
                  f"links on {len(members)} ports")
        self._add(t + w + 1e-3, -1,
                  f"INFO fabric: leaf switch {sw} link quality restored")

    def _reg_dns_flap(self, ev) -> None:
        rng = self.rng
        t = float(ev.time_h)
        w = max(float(getattr(ev, "window_h", 0.0)), 0.05)
        peers = [int(p) for p in getattr(ev, "peers", ())]
        members = [int(m) for m in getattr(ev, "members", ())]
        if not peers:
            return
        peer = peers[0]
        # partial-gang connectivity loss: only the flapped members speak,
        # and they all name the same unreachable peer (the Mycroft
        # setting again — the analyzer indicts the peer from references)
        for i, node in enumerate(members):
            self._add(t + 1e-4 * (i + 1), node,
                      f"ERROR rpc: name resolution for node-{peer} "
                      f"failed, transport reset after "
                      f"{int(rng.integers(1, 30))} retries")
        self._add(t + w + 1e-3, -1,
                  f"INFO dns: record for node-{peer} restored, "
                  f"flap cleared")

    def _reg_ctrl_blind(self, ev) -> None:
        t = float(ev.time_h)
        w = max(float(getattr(ev, "window_h", 0.0)), 0.0)
        self._add(t + 1e-3, -1,
                  "ERROR scheduler: control plane heartbeat missed, "
                  "decisions suspended")
        self._add(t + w, -1,
                  "INFO scheduler: control plane recovered, "
                  "replaying queued decisions")

    # -- emission (chunk order; noise draws live here) ----------------------

    def emit_window(self, t0: float, t1: float,
                    gang: Sequence[int]) -> List[LogLine]:
        """All log lines with ``t0 <= time < t1``; ``gang`` is the node set
        of the currently-running session (empty when idle)."""
        if not self._sealed:
            self._prog.sort(key=lambda p: p[0])
            self._sealed = True
        if t1 <= t0:
            return []
        gang_sorted = sorted(int(g) for g in gang) if len(gang) else []
        lines: List[LogLine] = []
        # 1) fault-program lines (registered; cursor over the sorted list)
        n = len(self._prog)
        while self._cursor < n and self._prog[self._cursor][0] < t1:
            t, node, text, gang_wide = self._prog[self._cursor]
            self._cursor += 1
            if t < t0:
                continue          # pre-campaign precursor tail, clamped out
            if gang_wide:
                for i, nd in enumerate(gang_sorted):
                    if nd == node:
                        continue  # the root cause does not report itself
                    lines.append(LogLine(t + 3e-5 * i, nd, text))
            else:
                lines.append(LogLine(t, node, text))
        # 2) lifecycle heartbeat: rank 0 reports progress on a fixed grid
        if gang_sorted:
            k = int(math.ceil(t0 / _HEARTBEAT_H - 1e-9))
            rank0 = gang_sorted[0]
            while k * _HEARTBEAT_H < t1 - 1e-12:
                tk = k * _HEARTBEAT_H
                if tk >= t0:
                    lines.append(LogLine(
                        tk, rank0,
                        f"INFO trainer: global step {k * 1800} complete, "
                        f"loss curve nominal"))
                k += 1
        # 3) background noise (window-level draws, chunk order)
        rng = self.rng
        span = t1 - t0
        count = int(rng.poisson(self.noise_per_node_h * self.n_nodes * span))
        if count:
            times = t0 + span * rng.uniform(0.0, 1.0, count)
            nodes = rng.integers(0, self.n_nodes, count)
            idxs = rng.integers(0, len(NOISE_TEMPLATES), count)
            vals = rng.integers(0, 100000, count)
            for j in range(count):
                lines.append(LogLine(
                    float(times[j]), int(nodes[j]),
                    NOISE_TEMPLATES[idxs[j]].format(v=int(vals[j]))))
        lines.sort(key=lambda ln: ln.time_h)   # stable: ties keep build order
        return lines
