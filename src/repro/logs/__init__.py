"""Synthetic operational log channel (L4-style diagnosis).

The paper's failure clusters were jointly diagnosed from operational logs
*and* Prometheus metrics; this package models the log side:

* :mod:`repro.logs.emitter` — a structured synthetic log emitter driven by
  the sim's failure schedule and session lifecycle (XID lines, NCCL/RPC
  errors, retry-chain output, storage stalls, background noise).
* :mod:`repro.logs.analysis` — an L4-style analysis pass: template
  extraction (tokenize -> variable masking -> template IDs), per-template
  burst + rarity scoring, and cross-node correlation that attributes a
  gang-wide error burst to one root-cause node (Mycroft-style).

`ControlPlane` fuses the analyzer's verdicts with the metric detector's
robust-stats vote behind the ``log_channel`` config gate (off by default;
see docs/LOG_CHANNEL.md).
"""
from repro.logs.emitter import (  # noqa: F401
    LogEmitter, LogLine, RNG_STREAM_LOGS,
)
from repro.logs.analysis import (  # noqa: F401
    LogAnalyzer, LogChannelConfig, LogVerdict,
)
