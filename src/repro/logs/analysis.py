"""L4-style log analysis: templates, burst/rarity scoring, attribution.

Pipeline (one pass, streaming, deterministic):

1. **Template extraction** — tokenize each line, mask digit-bearing and
   hex tokens to ``<*>``, intern the masked string as a template ID.  The
   level (first token) sets the template's base weight (ERROR 3, WARN 1,
   INFO 0); ``node-<id>`` references are captured *before* masking as
   cross-node attribution edges.
2. **Burst + rarity scoring** — lines bucket into fixed absolute windows
   of ``window_h``.  A template *qualifies* in a window when its count
   beats ``max(min_lines, burst_factor * rate * window_h)`` against its
   own historical rate baseline (burst), or when it is a near-unseen
   ERROR template (rarity).  Qualifying weight is boosted by rarity:
   ``level_w * (1 + rarity_boost / sqrt(1 + hist))``.
3. **Cross-node correlation** — qualifying line weight accrues to the
   *emitting* node, and ``ref_weight``-scaled weight to every *referenced*
   node (Mycroft-style: a gang-wide NCCL burst on 58 peers that all name
   ``node-17`` indicts node 17, not the 58 symptomatic peers).  A window
   yields at most one verdict: the top node, if its score clears
   ``min_score`` and ``dominance`` times the runner-up.

Windows are only scored once *complete* (fully covered by ingested
chunks); a trailing partial window is buffered for the next chunk, so
chunk boundaries — which differ between event spans but are mirrored
exactly between the scalar and batched engines — never change verdicts.
The first ``warmup_h`` hours only warm the baselines (cold-start guard:
with empty baselines every template would "burst" in window zero).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_MASK = re.compile(r"\S*\d\S*")
_REF = re.compile(r"node-(\d+)")
_KEEP = re.compile(r"[a-z]+")

_LEVEL_W = {"ERROR": 3.0, "WARN": 1.0}

_NET_KEYS = ("nfs", "rpc", "transport", "backlog", "retransmit")
_RES_KEYS = ("memory", "oom", "allocation", "reclaim", "cgroup")


def _class_of(masked: str) -> str:
    """Template class for alarm routing: ``net`` | ``res`` | ``node``."""
    t = masked.lower()
    if any(k in t for k in _NET_KEYS):
        return "net"
    if any(k in t for k in _RES_KEYS):
        return "res"
    return "node"


def _slug_of(masked: str) -> str:
    words = _KEEP.findall(masked.lower())[1:]      # drop the level token
    return "-".join(words)[:48] or "line"


@dataclass(frozen=True)
class LogChannelConfig:
    """Knobs for the log analysis pass (defaults tuned so steady noise
    never verdicts while fault programs verdict within one window)."""
    window_h: float = 0.25          # scoring window (absolute grid)
    warmup_h: float = 1.0           # baseline-only cold start
    min_lines: int = 2              # floor count for a burst
    burst_factor: float = 4.0       # count vs rate-baseline multiple
    rare_error_max: int = 8         # ERROR templates rarer than this
                                    #   qualify without bursting
    rarity_boost: float = 3.0       # weight boost ~ 1/sqrt(1 + hist)
    ref_weight: float = 1.0         # cross-node reference edge weight
    min_score: float = 6.0          # verdict floor
    dominance: float = 2.0          # top node vs runner-up ratio
    noise_per_node_h: float = 1.0   # emitter-side background chatter rate


@dataclass
class LogVerdict:
    """One window's root-cause attribution."""
    time_h: float                   # earliest contributing line on the node
    node: int
    score: float
    # (template name "log:<cls>:<slug>", contribution) — weight-sorted
    top: List[Tuple[str, float]] = field(default_factory=list)


class _Template:
    __slots__ = ("tid", "name", "cls", "level_w", "hist")

    def __init__(self, tid: int, masked: str):
        self.tid = tid
        self.cls = _class_of(masked)
        self.name = f"log:{self.cls}:{_slug_of(masked)}"
        self.level_w = _LEVEL_W.get(masked.split(" ", 1)[0], 0.0)
        self.hist = 0               # lifetime line count (rate baseline)


class LogAnalyzer:
    """Streaming template store + window scorer.  Feed it each chunk's
    lines via :meth:`ingest`; it returns the verdicts for every window
    the new chunk completed."""

    def __init__(self, config: Optional[LogChannelConfig] = None):
        self.cfg = config or LogChannelConfig()
        self._templates: Dict[str, _Template] = {}
        self._by_id: List[_Template] = []
        # parsed-but-unscored lines: (time_h, node, tid, refs)
        self._pending: List[tuple] = []
        self._scored_until = 0.0    # absolute time scored through

    @property
    def n_templates(self) -> int:
        return len(self._by_id)

    def template(self, text: str) -> _Template:
        masked = _MASK.sub("<*>", text)
        tmpl = self._templates.get(masked)
        if tmpl is None:
            tmpl = _Template(len(self._by_id), masked)
            self._templates[masked] = tmpl
            self._by_id.append(tmpl)
        return tmpl

    def ingest(self, lines, t1: float) -> List[LogVerdict]:
        """Parse ``lines`` (the chunk covering up to time ``t1``) and score
        every window that is now complete."""
        for ln in lines:
            refs = tuple(int(r) for r in _REF.findall(ln.text))
            self._pending.append(
                (ln.time_h, ln.node, self.template(ln.text).tid, refs))
        w = self.cfg.window_h
        m_end = int(math.floor(t1 / w + 1e-9))     # windows [0, m_end) done
        if m_end * w <= self._scored_until:
            return []
        ready: Dict[int, List[tuple]] = defaultdict(list)
        keep: List[tuple] = []
        for rec in self._pending:
            m = int(rec[0] / w)
            (ready[m] if m < m_end else keep).append(rec)
        self._pending = keep
        verdicts: List[LogVerdict] = []
        for m in sorted(ready):
            v = self._score_window(m, ready[m])
            if v is not None:
                verdicts.append(v)
        self._scored_until = m_end * w
        return verdicts

    def _score_window(self, m: int, recs: List[tuple]) -> \
            Optional[LogVerdict]:
        cfg = self.cfg
        w = cfg.window_h
        counts: Dict[int, int] = defaultdict(int)
        for rec in recs:
            counts[rec[2]] += 1
        verdict = None
        if m * w >= cfg.warmup_h - 1e-9:
            hours_before = max(m * w, w)
            weight: Dict[int, float] = {}
            for tid, c in counts.items():
                tmpl = self._by_id[tid]
                if tmpl.level_w <= 0.0:
                    continue                        # INFO never qualifies
                rate = tmpl.hist / hours_before
                burst = c >= max(cfg.min_lines, cfg.burst_factor * rate * w)
                rare_err = (tmpl.level_w >= 3.0
                            and tmpl.hist < cfg.rare_error_max)
                if burst or rare_err:
                    weight[tid] = tmpl.level_w * (
                        1.0 + cfg.rarity_boost / math.sqrt(1.0 + tmpl.hist))
            verdict = self._attribute(recs, weight) if weight else None
        for tid, c in counts.items():               # baselines after scoring
            self._by_id[tid].hist += c
        return verdict

    def _attribute(self, recs: List[tuple],
                   weight: Dict[int, float]) -> Optional[LogVerdict]:
        cfg = self.cfg
        score: Dict[int, float] = defaultdict(float)
        contrib: Dict[int, Dict[int, float]] = defaultdict(
            lambda: defaultdict(float))
        first: Dict[int, float] = {}
        for t, node, tid, refs in recs:
            wt = weight.get(tid)
            if wt is None:
                continue
            if node >= 0:
                score[node] += wt
                contrib[node][tid] += wt
                first[node] = min(first.get(node, t), t)
            for r in refs:
                if r != node and r >= 0:
                    score[r] += cfg.ref_weight * wt
                    contrib[r][tid] += cfg.ref_weight * wt
                    first[r] = min(first.get(r, t), t)
        if not score:
            return None
        # deterministic argmax: score desc, node asc on ties
        best = min(score, key=lambda nd: (-score[nd], nd))
        top_score = score[best]
        runner_up = max((s for nd, s in score.items() if nd != best),
                        default=0.0)
        if top_score < cfg.min_score or top_score < cfg.dominance * runner_up:
            return None
        top = sorted(contrib[best].items(), key=lambda kv: (-kv[1], kv[0]))
        return LogVerdict(
            time_h=first[best], node=best, score=top_score,
            top=[(self._by_id[tid].name, s) for tid, s in top[:5]])
