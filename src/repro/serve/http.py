"""Stdlib JSON front door for the what-if service.

A `ThreadingHTTPServer` (no dependency beyond the standard library, so
tier-1 stays hermetic) exposing the service core:

* ``POST /whatif`` — ``{"preset": name | "scenario": {...},
  "overrides": {...}, "seeds": N}`` -> the distributional answer
  (median/IQR/95%-CI per metric) with its provenance
  (``source``: cache / surface / engine) and per-request latency;
* ``GET /surface`` — the precomputed sweep surface's metadata (axes,
  grid size, error bound), or ``{"surface": null}`` when none is built;
* ``GET /healthz`` — liveness;
* ``GET /stats`` — queries, cache hit/miss/eviction counts, coalescer
  window/dedup counters, engine passes, uptime.

Run it:

    PYTHONPATH=src python -m repro.serve.http --port 8777 --surface

    curl -s localhost:8777/whatif -d '{"preset": "flaky-fabric",
                                       "seeds": 32}'
"""
from __future__ import annotations

import argparse
import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.ops.scenario import get_scenario
from repro.serve.service import (ServiceConfig, WhatIfService,
                                 scenario_from_request)
from repro.serve.surface import SurfaceSpec, SweepSurface

__all__ = ["WhatIfHTTPServer", "make_server", "main"]

_MAX_BODY = 1 << 20                 # 1 MiB: a scenario spec is ~1 KiB


class WhatIfHTTPServer(ThreadingHTTPServer):
    """One service instance shared by all handler threads."""

    daemon_threads = True

    def __init__(self, addr, service: WhatIfService, verbose: bool = False):
        self.service = service
        self.verbose = verbose
        super().__init__(addr, _Handler)


class _Handler(BaseHTTPRequestHandler):
    server: WhatIfHTTPServer

    # -- plumbing -----------------------------------------------------------

    def log_message(self, fmt, *args):             # noqa: A002
        if self.server.verbose:
            super().log_message(fmt, *args)

    def _reply(self, code: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str) -> None:
        self._reply(code, {"error": message})

    # -- routes -------------------------------------------------------------

    def do_GET(self) -> None:                      # noqa: N802
        svc = self.server.service
        if self.path == "/healthz":
            self._reply(200, {"ok": True})
        elif self.path == "/stats":
            self._reply(200, svc.stats())
        elif self.path == "/surface":
            self._reply(200, {"surface": svc.surface.info()
                              if svc.surface else None})
        else:
            self._error(404, f"unknown path {self.path!r} "
                             "(try /whatif, /surface, /healthz, /stats)")

    def do_POST(self) -> None:                     # noqa: N802
        if self.path != "/whatif":
            self._error(404, f"unknown path {self.path!r} (POST /whatif)")
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
        except ValueError:
            length = -1
        if not 0 < length <= _MAX_BODY:
            self._error(413 if length > _MAX_BODY else 400,
                        "body required (JSON query, <= 1 MiB)")
            return
        try:
            payload = json.loads(self.rfile.read(length))
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            self._error(400, f"bad JSON: {e}")
            return
        svc = self.server.service
        try:
            scenario = scenario_from_request(payload)
            seeds = payload.get("seeds")
            answer = svc.query(scenario,
                               None if seeds is None else int(seeds))
        except (KeyError, ValueError, TypeError) as e:
            self._error(400, str(e))
            return
        self._reply(200, answer.to_dict())


def make_server(service: WhatIfService, host: str = "127.0.0.1",
                port: int = 0, verbose: bool = False) -> WhatIfHTTPServer:
    """Bind (port 0 = ephemeral, for tests); caller runs serve_forever."""
    return WhatIfHTTPServer((host, port), service, verbose=verbose)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="what-if campaign query service (JSON over HTTP)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8777)
    ap.add_argument("--window-ms", type=float, default=20.0,
                    help="request-coalescing window: concurrent queries "
                         "arriving within it share one stacked engine "
                         "pass (0 disables coalescing)")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="LRU entries of finished distributions "
                         "(0 disables the cache)")
    ap.add_argument("--default-seeds", type=int, default=None,
                    help="Monte Carlo seeds per query when the request "
                         "does not set 'seeds'")
    ap.add_argument("--wavefront-backend", default="auto",
                    choices=("auto", "numpy", "xla", "pallas"),
                    help="campaign engine backend for live passes")
    ap.add_argument("--surface", action="store_true",
                    help="precompute the preset sweep surface (node "
                         "count x nvlink tilt x checkpoint cadence "
                         "around --surface-base) before serving; near-"
                         "miss queries interpolate instead of simulating")
    ap.add_argument("--surface-base", default="paper-faithful",
                    help="preset the surface grid is built around")
    ap.add_argument("--surface-days", type=float, default=None,
                    help="override the surface base campaign length "
                         "(shorter builds faster)")
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per request")
    args = ap.parse_args(argv)

    cfg = ServiceConfig(window_s=args.window_ms / 1e3,
                        coalesce=args.window_ms > 0,
                        cache_capacity=args.cache_capacity,
                        wavefront_backend=args.wavefront_backend)
    if args.default_seeds is not None:
        cfg.default_seeds = args.default_seeds
    surface = None
    if args.surface:
        base = get_scenario(args.surface_base)
        if args.surface_days is not None:
            base = base.replace(duration_days=args.surface_days)
        spec = SurfaceSpec(base=base)
        print(f"building surface: {spec.base.name}, "
              f"{len(spec.n_nodes)}x{len(spec.tilts)}x"
              f"{len(spec.ckpt_hours)} grid x {spec.seeds} seeds…",
              flush=True)
        surface = SweepSurface(
            spec, wavefront_backend=args.wavefront_backend).build()
        print(f"surface built in {surface.build_wall_s:.1f} s")
    service = WhatIfService(cfg, surface=surface)
    server = make_server(service, args.host, args.port,
                         verbose=args.verbose)
    host, port = server.server_address[:2]
    print(f"what-if service on http://{host}:{port} "
          f"(window {args.window_ms:.0f} ms, cache "
          f"{args.cache_capacity}, surface "
          f"{'on' if surface else 'off'}) — POST /whatif", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        service.close()


if __name__ == "__main__":
    main()
