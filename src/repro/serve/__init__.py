"""Sweep-as-a-service: coalesced what-if campaign queries.

The served front door over the Monte Carlo campaign engines: concurrent
"given this failure mix / node count / checkpoint cadence, what goodput
should I expect?" queries waterfall through a canonical-key LRU cache,
precomputed interpolated sweep surfaces, and window-coalesced stacked
engine passes (`repro.serve.service` has the layer-by-layer story;
`repro.serve.http` is the stdlib JSON transport; the model-inference
serving driver remains `repro.launch.serve`).
"""
from repro.serve.cache import DistributionCache
from repro.serve.coalesce import Coalescer
from repro.serve.service import (ServiceConfig, WhatIfAnswer,
                                 WhatIfService, scenario_from_request)
from repro.serve.surface import SurfaceSpec, SweepSurface

__all__ = [
    "Coalescer", "DistributionCache", "ServiceConfig", "SurfaceSpec",
    "SweepSurface", "WhatIfAnswer", "WhatIfService",
    "scenario_from_request",
]
