"""Bounded LRU of finished what-if distributions.

Keys are canonical scenario keys (`Scenario.canonical_key()` plus the
seed-count suffix the service appends), values are finished answer
payloads — the cache never stores in-flight work (the service's
in-flight table handles coalescing; the cache only ever sees completed
distributions).  Thread-safe; every operation is O(1) under one lock,
which is what makes cache hits a sub-millisecond answer path.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Optional

__all__ = ["DistributionCache"]


class DistributionCache:
    """LRU mapping canonical query keys to finished answers.

    ``capacity <= 0`` disables the cache (every ``get`` misses, ``put``
    is a no-op) — the service uses that for the naive benchmark arms.
    """

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._data: "OrderedDict[str, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: str) -> Optional[Any]:
        with self._lock:
            if key not in self._data:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]

    def put(self, key: str, value: Any) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._data), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}
