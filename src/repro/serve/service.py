"""The what-if campaign query service core (transport-agnostic).

One query = "given this scenario, what goodput / F-findings should I
expect?", answered distributionally (median/IQR/95%-CI per metric over N
Monte Carlo seeds).  Queries waterfall through three performance layers,
cheapest first:

1. **cache** — a bounded LRU of finished distributions keyed on the
   canonical scenario key (`Scenario.canonical_key()`), so equivalent
   specs (dict-order, preset-vs-explicit, int-vs-float spelling) hit
   without touching the engine;
2. **surface** — precomputed preset-grid distributions with multilinear
   interpolation for near-miss queries (`repro.serve.surface`), an
   *estimate* answer path that never claims engine parity;
3. **engine** — live stacked passes.  Concurrent misses are coalesced:
   an in-flight table attaches duplicate keys to the pass already
   running, and the `Coalescer` window batches the distinct keys of a
   burst into ONE `run_findings_stacked` call (grouped per config /
   node count inside).  Per-request answers are bitwise identical to a
   serial per-request pass — lanes never interact, so coalescing is
   free dispatch amortization, not approximation.

The core is plain objects + threads (unit-testable without sockets);
`repro.serve.http` wraps it in a stdlib JSON API.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.batch import run_findings_stacked
from repro.ops.scenario import Scenario, get_scenario
from repro.ops.sweep import MIN_DIST_SEEDS, findings_distribution
from repro.serve.cache import DistributionCache
from repro.serve.coalesce import Coalescer
from repro.serve.surface import SweepSurface

__all__ = ["ServiceConfig", "WhatIfAnswer", "WhatIfService",
           "scenario_from_request"]


@dataclass
class ServiceConfig:
    """Knobs for the three layers (all independently disableable, which
    is how the benchmark isolates each layer's contribution)."""

    window_s: float = 0.02          # coalescing window (10-50 ms)
    max_batch: int = 64             # early-dispatch threshold
    cache_capacity: int = 256       # LRU entries; <=0 disables
    default_seeds: int = 2 * MIN_DIST_SEEDS
    max_seeds: int = 1024           # per-query ceiling (DoS guard)
    coalesce: bool = True           # False: misses run in caller thread
    dedupe_inflight: bool = True    # False: duplicates each run a pass
    wavefront_backend: str = "auto"


@dataclass
class WhatIfAnswer:
    """One served answer: the distribution plus provenance."""

    scenario: str                   # query's scenario name (label only)
    key: str                        # canonical cache key
    n_seeds: int
    source: str                     # "cache" | "surface" | "engine"
    distribution: Dict[str, dict]   # metric -> n/mean/median/q25/q75/ci
    distributional: bool            # n_seeds >= MIN_DIST_SEEDS
    wall_s: float = 0.0
    meta: Optional[dict] = None     # surface: coords + error estimate

    def to_dict(self) -> dict:
        return {"scenario": self.scenario, "key": self.key,
                "n_seeds": self.n_seeds, "source": self.source,
                "distributional": self.distributional,
                "wall_s": self.wall_s, "meta": self.meta,
                "distribution": self.distribution}


def scenario_from_request(payload: dict) -> Scenario:
    """Resolve a request payload to a `Scenario`.

    ``{"preset": name}`` resolves a preset; ``{"scenario": {...}}``
    builds from an (optionally partial) spec dict — missing fields fill
    from the dataclass defaults, a missing ``name`` becomes "adhoc".
    ``"overrides"`` (field -> value) applies on top of either; unknown
    fields raise (a typo must not silently become the default campaign).
    """
    if not isinstance(payload, dict):
        raise ValueError("request payload must be a JSON object")
    has_preset = "preset" in payload
    spec = payload.get("scenario")
    if has_preset == (spec is not None):
        raise ValueError(
            "request needs exactly one of 'preset' or 'scenario'")
    if has_preset:
        sc = get_scenario(payload["preset"])
    else:
        if not isinstance(spec, dict):
            raise ValueError("'scenario' must be a spec object")
        spec = dict(spec)
        spec.setdefault("name", "adhoc")
        try:
            sc = Scenario.from_dict(spec)
        except TypeError as e:
            raise ValueError(f"bad scenario spec: {e}") from None
    overrides = payload.get("overrides") or {}
    if overrides:
        if not isinstance(overrides, dict):
            raise ValueError("'overrides' must be an object")
        try:
            sc = sc.replace(**overrides)
        except TypeError as e:
            raise ValueError(f"bad overrides: {e}") from None
    return sc


class WhatIfService:
    """Coalesced, cached, surface-accelerated what-if queries.

    ``engine_fn`` defaults to `run_findings_stacked` and exists for
    instrumentation (tests count engine passes through it); it must
    preserve that function's contract.
    """

    def __init__(self, config: Optional[ServiceConfig] = None,
                 surface: Optional[SweepSurface] = None,
                 engine_fn: Optional[Callable] = None):
        self.config = config or ServiceConfig()
        self.surface = surface
        self._engine_fn = engine_fn or (
            lambda cfgs, seeds: run_findings_stacked(
                cfgs, seeds,
                wavefront_backend=self.config.wavefront_backend))
        self.cache = DistributionCache(self.config.cache_capacity)
        self._coalescer = Coalescer(
            self._run_batch, window_s=self.config.window_s,
            max_batch=self.config.max_batch) if self.config.coalesce \
            else None
        self._inflight: Dict[str, Future] = {}
        self._inflight_lock = threading.Lock()
        self.n_queries = 0
        self.n_surface_hits = 0
        self.n_engine_configs = 0
        self.started = time.time()

    # -- public API ---------------------------------------------------------

    def query(self, scenario: Scenario,
              n_seeds: Optional[int] = None) -> WhatIfAnswer:
        return self.query_async(scenario, n_seeds).result()

    def query_async(self, scenario: Scenario,
                    n_seeds: Optional[int] = None) -> "Future[WhatIfAnswer]":
        t0 = time.perf_counter()
        self.n_queries += 1
        n = self.config.default_seeds if n_seeds is None else int(n_seeds)
        if not 1 <= n <= self.config.max_seeds:
            raise ValueError(
                f"n_seeds must be in [1, {self.config.max_seeds}], got {n}")
        key = f"{scenario.canonical_key()}:s{n}"

        done: "Future[WhatIfAnswer]" = Future()
        cached = self.cache.get(key)
        if cached is not None:
            done.set_result(self._stamp(cached, "cache", t0))
            return done
        hit = self.surface.lookup(scenario) if self.surface else None
        if hit is not None:
            self.n_surface_hits += 1
            ans = WhatIfAnswer(
                scenario=scenario.name, key=key,
                n_seeds=hit["distribution"].get(
                    "goodput", {}).get("n", self.surface.spec.seeds),
                source="surface", distribution=hit["distribution"],
                distributional=self.surface.spec.seeds >= MIN_DIST_SEEDS,
                wall_s=time.perf_counter() - t0,
                meta={"coords": hit["coords"],
                      "interp_err_goodput": hit["interp_err_goodput"]})
            done.set_result(ans)
            return done
        return self._engine_path(scenario, n, key, t0)

    def close(self) -> None:
        if self._coalescer is not None:
            self._coalescer.close()

    def stats(self) -> dict:
        out = {
            "queries": self.n_queries,
            "engine_configs": self.n_engine_configs,
            "surface_hits": self.n_surface_hits,
            "cache": self.cache.stats(),
            "coalescer": self._coalescer.stats()
            if self._coalescer else None,
            "surface": self.surface.info() if self.surface else None,
            "uptime_s": time.time() - self.started,
            "config": {
                "window_s": self.config.window_s,
                "default_seeds": self.config.default_seeds,
                "max_seeds": self.config.max_seeds,
                "coalesce": self.config.coalesce,
                "wavefront_backend": self.config.wavefront_backend,
            },
        }
        return out

    # -- engine path --------------------------------------------------------

    def _engine_path(self, scenario: Scenario, n: int, key: str,
                     t0: float) -> "Future[WhatIfAnswer]":
        payload = (scenario, n)
        if self.config.dedupe_inflight:
            with self._inflight_lock:
                running = self._inflight.get(key)
                owner = running is None
                if owner:
                    # placeholder registered under the lock; the engine
                    # work runs outside it so distinct keys never block
                    # on each other's passes
                    running = Future()
                    self._inflight[key] = running
                    running.add_done_callback(
                        lambda _f, k=key: self._inflight.pop(k, None))
            if owner:
                self._chain(self._submit(key, payload), running)
        else:
            running = self._submit(key, payload)
        done: "Future[WhatIfAnswer]" = Future()

        def _relay(f: Future) -> None:
            e = f.exception()
            if e is not None:
                done.set_exception(e)
            else:
                done.set_result(self._stamp(f.result(), "engine", t0))
        running.add_done_callback(_relay)
        return done

    @staticmethod
    def _chain(src: Future, dst: Future) -> None:
        def _copy(f: Future) -> None:
            e = f.exception()
            if e is not None:
                dst.set_exception(e)
            else:
                dst.set_result(f.result())
        src.add_done_callback(_copy)

    def _submit(self, key: str, payload: Tuple[Scenario, int]) -> Future:
        if self._coalescer is not None:
            return self._coalescer.submit(key, payload)
        fut: Future = Future()
        try:
            fut.set_result(self._run_batch([(key, payload)])[key])
        except BaseException as e:                 # noqa: BLE001
            fut.set_exception(e)
        return fut

    def _run_batch(self, batch: List[Tuple[str, Tuple[Scenario, int]]]
                   ) -> Dict[str, WhatIfAnswer]:
        """One coalesced dispatch: group the window's distinct queries by
        seed count (the engine's seed axis is shared per pass), run each
        group as ONE stacked call, demultiplex per-key distributions."""
        by_seeds: Dict[int, List[Tuple[str, Scenario]]] = {}
        for key, (scenario, n) in batch:
            by_seeds.setdefault(n, []).append((key, scenario))
        out: Dict[str, WhatIfAnswer] = {}
        for n, items in sorted(by_seeds.items()):
            cfgs = [sc.to_campaign_config(0) for _, sc in items]
            self.n_engine_configs += len(cfgs)
            per_cfg = self._engine_fn(cfgs, list(range(n)))
            for (key, sc), by_seed in zip(items, per_cfg):
                ans = WhatIfAnswer(
                    scenario=sc.name, key=key, n_seeds=n, source="engine",
                    distribution=findings_distribution(
                        list(by_seed.values())),
                    distributional=n >= MIN_DIST_SEEDS)
                self.cache.put(key, ans)
                out[key] = ans
        return out

    @staticmethod
    def _stamp(ans: WhatIfAnswer, source: str, t0: float) -> WhatIfAnswer:
        """Per-request copy: the cached/shared answer object stays
        immutable, each caller gets its own provenance + latency."""
        return WhatIfAnswer(
            scenario=ans.scenario, key=ans.key, n_seeds=ans.n_seeds,
            source=source, distribution=ans.distribution,
            distributional=ans.distributional,
            wall_s=time.perf_counter() - t0, meta=ans.meta)
