"""Request coalescing: a batching queue over the campaign engine.

Concurrent what-if queries arrive on caller threads; a single dispatcher
thread collects them for a short window (``window_s``), dedupes by
canonical key, hands ONE batch to the runner callable, and demultiplexes
the per-key results back onto each caller's future.  The engine cost of
a window is therefore one stacked pass over the *distinct* scenarios in
it, not one pass per request — the dispatch amortization the service
exists for.

The coalescer is generic: it knows keys, payloads and a runner
``batch -> {key: result}``; what a "pass" means (grouping heterogeneous
configs, seed stacking) lives in the runner (`WhatIfService._run_batch`).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Tuple

__all__ = ["Coalescer"]


class Coalescer:
    """Window-batching queue with per-key dedup.

    ``runner(batch)`` receives ``[(key, payload), ...]`` with distinct
    keys (first payload wins for duplicates submitted in one window) and
    returns ``{key: result}``.  Every future submitted under a key gets
    that key's result; a runner exception fails every future of the
    window.  ``submit`` never blocks on the engine — callers wait on the
    returned future.

    * ``window_s`` — how long the dispatcher collects after the first
      request of a window lands (10-50 ms trades latency for batching).
    * ``max_batch`` — dispatch early once this many requests are queued
      (bounds worst-case batch latency under a thundering herd).
    """

    def __init__(self, runner: Callable[[List[Tuple[str, Any]]],
                                        Dict[str, Any]],
                 window_s: float = 0.02, max_batch: int = 64):
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        self.runner = runner
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self._cv = threading.Condition()
        self._queue: List[Tuple[str, Any, Future]] = []
        self._closed = False
        # stats (read without the lock: monotone counters, display only)
        self.n_requests = 0
        self.n_deduped = 0
        self.n_windows = 0
        self.n_dispatched = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="whatif-coalescer")
        self._thread.start()

    # -- caller side --------------------------------------------------------

    def submit(self, key: str, payload: Any) -> Future:
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("coalescer is closed")
            self._queue.append((key, payload, fut))
            self.n_requests += 1
            self._cv.notify()
        return fut

    def close(self) -> None:
        """Stop the dispatcher; queued requests still run (one final
        window), new submissions are rejected."""
        with self._cv:
            self._closed = True
            self._cv.notify()
        self._thread.join(timeout=30.0)

    # -- dispatcher side ----------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue and self._closed:
                    return
                # first request opens the window; keep collecting until
                # the deadline or the early-dispatch threshold
                deadline = time.monotonic() + self.window_s
                while len(self._queue) < self.max_batch:
                    left = deadline - time.monotonic()
                    if left <= 0 or self._closed:
                        break
                    self._cv.wait(timeout=left)
                batch, self._queue = self._queue, []
            self._dispatch(batch)

    def _dispatch(self, batch: List[Tuple[str, Any, Future]]) -> None:
        distinct: "Dict[str, Any]" = {}
        for key, payload, _ in batch:
            distinct.setdefault(key, payload)
        self.n_windows += 1
        self.n_dispatched += len(distinct)
        self.n_deduped += len(batch) - len(distinct)
        try:
            results = self.runner(list(distinct.items()))
        except BaseException as e:                 # noqa: BLE001
            for _, _, fut in batch:
                if not fut.done():
                    fut.set_exception(e)
            return
        for key, _, fut in batch:
            if fut.done():
                continue
            if key in results:
                fut.set_result(results[key])
            else:
                fut.set_exception(KeyError(
                    f"runner returned no result for key {key!r}"))

    def stats(self) -> dict:
        return {"requests": self.n_requests, "windows": self.n_windows,
                "dispatched": self.n_dispatched, "deduped": self.n_deduped,
                "window_s": self.window_s, "max_batch": self.max_batch}
