"""Precomputed what-if sweep surfaces with multilinear interpolation.

The service's cheapest answer path after the cache: the preset grid —
node count x failure-mix tilt x checkpoint cadence around a base
scenario — is evaluated offline into dense per-metric distribution
surfaces (one stacked engine pass per node count, via
`run_findings_stacked`).  A query that differs from the base scenario
*only* along those three axes and lands inside the grid is answered by
multilinear interpolation in microseconds; everything else — off-grid
axes, out-of-hull coordinates, or an interpolation error estimate above
the spec's bound — falls back to a live engine pass.

Interpolated answers are estimates, not simulations: the service labels
them ``source="surface"`` and never mixes them into the bitwise-parity
engine path.  The error estimate is the standard linear-interpolation
curvature bound |f''| h^2 / 8, read off the grid's own second
differences of the goodput median along each axis (axes with only two
points carry no curvature information and contribute zero — size such
axes to three points when the bound matters).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ops.scenario import Scenario
from repro.ops.sweep import findings_distribution

__all__ = ["SurfaceSpec", "SweepSurface"]

# the distribution fields interpolated per metric (n is carried verbatim)
_STAT_FIELDS = ("mean", "median", "q25", "q75", "ci_lo", "ci_hi")


@dataclass
class SurfaceSpec:
    """The preset grid: which three axes vary, over which values.

    * ``n_nodes`` — cluster sizes; the gang size follows with the base
      scenario's spare count (``job_nodes = n_nodes - spares``);
    * ``tilts`` — multiplicative ``kind_weights`` tilt applied to
      ``tilt_kind`` (1.0 = the base mix);
    * ``ckpt_hours`` — fixed checkpoint cadence values (the base
      scenario must use ``checkpoint_strategy="fixed"``);
    * ``seeds`` — Monte Carlo seeds per grid point;
    * ``max_goodput_err`` — interpolation error bound on the goodput
      median above which the service falls back to a live pass.
    """

    base: Scenario
    n_nodes: Tuple[int, ...] = (31, 63, 127)
    tilt_kind: str = "nvlink"
    tilts: Tuple[float, ...] = (1.0, 2.0, 4.0)
    ckpt_hours: Tuple[float, ...] = (1.0, 2.23, 4.0)
    seeds: int = 16
    max_goodput_err: float = 0.02

    def __post_init__(self):
        self.n_nodes = tuple(self.n_nodes)
        self.tilts = tuple(float(t) for t in self.tilts)
        self.ckpt_hours = tuple(float(c) for c in self.ckpt_hours)
        for name, ax in (("n_nodes", self.n_nodes), ("tilts", self.tilts),
                         ("ckpt_hours", self.ckpt_hours)):
            if len(ax) < 2 or any(b <= a for a, b in zip(ax, ax[1:])):
                raise ValueError(
                    f"surface axis {name} must be >=2 strictly "
                    f"ascending values, got {ax}")
        if self.base.checkpoint_strategy != "fixed":
            raise ValueError(
                "surface cadence axis needs checkpoint_strategy='fixed' "
                f"(base uses {self.base.checkpoint_strategy!r})")
        spares = self.base.n_nodes - self.base.job_nodes
        if self.n_nodes[0] <= spares:
            raise ValueError(
                f"n_nodes axis starts at {self.n_nodes[0]} but the base "
                f"scenario keeps {spares} spares")

    def point(self, nv: int, tilt: float, ckpt_h: float) -> Scenario:
        """The scenario at one grid point."""
        spares = self.base.n_nodes - self.base.job_nodes
        kw = dict(self.base.kind_weights or {})
        kw[self.tilt_kind] = tilt
        return self.base.replace(
            name=f"{self.base.name}@{nv}n/{tilt:g}x/{ckpt_h:g}h",
            n_nodes=int(nv), job_nodes=int(nv) - spares,
            kind_weights=kw, checkpoint_interval_h=float(ckpt_h))


class SweepSurface:
    """Dense distribution surfaces over a `SurfaceSpec` grid."""

    def __init__(self, spec: SurfaceSpec,
                 wavefront_backend: str = "auto"):
        self.spec = spec
        self.wavefront_backend = wavefront_backend
        self.shape = (len(spec.n_nodes), len(spec.tilts),
                      len(spec.ckpt_hours))
        # metric -> stat field -> grid ndarray (nan where not applicable)
        self.values: Dict[str, Dict[str, np.ndarray]] = {}
        self.built = False
        self.build_wall_s = 0.0
        self._axes = (np.asarray(spec.n_nodes, dtype=float),
                      np.asarray(spec.tilts, dtype=float),
                      np.asarray(spec.ckpt_hours, dtype=float))
        # residual check: a query is surface-shaped iff resetting the
        # three axis fields to the base's values reproduces the base key
        self._base_key = spec.base.canonical_key()

    # -- offline build ------------------------------------------------------

    def build(self, engine_fn=None) -> "SweepSurface":
        """Evaluate every grid point (one stacked pass per node count —
        grid scenarios are control-free iff the base is; the engine
        groups them, see `run_findings_stacked`)."""
        from repro.core.batch import run_findings_stacked
        if engine_fn is None:
            def engine_fn(cfgs, seeds):
                return run_findings_stacked(
                    cfgs, seeds, wavefront_backend=self.wavefront_backend)
        t0 = time.perf_counter()
        spec = self.spec
        points = list(itertools.product(spec.n_nodes, spec.tilts,
                                        spec.ckpt_hours))
        cfgs = [spec.point(*p).to_campaign_config(0) for p in points]
        per_cfg = engine_fn(cfgs, list(range(spec.seeds)))
        dists = [findings_distribution(list(by_seed.values()))
                 for by_seed in per_cfg]
        metrics = sorted({m for d in dists for m in d})
        for m in metrics:
            self.values[m] = {
                f: np.full(self.shape, np.nan) for f in _STAT_FIELDS}
        for flat, d in enumerate(dists):
            idx = np.unravel_index(flat, self.shape)
            for m, st in d.items():
                for f in _STAT_FIELDS:
                    self.values[m][f][idx] = st[f]
        self.built = True
        self.build_wall_s = time.perf_counter() - t0
        return self

    # -- query side ---------------------------------------------------------

    def coords(self, scenario: Scenario) -> Optional[Tuple[float, ...]]:
        """Grid coordinates for a surface-shaped query, else None.

        Surface-shaped means: identical to the base scenario on every
        non-axis field (canonical residual check), gang size keeping the
        base's spare count, fixed-cadence checkpointing, non-tilt kind
        weights matching the base, and all three axis values inside the
        grid hull.
        """
        spec = self.spec
        if scenario.checkpoint_strategy != "fixed":
            return None
        spares = spec.base.n_nodes - spec.base.job_nodes
        if scenario.n_nodes - scenario.job_nodes != spares:
            return None
        kw = {k: v for k, v in (scenario.kind_weights or {}).items()
              if v != 1.0}
        tilt = kw.pop(spec.tilt_kind, 1.0)
        base_kw = {k: v for k, v in (spec.base.kind_weights or {}).items()
                   if v != 1.0 and k != spec.tilt_kind}
        if kw != base_kw:
            return None
        probe = scenario.replace(
            n_nodes=spec.base.n_nodes, job_nodes=spec.base.job_nodes,
            kind_weights=spec.base.kind_weights,
            checkpoint_interval_h=spec.base.checkpoint_interval_h)
        if probe.canonical_key() != self._base_key:
            return None
        q = (float(scenario.n_nodes), float(tilt),
             float(scenario.checkpoint_interval_h))
        for v, ax in zip(q, self._axes):
            if not (ax[0] <= v <= ax[-1]):
                return None
        return q

    def _cell(self, q: Sequence[float]) -> Tuple[List[int], List[float]]:
        """Lower corner index + fractional offset per axis."""
        lo, frac = [], []
        for v, ax in zip(q, self._axes):
            i = int(np.searchsorted(ax, v, side="right") - 1)
            i = min(max(i, 0), len(ax) - 2)
            t = (v - ax[i]) / (ax[i + 1] - ax[i])
            lo.append(i)
            frac.append(float(t))
        return lo, frac

    def _interp(self, grid: np.ndarray, lo: List[int],
                frac: List[float]) -> float:
        acc = 0.0
        for corner in itertools.product((0, 1), repeat=len(lo)):
            w = 1.0
            for c, t in zip(corner, frac):
                w *= t if c else 1.0 - t
            if w == 0.0:
                continue
            v = grid[tuple(i + c for i, c in zip(lo, corner))]
            if np.isnan(v):
                return float("nan")
            acc += w * v
        return float(acc)

    def error_estimate(self, q: Sequence[float]) -> float:
        """Linear-interpolation error bound on the goodput median at
        ``q``: sum over axes of |second difference| / 8 at the nearest
        grid lines (exactly 0 on grid nodes; 0 contribution from 2-point
        axes, which carry no curvature information)."""
        g = self.values.get("goodput", {}).get("median")
        if g is None:
            return 0.0
        lo, frac = self._cell(q)
        nearest = [i + (1 if t > 0.5 else 0) for i, t in zip(lo, frac)]
        if all(t in (0.0, 1.0) for t in frac):
            return 0.0
        err = 0.0
        for ax in range(len(lo)):
            n_ax = g.shape[ax]
            if n_ax < 3:
                continue
            c = min(max(nearest[ax], 1), n_ax - 2)
            idx = list(nearest)
            vals = []
            for off in (-1, 0, 1):
                idx[ax] = c + off
                vals.append(g[tuple(idx)])
            if any(np.isnan(v) for v in vals):
                continue
            err += abs(vals[0] - 2.0 * vals[1] + vals[2]) / 8.0
        return err

    def lookup(self, scenario: Scenario) -> Optional[dict]:
        """Interpolated distribution answer for a surface-shaped query
        within the error bound; None -> the caller runs a live pass."""
        if not self.built:
            return None
        q = self.coords(scenario)
        if q is None:
            return None
        err = self.error_estimate(q)
        if err > self.spec.max_goodput_err:
            return None
        lo, frac = self._cell(q)
        dist: Dict[str, dict] = {}
        for m, fields in self.values.items():
            st = {f: self._interp(fields[f], lo, frac)
                  for f in _STAT_FIELDS}
            if any(np.isnan(v) for v in st.values()):
                continue
            st["n"] = self.spec.seeds
            dist[m] = st
        return {"distribution": dist, "coords": list(q),
                "interp_err_goodput": err}

    def info(self) -> dict:
        """Metadata for the ``/surface`` endpoint."""
        spec = self.spec
        return {
            "built": self.built,
            "base": spec.base.name,
            "base_key": self._base_key,
            "axes": {"n_nodes": list(spec.n_nodes),
                     f"tilt[{spec.tilt_kind}]": list(spec.tilts),
                     "ckpt_hours": list(spec.ckpt_hours)},
            "grid_points": int(np.prod(self.shape)),
            "seeds_per_point": spec.seeds,
            "max_goodput_err": spec.max_goodput_err,
            "metrics": sorted(self.values),
            "build_wall_s": self.build_wall_s,
        }
