"""Multi-signal failure (precursor) detection — paper F1 / §4.1.

Because all N nodes execute the same SPMD program, anomaly detection is
framed as deviation from the peer distribution: at each scrape tick, for each
metric, compute a robust z-score of every node against the other N-1 nodes
(median/MAD — resistant to the faulty node polluting the baseline).  A node
alarms when >= ``min_signals`` metrics exceed ``z_threshold`` simultaneously
for ``persistence`` consecutive ticks.

The paper's result with this family of detectors: 10/10 detection at the XID
point, 2/10 pre-XID, ~0.84 false positives/day — and *no single metric is
consistently dominant*, which is why the vote is across the whole metric set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.telemetry.registry import TimeSeriesStore


@dataclass(frozen=True)
class DetectorConfig:
    z_threshold: float = 6.0
    min_signals: int = 4          # metrics that must agree (multi-signal vote)
    persistence: int = 1          # consecutive ticks before alarming
    exclude_metrics: tuple = ("DCGM_FI_DEV_XID_ERRORS",)  # no label leakage
    # peer cohort: only nodes actively running the same SPMD workload are
    # comparable (paper: "the remaining 59 healthy nodes"); idle spares and
    # operator-isolated nodes would otherwise alarm constantly.
    activity_metric: str = "DCGM_FI_DEV_GPU_UTIL"
    activity_threshold: float = 30.0


@dataclass
class Alarm:
    tick: int
    time_h: float
    node: int
    n_signals: int
    top_metrics: List[Tuple[str, float]]   # (metric, |z|) strongest first


def robust_peer_z(values: np.ndarray) -> np.ndarray:
    """Per-node robust z-score vs the peer distribution at one tick.

    values: (n_nodes,).  Uses median/MAD of all nodes (the faulty node is
    <=1/N of the sample, so median/MAD are stable).
    """
    med = np.median(values)
    mad = np.median(np.abs(values - med))
    scale = 1.4826 * mad
    if scale < 1e-12:
        scale = max(1e-12, 1e-6 * max(abs(med), 1.0))
    return (values - med) / scale


class PrecursorDetector:
    def __init__(self, config: Optional[DetectorConfig] = None,
                 backend: str = "numpy"):
        # per-instance default: a shared default-argument instance would
        # alias every detector's config
        self.config = config if config is not None else DetectorConfig()
        self.backend = backend

    def scan(self, store: TimeSeriesStore) -> List[Alarm]:
        """Run detection over a full telemetry store; returns alarms.

        Delegates to the streaming core (`repro.control.streaming`) with a
        single push of the whole store, so the offline and online paths
        share one implementation: a chunked online feed of the same store
        reproduces this alarm list exactly (see the control-plane parity
        test).  ``backend`` routes pass 1 through the fused
        `repro.kernels.robust_stats` implementation ("xla" / "pallas");
        the default numpy path is the parity oracle.
        """
        from repro.control.streaming import StreamingDetector
        det = StreamingDetector(self.config, backend=self.backend)
        return det.push(store.times(),
                        {name: store.series(name) for name in store.names})


@dataclass
class EvalResult:
    n_failures: int
    detected: int
    pre_xid: int
    false_positives: int
    fp_per_day: float
    detection_lead_h: List[float]
    per_failure: List[dict] = field(default_factory=list)
    # indices (into the scored alarm sequence) that matched a failure —
    # the control plane uses this to split urgent-checkpoint spend into
    # justified (true positive) vs wasted (false positive)
    matched_alarm_ids: set = field(default_factory=set)

    @property
    def detection_rate(self) -> float:
        return self.detected / max(self.n_failures, 1)

    @property
    def pre_xid_rate(self) -> float:
        return self.pre_xid / max(self.n_failures, 1)


def evaluate(alarms: Sequence[Alarm], failures, duration_h: float,
             match_window_h: float = 0.5) -> EvalResult:
    """Score alarms against ground-truth failure events.

    detected  : an alarm on the failing node within +-match_window of the event
    pre_xid   : the alarm strictly precedes the event time
    false pos : alarms on healthy nodes / outside any event window, deduped
                per (node, hour) so a persisting anomaly counts once
    """
    detected = pre = 0
    leads: List[float] = []
    per_failure = []
    matched_alarm_ids = set()
    for ev in failures:
        window = [(i, a) for i, a in enumerate(alarms)
                  if a.node == ev.node
                  and ev.time_h - max(match_window_h, ev.precursor_lead_h + 0.1)
                  <= a.time_h <= ev.time_h + match_window_h]
        ok = len(window) > 0
        first = min((a.time_h for _, a in window), default=None)
        is_pre = ok and first < ev.time_h - 1e-9
        detected += ok
        pre += is_pre
        if ok:
            leads.append(ev.time_h - first)
            matched_alarm_ids.update(i for i, _ in window)
        per_failure.append({
            "node": ev.node, "time_h": ev.time_h, "xid": getattr(ev, "xid", None),
            "detected": ok, "pre_xid": bool(is_pre),
            "lead_h": (ev.time_h - first) if ok else None,
        })

    fp_keys = set()
    for i, a in enumerate(alarms):
        if i in matched_alarm_ids:
            continue
        fp_keys.add((a.node, int(a.time_h)))   # dedupe per node-hour
    n_fp = len(fp_keys)
    return EvalResult(
        n_failures=len(list(failures)), detected=detected, pre_xid=pre,
        false_positives=n_fp, fp_per_day=n_fp / max(duration_h / 24.0, 1e-9),
        detection_lead_h=leads, per_failure=per_failure,
        matched_alarm_ids=matched_alarm_ids)
