"""End-to-end cluster campaign simulation.

Drives a training campaign through: the gang scheduler, session lifecycle,
failure injection, telemetry scraping, XID-classified recovery, auto-retry
chains, node exclusion, and checkpoint timing — everything the paper's §4
measures.

Failure semantics (paper §4.3):
* transient failures (most XID hardware events with spares available, app
  errors) — the next gang allocation succeeds and the chain recovers;
* structural failures (software/NCCL-level, license/pool exhaustion) —
  restarts fail repeatedly at PREPARING until an operator intervenes; this
  is what made 8/12 of the paper's chains fail and burned a 30-attempt
  chain (§4.3.5).

Two engines share one campaign state machine (``_CampaignState``):

* ``engine="event"`` (default) — discrete-event loop.  Time jumps straight
  between state-changing events (failure arrivals, retry timers, PREPARING
  completions, repairs); checkpoint ticks are accounted analytically and
  telemetry for the constant-state span between events is generated in one
  batched numpy call (`ExporterSuite.tick_batch`).  This is what makes
  campaign sweeps cheap: a 73-day campaign is a few hundred events instead
  of ~210k 30-second ticks.
* ``engine="tick"`` — the original serial 30 s-tick loop, kept as the
  reference for the speedup benchmark and engine-parity tests.

Used by: benchmarks (taxonomy / precursor / retry / exclusion / downtime),
the scenario sweep runner (`repro.ops`), the fault-tolerant training
example, and the integration tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.youngdaly import MTBF_H_PAPER
from repro.control.policy import ControlConfig, ControlPlane, ControlStats
from repro.core.exclusion import ExclusionTracker
from repro.storage.fabric import FabricConfig, StorageFabric
from repro.core.failures import (CORRELATED_KINDS, DEGRADE_KINDS,
                                 FailureEvent, FailureInjector, INFRA_KINDS,
                                 blind_windows, degradation_windows,
                                 degraded_overlap_h, escalation_events)
from repro.core.retry import Attempt, Chain, RetryConfig, RetryEngine
from repro.core.scheduler import GangScheduler
from repro.core.session import Session, SessionState
from repro.core.xid import XID_TABLE
from repro.telemetry.exporters import (ExporterSuite, N_PAD_METRICS,
                                       NodeState, NodeStateBatch)
from repro.telemetry.registry import SCRAPE_INTERVAL_S, TimeSeriesStore

TICK_H = SCRAPE_INTERVAL_S / 3600.0

# batched telemetry emission: cap span chunks so transient (T, n_nodes)
# buffers stay modest even when the campaign runs uninterrupted for days
_MAX_SPAN_TICKS = 2048

# Dedicated rng streams (seeded ``default_rng([seed, salt])``) for the two
# exponential-draw families.  Keeping them off the main ``default_rng(seed)``
# stream leaves that stream consuming *only* ``random()`` uniforms, which
# makes it materializable up front as a flat draw tape (``rng.random(N)``
# equals N sequential ``rng.random()`` calls positionally) — the compiled
# wavefront core (kernels/wavefront) depends on this.  Ziggurat
# exponentials consume a variable number of raw draws per sample, so they
# can only be tape-ified from streams of their own.
RNG_STREAM_MANUAL = 7001      # operator manual-response delays
RNG_STREAM_STRUCT = 7013      # structural-fix (root-cause) durations


@dataclass
class CampaignConfig:
    n_nodes: int = 63
    job_nodes: int = 60
    duration_h: float = 73 * 24.0
    mtbf_h: float = MTBF_H_PAPER
    retry: RetryConfig = field(default_factory=RetryConfig)
    checkpoint_interval_h: float = 2.23      # 4K phase median
    checkpoint_save_s: float = 18.0
    loading_time_h: float = 31.0 / 60.0      # warm-cache restart loading
    loading_cold_h: float = 58.0 / 60.0      # cold cache (node replaced /
                                             #   full reboot; paper §4.2.4)
    # shared-NFS storage fabric: when set, checkpoint_save_s and the two
    # loading times above are REPLACED by fabric queries at the gang fanin
    # (save: the ckpt_pack bf16 wire volume bursting from job_nodes
    # writers; load: restore_bytes_per_node read by the whole gang on top
    # of the non-storage loading overhead)
    storage: Optional[FabricConfig] = None
    storage_slots: int = 128                 # client RPC slot table (loads
                                             #   run over nconnect=2 -> 2x)
    ckpt_bytes_per_node: int = 20 << 30
    ckpt_wire_ratio: float = 0.5             # fp32 -> bf16 ckpt_pack payload
    restore_bytes_per_node: int = 200 << 30
    loading_overhead_h: float = 29.5 / 60.0  # container/NCCL/dataset init
    loading_overhead_cold_h: float = 56.5 / 60.0
    # failure-class behaviour
    p_software_failure: float = 0.5          # NCCL/driver-level (structural)
    p_transient_retry_fail: float = 0.4      # residual issue on early retries
    structural_fix_mean_h: float = 5.0       # time until root cause fixed
    operator_notice_mean_h: float = 1.2      # failing chain noticed & stopped
    p_manual_misfix: float = 0.4             # operator fix incomplete ->
                                             #   next chain fails from start
    manual_response_h_day: float = 0.3
    manual_response_h_night: float = 1.5
    repair_time_h: float = 12.0              # node repair turnaround
    slow_isolation_h: float = 400.0          # fail-slow deliberate isolation
    p_pressure_readmit: float = 0.01         # per failed gang attempt: chance
                                             #   the operator readmits an
                                             #   isolated healthy node; at one
                                             #   attempt per ~11 min this is a
                                             #   mean ~18 h response (paper:
                                             #   the license case took hours)
    # failure-mix shaping (passed through to FailureInjector)
    hot_fraction: float = 0.05
    hot_weight: float = 0.55
    kind_weights: Optional[Dict[str, float]] = None
    topology_fanout: int = 8                 # leaf-switch fanout (the blast
                                             #   radius of switch_degrade)
    telemetry: bool = False
    telemetry_pad_metrics: Optional[int] = None   # None -> full 275-metric pad
    telemetry_store: bool = True             # False: stream-and-discard (the
                                             #   control plane consumes spans
                                             #   online; nothing is retained)
    # online detection->recovery control plane (event engine only).  Setting
    # this implies telemetry generation even when ``telemetry`` is False —
    # the streaming detector consumes the emitted spans.
    control: Optional[ControlConfig] = None
    engine: str = "event"                    # "event" | "tick"
    seed: int = 0


@dataclass
class CampaignResult:
    sessions: List[Session]
    chains: List[Chain]
    failures: List[FailureEvent]
    exclusions: ExclusionTracker
    store: Optional[TimeSeriesStore]
    downtimes: List[dict]                    # per recovery episode
    checkpoint_events: int
    lost_hours: List[float]
    duration_h: float
    checkpoint_save_s: float = 18.0          # resolved save cost (fabric-
                                             #   priced when storage is set)
    control: Optional[ControlStats] = None   # detection->recovery ledger
    degraded_hours: List[float] = field(default_factory=list)
                                             # per session: effective hours
                                             #   lost to degrade-band windows

    def training_occupancy(self) -> float:
        run = sum(s.elapsed_running_h(self.duration_h) for s in self.sessions
                  if s.n_nodes > 1)
        return min(run / self.duration_h, 1.0)

    def goodput_h(self) -> float:
        """Productive training hours: RUNNING wall time minus redone (lost)
        work minus checkpoint-save overhead (scheduled + urgent) minus the
        effective hours eaten by degrade-band windows (a degraded gang
        still runs, just slower).  This is the quantity the proactive
        control plane trades on: urgent saves spend save time to shrink
        the lost-work window; drains spend a controlled restart to dodge
        a crash."""
        run = sum(s.elapsed_running_h(self.duration_h) for s in self.sessions
                  if s.n_nodes > 1)
        ckpt_h = self.checkpoint_events * self.checkpoint_save_s / 3600.0
        urgent_h = self.control.urgent_save_h if self.control else 0.0
        return run - float(np.sum(self.lost_hours)) - ckpt_h - urgent_h \
            - float(np.sum(self.degraded_hours))

    def goodput(self) -> float:
        """Goodput as a fraction of the campaign wall clock."""
        return max(self.goodput_h(), 0.0) / self.duration_h

    def retry_chains(self) -> List[Chain]:
        """Chains with at least one retry (the paper's unit of analysis)."""
        return [c for c in self.chains if len(c.attempts) > 1]


class _CampaignState:
    """Mutable campaign state + transition rules shared by both engines."""

    def __init__(self, cfg: CampaignConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng
        # exponential draws live on dedicated streams (see RNG_STREAM_*):
        # the main stream stays pure-uniform and therefore tape-friendly
        self.rng_manual = np.random.default_rng(
            [cfg.seed, RNG_STREAM_MANUAL])
        self.rng_struct = np.random.default_rng(
            [cfg.seed, RNG_STREAM_STRUCT])
        self.sched = GangScheduler(cfg.n_nodes,
                                   spares=cfg.n_nodes - cfg.job_nodes)
        self.retry_engine = RetryEngine(cfg.retry)
        self.exclusions = ExclusionTracker(cfg.n_nodes)

        self.sessions: List[Session] = []
        self.chains: List[Chain] = []
        self.downtimes: List[dict] = []
        self.lost_hours: List[float] = []
        self.ckpt_events = 0
        self.version = 0

        self.isolated: Dict[int, str] = {}       # node -> reason
        self.repair_until: Dict[int, float] = {}

        self.chain = Chain(task_name=f"b200_v{self.version}")
        self.chains.append(self.chain)
        self.current: Optional[Session] = None
        self.prepare_until = 0.0
        self.prepare_fails = False               # structural: PREPARING fails
        self.structural_until = -1.0             # root cause fixed then
        self.pending_start: Optional[float] = 0.0  # next attempt start time
        self.start_is_manual = True              # operator-initiated attempt
        # two checkpoint clocks: ``last_ckpt`` is the scheduled cadence;
        # ``last_save`` is the effective latest save (urgent control-plane
        # saves advance it past the cadence).  Without a control plane the
        # two are always equal.
        self.last_ckpt = 0.0
        self.last_save = 0.0
        self.down_since: Optional[float] = None
        self.down_is_auto = True
        self.down_kind = "failure"               # "failure" | "drain"
        self.last_fail_hardware = False
        self.control: Optional[ControlPlane] = None
        # degrade-band ledger: windows from the sampled schedule, and the
        # per-session effective hours they cost (closed in event order)
        self.deg_windows: List[tuple] = []
        self.degraded: List[float] = []

    # -- attempt lifecycle --------------------------------------------------

    def start_attempt(self, t: float) -> bool:
        cfg, rng = self.cfg, self.rng
        s = Session(task_name=self.chain.task_name, n_nodes=cfg.job_nodes,
                    created_h=t)
        # alarm-informed placement: retries prefer nodes without a recent
        # alarm (the gang requirement still wins when the pool is tight)
        avoid = self.control.avoid_nodes(t) if self.control is not None \
            else None
        if not self.sched.try_allocate(s, t, avoid=avoid):
            # gang unmet: operators readmit a deliberately-isolated node
            # under pressure if it is healthy (paper: the license case took
            # hours) — only fail-slow isolations qualify; hardware-down
            # nodes stay out until repaired
            cand = [i for i in self.isolated
                    if self.sched.nodes[i].healthy]
            if cand and rng.random() < cfg.p_pressure_readmit:
                self.sched.readmit(cand[0], t)
                self.isolated.pop(cand[0], None)
                self.repair_until.pop(cand[0], None)
            self.chain.attempts.append(
                Attempt(start_h=t, end_h=t, failure_kind="alloc_fail"))
            return False
        s.transition(SessionState.PREPARING, t)
        self.sessions.append(s)
        self.chain.attempts.append(Attempt(start_h=t))
        self.current = s
        self.prepare_fails = t < self.structural_until
        # residual transient issues can also kill the first retry or two
        # (node not yet isolated, stale NCCL state) — paper's successful
        # chains still averaged >1 retry
        if not self.prepare_fails and len(self.chain.attempts) in (2, 3) \
                and rng.random() < cfg.p_transient_retry_fail:
            self.prepare_fails = True
        warm = cfg.loading_cold_h if self.last_fail_hardware \
            else cfg.loading_time_h
        dur = (warm + rng.uniform(-0.08, 0.3)) \
            if not self.prepare_fails else rng.uniform(0.05, 0.15)
        self.prepare_until = t + dur
        return True

    def account_degradation(self, t1: float):
        """Close the degradation ledger for the current session's RUNNING
        span ending at ``t1`` (called wherever the span closes: failure,
        drain, or campaign end)."""
        cur = self.current
        if cur is None or cur.started_h is None or not self.deg_windows:
            return
        d = degraded_overlap_h(self.deg_windows, cur.started_h, t1,
                               cur.nodes)
        if d:
            self.degraded.append(d)

    def exclusion_reasons(self, t0: float, t1: float) -> Dict[int, str]:
        """Per-node exclusion attribution for a session interval: the
        isolation ledger first (first-reason-wins in the tracker), then the
        control plane's correlated-band switch indictments — members of an
        indicted switch that were never individually isolated still
        concentrate exclusion intervals on the rack (reason ``"switch"``)."""
        reasons = dict(self.isolated)
        if self.control is not None:
            for node, why in self.control.switch_reasons(t0, t1).items():
                reasons.setdefault(node, why)
        return reasons

    def fail_session(self, t: float, kind: str, xid=None):
        self.account_degradation(t)
        self.last_fail_hardware = kind == "unreachable" or (
            xid is not None and XID_TABLE[xid].hardware)
        att = self.chain.attempts[-1]
        att.end_h = t
        att.failure_kind = kind
        att.xid = xid
        self.current.transition(SessionState.ERROR, t, error=f"{kind}:{xid}")
        self.sched.release(self.current, t)
        self.exclusions.record_session(self.current.created_h, t,
                                       self.current.nodes,
                                       self.exclusion_reasons(
                                           self.current.created_h, t))
        self.current = None
        if self.down_since is None:
            self.down_since = t

    def schedule_next(self, t: float, xid=None, structural: bool = False):
        """Decide auto-retry vs operator handoff after a failure."""
        cfg, rng = self.cfg, self.rng
        n_attempt = len(self.chain.attempts)
        delay_min = self.retry_engine.next_delay_min(n_attempt, xid=xid)
        # operators notice a repeatedly-failing chain via alerting and kill
        # it before max_retries (except off-hours: the paper's 30-attempt
        # chain ran overnight)
        noticed = n_attempt >= 3 and rng.random() < (
            (cfg.retry.delay_min / 60.0)
            / max(cfg.operator_notice_mean_h, 1e-6) * 0.5)
        if structural and cfg.retry.structural_stop:
            noticed = True                   # gang unmet: retrying is futile
        if cfg.retry.enabled and delay_min is not None \
                and n_attempt < cfg.retry.max_retries and not noticed:
            self.pending_start = t + delay_min / 60.0
            self.start_is_manual = False
        else:
            # chain abandoned -> operator intervention
            if n_attempt >= cfg.retry.max_retries:
                self.chain.stopped_reason = "max retries"
            self.version += 1
            self.chain = Chain(task_name=f"b200_v{self.version}")
            self.chains.append(self.chain)
            self.pending_start = t + self.manual_delay(t)
            self.start_is_manual = True
            self.down_is_auto = False
            # the operator fixes the root cause... usually
            if rng.random() < cfg.p_manual_misfix:
                self.structural_until = max(
                    self.structural_until,
                    self.pending_start + (cfg.structural_fix_mean_h / 2)
                    * self.rng_struct.standard_exponential())
            else:
                self.structural_until = min(self.structural_until,
                                            self.pending_start)

    def manual_delay(self, t_h: float) -> float:
        """Operator response latency: fast in working hours, slow at night
        and on weekends (paper Fig 17's 0-53 h manual tail)."""
        cfg = self.cfg
        hour_of_day = (t_h % 24.0)
        day = int(t_h // 24.0) % 7
        if day >= 5 or hour_of_day < 8 or hour_of_day > 20:
            return float(cfg.manual_response_h_night
                         * self.rng_manual.standard_exponential())
        return float(cfg.manual_response_h_day
                     * self.rng_manual.standard_exponential())

    # -- shared per-time-step handlers --------------------------------------

    def process_repairs(self, t: float):
        for node, until in list(self.repair_until.items()):
            if t >= until:
                self.sched.readmit(node, t)
                del self.repair_until[node]
                self.isolated.pop(node, None)

    def process_pending_start(self, t: float):
        if self.current is None and self.pending_start is not None \
                and t >= self.pending_start:
            if self.start_attempt(t):
                self.pending_start = None
            else:
                self.schedule_next(t, structural=True)

    def process_prepare_done(self, t: float):
        if self.current is not None \
                and self.current.state is SessionState.PREPARING \
                and t >= self.prepare_until:
            if self.prepare_fails:          # structural failure at NCCL init
                self.fail_session(t, "software")
                self.schedule_next(t)
            else:
                self.current.transition(SessionState.RUNNING, t)
                self.chain.attempts[-1].reached_training = True
                self.last_ckpt = t
                self.last_save = t
                if self.down_since is not None:
                    self.downtimes.append({"t": t,
                                           "hours": t - self.down_since,
                                           "auto": self.down_is_auto,
                                           "kind": self.down_kind})
                    self.down_since = None
                    self.down_is_auto = True
                    self.down_kind = "failure"

    def account_checkpoints(self, t: float):
        """Catch up checkpoint bookkeeping for a RUNNING span ending at
        ``t`` (analytic replacement for the per-tick interval check)."""
        cfg = self.cfg
        if self.current is None \
                or self.current.state is not SessionState.RUNNING:
            return
        k = int(np.floor((t - self.last_ckpt + 1e-12)
                         / cfg.checkpoint_interval_h))
        if k > 0:
            self.ckpt_events += k
            self.current.checkpoint_step += k
            self.last_ckpt += k * cfg.checkpoint_interval_h
            self.last_save = max(self.last_save, self.last_ckpt)

    def process_failure(self, t: float, ev: FailureEvent):
        cfg, rng = self.cfg, self.rng
        if ev.kind in INFRA_KINDS:
            # degrade-don't-kill: the event opens a window that acts via
            # telemetry overlays, the degradation ledger and (for
            # escalating pressure) a separate crash timer — no immediate
            # state change and, critically, no RNG draws here
            return
        if ev.kind == "fail_slow":
            self.isolated[ev.node] = "performance degradation"
            self.sched.exclude(ev.node, t, "fail-slow (deliberate isolation)")
            self.repair_until[ev.node] = t + cfg.slow_isolation_h
            return
        # a failure landing on a predictively-drained node cannot take the
        # gang down — that is the drain paying off
        if self.control is not None \
                and self.isolated.get(ev.node) == "predictive drain":
            self.control.stats.failures_on_drained_node += 1
        if ev.is_hardware:
            self.sched.mark_down(ev.node, t, f"xid={ev.xid}"
                                 if ev.xid else "unreachable")
            self.repair_until[ev.node] = t + cfg.repair_time_h
            # a node already isolated (fail-slow, predictive drain) keeps
            # the reason that took it out of the pool — that is the
            # exclusion mechanism F3 attributes the interval to
            self.isolated.setdefault(ev.node, "hardware failure")
        if self.current is not None and not self.current.is_terminal \
                and ev.node in self.current.nodes:
            if self.current.state is SessionState.RUNNING:
                lost = min(t - self.last_save, cfg.checkpoint_interval_h)
                self.lost_hours.append(lost)
                if self.control is not None:
                    baseline = min(t - self.last_ckpt,
                                   cfg.checkpoint_interval_h)
                    self.control.stats.lost_work_avoided_h += \
                        max(baseline - lost, 0.0)
            # software-level follow-on? (NCCL wedged after the event)
            if rng.random() < cfg.p_software_failure:
                self.structural_until = max(
                    self.structural_until,
                    t + cfg.structural_fix_mean_h
                    * self.rng_struct.standard_exponential())
            self.fail_session(t, ev.kind, xid=ev.xid)
            self.schedule_next(t, xid=ev.xid)

    def process_escalation(self, t: float, node: int):
        """An escalating resource-exhaustion window ends in a process-level
        crash: the node's runtime dies (no hardware isolation — the host
        recovers once the pressure source is gone) and takes the gang down
        if the node is in the current job."""
        cfg, rng = self.cfg, self.rng
        if self.control is not None \
                and self.isolated.get(node) == "predictive drain":
            self.control.stats.failures_on_drained_node += 1
        if self.current is not None and not self.current.is_terminal \
                and node in self.current.nodes:
            if self.current.state is SessionState.RUNNING:
                lost = min(t - self.last_save, cfg.checkpoint_interval_h)
                self.lost_hours.append(lost)
                if self.control is not None:
                    baseline = min(t - self.last_ckpt,
                                   cfg.checkpoint_interval_h)
                    self.control.stats.lost_work_avoided_h += \
                        max(baseline - lost, 0.0)
            if rng.random() < cfg.p_software_failure:
                self.structural_until = max(
                    self.structural_until,
                    t + cfg.structural_fix_mean_h
                    * self.rng_struct.standard_exponential())
            self.fail_session(t, "resource_exhaust")
            self.schedule_next(t)

    def drain_session(self, t: float, node: int, *, redeploy_h: float,
                      recheck_h: float):
        """Predictive drain (control plane): gracefully stop the session
        behind its final checkpoint, isolate ``node`` pending a health
        recheck, and redeploy the gang from the remaining pool.  Not a
        failure: the chain closes with a drain reason and the next chain
        starts automatically after the controlled handoff."""
        self.account_degradation(t)
        s = self.current
        att = self.chain.attempts[-1]
        att.end_h = t
        att.failure_kind = "drain"
        s.transition(SessionState.TERMINATING, t)
        s.transition(SessionState.TERMINATED, t)
        self.sched.release(s, t)
        self.exclusions.record_session(s.created_h, t, s.nodes,
                                       self.exclusion_reasons(s.created_h, t))
        self.current = None
        self.isolated[node] = "predictive drain"
        self.sched.exclude(node, t, "predictive drain (control plane)")
        self.repair_until[node] = t + recheck_h
        self.chain.stopped_reason = "predictive drain"
        self.version += 1
        self.chain = Chain(task_name=f"b200_v{self.version}")
        self.chains.append(self.chain)
        self.pending_start = t + redeploy_h
        self.start_is_manual = False
        self.last_fail_hardware = False          # controlled: warm restart
        self.down_since = t
        self.down_kind = "drain"

    def finalize(self, failures, store) -> CampaignResult:
        cfg = self.cfg
        if self.current is not None and not self.current.is_terminal:
            self.account_degradation(cfg.duration_h)
            self.exclusions.record_session(self.current.created_h,
                                           cfg.duration_h,
                                           self.current.nodes,
                                           self.exclusion_reasons(
                                               self.current.created_h,
                                               cfg.duration_h))
            self.current.transition(SessionState.TERMINATING, cfg.duration_h)
            self.current.transition(SessionState.TERMINATED, cfg.duration_h)
        return CampaignResult(
            sessions=self.sessions, chains=self.chains, failures=failures,
            exclusions=self.exclusions, store=store,
            downtimes=self.downtimes, checkpoint_events=self.ckpt_events,
            lost_hours=self.lost_hours, duration_h=cfg.duration_h,
            checkpoint_save_s=cfg.checkpoint_save_s,
            control=self.control.stats if self.control is not None else None,
            degraded_hours=self.degraded)


class _TelemetryBatcher:
    """Emits scrape snapshots for constant-state spans between events.

    Keeps an integer cursor over the global 30 s scrape grid; ``emit``
    generates every tick in [span start, span end) with one batched
    exporter call per <=``max_chunk`` chunk.  Failure signatures are
    pinned to the first grid tick at/after the event time (matching the
    serial loop, which applied them on the tick that processed the event).

    When a control plane is attached (``consumer``) every chunk is handed
    to it right after generation; a drain-grade alarm halts emission at
    that chunk's boundary so the drain can run as a first-class event
    (``max_chunk`` is then the control plane's reaction interval).
    ``store`` may be None for stream-and-discard campaigns — online
    consumers don't need day-scale telemetry retained in memory.
    """

    def __init__(self, cfg: CampaignConfig, exporters: ExporterSuite,
                 store: Optional[TimeSeriesStore],
                 consumer: Optional[ControlPlane] = None,
                 max_chunk: int = _MAX_SPAN_TICKS):
        self.cfg = cfg
        self.exporters = exporters
        self.store = store
        self.consumer = consumer
        self.max_chunk = max_chunk
        self.n_ticks_total = int(np.ceil(cfg.duration_h / TICK_H - 1e-9))
        self.next_k = 0                       # next un-emitted grid tick
        self.pending_sigs: List[Tuple[int, FailureEvent]] = []

    def add_failure_signature(self, ev: FailureEvent):
        if ev.kind in INFRA_KINDS:
            return      # window signatures are registered at setup
        k = int(np.ceil(ev.time_h / TICK_H - 1e-9))
        if k < self.n_ticks_total:
            self.pending_sigs.append((k, ev))

    def emit(self, t_end: float, state: _CampaignState) -> Optional[float]:
        """Emit all grid ticks with time < ``t_end`` (campaign state is
        constant over the span except checkpoint-save flags).

        Returns the early-stop time when the attached control plane
        demands an action (the main loop truncates the span there), else
        None."""
        cfg = self.cfg
        k_end = min(int(np.ceil(t_end / TICK_H - 1e-9)), self.n_ticks_total)
        if k_end <= self.next_k:
            return None
        n = cfg.n_nodes
        down_row = np.array([not nd.healthy for nd in state.sched.nodes],
                            dtype=float)
        training_row = np.zeros(n)
        loading_row = np.zeros(n)
        running = False
        cur = state.current
        if cur is not None:
            if cur.state is SessionState.RUNNING:
                training_row[cur.nodes] = 1.0
                running = True
            elif cur.state is SessionState.PREPARING:
                loading_row[cur.nodes] = 1.0

        while self.next_k < k_end:
            k0 = self.next_k
            k1 = min(k0 + self.max_chunk, k_end)
            ts = np.arange(k0, k1) * TICK_H
            T = len(ts)
            if running:
                # time since the most recent checkpoint at each tick
                phase = np.mod(ts - state.last_ckpt,
                               cfg.checkpoint_interval_h)
                ckpt_mask = (phase < cfg.checkpoint_save_s / 3600.0)
                ckpt = ckpt_mask[:, None] * training_row[None, :]
            else:
                ckpt = None
            batch = NodeStateBatch.constant(
                T, n, training=training_row, loading=loading_row,
                checkpointing=ckpt, down=down_row)
            rows = [(k - k0, ev) for k, ev in self.pending_sigs
                    if k0 <= k < k1]
            self.pending_sigs = [(k, ev) for k, ev in self.pending_sigs
                                 if k >= k1]
            snap = self.exporters.tick_batch(ts, batch, rows)
            if self.store is not None:
                self.store.append_batch(ts, snap)
            self.next_k = k1
            if self.consumer is not None \
                    and self.consumer.on_chunk(ts, snap, state):
                return float(k1) * TICK_H
        return None


class ClusterSim:
    def __init__(self, config: Optional[CampaignConfig] = None):
        # per-instance default (a shared default-argument instance would
        # alias every sim's config)
        config = config if config is not None else CampaignConfig()
        self.fabric: Optional[StorageFabric] = None
        if config.storage is not None:
            config = self._resolve_storage(config)
        self.cfg = config
        self.rng = np.random.default_rng(config.seed)

    def _resolve_storage(self, cfg: CampaignConfig) -> CampaignConfig:
        """Replace the checkpoint-timing constants with fabric queries at
        the gang fanin — the layer where the paper's scale-emergent F2
        bottleneck enters the campaign simulation."""
        import dataclasses
        self.fabric = StorageFabric(cfg.storage)
        wire = int(cfg.ckpt_bytes_per_node * cfg.ckpt_wire_ratio)
        save_s = self.fabric.expected_duration_s(
            "write", cfg.job_nodes, wire,
            slots_per_client=cfg.storage_slots)
        read_h = self.fabric.expected_duration_s(
            "read", cfg.job_nodes, cfg.restore_bytes_per_node,
            slots_per_client=2 * cfg.storage_slots) / 3600.0
        return dataclasses.replace(
            cfg,
            checkpoint_save_s=save_s,
            loading_time_h=cfg.loading_overhead_h + read_h,
            loading_cold_h=cfg.loading_overhead_cold_h + read_h)

    def _make_injector(self) -> FailureInjector:
        cfg = self.cfg
        return FailureInjector(n_nodes=cfg.n_nodes, mtbf_h=cfg.mtbf_h,
                               hot_fraction=cfg.hot_fraction,
                               hot_weight=cfg.hot_weight,
                               kind_weights=cfg.kind_weights,
                               topology_fanout=cfg.topology_fanout,
                               seed=cfg.seed)

    def _make_telemetry(self, failures):
        cfg = self.cfg
        # a control plane implies telemetry: the streaming detector is fed
        # by the emitted spans even when nothing is retained
        if not cfg.telemetry and cfg.control is None:
            return None, None
        n_pad = N_PAD_METRICS if cfg.telemetry_pad_metrics is None \
            else cfg.telemetry_pad_metrics
        # non-fabric campaigns still export storage signals, from a
        # paper-default fabric at THIS campaign's gang fanin
        fabric = self.fabric if self.fabric is not None else StorageFabric()
        exporters = ExporterSuite(
            cfg.n_nodes, seed=cfg.seed, n_pad=n_pad,
            storage_levels=fabric.telemetry_levels(cfg.job_nodes))
        # retention needs BOTH flags: a control-only campaign (telemetry
        # False) streams spans to the detector and discards them — holding
        # a 73-day full-registry store would be tens of GB nobody asked for
        store = TimeSeriesStore(cfg.n_nodes) \
            if cfg.telemetry and cfg.telemetry_store else None
        for ev in failures:
            if ev.precursor_lead_h > 0:
                exporters.begin_gradual_precursor(
                    ev.node, ev.time_h - ev.precursor_lead_h,
                    until_h=ev.time_h + 0.05)
            if ev.kind in DEGRADE_KINDS and ev.window_h > 0:
                exporters.begin_degradation(
                    ev.node, ev.time_h, ev.time_h + ev.window_h,
                    ev.slow_factor, ev.kind, ev.onset)
            elif ev.kind == "ctrl_blind" and ev.window_h > 0:
                exporters.begin_outage(ev.time_h, ev.time_h + ev.window_h)
            elif ev.kind in CORRELATED_KINDS and ev.window_h > 0:
                # correlated band: one fabric event co-degrades the whole
                # blast radius (switch members, or the flapping peer's gang)
                exporters.begin_link_degradation(
                    sorted(set(ev.members) | set(ev.peers)),
                    ev.time_h, ev.time_h + ev.window_h, ev.slow_factor)
        return exporters, store

    def run(self) -> CampaignResult:
        if self.cfg.engine == "tick":
            return self._run_tick()
        if self.cfg.engine == "event":
            return self._run_event()
        raise ValueError(f"unknown engine {self.cfg.engine!r}")

    # ------------------------------------------------------------------
    # event-driven engine (default)
    # ------------------------------------------------------------------

    def _run_event(self) -> CampaignResult:
        cfg = self.cfg
        st = _CampaignState(cfg, self.rng)
        failures = self._make_injector().sample(cfg.duration_h)
        fail_idx = 0
        # infra fault band timelines (all derived deterministically from
        # the schedule — shared helpers keep both engines bit-identical)
        st.deg_windows = degradation_windows(failures)
        escs = escalation_events(failures)
        esc_idx = 0
        blind_ends = [b1 for _, b1 in blind_windows(failures)]
        blind_idx = 0
        exporters, store = self._make_telemetry(failures)
        ctl = None
        if cfg.control is not None:
            # urgent saves are priced like regular ones: fabric-resolved at
            # the gang fanin when CampaignConfig.storage is set
            ctl = ControlPlane(cfg.control,
                               urgent_save_s=cfg.checkpoint_save_s,
                               n_nodes=cfg.n_nodes, seed=cfg.seed)
            ctl.infra_active = any(f.kind in INFRA_KINDS for f in failures)
            for b0, b1 in blind_windows(failures):
                ctl.begin_blind(b0, b1)
            ctl.register_failures(failures)
            st.control = ctl
        # only drains need a bounded alarm->action latency (they truncate
        # spans); urgent checkpoints apply retroactively at the alarm's own
        # timestamp, so drain-less control runs keep full-size spans
        max_chunk = min(_MAX_SPAN_TICKS, cfg.control.reaction_ticks) \
            if ctl is not None and cfg.control.drain else _MAX_SPAN_TICKS
        tel = _TelemetryBatcher(cfg, exporters, store, consumer=ctl,
                                max_chunk=max_chunk) if exporters else None

        t = 0.0
        while True:
            # ---- process everything due at t (same order as the serial
            # loop: repairs, control actions, pending start, session
            # progress, failures) ----
            st.process_repairs(t)
            if ctl is not None:
                ctl.process(t, st)
            st.process_pending_start(t)
            st.process_prepare_done(t)
            while fail_idx < len(failures) \
                    and failures[fail_idx].time_h <= t + 1e-12:
                ev = failures[fail_idx]
                fail_idx += 1
                if tel is not None:
                    tel.add_failure_signature(ev)
                st.process_failure(t, ev)
            while esc_idx < len(escs) and escs[esc_idx][0] <= t + 1e-12:
                _, node = escs[esc_idx]
                esc_idx += 1
                st.process_escalation(t, node)

            # ---- next event time ----
            cands = [cfg.duration_h]
            if st.repair_until:
                cands.append(min(st.repair_until.values()))
            if st.current is None and st.pending_start is not None:
                cands.append(st.pending_start)
            if st.current is not None \
                    and st.current.state is SessionState.PREPARING:
                cands.append(st.prepare_until)
            if fail_idx < len(failures):
                cands.append(failures[fail_idx].time_h)
            if esc_idx < len(escs):
                cands.append(escs[esc_idx][0])
            if ctl is not None:
                # wake at blind-window ends so queued decisions replay
                while blind_idx < len(blind_ends) \
                        and blind_ends[blind_idx] <= t + 1e-12:
                    blind_idx += 1
                if blind_idx < len(blind_ends):
                    cands.append(blind_ends[blind_idx])
            t_next = min(c for c in cands if c > t + 1e-12) \
                if any(c > t + 1e-12 for c in cands) else cfg.duration_h
            t_next = min(t_next, cfg.duration_h)

            # ---- emit the constant-state telemetry span, then catch up
            # checkpoint bookkeeping to the span end; the control plane
            # may truncate the span when a drain-grade alarm fires ----
            if tel is not None:
                t_stop = tel.emit(t_next, st)
                if t_stop is not None and t_stop < t_next:
                    t_next = t_stop
            st.account_checkpoints(t_next)
            if t_next >= cfg.duration_h:
                break
            t = t_next

        return st.finalize(failures, store)

    # ------------------------------------------------------------------
    # serial 30 s-tick engine (legacy reference)
    # ------------------------------------------------------------------

    def _run_tick(self) -> CampaignResult:
        cfg = self.cfg
        if cfg.control is not None:
            raise ValueError(
                "the control plane consumes span-batched telemetry and is "
                "only supported by the event engine (engine='event')")
        st = _CampaignState(cfg, self.rng)
        failures = self._make_injector().sample(cfg.duration_h)
        fail_iter = iter(failures)
        next_fail = next(fail_iter, None)
        st.deg_windows = degradation_windows(failures)
        esc_iter = iter(escalation_events(failures))
        next_esc = next(esc_iter, None)
        exporters, store = self._make_telemetry(failures)

        t = 0.0
        while t < cfg.duration_h:
            st.process_repairs(t)
            st.process_pending_start(t)
            st.process_prepare_done(t)
            if st.current is not None \
                    and st.current.state is SessionState.RUNNING \
                    and t - st.last_ckpt >= cfg.checkpoint_interval_h:
                st.ckpt_events += 1
                st.last_ckpt = t
                st.last_save = t
                st.current.checkpoint_step += 1

            fired: List[FailureEvent] = []
            while next_fail is not None and next_fail.time_h <= t:
                fired.append(next_fail)
                next_fail = next(fail_iter, None)
            for ev in fired:
                st.process_failure(t, ev)
            while next_esc is not None and next_esc[0] <= t:
                st.process_escalation(t, next_esc[1])
                next_esc = next(esc_iter, None)

            if exporters is not None and store is not None:
                cur = st.current
                states = []
                for i in range(cfg.n_nodes):
                    in_job = cur is not None and i in cur.nodes \
                        and cur.state is SessionState.RUNNING
                    loading = cur is not None and i in cur.nodes \
                        and cur.state is SessionState.PREPARING
                    states.append(NodeState(
                        training=in_job,
                        checkpointing=in_job and
                        (t - st.last_ckpt) < cfg.checkpoint_save_s / 3600.0,
                        loading=loading,
                        down=not st.sched.nodes[i].healthy,
                    ))
                snap = exporters.tick(t, states, fired)
                store.append(t, snap)

            t += TICK_H

        return st.finalize(failures, store)
