"""End-to-end cluster campaign simulation.

Drives a 63-node training campaign through: the gang scheduler, session
lifecycle, failure injection, telemetry scraping, XID-classified recovery,
auto-retry chains, node exclusion, and checkpoint timing — everything the
paper's §4 measures, in one discrete-time loop (30 s ticks).

Failure semantics (paper §4.3):
* transient failures (most XID hardware events with spares available, app
  errors) — the next gang allocation succeeds and the chain recovers;
* structural failures (software/NCCL-level, license/pool exhaustion) —
  restarts fail repeatedly at PREPARING until an operator intervenes; this
  is what made 8/12 of the paper's chains fail and burned a 30-attempt
  chain (§4.3.5).

Used by: benchmarks (taxonomy / precursor / retry / exclusion / downtime),
the fault-tolerant training example, and the integration tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.checkpoint.youngdaly import MTBF_H_PAPER
from repro.core.exclusion import ExclusionTracker
from repro.core.failures import FailureEvent, FailureInjector
from repro.core.retry import Attempt, Chain, RetryConfig, RetryEngine
from repro.core.scheduler import GangScheduler
from repro.core.session import Session, SessionState
from repro.telemetry.exporters import ExporterSuite, NodeState
from repro.telemetry.registry import SCRAPE_INTERVAL_S, TimeSeriesStore

TICK_H = SCRAPE_INTERVAL_S / 3600.0


@dataclass
class CampaignConfig:
    n_nodes: int = 63
    job_nodes: int = 60
    duration_h: float = 73 * 24.0
    mtbf_h: float = MTBF_H_PAPER
    retry: RetryConfig = field(default_factory=RetryConfig)
    checkpoint_interval_h: float = 2.23      # 4K phase median
    checkpoint_save_s: float = 18.0
    loading_time_h: float = 31.0 / 60.0      # warm-cache restart loading
    loading_cold_h: float = 58.0 / 60.0      # cold cache (node replaced /
                                             #   full reboot; paper §4.2.4)
    # failure-class behaviour
    p_software_failure: float = 0.5          # NCCL/driver-level (structural)
    p_transient_retry_fail: float = 0.4      # residual issue on early retries
    structural_fix_mean_h: float = 5.0       # time until root cause fixed
    operator_notice_mean_h: float = 1.2      # failing chain noticed & stopped
    p_manual_misfix: float = 0.4             # operator fix incomplete ->
                                             #   next chain fails from start
    manual_response_h_day: float = 0.3
    manual_response_h_night: float = 1.5
    repair_time_h: float = 12.0              # node repair turnaround
    slow_isolation_h: float = 400.0          # fail-slow deliberate isolation
    telemetry: bool = False
    seed: int = 0


@dataclass
class CampaignResult:
    sessions: List[Session]
    chains: List[Chain]
    failures: List[FailureEvent]
    exclusions: ExclusionTracker
    store: Optional[TimeSeriesStore]
    downtimes: List[dict]                    # per recovery episode
    checkpoint_events: int
    lost_hours: List[float]
    duration_h: float

    def training_occupancy(self) -> float:
        run = sum(s.elapsed_running_h(self.duration_h) for s in self.sessions
                  if s.n_nodes > 1)
        return min(run / self.duration_h, 1.0)

    def retry_chains(self) -> List[Chain]:
        """Chains with at least one retry (the paper's unit of analysis)."""
        return [c for c in self.chains if len(c.attempts) > 1]


class ClusterSim:
    def __init__(self, config: CampaignConfig = CampaignConfig()):
        self.cfg = config
        self.rng = np.random.default_rng(config.seed)

    # ------------------------------------------------------------------

    def run(self) -> CampaignResult:
        cfg = self.cfg
        rng = self.rng
        sched = GangScheduler(cfg.n_nodes, spares=cfg.n_nodes - cfg.job_nodes)
        injector = FailureInjector(n_nodes=cfg.n_nodes, mtbf_h=cfg.mtbf_h,
                                   seed=cfg.seed)
        failures = injector.sample(cfg.duration_h)
        fail_iter = iter(failures)
        next_fail = next(fail_iter, None)

        exporters = ExporterSuite(cfg.n_nodes, seed=cfg.seed) \
            if cfg.telemetry else None
        store = TimeSeriesStore(cfg.n_nodes) if cfg.telemetry else None
        retry_engine = RetryEngine(cfg.retry)
        exclusions = ExclusionTracker(cfg.n_nodes)

        sessions: List[Session] = []
        chains: List[Chain] = []
        downtimes: List[dict] = []
        lost_hours: List[float] = []
        ckpt_events = 0
        version = 0

        if exporters:
            for ev in failures:
                if ev.precursor_lead_h > 0:
                    exporters.begin_gradual_precursor(
                        ev.node, ev.time_h - ev.precursor_lead_h,
                        until_h=ev.time_h + 0.05)

        isolated: Dict[int, str] = {}          # node -> reason
        repair_until: Dict[int, float] = {}

        # campaign state
        chain = Chain(task_name=f"b200_v{version}")
        chains.append(chain)
        current: Optional[Session] = None
        prepare_until = 0.0
        prepare_fails = False                  # structural: PREPARING will fail
        structural_until = -1.0                # root cause fixed at this time
        pending_start: Optional[float] = 0.0   # next attempt start time
        start_is_manual = True                 # operator-initiated attempt
        last_ckpt = 0.0
        down_since: Optional[float] = None
        down_is_auto = True
        last_fail_hardware = False

        def start_attempt(t: float) -> bool:
            nonlocal current, prepare_until, prepare_fails
            s = Session(task_name=chain.task_name, n_nodes=cfg.job_nodes,
                        created_h=t)
            if not sched.try_allocate(s, t):
                # gang unmet: operators readmit an isolated node under
                # pressure if one is healthy (paper: license case took hours)
                cand = [i for i, r in isolated.items()
                        if sched.nodes[i].healthy and i not in repair_until]
                if cand and rng.random() < 0.5:
                    sched.readmit(cand[0], t)
                    isolated.pop(cand[0], None)
                chain.attempts.append(
                    Attempt(start_h=t, end_h=t, failure_kind="alloc_fail"))
                return False
            s.transition(SessionState.PREPARING, t)
            sessions.append(s)
            chain.attempts.append(Attempt(start_h=t))
            current = s
            prepare_fails = t < structural_until
            # residual transient issues can also kill the first retry or two
            # (node not yet isolated, stale NCCL state) — paper's successful
            # chains still averaged >1 retry
            if not prepare_fails and len(chain.attempts) in (2, 3) \
                    and rng.random() < cfg.p_transient_retry_fail:
                prepare_fails = True
            warm = cfg.loading_cold_h if last_fail_hardware \
                else cfg.loading_time_h
            dur = (warm + rng.uniform(-0.08, 0.3)) \
                if not prepare_fails else rng.uniform(0.05, 0.15)
            prepare_until = t + dur
            return True

        def fail_session(t: float, kind: str, xid=None):
            nonlocal current, down_since, last_fail_hardware
            from repro.core.xid import XID_TABLE
            last_fail_hardware = kind == "unreachable" or (
                xid is not None and XID_TABLE[xid].hardware)
            att = chain.attempts[-1]
            att.end_h = t
            att.failure_kind = kind
            att.xid = xid
            current.transition(SessionState.ERROR, t, error=f"{kind}:{xid}")
            sched.release(current, t)
            exclusions.record_session(current.created_h, t, current.nodes,
                                      dict(isolated))
            current = None
            if down_since is None:
                down_since = t

        def schedule_next(t: float, xid=None):
            """Decide auto-retry vs operator handoff after a failure."""
            nonlocal pending_start, start_is_manual, chain, version, \
                structural_until, down_is_auto
            n_attempt = len(chain.attempts)
            delay_min = retry_engine.next_delay_min(n_attempt, xid=xid)
            # operators notice a repeatedly-failing chain via alerting and
            # kill it before max_retries (except off-hours: the paper's
            # 30-attempt chain ran overnight)
            noticed = n_attempt >= 3 and rng.random() < (
                TICK_H * 0 + (cfg.retry.delay_min / 60.0)
                / max(cfg.operator_notice_mean_h, 1e-6) * 0.5)
            if cfg.retry.enabled and delay_min is not None \
                    and n_attempt < cfg.retry.max_retries and not noticed:
                pending_start = t + delay_min / 60.0
                start_is_manual = False
            else:
                # chain abandoned -> operator intervention
                if n_attempt >= cfg.retry.max_retries:
                    chain.stopped_reason = "max retries"
                version += 1
                chain = Chain(task_name=f"b200_v{version}")
                chains.append(chain)
                pending_start = t + self._manual_delay(t)
                start_is_manual = True
                down_is_auto = False
                # the operator fixes the root cause... usually
                if rng.random() < cfg.p_manual_misfix:
                    structural_until = max(
                        structural_until,
                        pending_start + rng.exponential(
                            cfg.structural_fix_mean_h / 2))
                else:
                    structural_until = min(structural_until, pending_start)

        t = 0.0
        while t < cfg.duration_h:
            # ---- repairs ----
            for node, until in list(repair_until.items()):
                if t >= until:
                    sched.readmit(node, t)
                    del repair_until[node]
                    isolated.pop(node, None)

            # ---- start pending attempt ----
            if current is None and pending_start is not None \
                    and t >= pending_start:
                if start_attempt(t):
                    pending_start = None
                else:
                    schedule_next(t)

            # ---- session progress ----
            if current is not None:
                if current.state is SessionState.PREPARING \
                        and t >= prepare_until:
                    if prepare_fails:       # structural failure at NCCL init
                        fail_session(t, "software")
                        schedule_next(t)
                    else:
                        current.transition(SessionState.RUNNING, t)
                        chain.attempts[-1].reached_training = True
                        last_ckpt = t
                        if down_since is not None:
                            downtimes.append({"t": t,
                                              "hours": t - down_since,
                                              "auto": down_is_auto})
                            down_since = None
                            down_is_auto = True
                elif current.state is SessionState.RUNNING \
                        and t - last_ckpt >= cfg.checkpoint_interval_h:
                    ckpt_events += 1
                    last_ckpt = t
                    current.checkpoint_step += 1

            # ---- failures ----
            fired: List[FailureEvent] = []
            while next_fail is not None and next_fail.time_h <= t:
                fired.append(next_fail)
                next_fail = next(fail_iter, None)
            for ev in fired:
                if ev.kind == "fail_slow":
                    isolated[ev.node] = "performance degradation"
                    sched.exclude(ev.node, t,
                                  "fail-slow (deliberate isolation)")
                    repair_until[ev.node] = t + cfg.slow_isolation_h
                    continue
                if ev.is_hardware:
                    sched.mark_down(ev.node, t, f"xid={ev.xid}"
                                    if ev.xid else "unreachable")
                    repair_until[ev.node] = t + cfg.repair_time_h
                    isolated[ev.node] = "hardware failure"
                if current is not None and not current.is_terminal \
                        and ev.node in current.nodes:
                    if current.state is SessionState.RUNNING:
                        lost_hours.append(min(t - last_ckpt,
                                              cfg.checkpoint_interval_h))
                    # software-level follow-on? (NCCL wedged after the event)
                    if rng.random() < cfg.p_software_failure:
                        structural_until = max(
                            structural_until,
                            t + rng.exponential(cfg.structural_fix_mean_h))
                    fail_session(t, ev.kind, xid=ev.xid)
                    schedule_next(t, xid=ev.xid)

            # ---- telemetry scrape ----
            if exporters is not None:
                states = []
                for i in range(cfg.n_nodes):
                    in_job = current is not None and i in current.nodes \
                        and current.state is SessionState.RUNNING
                    loading = current is not None and i in current.nodes \
                        and current.state is SessionState.PREPARING
                    st = NodeState(
                        training=in_job,
                        checkpointing=in_job and
                        (t - last_ckpt) < cfg.checkpoint_save_s / 3600.0,
                        loading=loading,
                        down=not sched.nodes[i].healthy,
                    )
                    states.append(st)
                snap = exporters.tick(t, states, fired)
                store.append(t, snap)

            t += TICK_H

        if current is not None and not current.is_terminal:
            exclusions.record_session(current.created_h, cfg.duration_h,
                                      current.nodes, dict(isolated))
            current.transition(SessionState.TERMINATING, cfg.duration_h)
            current.transition(SessionState.TERMINATED, cfg.duration_h)

        return CampaignResult(
            sessions=sessions, chains=chains, failures=failures,
            exclusions=exclusions, store=store, downtimes=downtimes,
            checkpoint_events=ckpt_events, lost_hours=lost_hours,
            duration_h=cfg.duration_h)

    # ------------------------------------------------------------------

    def _manual_delay(self, t_h: float) -> float:
        """Operator response latency: fast in working hours, slow at night
        and on weekends (paper Fig 17's 0-53 h manual tail)."""
        hour_of_day = (t_h % 24.0)
        day = int(t_h // 24.0) % 7
        if day >= 5 or hour_of_day < 8 or hour_of_day > 20:
            return float(self.rng.exponential(self.cfg.manual_response_h_night))
        return float(self.rng.exponential(self.cfg.manual_response_h_day))
