"""Failure injection: fail-stop (XID) + fail-slow events with precursor
signatures, seeded from the paper's observed 55-day distribution.

Paper evidence (Tables 2, 9-11):
* 17 failure events / 55 days; NVLink (XID 145/149) dominant at 29.4%.
* MTBF 56.2 h estimated from 1,294 training hours / 23 abnormal ends.
* Most signals emerge ABRUPTLY at the XID time point (pre-XID detection was
  only 2/10); a minority show gradual precursors (e.g. accelerating
  correctable row-remap on gpu124).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

# paper Table 2 mix (XID-detectable part) -----------------------------------
XID_MIX = [
    (145, 0.20), (149, 0.094),      # NVLink errors, 29.4% combined
    (94, 0.118),                    # ECC errors
    (79, 0.118),                    # GPU card dropout
    (119, 0.059),                   # GPU execution errors (GSP RPC timeout)
    (31, 0.03), (43, 0.03),         # app-level page fault / halt
]
P_MACHINE_UNREACHABLE = 0.118
P_FAIL_SLOW = 0.233                 # "Others": perf degradation etc.

MTBF_HOURS = 56.2                   # paper Table 11

# scenario-facing failure categories (ops/scenario.py tilts these weights)
CATEGORY_OF_XID = {
    145: "nvlink", 149: "nvlink",
    94: "ecc",
    79: "dropout",
    119: "exec",
    31: "app", 43: "app",
}
FAILURE_CATEGORIES = frozenset(CATEGORY_OF_XID.values()) \
    | {"unreachable", "fail_slow"}


@dataclass
class FailureEvent:
    time_h: float                   # hours since campaign start
    node: int
    kind: str                       # "xid" | "unreachable" | "fail_slow"
    xid: Optional[int] = None
    # precursor signature
    precursor_lead_h: float = 0.0   # >0: signals degrade before the XID
    slow_factor: float = 1.0        # fail-slow: relative step-time multiplier

    @property
    def is_hardware(self) -> bool:
        from repro.core.xid import XID_TABLE
        return self.kind == "unreachable" or (
            self.xid is not None and XID_TABLE[self.xid].hardware)


@dataclass
class FailureInjector:
    """Samples a failure schedule for an N-node campaign.

    Inter-failure times ~ Exponential(MTBF); node selection is *skewed*
    (paper F3: exclusions concentrate — a few nodes are repeat offenders).
    ``hot_nodes``: fraction of nodes carrying ``hot_weight`` of the hazard.
    """
    n_nodes: int = 63
    mtbf_h: float = MTBF_HOURS
    hot_fraction: float = 0.05
    hot_weight: float = 0.55
    pre_xid_fraction: float = 0.2   # paper: 2/10 failures had precursors
    seed: int = 0
    # multiplicative tilts on the paper mix, keyed by category
    # ("nvlink" | "ecc" | "dropout" | "exec" | "app" | "unreachable" |
    #  "fail_slow"); the mix is renormalised after tilting
    kind_weights: Optional[Dict[str, float]] = None

    def node_hazard(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed + 1)
        n_hot = max(int(round(self.n_nodes * self.hot_fraction)), 1)
        hot = rng.choice(self.n_nodes, size=n_hot, replace=False)
        w = np.full(self.n_nodes, (1 - self.hot_weight) / (self.n_nodes - n_hot))
        w[hot] = self.hot_weight / n_hot
        return w

    def sample(self, duration_h: float) -> List[FailureEvent]:
        """Vectorized schedule draw: exponential inter-failure gaps, skewed
        node choice, and mix assignment all in block numpy operations."""
        rng = np.random.default_rng(self.seed)
        hazard = self.node_hazard()
        kinds, probs = self._mix()

        # draw gap blocks until the cumulative time passes the horizon
        times = np.empty(0)
        block = max(int(duration_h / self.mtbf_h * 1.5) + 8, 16)
        total = 0.0
        while total < duration_h:
            gaps = rng.exponential(self.mtbf_h, block)
            times = np.concatenate([times, total + np.cumsum(gaps)])
            total = float(times[-1])
        times = times[times < duration_h]
        k = len(times)
        if k == 0:
            return []

        nodes = rng.choice(self.n_nodes, size=k, p=hazard)
        kind_idx = rng.choice(len(kinds), size=k, p=probs)
        is_xid = np.array([kinds[i][0] == "xid" for i in kind_idx])
        is_slow = np.array([kinds[i][0] == "fail_slow" for i in kind_idx])
        leads = np.where(is_xid & (rng.random(k) < self.pre_xid_fraction),
                         rng.uniform(0.25, 2.0, k),   # gradual degradation
                         0.0)
        slows = np.where(is_slow,
                         rng.uniform(1.15, 1.6, k),   # 15-60% step-time hit
                         1.0)
        return [FailureEvent(time_h=float(times[i]), node=int(nodes[i]),
                             kind=kinds[kind_idx[i]][0],
                             xid=kinds[kind_idx[i]][1],
                             precursor_lead_h=float(leads[i]),
                             slow_factor=float(slows[i]))
                for i in range(k)]

    def _mix(self):
        kinds = []
        probs = []
        w = self.kind_weights or {}
        for xid, p in XID_MIX:
            kinds.append(("xid", xid))
            probs.append(p * w.get(CATEGORY_OF_XID[xid], 1.0))
        kinds.append(("unreachable", None))
        probs.append(P_MACHINE_UNREACHABLE * w.get("unreachable", 1.0))
        kinds.append(("fail_slow", None))
        probs.append(P_FAIL_SLOW * w.get("fail_slow", 1.0))
        probs = np.asarray(probs)
        return kinds, probs / probs.sum()
