"""Failure injection: fail-stop (XID) + fail-slow events with precursor
signatures, seeded from the paper's observed 55-day distribution.

Paper evidence (Tables 2, 9-11):
* 17 failure events / 55 days; NVLink (XID 145/149) dominant at 29.4%.
* MTBF 56.2 h estimated from 1,294 training hours / 23 abnormal ends.
* Most signals emerge ABRUPTLY at the XID time point (pre-XID detection was
  only 2/10); a minority show gradual precursors (e.g. accelerating
  correctable row-remap on gpu124).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

# paper Table 2 mix (XID-detectable part) -----------------------------------
XID_MIX = [
    (145, 0.20), (149, 0.094),      # NVLink errors, 29.4% combined
    (94, 0.118),                    # ECC errors
    (79, 0.118),                    # GPU card dropout
    (119, 0.059),                   # GPU execution errors (GSP RPC timeout)
    (31, 0.03), (43, 0.03),         # app-level page fault / halt
]
P_MACHINE_UNREACHABLE = 0.118
P_FAIL_SLOW = 0.233                 # "Others": perf degradation etc.

MTBF_HOURS = 56.2                   # paper Table 11

# cluster-infrastructure fault band (degrade-don't-kill; opt-in via
# ``kind_weights`` — the paper's Table 2 mix carries zero weight for these,
# calibration anchors are Meta's research-cluster category rates):
# base rates relative to the Table 2 mix mass, scaled by w[name] (default 0)
P_NET_DEGRADE = 0.08                # network latency/loss windows
P_RESOURCE_EXHAUST = 0.06           # host memory / ephemeral-disk pressure
P_CTRL_BLIND = 0.03                 # scheduler / control-plane outages
P_RESOURCE_ESCALATE = 0.35          # pressure windows that end in a crash

# correlated fault band (opt-in via ``kind_weights``, like the infra band;
# calibration anchors are the switch/network category rates in "Revisiting
# Reliability"): failures that live in the *fabric*, not a node
P_SWITCH_DEGRADE = 0.05             # leaf switch degrades its whole rack
P_DNS_FLAP = 0.04                   # service-discovery flap: partial gang
                                    #   loses connectivity to specific peers

# dedicated stream for dns_flap member-subset draws; constructed lazily and
# consumed only when a dns_flap event exists, so band-off schedules never
# touch it (docs/PARITY.md)
RNG_STREAM_CORR = 7039

# scenario-facing failure categories (ops/scenario.py tilts these weights)
CATEGORY_OF_XID = {
    145: "nvlink", 149: "nvlink",
    94: "ecc",
    79: "dropout",
    119: "exec",
    31: "app", 43: "app",
}
FAILURE_CATEGORIES = frozenset(CATEGORY_OF_XID.values()) \
    | {"unreachable", "fail_slow",
       "net_degrade", "resource_exhaust", "ctrl_blind",
       "switch_degrade", "dns_flap"}

# the degrade-don't-kill band: faults that open a window instead of
# killing a session outright
DEGRADE_KINDS = frozenset({"net_degrade", "resource_exhaust"})
# the correlated band: fabric faults whose blast radius spans several
# nodes at once (a rack behind one leaf switch, a flapping peer's gang)
CORRELATED_KINDS = frozenset({"switch_degrade", "dns_flap"})
INFRA_KINDS = DEGRADE_KINDS | {"ctrl_blind"} | CORRELATED_KINDS


@dataclass
class FailureEvent:
    time_h: float                   # hours since campaign start
    node: int
    kind: str                       # KIND_NAMES entry
    xid: Optional[int] = None
    # precursor signature
    precursor_lead_h: float = 0.0   # >0: signals degrade before the XID
    slow_factor: float = 1.0        # fail-slow / degrade severity multiplier
    # infra fault band: degradation / outage window geometry
    window_h: float = 0.0           # >0: event opens a [t, t+window_h) window
    onset: str = ""                 # "" | "gradual" | "spike"
    escalate: bool = False          # resource window ends in a process crash
    # correlated fault band: blast-radius geometry
    switch: int = -1                # switch_degrade: the degraded leaf switch
    members: tuple = ()             # nodes inside the blast radius
    peers: tuple = ()               # dns_flap: the unreachable peer(s)

    @property
    def is_hardware(self) -> bool:
        from repro.core.xid import XID_TABLE
        return self.kind == "unreachable" or (
            self.xid is not None and XID_TABLE[self.xid].hardware)

    @property
    def is_degrade(self) -> bool:
        return self.kind in DEGRADE_KINDS

    @property
    def is_correlated(self) -> bool:
        return self.kind in CORRELATED_KINDS


@dataclass
class FailureInjector:
    """Samples a failure schedule for an N-node campaign.

    Inter-failure times ~ Exponential(MTBF); node selection is *skewed*
    (paper F3: exclusions concentrate — a few nodes are repeat offenders).
    ``hot_nodes``: fraction of nodes carrying ``hot_weight`` of the hazard.
    """
    n_nodes: int = 63
    mtbf_h: float = MTBF_HOURS
    hot_fraction: float = 0.05
    hot_weight: float = 0.55
    pre_xid_fraction: float = 0.2   # paper: 2/10 failures had precursors
    seed: int = 0
    # multiplicative tilts on the paper mix, keyed by category
    # ("nvlink" | "ecc" | "dropout" | "exec" | "app" | "unreachable" |
    #  "fail_slow"); the mix is renormalised after tilting
    kind_weights: Optional[Dict[str, float]] = None
    # leaf-switch fanout for the correlated band's blast radius
    # (core/topology.py; only consulted when correlated events exist)
    topology_fanout: int = 8

    def node_hazard(self) -> np.ndarray:
        return self.node_hazard_for(self.seed)

    def sample(self, duration_h: float) -> List[FailureEvent]:
        """Sample this injector's schedule (one seed).  Delegates to the
        batched drawer so the per-seed and campaign-batched paths share one
        implementation — `sample_batch(d, [seed]).events(0)` is the
        definition, not an approximation."""
        return self.sample_batch(duration_h, [self.seed]).events(0)

    def node_hazard_for(self, seed: int) -> np.ndarray:
        """`node_hazard` for an explicit seed (the batch drawer's form)."""
        rng = np.random.default_rng(seed + 1)
        n_hot = max(int(round(self.n_nodes * self.hot_fraction)), 1)
        hot = rng.choice(self.n_nodes, size=n_hot, replace=False)
        w = np.full(self.n_nodes,
                    (1 - self.hot_weight) / (self.n_nodes - n_hot))
        w[hot] = self.hot_weight / n_hot
        return w

    def sample_batch(self, duration_h: float,
                     seeds: Sequence[int]) -> "FailureBatch":
        """Draw S independent failure schedules as one stacked structure.

        Every seed consumes its own ``default_rng(seed)`` stream with the
        exact call sequence of the historical scalar ``sample`` (gap blocks,
        node choice, mix assignment, precursor/slow draws), so column ``i``
        is bit-identical to ``FailureInjector(seed=seeds[i]).sample(...)``.
        The mix tables, category lookup arrays and hazard shaping are
        computed once and shared across seeds; per-event python objects are
        only materialized on demand (``events(i)``)."""
        kinds, probs = self._mix()
        kind_is_xid = np.array([k[0] == "xid" for k in kinds])
        kind_is_slow = np.array([k[0] == "fail_slow" for k in kinds])
        kind_is_net = np.array([k[0] == "net_degrade" for k in kinds])
        kind_is_res = np.array([k[0] == "resource_exhaust" for k in kinds])
        kind_is_blind = np.array([k[0] == "ctrl_blind" for k in kinds])
        kind_is_switch = np.array([k[0] == "switch_degrade" for k in kinds])
        kind_is_dns = np.array([k[0] == "dns_flap" for k in kinds])
        kind_xid = np.array([k[1] if k[1] is not None else -1
                             for k in kinds], dtype=np.int64)
        from repro.core.xid import XID_TABLE
        kind_hw = np.array([k[0] == "unreachable"
                            or (k[1] is not None and XID_TABLE[k[1]].hardware)
                            for k in kinds])
        kind_code = np.array([_KIND_CODES[k[0]] for k in kinds],
                             dtype=np.int8)

        # blast-radius lookup for the correlated band — deterministic and
        # draw-free, so building it cannot perturb any rng stream
        from repro.core.topology import ClusterTopology
        topo = ClusterTopology(self.n_nodes, self.topology_fanout)
        node_switch = topo.switch_map()

        block = max(int(duration_h / self.mtbf_h * 1.5) + 8, 16)
        cols = []
        for seed in seeds:
            rng = np.random.default_rng(seed)
            hazard = self.node_hazard_for(seed)
            times = np.empty(0)
            total = 0.0
            while total < duration_h:
                gaps = rng.exponential(self.mtbf_h, block)
                times = np.concatenate([times, total + np.cumsum(gaps)])
                total = float(times[-1])
            times = times[times < duration_h]
            k = len(times)
            if k == 0:
                cols.append((times, np.empty(0, np.int64),
                             np.empty(0, np.int64), np.empty(0),
                             np.empty(0), np.empty(0),
                             np.empty(0, np.int8), np.empty(0, bool),
                             np.empty(0, np.int64), [], []))
                continue
            nodes = rng.choice(self.n_nodes, size=k, p=hazard)
            kind_idx = rng.choice(len(kinds), size=k, p=probs)
            is_xid = kind_is_xid[kind_idx]
            is_slow = kind_is_slow[kind_idx]
            leads = np.where(is_xid & (rng.random(k) < self.pre_xid_fraction),
                             rng.uniform(0.25, 2.0, k),
                             0.0)
            slows = np.where(is_slow,
                             rng.uniform(1.15, 1.6, k),
                             1.0)
            # infra fault band draws — appended AFTER the historical draw
            # sequence so pre-existing schedules stay bit-identical
            win_u = rng.random(k)
            sev_u = rng.random(k)
            onset_u = rng.random(k)
            esc_u = rng.random(k)
            is_net = kind_is_net[kind_idx]
            is_res = kind_is_res[kind_idx]
            is_blind = kind_is_blind[kind_idx]
            windows = np.where(
                is_net, 0.5 + 1.5 * win_u,
                np.where(is_res, 1.0 + 2.0 * win_u,
                         np.where(is_blind, 0.25 + 0.75 * win_u, 0.0)))
            slows = np.where(is_net, 1.2 + 0.6 * sev_u,
                             np.where(is_res, 1.3 + 0.7 * sev_u, slows))
            onset = np.where(is_res, np.where(onset_u < 0.5, 1, 2),
                             np.where(is_net, 2, 0)).astype(np.int8)
            escalate = is_res & (esc_u < P_RESOURCE_ESCALATE)
            # correlated band geometry REUSES the win_u / sev_u uniforms
            # drawn above — zero extra draws on the main stream, so
            # band-off schedules stay bit-identical (docs/PARITY.md)
            is_switch = kind_is_switch[kind_idx]
            is_dns = kind_is_dns[kind_idx]
            windows = np.where(
                is_switch, 1.0 + 3.0 * win_u,
                np.where(is_dns, 0.1 + 0.3 * win_u, windows))
            slows = np.where(
                is_switch, 1.2 + 0.6 * sev_u,
                np.where(is_dns, 1.05 + 0.25 * sev_u, slows))
            onset = np.where(is_switch | is_dns, 2, onset).astype(np.int8)
            # switch identity is a deterministic lookup on the already-
            # sampled node — no draw
            switch = np.where(is_switch, node_switch[nodes], -1)
            windows = self._clip_windows(times, nodes, windows,
                                         is_net | is_res, is_blind,
                                         duration_h,
                                         is_switch, switch, is_dns)
            members = [()] * k
            peers = [()] * k
            corr_idx = np.nonzero(is_switch | is_dns)[0]
            if corr_idx.size:
                # dns member subsets go on a dedicated stream, consumed
                # in schedule order and only when correlated events exist
                rng_corr = np.random.default_rng([seed, RNG_STREAM_CORR])
                for j in corr_idx:
                    if is_switch[j]:
                        members[j] = topo.members(int(switch[j]))
                    else:
                        peer = int(nodes[j])
                        size = int(rng_corr.integers(2, 7))
                        cand = np.delete(np.arange(self.n_nodes), peer)
                        pick = rng_corr.choice(len(cand),
                                               size=min(size, len(cand)),
                                               replace=False)
                        members[j] = tuple(sorted(int(cand[p])
                                                  for p in pick))
                        peers[j] = (peer,)
            cols.append((times, nodes, kind_idx, leads, slows,
                         windows, onset, escalate, switch, members, peers))

        counts = [len(c[0]) for c in cols]
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        if offsets[-1] == 0:
            empty_f = np.empty(0)
            return FailureBatch(
                seeds=list(seeds), offsets=offsets, times=empty_f,
                nodes=np.empty(0, np.int64), kind=np.empty(0, np.int8),
                xid=np.empty(0, np.int64), hardware=np.empty(0, bool),
                leads=empty_f, slows=empty_f, windows=np.empty(0),
                onset=np.empty(0, np.int8), escalate=np.empty(0, bool),
                switch=np.empty(0, np.int64), members=[], peers=[])
        times = np.concatenate([c[0] for c in cols if len(c[0])])
        nodes = np.concatenate([c[1] for c in cols if len(c[0])])
        kind_idx = np.concatenate([c[2] for c in cols if len(c[0])])
        leads = np.concatenate([c[3] for c in cols if len(c[0])])
        slows = np.concatenate([c[4] for c in cols if len(c[0])])
        windows = np.concatenate([c[5] for c in cols if len(c[0])])
        onset = np.concatenate([c[6] for c in cols if len(c[0])])
        escalate = np.concatenate([c[7] for c in cols if len(c[0])])
        switch = np.concatenate([c[8] for c in cols if len(c[0])])
        members = [m for c in cols if len(c[0]) for m in c[9]]
        peers = [p for c in cols if len(c[0]) for p in c[10]]
        return FailureBatch(
            seeds=list(seeds), offsets=offsets, times=times,
            nodes=nodes.astype(np.int64), kind=kind_code[kind_idx],
            xid=kind_xid[kind_idx], hardware=kind_hw[kind_idx],
            leads=leads, slows=slows, windows=windows,
            onset=onset.astype(np.int8), escalate=escalate.astype(bool),
            switch=switch.astype(np.int64), members=members, peers=peers)

    @staticmethod
    def _clip_windows(times, nodes, windows, is_deg, is_blind, duration_h,
                      is_switch=None, switch_ids=None, is_dns=None):
        """Deterministic (draw-free) window clipping: a degradation window
        ends no later than the next window-bearing event on the same node
        (per-node non-overlap), a blind window no later than the next blind
        window (the control plane is a single global resource), a switch
        window no later than the next event on the same switch, a dns flap
        no later than the next flap of the same peer, and every window ends
        by the campaign horizon."""
        deg_idx = np.nonzero(is_deg)[0]
        for a, j in enumerate(deg_idx):
            for j2 in deg_idx[a + 1:]:
                if nodes[j2] == nodes[j]:
                    windows[j] = min(windows[j], times[j2] - times[j])
                    break
        blind_idx = np.nonzero(is_blind)[0]
        for a, b in zip(blind_idx, blind_idx[1:]):
            windows[a] = min(windows[a], times[b] - times[a])
        if is_switch is not None:
            sw_idx = np.nonzero(is_switch)[0]
            for a, j in enumerate(sw_idx):
                for j2 in sw_idx[a + 1:]:
                    if switch_ids[j2] == switch_ids[j]:
                        windows[j] = min(windows[j], times[j2] - times[j])
                        break
            dns_idx = np.nonzero(is_dns)[0]
            for a, j in enumerate(dns_idx):
                for j2 in dns_idx[a + 1:]:
                    if nodes[j2] == nodes[j]:
                        windows[j] = min(windows[j], times[j2] - times[j])
                        break
        return np.where(windows > 0,
                        np.minimum(windows, duration_h - times), 0.0)

    def _mix(self):
        kinds = []
        probs = []
        w = self.kind_weights or {}
        for xid, p in XID_MIX:
            kinds.append(("xid", xid))
            probs.append(p * w.get(CATEGORY_OF_XID[xid], 1.0))
        kinds.append(("unreachable", None))
        probs.append(P_MACHINE_UNREACHABLE * w.get("unreachable", 1.0))
        kinds.append(("fail_slow", None))
        probs.append(P_FAIL_SLOW * w.get("fail_slow", 1.0))
        # infra fault band: zero-weight by default (appending zero-mass
        # entries does not perturb `Generator.choice` draws, so existing
        # seeds keep their exact schedules)
        kinds.append(("net_degrade", None))
        probs.append(P_NET_DEGRADE * w.get("net_degrade", 0.0))
        kinds.append(("resource_exhaust", None))
        probs.append(P_RESOURCE_EXHAUST * w.get("resource_exhaust", 0.0))
        kinds.append(("ctrl_blind", None))
        probs.append(P_CTRL_BLIND * w.get("ctrl_blind", 0.0))
        # correlated band: zero-weight by default, same zero-mass-append
        # guarantee as the infra band above
        kinds.append(("switch_degrade", None))
        probs.append(P_SWITCH_DEGRADE * w.get("switch_degrade", 0.0))
        kinds.append(("dns_flap", None))
        probs.append(P_DNS_FLAP * w.get("dns_flap", 0.0))
        probs = np.asarray(probs)
        return kinds, probs / probs.sum()


# kind codes used by the stacked schedule (FailureBatch.kind); codes >= 3
# are the degrade-don't-kill infra band, codes >= 6 its correlated subset
KIND_NAMES = ("xid", "unreachable", "fail_slow",
              "net_degrade", "resource_exhaust", "ctrl_blind",
              "switch_degrade", "dns_flap")
_KIND_CODES = {name: i for i, name in enumerate(KIND_NAMES)}
ONSET_NAMES = ("", "gradual", "spike")


@dataclass
class FailureBatch:
    """S stacked failure schedules (struct-of-arrays).

    Column ``i`` (rows ``offsets[i]:offsets[i+1]``) is the schedule for
    ``seeds[i]``, bit-identical to the scalar ``sample`` draw for that
    seed.  ``hardware`` pre-resolves ``FailureEvent.is_hardware`` so the
    batched campaign engine never touches the XID table in its hot loop.
    """
    seeds: List[int]
    offsets: np.ndarray            # (S+1,) int64
    times: np.ndarray              # (K,) hours
    nodes: np.ndarray              # (K,) int64
    kind: np.ndarray               # (K,) int8 — index into KIND_NAMES
    xid: np.ndarray                # (K,) int64, -1 = none
    hardware: np.ndarray           # (K,) bool
    leads: np.ndarray              # (K,) precursor lead hours
    slows: np.ndarray              # (K,) fail-slow / degrade severity
    windows: np.ndarray            # (K,) degradation/outage window hours
    onset: np.ndarray              # (K,) int8 — index into ONSET_NAMES
    escalate: np.ndarray           # (K,) bool — window ends in a crash
    switch: np.ndarray             # (K,) int64 — degraded switch, -1 = none
    members: List[tuple]           # (K,) blast-radius node tuples
    peers: List[tuple]             # (K,) dns_flap unreachable peer tuples
    _cache: Dict[int, List[FailureEvent]] = field(default_factory=dict,
                                                  repr=False)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    def count(self, i: int) -> int:
        return int(self.offsets[i + 1] - self.offsets[i])

    def events(self, i: int) -> List[FailureEvent]:
        """Materialize seed ``i``'s schedule as FailureEvent objects."""
        if i not in self._cache:
            a, b = int(self.offsets[i]), int(self.offsets[i + 1])
            self._cache[i] = [
                FailureEvent(time_h=float(self.times[j]),
                             node=int(self.nodes[j]),
                             kind=KIND_NAMES[self.kind[j]],
                             xid=int(self.xid[j]) if self.xid[j] >= 0
                             else None,
                             precursor_lead_h=float(self.leads[j]),
                             slow_factor=float(self.slows[j]),
                             window_h=float(self.windows[j]),
                             onset=ONSET_NAMES[self.onset[j]],
                             escalate=bool(self.escalate[j]),
                             switch=int(self.switch[j]),
                             members=self.members[j],
                             peers=self.peers[j])
                for j in range(a, b)]
        return self._cache[i]


# ---------------------------------------------------------------------------
# shared window geometry — the single source of truth both campaign engines
# (scalar ClusterSim and BatchedCampaignEngine) evaluate, so their degraded-
# hours ledgers and escalation/blind timelines are bit-identical
# ---------------------------------------------------------------------------

def onset_progress(ts, t0: float, t1: float, onset: str) -> np.ndarray:
    """Severity progress in [0, 1] on the half-open window [t0, t1).

    ``gradual`` ramps linearly over the first half of the window then
    plateaus (monotone nondecreasing within the window); ``spike`` jumps
    straight to 1.  Outside the window the progress is 0."""
    ts = np.asarray(ts, dtype=float)
    active = (ts >= t0) & (ts < t1)
    if onset == "gradual":
        ramp = max((t1 - t0) * 0.5, 1e-9)
        prog = np.minimum((ts - t0) / ramp, 1.0)
    else:
        prog = np.ones_like(ts)
    return np.where(active, prog, 0.0)


def degradation_windows(events: Sequence[FailureEvent]):
    """(node, t0, t1, severity, kind, onset) per degrade-band event, plus
    the per-member expansion of every correlated blast radius — so both
    engines' degraded-hours ledgers charge fabric faults to every affected
    node through the one helper they already share.

    ``events`` may be empty (or a zero-event seed's slice); the result is
    then simply ``[]`` — callers never need to special-case it."""
    wins = [(ev.node, ev.time_h, ev.time_h + ev.window_h, ev.slow_factor,
             ev.kind, ev.onset)
            for ev in events if ev.kind in DEGRADE_KINDS]
    wins.extend(blast_radius_windows(events))
    return wins


def blast_radius_windows(events: Sequence[FailureEvent]):
    """Per-node expansion of correlated (fabric) events: one entry
    ``(node, t0, t1, severity, kind, onset)`` per affected node per event,
    truncated deterministically so no node carries two overlapping
    correlated entries.  Empty input round-trips to ``[]``."""
    out = []
    last_end: Dict[int, float] = {}
    for ev in events:
        if ev.kind not in CORRELATED_KINDS or ev.window_h <= 0.0:
            continue
        t0, t1 = ev.time_h, ev.time_h + ev.window_h
        for node in sorted(set(ev.members) | set(ev.peers)):
            a0 = max(t0, last_end.get(node, 0.0))
            if a0 >= t1:
                continue
            out.append((node, a0, t1, ev.slow_factor, ev.kind, ev.onset))
            last_end[node] = t1
    return out


def flap_pairs(ev: FailureEvent) -> frozenset:
    """Symmetric pairwise connectivity mask for a dns_flap event: the
    (a, b) node pairs that cannot reach each other during the window.
    A flap is a *link* property, so the mask always contains both
    directions; non-flap events yield the empty mask."""
    pairs = set()
    for a in ev.members:
        for b in ev.peers:
            if a != b:
                pairs.add((a, b))
                pairs.add((b, a))
    return frozenset(pairs)


def escalation_events(events: Sequence[FailureEvent]):
    """(crash_time_h, node), time-sorted, for escalating pressure windows.
    Empty input round-trips to ``[]``."""
    return sorted((ev.time_h + ev.window_h, ev.node)
                  for ev in events
                  if ev.kind == "resource_exhaust" and ev.escalate)


def blind_windows(events: Sequence[FailureEvent]):
    """(t0, t1) per control-plane outage, in schedule order.  Empty input
    round-trips to ``[]``."""
    return [(ev.time_h, ev.time_h + ev.window_h)
            for ev in events if ev.kind == "ctrl_blind"]


def has_correlated_band(kind_weights: Optional[Dict[str, float]]) -> bool:
    """True when the weight dict gives any correlated kind positive mass —
    the wavefront eligibility check (kernels/wavefront) and the engines'
    fast paths key off this."""
    if not kind_weights:
        return False
    return any(kind_weights.get(k, 0.0) > 0.0 for k in CORRELATED_KINDS)


def degraded_overlap_h(windows, t0: float, t1: float, nodes) -> float:
    """Effective training hours lost to degradation windows overlapping a
    session's [t0, t1) run span on its gang nodes: overlap * (1 - 1/sev)
    at plateau severity (the ramp is a telemetry shape, not an accounting
    term — keeping the ledger a closed form both engines share)."""
    total = 0.0
    for node, w0, w1, sev, _kind, _onset in windows:
        if node in nodes:
            ov = min(t1, w1) - max(t0, w0)
            if ov > 0.0:
                total += ov * (1.0 - 1.0 / sev)
    return total
