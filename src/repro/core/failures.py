"""Failure injection: fail-stop (XID) + fail-slow events with precursor
signatures, seeded from the paper's observed 55-day distribution.

Paper evidence (Tables 2, 9-11):
* 17 failure events / 55 days; NVLink (XID 145/149) dominant at 29.4%.
* MTBF 56.2 h estimated from 1,294 training hours / 23 abnormal ends.
* Most signals emerge ABRUPTLY at the XID time point (pre-XID detection was
  only 2/10); a minority show gradual precursors (e.g. accelerating
  correctable row-remap on gpu124).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

# paper Table 2 mix (XID-detectable part) -----------------------------------
XID_MIX = [
    (145, 0.20), (149, 0.094),      # NVLink errors, 29.4% combined
    (94, 0.118),                    # ECC errors
    (79, 0.118),                    # GPU card dropout
    (119, 0.059),                   # GPU execution errors (GSP RPC timeout)
    (31, 0.03), (43, 0.03),         # app-level page fault / halt
]
P_MACHINE_UNREACHABLE = 0.118
P_FAIL_SLOW = 0.233                 # "Others": perf degradation etc.

MTBF_HOURS = 56.2                   # paper Table 11

# cluster-infrastructure fault band (degrade-don't-kill; opt-in via
# ``kind_weights`` — the paper's Table 2 mix carries zero weight for these,
# calibration anchors are Meta's research-cluster category rates):
# base rates relative to the Table 2 mix mass, scaled by w[name] (default 0)
P_NET_DEGRADE = 0.08                # network latency/loss windows
P_RESOURCE_EXHAUST = 0.06           # host memory / ephemeral-disk pressure
P_CTRL_BLIND = 0.03                 # scheduler / control-plane outages
P_RESOURCE_ESCALATE = 0.35          # pressure windows that end in a crash

# scenario-facing failure categories (ops/scenario.py tilts these weights)
CATEGORY_OF_XID = {
    145: "nvlink", 149: "nvlink",
    94: "ecc",
    79: "dropout",
    119: "exec",
    31: "app", 43: "app",
}
FAILURE_CATEGORIES = frozenset(CATEGORY_OF_XID.values()) \
    | {"unreachable", "fail_slow",
       "net_degrade", "resource_exhaust", "ctrl_blind"}

# the degrade-don't-kill band: faults that open a window instead of
# killing a session outright
DEGRADE_KINDS = frozenset({"net_degrade", "resource_exhaust"})
INFRA_KINDS = DEGRADE_KINDS | {"ctrl_blind"}


@dataclass
class FailureEvent:
    time_h: float                   # hours since campaign start
    node: int
    kind: str                       # KIND_NAMES entry
    xid: Optional[int] = None
    # precursor signature
    precursor_lead_h: float = 0.0   # >0: signals degrade before the XID
    slow_factor: float = 1.0        # fail-slow / degrade severity multiplier
    # infra fault band: degradation / outage window geometry
    window_h: float = 0.0           # >0: event opens a [t, t+window_h) window
    onset: str = ""                 # "" | "gradual" | "spike"
    escalate: bool = False          # resource window ends in a process crash

    @property
    def is_hardware(self) -> bool:
        from repro.core.xid import XID_TABLE
        return self.kind == "unreachable" or (
            self.xid is not None and XID_TABLE[self.xid].hardware)

    @property
    def is_degrade(self) -> bool:
        return self.kind in DEGRADE_KINDS


@dataclass
class FailureInjector:
    """Samples a failure schedule for an N-node campaign.

    Inter-failure times ~ Exponential(MTBF); node selection is *skewed*
    (paper F3: exclusions concentrate — a few nodes are repeat offenders).
    ``hot_nodes``: fraction of nodes carrying ``hot_weight`` of the hazard.
    """
    n_nodes: int = 63
    mtbf_h: float = MTBF_HOURS
    hot_fraction: float = 0.05
    hot_weight: float = 0.55
    pre_xid_fraction: float = 0.2   # paper: 2/10 failures had precursors
    seed: int = 0
    # multiplicative tilts on the paper mix, keyed by category
    # ("nvlink" | "ecc" | "dropout" | "exec" | "app" | "unreachable" |
    #  "fail_slow"); the mix is renormalised after tilting
    kind_weights: Optional[Dict[str, float]] = None

    def node_hazard(self) -> np.ndarray:
        return self.node_hazard_for(self.seed)

    def sample(self, duration_h: float) -> List[FailureEvent]:
        """Sample this injector's schedule (one seed).  Delegates to the
        batched drawer so the per-seed and campaign-batched paths share one
        implementation — `sample_batch(d, [seed]).events(0)` is the
        definition, not an approximation."""
        return self.sample_batch(duration_h, [self.seed]).events(0)

    def node_hazard_for(self, seed: int) -> np.ndarray:
        """`node_hazard` for an explicit seed (the batch drawer's form)."""
        rng = np.random.default_rng(seed + 1)
        n_hot = max(int(round(self.n_nodes * self.hot_fraction)), 1)
        hot = rng.choice(self.n_nodes, size=n_hot, replace=False)
        w = np.full(self.n_nodes,
                    (1 - self.hot_weight) / (self.n_nodes - n_hot))
        w[hot] = self.hot_weight / n_hot
        return w

    def sample_batch(self, duration_h: float,
                     seeds: Sequence[int]) -> "FailureBatch":
        """Draw S independent failure schedules as one stacked structure.

        Every seed consumes its own ``default_rng(seed)`` stream with the
        exact call sequence of the historical scalar ``sample`` (gap blocks,
        node choice, mix assignment, precursor/slow draws), so column ``i``
        is bit-identical to ``FailureInjector(seed=seeds[i]).sample(...)``.
        The mix tables, category lookup arrays and hazard shaping are
        computed once and shared across seeds; per-event python objects are
        only materialized on demand (``events(i)``)."""
        kinds, probs = self._mix()
        kind_is_xid = np.array([k[0] == "xid" for k in kinds])
        kind_is_slow = np.array([k[0] == "fail_slow" for k in kinds])
        kind_is_net = np.array([k[0] == "net_degrade" for k in kinds])
        kind_is_res = np.array([k[0] == "resource_exhaust" for k in kinds])
        kind_is_blind = np.array([k[0] == "ctrl_blind" for k in kinds])
        kind_xid = np.array([k[1] if k[1] is not None else -1
                             for k in kinds], dtype=np.int64)
        from repro.core.xid import XID_TABLE
        kind_hw = np.array([k[0] == "unreachable"
                            or (k[1] is not None and XID_TABLE[k[1]].hardware)
                            for k in kinds])
        kind_code = np.array([_KIND_CODES[k[0]] for k in kinds],
                             dtype=np.int8)

        block = max(int(duration_h / self.mtbf_h * 1.5) + 8, 16)
        cols = []
        for seed in seeds:
            rng = np.random.default_rng(seed)
            hazard = self.node_hazard_for(seed)
            times = np.empty(0)
            total = 0.0
            while total < duration_h:
                gaps = rng.exponential(self.mtbf_h, block)
                times = np.concatenate([times, total + np.cumsum(gaps)])
                total = float(times[-1])
            times = times[times < duration_h]
            k = len(times)
            if k == 0:
                cols.append((times, np.empty(0, np.int64),
                             np.empty(0, np.int64), np.empty(0),
                             np.empty(0), np.empty(0),
                             np.empty(0, np.int8), np.empty(0, bool)))
                continue
            nodes = rng.choice(self.n_nodes, size=k, p=hazard)
            kind_idx = rng.choice(len(kinds), size=k, p=probs)
            is_xid = kind_is_xid[kind_idx]
            is_slow = kind_is_slow[kind_idx]
            leads = np.where(is_xid & (rng.random(k) < self.pre_xid_fraction),
                             rng.uniform(0.25, 2.0, k),
                             0.0)
            slows = np.where(is_slow,
                             rng.uniform(1.15, 1.6, k),
                             1.0)
            # infra fault band draws — appended AFTER the historical draw
            # sequence so pre-existing schedules stay bit-identical
            win_u = rng.random(k)
            sev_u = rng.random(k)
            onset_u = rng.random(k)
            esc_u = rng.random(k)
            is_net = kind_is_net[kind_idx]
            is_res = kind_is_res[kind_idx]
            is_blind = kind_is_blind[kind_idx]
            windows = np.where(
                is_net, 0.5 + 1.5 * win_u,
                np.where(is_res, 1.0 + 2.0 * win_u,
                         np.where(is_blind, 0.25 + 0.75 * win_u, 0.0)))
            slows = np.where(is_net, 1.2 + 0.6 * sev_u,
                             np.where(is_res, 1.3 + 0.7 * sev_u, slows))
            onset = np.where(is_res, np.where(onset_u < 0.5, 1, 2),
                             np.where(is_net, 2, 0)).astype(np.int8)
            escalate = is_res & (esc_u < P_RESOURCE_ESCALATE)
            windows = self._clip_windows(times, nodes, windows,
                                         is_net | is_res, is_blind,
                                         duration_h)
            cols.append((times, nodes, kind_idx, leads, slows,
                         windows, onset, escalate))

        counts = [len(c[0]) for c in cols]
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        if offsets[-1] == 0:
            empty_f = np.empty(0)
            return FailureBatch(
                seeds=list(seeds), offsets=offsets, times=empty_f,
                nodes=np.empty(0, np.int64), kind=np.empty(0, np.int8),
                xid=np.empty(0, np.int64), hardware=np.empty(0, bool),
                leads=empty_f, slows=empty_f, windows=np.empty(0),
                onset=np.empty(0, np.int8), escalate=np.empty(0, bool))
        times = np.concatenate([c[0] for c in cols if len(c[0])])
        nodes = np.concatenate([c[1] for c in cols if len(c[0])])
        kind_idx = np.concatenate([c[2] for c in cols if len(c[0])])
        leads = np.concatenate([c[3] for c in cols if len(c[0])])
        slows = np.concatenate([c[4] for c in cols if len(c[0])])
        windows = np.concatenate([c[5] for c in cols if len(c[0])])
        onset = np.concatenate([c[6] for c in cols if len(c[0])])
        escalate = np.concatenate([c[7] for c in cols if len(c[0])])
        return FailureBatch(
            seeds=list(seeds), offsets=offsets, times=times,
            nodes=nodes.astype(np.int64), kind=kind_code[kind_idx],
            xid=kind_xid[kind_idx], hardware=kind_hw[kind_idx],
            leads=leads, slows=slows, windows=windows,
            onset=onset.astype(np.int8), escalate=escalate.astype(bool))

    @staticmethod
    def _clip_windows(times, nodes, windows, is_deg, is_blind, duration_h):
        """Deterministic (draw-free) window clipping: a degradation window
        ends no later than the next window-bearing event on the same node
        (per-node non-overlap), a blind window no later than the next blind
        window (the control plane is a single global resource), and every
        window ends by the campaign horizon."""
        k = len(times)
        deg_idx = np.nonzero(is_deg)[0]
        for a, j in enumerate(deg_idx):
            for j2 in deg_idx[a + 1:]:
                if nodes[j2] == nodes[j]:
                    windows[j] = min(windows[j], times[j2] - times[j])
                    break
        blind_idx = np.nonzero(is_blind)[0]
        for a, b in zip(blind_idx, blind_idx[1:]):
            windows[a] = min(windows[a], times[b] - times[a])
        return np.where(windows > 0,
                        np.minimum(windows, duration_h - times), 0.0)

    def _mix(self):
        kinds = []
        probs = []
        w = self.kind_weights or {}
        for xid, p in XID_MIX:
            kinds.append(("xid", xid))
            probs.append(p * w.get(CATEGORY_OF_XID[xid], 1.0))
        kinds.append(("unreachable", None))
        probs.append(P_MACHINE_UNREACHABLE * w.get("unreachable", 1.0))
        kinds.append(("fail_slow", None))
        probs.append(P_FAIL_SLOW * w.get("fail_slow", 1.0))
        # infra fault band: zero-weight by default (appending zero-mass
        # entries does not perturb `Generator.choice` draws, so existing
        # seeds keep their exact schedules)
        kinds.append(("net_degrade", None))
        probs.append(P_NET_DEGRADE * w.get("net_degrade", 0.0))
        kinds.append(("resource_exhaust", None))
        probs.append(P_RESOURCE_EXHAUST * w.get("resource_exhaust", 0.0))
        kinds.append(("ctrl_blind", None))
        probs.append(P_CTRL_BLIND * w.get("ctrl_blind", 0.0))
        probs = np.asarray(probs)
        return kinds, probs / probs.sum()


# kind codes used by the stacked schedule (FailureBatch.kind); codes >= 3
# are the degrade-don't-kill infra band
KIND_NAMES = ("xid", "unreachable", "fail_slow",
              "net_degrade", "resource_exhaust", "ctrl_blind")
_KIND_CODES = {name: i for i, name in enumerate(KIND_NAMES)}
ONSET_NAMES = ("", "gradual", "spike")


@dataclass
class FailureBatch:
    """S stacked failure schedules (struct-of-arrays).

    Column ``i`` (rows ``offsets[i]:offsets[i+1]``) is the schedule for
    ``seeds[i]``, bit-identical to the scalar ``sample`` draw for that
    seed.  ``hardware`` pre-resolves ``FailureEvent.is_hardware`` so the
    batched campaign engine never touches the XID table in its hot loop.
    """
    seeds: List[int]
    offsets: np.ndarray            # (S+1,) int64
    times: np.ndarray              # (K,) hours
    nodes: np.ndarray              # (K,) int64
    kind: np.ndarray               # (K,) int8 — index into KIND_NAMES
    xid: np.ndarray                # (K,) int64, -1 = none
    hardware: np.ndarray           # (K,) bool
    leads: np.ndarray              # (K,) precursor lead hours
    slows: np.ndarray              # (K,) fail-slow / degrade severity
    windows: np.ndarray            # (K,) degradation/outage window hours
    onset: np.ndarray              # (K,) int8 — index into ONSET_NAMES
    escalate: np.ndarray           # (K,) bool — window ends in a crash
    _cache: Dict[int, List[FailureEvent]] = field(default_factory=dict,
                                                  repr=False)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    def count(self, i: int) -> int:
        return int(self.offsets[i + 1] - self.offsets[i])

    def events(self, i: int) -> List[FailureEvent]:
        """Materialize seed ``i``'s schedule as FailureEvent objects."""
        if i not in self._cache:
            a, b = int(self.offsets[i]), int(self.offsets[i + 1])
            self._cache[i] = [
                FailureEvent(time_h=float(self.times[j]),
                             node=int(self.nodes[j]),
                             kind=KIND_NAMES[self.kind[j]],
                             xid=int(self.xid[j]) if self.xid[j] >= 0
                             else None,
                             precursor_lead_h=float(self.leads[j]),
                             slow_factor=float(self.slows[j]),
                             window_h=float(self.windows[j]),
                             onset=ONSET_NAMES[self.onset[j]],
                             escalate=bool(self.escalate[j]))
                for j in range(a, b)]
        return self._cache[i]


# ---------------------------------------------------------------------------
# shared window geometry — the single source of truth both campaign engines
# (scalar ClusterSim and BatchedCampaignEngine) evaluate, so their degraded-
# hours ledgers and escalation/blind timelines are bit-identical
# ---------------------------------------------------------------------------

def onset_progress(ts, t0: float, t1: float, onset: str) -> np.ndarray:
    """Severity progress in [0, 1] on the half-open window [t0, t1).

    ``gradual`` ramps linearly over the first half of the window then
    plateaus (monotone nondecreasing within the window); ``spike`` jumps
    straight to 1.  Outside the window the progress is 0."""
    ts = np.asarray(ts, dtype=float)
    active = (ts >= t0) & (ts < t1)
    if onset == "gradual":
        ramp = max((t1 - t0) * 0.5, 1e-9)
        prog = np.minimum((ts - t0) / ramp, 1.0)
    else:
        prog = np.ones_like(ts)
    return np.where(active, prog, 0.0)


def degradation_windows(events: Sequence[FailureEvent]):
    """(node, t0, t1, severity, kind, onset) per degrade-band event."""
    return [(ev.node, ev.time_h, ev.time_h + ev.window_h, ev.slow_factor,
             ev.kind, ev.onset)
            for ev in events if ev.kind in DEGRADE_KINDS]


def escalation_events(events: Sequence[FailureEvent]):
    """(crash_time_h, node), time-sorted, for escalating pressure windows."""
    return sorted((ev.time_h + ev.window_h, ev.node)
                  for ev in events
                  if ev.kind == "resource_exhaust" and ev.escalate)


def blind_windows(events: Sequence[FailureEvent]):
    """(t0, t1) per control-plane outage, in schedule order."""
    return [(ev.time_h, ev.time_h + ev.window_h)
            for ev in events if ev.kind == "ctrl_blind"]


def degraded_overlap_h(windows, t0: float, t1: float, nodes) -> float:
    """Effective training hours lost to degradation windows overlapping a
    session's [t0, t1) run span on its gang nodes: overlap * (1 - 1/sev)
    at plateau severity (the ramp is a telemetry shape, not an accounting
    term — keeping the ledger a closed form both engines share)."""
    total = 0.0
    for node, w0, w1, sev, _kind, _onset in windows:
        if node in nodes:
            ov = min(t1, w1) - max(t0, w0)
            if ov > 0.0:
                total += ov * (1.0 - 1.0 / sev)
    return total
