"""Failure injection: fail-stop (XID) + fail-slow events with precursor
signatures, seeded from the paper's observed 55-day distribution.

Paper evidence (Tables 2, 9-11):
* 17 failure events / 55 days; NVLink (XID 145/149) dominant at 29.4%.
* MTBF 56.2 h estimated from 1,294 training hours / 23 abnormal ends.
* Most signals emerge ABRUPTLY at the XID time point (pre-XID detection was
  only 2/10); a minority show gradual precursors (e.g. accelerating
  correctable row-remap on gpu124).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

# paper Table 2 mix (XID-detectable part) -----------------------------------
XID_MIX = [
    (145, 0.20), (149, 0.094),      # NVLink errors, 29.4% combined
    (94, 0.118),                    # ECC errors
    (79, 0.118),                    # GPU card dropout
    (119, 0.059),                   # GPU execution errors (GSP RPC timeout)
    (31, 0.03), (43, 0.03),         # app-level page fault / halt
]
P_MACHINE_UNREACHABLE = 0.118
P_FAIL_SLOW = 0.233                 # "Others": perf degradation etc.

MTBF_HOURS = 56.2                   # paper Table 11

# scenario-facing failure categories (ops/scenario.py tilts these weights)
CATEGORY_OF_XID = {
    145: "nvlink", 149: "nvlink",
    94: "ecc",
    79: "dropout",
    119: "exec",
    31: "app", 43: "app",
}
FAILURE_CATEGORIES = frozenset(CATEGORY_OF_XID.values()) \
    | {"unreachable", "fail_slow"}


@dataclass
class FailureEvent:
    time_h: float                   # hours since campaign start
    node: int
    kind: str                       # "xid" | "unreachable" | "fail_slow"
    xid: Optional[int] = None
    # precursor signature
    precursor_lead_h: float = 0.0   # >0: signals degrade before the XID
    slow_factor: float = 1.0        # fail-slow: relative step-time multiplier

    @property
    def is_hardware(self) -> bool:
        from repro.core.xid import XID_TABLE
        return self.kind == "unreachable" or (
            self.xid is not None and XID_TABLE[self.xid].hardware)


@dataclass
class FailureInjector:
    """Samples a failure schedule for an N-node campaign.

    Inter-failure times ~ Exponential(MTBF); node selection is *skewed*
    (paper F3: exclusions concentrate — a few nodes are repeat offenders).
    ``hot_nodes``: fraction of nodes carrying ``hot_weight`` of the hazard.
    """
    n_nodes: int = 63
    mtbf_h: float = MTBF_HOURS
    hot_fraction: float = 0.05
    hot_weight: float = 0.55
    pre_xid_fraction: float = 0.2   # paper: 2/10 failures had precursors
    seed: int = 0
    # multiplicative tilts on the paper mix, keyed by category
    # ("nvlink" | "ecc" | "dropout" | "exec" | "app" | "unreachable" |
    #  "fail_slow"); the mix is renormalised after tilting
    kind_weights: Optional[Dict[str, float]] = None

    def node_hazard(self) -> np.ndarray:
        return self.node_hazard_for(self.seed)

    def sample(self, duration_h: float) -> List[FailureEvent]:
        """Sample this injector's schedule (one seed).  Delegates to the
        batched drawer so the per-seed and campaign-batched paths share one
        implementation — `sample_batch(d, [seed]).events(0)` is the
        definition, not an approximation."""
        return self.sample_batch(duration_h, [self.seed]).events(0)

    def node_hazard_for(self, seed: int) -> np.ndarray:
        """`node_hazard` for an explicit seed (the batch drawer's form)."""
        rng = np.random.default_rng(seed + 1)
        n_hot = max(int(round(self.n_nodes * self.hot_fraction)), 1)
        hot = rng.choice(self.n_nodes, size=n_hot, replace=False)
        w = np.full(self.n_nodes,
                    (1 - self.hot_weight) / (self.n_nodes - n_hot))
        w[hot] = self.hot_weight / n_hot
        return w

    def sample_batch(self, duration_h: float,
                     seeds: Sequence[int]) -> "FailureBatch":
        """Draw S independent failure schedules as one stacked structure.

        Every seed consumes its own ``default_rng(seed)`` stream with the
        exact call sequence of the historical scalar ``sample`` (gap blocks,
        node choice, mix assignment, precursor/slow draws), so column ``i``
        is bit-identical to ``FailureInjector(seed=seeds[i]).sample(...)``.
        The mix tables, category lookup arrays and hazard shaping are
        computed once and shared across seeds; per-event python objects are
        only materialized on demand (``events(i)``)."""
        kinds, probs = self._mix()
        kind_is_xid = np.array([k[0] == "xid" for k in kinds])
        kind_is_slow = np.array([k[0] == "fail_slow" for k in kinds])
        kind_xid = np.array([k[1] if k[1] is not None else -1
                             for k in kinds], dtype=np.int64)
        from repro.core.xid import XID_TABLE
        kind_hw = np.array([k[0] == "unreachable"
                            or (k[1] is not None and XID_TABLE[k[1]].hardware)
                            for k in kinds])
        kind_code = np.array([_KIND_CODES[k[0]] for k in kinds],
                             dtype=np.int8)

        block = max(int(duration_h / self.mtbf_h * 1.5) + 8, 16)
        cols = []
        for seed in seeds:
            rng = np.random.default_rng(seed)
            hazard = self.node_hazard_for(seed)
            times = np.empty(0)
            total = 0.0
            while total < duration_h:
                gaps = rng.exponential(self.mtbf_h, block)
                times = np.concatenate([times, total + np.cumsum(gaps)])
                total = float(times[-1])
            times = times[times < duration_h]
            k = len(times)
            if k == 0:
                cols.append((times, np.empty(0, np.int64),
                             np.empty(0, np.int64), np.empty(0),
                             np.empty(0)))
                continue
            nodes = rng.choice(self.n_nodes, size=k, p=hazard)
            kind_idx = rng.choice(len(kinds), size=k, p=probs)
            is_xid = kind_is_xid[kind_idx]
            is_slow = kind_is_slow[kind_idx]
            leads = np.where(is_xid & (rng.random(k) < self.pre_xid_fraction),
                             rng.uniform(0.25, 2.0, k),
                             0.0)
            slows = np.where(is_slow,
                             rng.uniform(1.15, 1.6, k),
                             1.0)
            cols.append((times, nodes, kind_idx, leads, slows))

        counts = [len(c[0]) for c in cols]
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        if offsets[-1] == 0:
            empty_f = np.empty(0)
            return FailureBatch(
                seeds=list(seeds), offsets=offsets, times=empty_f,
                nodes=np.empty(0, np.int64), kind=np.empty(0, np.int8),
                xid=np.empty(0, np.int64), hardware=np.empty(0, bool),
                leads=empty_f, slows=empty_f)
        times = np.concatenate([c[0] for c in cols if len(c[0])])
        nodes = np.concatenate([c[1] for c in cols if len(c[0])])
        kind_idx = np.concatenate([c[2] for c in cols if len(c[0])])
        leads = np.concatenate([c[3] for c in cols if len(c[0])])
        slows = np.concatenate([c[4] for c in cols if len(c[0])])
        return FailureBatch(
            seeds=list(seeds), offsets=offsets, times=times,
            nodes=nodes.astype(np.int64), kind=kind_code[kind_idx],
            xid=kind_xid[kind_idx], hardware=kind_hw[kind_idx],
            leads=leads, slows=slows)

    def _mix(self):
        kinds = []
        probs = []
        w = self.kind_weights or {}
        for xid, p in XID_MIX:
            kinds.append(("xid", xid))
            probs.append(p * w.get(CATEGORY_OF_XID[xid], 1.0))
        kinds.append(("unreachable", None))
        probs.append(P_MACHINE_UNREACHABLE * w.get("unreachable", 1.0))
        kinds.append(("fail_slow", None))
        probs.append(P_FAIL_SLOW * w.get("fail_slow", 1.0))
        probs = np.asarray(probs)
        return kinds, probs / probs.sum()


# kind codes used by the stacked schedule (FailureBatch.kind)
KIND_NAMES = ("xid", "unreachable", "fail_slow")
_KIND_CODES = {name: i for i, name in enumerate(KIND_NAMES)}


@dataclass
class FailureBatch:
    """S stacked failure schedules (struct-of-arrays).

    Column ``i`` (rows ``offsets[i]:offsets[i+1]``) is the schedule for
    ``seeds[i]``, bit-identical to the scalar ``sample`` draw for that
    seed.  ``hardware`` pre-resolves ``FailureEvent.is_hardware`` so the
    batched campaign engine never touches the XID table in its hot loop.
    """
    seeds: List[int]
    offsets: np.ndarray            # (S+1,) int64
    times: np.ndarray              # (K,) hours
    nodes: np.ndarray              # (K,) int64
    kind: np.ndarray               # (K,) int8 — index into KIND_NAMES
    xid: np.ndarray                # (K,) int64, -1 = none
    hardware: np.ndarray           # (K,) bool
    leads: np.ndarray              # (K,) precursor lead hours
    slows: np.ndarray              # (K,) fail-slow step-time factor
    _cache: Dict[int, List[FailureEvent]] = field(default_factory=dict,
                                                  repr=False)

    @property
    def n_seeds(self) -> int:
        return len(self.seeds)

    def count(self, i: int) -> int:
        return int(self.offsets[i + 1] - self.offsets[i])

    def events(self, i: int) -> List[FailureEvent]:
        """Materialize seed ``i``'s schedule as FailureEvent objects."""
        if i not in self._cache:
            a, b = int(self.offsets[i]), int(self.offsets[i + 1])
            self._cache[i] = [
                FailureEvent(time_h=float(self.times[j]),
                             node=int(self.nodes[j]),
                             kind=KIND_NAMES[self.kind[j]],
                             xid=int(self.xid[j]) if self.xid[j] >= 0
                             else None,
                             precursor_lead_h=float(self.leads[j]),
                             slow_factor=float(self.slows[j]))
                for j in range(a, b)]
        return self._cache[i]
