"""Node-exclusion pattern tracking — paper F3 / §4.3.1.

Two exclusion mechanisms coexist:
* deliberate isolation — operators pre-allocate a single-node session on a
  suspect node so the gang scheduler cannot pick it (paper: gpu074 100%,
  gpu086 97%, gpu116 99.6% overlap with single-node occupancy);
* natural non-selection — the scheduler picks 60 of 63, so some healthy
  nodes simply miss the draw (gpu085: 4% overlap).

The tracker records per-node exclusion intervals tagged with the mechanism
and computes the concentration statistics of Fig 11-13.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class ExclusionInterval:
    node: int
    t0_h: float
    t1_h: float
    deliberate: bool          # overlaps single-node occupancy
    reason: str = ""

    @property
    def hours(self) -> float:
        return self.t1_h - self.t0_h


@dataclass
class ExclusionTracker:
    n_nodes: int = 63
    intervals: List[ExclusionInterval] = field(default_factory=list)

    def record_session(self, t0_h: float, t1_h: float,
                       participating: List[int],
                       isolated: Dict[int, str]):
        """One multi-node session: every non-participating node is excluded
        for its duration; ``isolated`` maps node -> reason for nodes under
        deliberate single-node occupancy."""
        part = set(participating)
        for node in range(self.n_nodes):
            if node in part:
                continue
            self.intervals.append(ExclusionInterval(
                node=node, t0_h=t0_h, t1_h=t1_h,
                deliberate=node in isolated,
                reason=isolated.get(node, "not selected")))

    # -- statistics (Fig 11-13) ---------------------------------------------

    def exclusion_hours(self) -> np.ndarray:
        out = np.zeros(self.n_nodes)
        for iv in self.intervals:
            out[iv.node] += iv.hours
        return out

    def exclusion_counts(self) -> np.ndarray:
        out = np.zeros(self.n_nodes, dtype=int)
        for iv in self.intervals:
            out[iv.node] += 1
        return out

    def top_k_share(self, k: int = 3) -> float:
        """Fraction of all exclusion events on the k most-excluded nodes."""
        c = self.exclusion_counts().astype(float)
        total = c.sum()
        if total == 0:
            return 0.0
        return float(np.sort(c)[::-1][:k].sum() / total)

    def by_reason(self) -> Dict[str, dict]:
        """Exclusion events grouped by reason — separates the injected
        mechanisms (fail-slow isolation, hardware down, not-selected) from
        detector-driven ones ("predictive drain"), so control-plane
        campaigns can show F3 concentration *emerging* from alarms."""
        out: Dict[str, dict] = {}
        for iv in self.intervals:
            g = out.setdefault(iv.reason, {"count": 0, "hours": 0.0,
                                           "nodes": set()})
            g["count"] += 1
            g["hours"] += iv.hours
            g["nodes"].add(iv.node)
        return {reason: {"count": g["count"], "hours": g["hours"],
                         "nodes": sorted(g["nodes"])}
                for reason, g in out.items()}

    def deliberate_overlap(self) -> Dict[int, float]:
        """Per node: fraction of exclusion hours that were deliberate."""
        total = np.zeros(self.n_nodes)
        delib = np.zeros(self.n_nodes)
        for iv in self.intervals:
            total[iv.node] += iv.hours
            if iv.deliberate:
                delib[iv.node] += iv.hours
        return {n: float(delib[n] / total[n])
                for n in range(self.n_nodes) if total[n] > 0}

    def summary(self) -> dict:
        counts = self.exclusion_counts()
        hours = self.exclusion_hours()
        order = np.argsort(counts)[::-1]
        return {
            "top3_nodes": [int(i) for i in order[:3]],
            "top3_share": self.top_k_share(3),
            "max_hours": float(hours.max(initial=0.0)),
            "n_intervals": len(self.intervals),
            "deliberate_fraction": float(
                sum(iv.deliberate for iv in self.intervals)
                / max(len(self.intervals), 1)),
        }
