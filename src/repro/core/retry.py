"""Auto-retry chains — paper F4 / §4.3.2-4.3.5.

Paper-faithful policy: fixed retry delay (10 min) + teardown/restart
overhead -> 11-minute median inter-session gap (IQR 10-11).  Chain success
(reaching RUNNING at least once after a retry) was 33.3% vs 12.5% for manual
one-shot restarts (2.7x), with median downtime 1.9 h vs 3.3 h.

Beyond-paper policies implemented from the paper's §4.3.5 improvement list:
* exponential backoff (10 -> 20 -> 40 min, capped),
* XID-based branching (RESTART_APP: retry immediately; RESET_GPU: retry
  after device-reset delay; RESTART_BM/CONTACT_SUPPORT: stop and page),
* structural-failure detection: stop retrying when the free pool cannot
  satisfy the gang requirement (the paper's chains burned 30 consecutive
  failed attempts / ~35 GPU-hours on exactly this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Collection, List, Optional, Sequence

from repro.core.xid import XID_TABLE, Resolution


class RetryPolicy(Enum):
    FIXED = "fixed"                  # paper-faithful
    EXP_BACKOFF = "exp_backoff"      # §4.3.5 improvement 1
    XID_BRANCH = "xid_branch"        # §4.3.5 improvement 2


@dataclass
class RetryConfig:
    enabled: bool = True
    max_retries: int = 30
    delay_min: float = 10.0          # minutes (paper setting)
    teardown_min: float = 1.0        # observed teardown+restart overhead
    policy: RetryPolicy = RetryPolicy.FIXED
    backoff_factor: float = 2.0
    backoff_cap_min: float = 80.0
    gpu_reset_min: float = 6.0       # device reset before retry (XID branch)
    # §4.3.5 improvement 3: when the healthy pool cannot satisfy the gang
    # requirement, hand off to the operator immediately instead of burning
    # attempts (the paper's chains lacked this and burned 30 in a row)
    structural_stop: bool = False


@dataclass
class Attempt:
    start_h: float
    end_h: Optional[float] = None
    reached_training: bool = False
    failure_kind: Optional[str] = None   # xid | unreachable | alloc_fail | None
    xid: Optional[int] = None


@dataclass
class Chain:
    task_name: str
    attempts: List[Attempt] = field(default_factory=list)
    stopped_reason: Optional[str] = None

    @property
    def n_retries(self) -> int:
        return max(len(self.attempts) - 1, 0)

    @property
    def success(self) -> bool:
        """Paper definition: training reached after at least one retry."""
        return any(a.reached_training for a in self.attempts[1:])

    @property
    def first_reached(self) -> bool:
        return bool(self.attempts) and self.attempts[0].reached_training

    def classify(self) -> str:
        """Paper Table 14 buckets."""
        if self.success:
            return "SUCCESS"
        if self.first_reached:
            return "FAIL_AFTER_TRAINING"
        return "FAIL_START"

    def gaps_min(self) -> List[float]:
        out = []
        for prev, nxt in zip(self.attempts, self.attempts[1:]):
            if prev.end_h is not None:
                out.append((nxt.start_h - prev.end_h) * 60.0)
        return out


class RetryEngine:
    """Decides when (and whether) the next attempt starts."""

    def __init__(self, config: RetryConfig):
        self.config = config

    def next_delay_min(self, attempt_idx: int,
                       xid: Optional[int] = None) -> Optional[float]:
        """Minutes to wait before attempt ``attempt_idx`` (1-based retry
        index); None = stop retrying (operator action required)."""
        c = self.config
        if not c.enabled or attempt_idx > c.max_retries:
            return None
        if c.policy is RetryPolicy.FIXED:
            return c.delay_min + c.teardown_min
        if c.policy is RetryPolicy.EXP_BACKOFF:
            d = c.delay_min * (c.backoff_factor ** (attempt_idx - 1))
            return min(d, c.backoff_cap_min) + c.teardown_min
        if c.policy is RetryPolicy.XID_BRANCH:
            if xid is None:
                return c.delay_min + c.teardown_min
            res = XID_TABLE[xid].resolution
            if res is Resolution.RESTART_APP:
                return c.teardown_min                  # immediate
            if res is Resolution.RESET_GPU:
                return c.gpu_reset_min + c.teardown_min
            return None                                # RESTART_BM: page operator
        raise ValueError(c.policy)

    @staticmethod
    def is_structural(free_nodes: int, required: int) -> bool:
        """Gang requirement cannot be met — retrying is futile (§4.3.5)."""
        return free_nodes < required

    @staticmethod
    def placement_order(nodes: Sequence[int],
                        avoid: Collection[int]) -> List[int]:
        """Alarm-informed retry placement: order candidate nodes so that
        recently-alarmed ones are chosen last.  The ordering is stable, so
        the scheduler's own preference is preserved within each group, and
        the gang requirement still wins — avoided nodes ARE used when the
        pool is tight (a degraded gang beats no gang)."""
        return sorted(nodes, key=lambda idx: idx in avoid)


# ---------------------------------------------------------------------------
# chain-level statistics (Table 14 / Fig 16 / Fig 17)
# ---------------------------------------------------------------------------

def chain_stats(chains: List[Chain]) -> dict:
    import numpy as np
    n = len(chains)
    classes = [c.classify() for c in chains]
    gaps = [g for c in chains for g in c.gaps_min()]
    succ = sum(1 for c in classes if c == "SUCCESS")
    return {
        "n_chains": n,
        "n_attempts": sum(len(c.attempts) for c in chains),
        "n_retries": sum(c.n_retries for c in chains),
        "success": succ,
        "fail_after_training": sum(1 for c in classes
                                   if c == "FAIL_AFTER_TRAINING"),
        "fail_start": sum(1 for c in classes if c == "FAIL_START"),
        "chain_success_rate": succ / n if n else 0.0,
        "gap_median_min": float(np.median(gaps)) if gaps else None,
        "gap_iqr_min": (float(np.percentile(gaps, 25)),
                        float(np.percentile(gaps, 75))) if gaps else None,
    }
