"""Seed-batched Monte Carlo campaign engine.

`BatchedCampaignEngine` simulates S seeds of one campaign configuration in
a single struct-of-arrays pass: per-seed clocks and session state live in
``(S,)`` numpy arrays, node pool / exclusion / repair state in ``(S,
n_nodes)`` arrays, and every wavefront iteration advances **all** seeds to
their own next event at once — the per-iteration bookkeeping (candidate
event times, checkpoint catch-up, repair scans) is one set of numpy calls
for the whole seed batch instead of S python loops.  Failure timelines are
pre-sampled per seed by the batched `FailureInjector.sample_batch`;
telemetry spans are pushed through `StreamingDetector.push_group` (the
leading-seed-axis form) and `ControlPlane` policy decisions are applied
per seed against lightweight array-backed views.

The parity contract
-------------------
``BatchedCampaignEngine(cfg).run(seeds)[i]`` reproduces
``ClusterSim(replace(cfg, seed=seeds[i])).run()`` **field-for-field**
(sessions, chains, failures, exclusion intervals, downtimes, lost-work
hours, checkpoint counts, and the control plane's counterfactual ledger;
``session_id`` is a process-global counter and is the one exempt field).
This holds because each seed consumes its own ``default_rng(seed)`` stream
with the exact draw sequence of the scalar event engine — the vectorized
wavefront only batches the *deterministic* bookkeeping, never the sampled
decisions — and because the stacked telemetry/detector math is row-wise
independent (see `StreamingDetector.push_group`).  Divergent retry chains,
predictive drains and span truncation stay exact: seeds advance in
lockstep over the shared event horizon, but each one's clocks move by its
own per-seed mask.

Why it exists: CI over hundreds of seeds.  The per-seed `SweepRunner`
path pays a full python event loop per campaign (one process-pool task
each); the batched engine runs 256 73-day seeds in roughly the wall-clock
of a handful of scalar campaigns (the ``--only mc_batch`` benchmark gates
>=10x over the pool path), which is what makes median/IQR/95%-CI columns
for the paper's F1-F4 findings routine instead of a batch job.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import (RNG_STREAM_MANUAL, RNG_STREAM_STRUCT,
                                TICK_H, _MAX_SPAN_TICKS, CampaignConfig,
                                CampaignResult, ClusterSim)
from repro.core.exclusion import ExclusionInterval, ExclusionTracker
from repro.core.failures import (CORRELATED_KINDS, DEGRADE_KINDS,
                                 KIND_NAMES, FailureBatch, FailureInjector,
                                 blind_windows, degradation_windows,
                                 degraded_overlap_h, escalation_events)
from repro.core.retry import Attempt, Chain, RetryEngine, RetryPolicy
from repro.core.session import Session, SessionState
from repro.core.xid import XID_TABLE
from repro.control.policy import ControlPlane
from repro.control.streaming import StreamingDetector
from repro.storage.fabric import StorageFabric
from repro.telemetry.exporters import (ExporterSuite, N_PAD_METRICS,
                                       NodeStateBatch)
from repro.telemetry.registry import TimeSeriesStore

__all__ = ["BatchedCampaignEngine", "run_findings_stacked"]

# hot-loop lookup: XID -> is-hardware (mirrors FailureEvent.is_hardware)
_XID_HW = {x: meta.hardware for x, meta in XID_TABLE.items()}
_NAN = float("nan")


# ---------------------------------------------------------------------------
# array-backed views: what ControlPlane sees for one seed of the batch
# ---------------------------------------------------------------------------

class _NodeView:
    """One node of one seed, duck-typing `repro.core.scheduler.Node`."""
    __slots__ = ("B", "s", "i")

    def __init__(self, B, s, i):
        self.B, self.s, self.i = B, s, i

    @property
    def healthy(self):
        return bool(self.B.healthy[self.s, self.i])

    @property
    def free(self):
        B, s, i = self.B, self.s, self.i
        return bool(B.healthy[s, i] and not B.excl[s, i]
                    and not (B.cur_on[s] and B.in_gang[s, i]))


class _SchedNodes:
    """Per-seed ``sched.nodes`` list view over the (S, n) pool arrays."""
    __slots__ = ("B", "s")

    def __init__(self, B, s):
        self.B, self.s = B, s

    def __getitem__(self, i):
        return _NodeView(self.B, self.s, i)

    def __iter__(self):
        for i in range(self.B.n):
            yield _NodeView(self.B, self.s, i)


class _SchedView:
    __slots__ = ("nodes",)

    def __init__(self, B, s):
        self.nodes = _SchedNodes(B, s)


class _CurView:
    """Current-session stand-in (state + node membership is all the
    control plane reads)."""
    __slots__ = ("state", "nodes")

    def __init__(self, state, nodes):
        self.state, self.nodes = state, nodes


class _SeedView:
    """The `_CampaignState` surface `ControlPlane` interacts with, backed
    by seed ``s``'s slice of the batch arrays."""
    __slots__ = ("eng", "B", "s", "sched")

    def __init__(self, eng, B, s):
        self.eng, self.B, self.s = eng, B, s
        self.sched = _SchedView(B, s)

    @property
    def current(self):
        B, s = self.B, self.s
        if not B.cur_on[s]:
            return None
        state = SessionState.RUNNING if B.cur_run[s] \
            else SessionState.PREPARING
        return _CurView(state, B.cur_nodes_idx[s])

    @property
    def last_save(self):
        return self.B.last_save[self.s]

    @last_save.setter
    def last_save(self, v):
        self.B.last_save[self.s] = v

    def drain_session(self, t, node, *, redeploy_h, recheck_h):
        self.eng._drain_session(self.B, self.s, t, node,
                                redeploy_h=redeploy_h, recheck_h=recheck_h)


# ---------------------------------------------------------------------------
# per-batch mutable state (struct-of-arrays + per-seed logs)
# ---------------------------------------------------------------------------

class _Batch:
    """All mutable state for one ``run``: (S,) / (S, n) arrays for the hot
    clocks and pool masks, plain per-seed python structures for the
    variable-length logs (chains, session records, downtimes) that the
    scalar engine also keeps as objects."""

    def __init__(self, cfg: CampaignConfig, seeds: Sequence[int],
                 fails: FailureBatch, materialize: bool):
        S, n = len(seeds), cfg.n_nodes
        self.cfg = cfg
        self.seeds = list(seeds)
        self.S, self.n = S, n
        self.fails = fails
        self.mat = materialize
        self.has_control = cfg.control is not None
        inf = np.inf

        # (S,) clocks that the vectorized wavefront steps consume
        self.t = np.zeros(S)
        self.alive = np.ones(S, dtype=bool)
        self.pend = np.zeros(S)                    # pending_start; NaN=None
        self.prep_until = np.zeros(S)
        self.last_ckpt = np.zeros(S)
        self.last_save = np.zeros(S)
        self.cur_on = np.zeros(S, dtype=bool)
        self.cur_run = np.zeros(S, dtype=bool)     # RUNNING vs PREPARING
        self.ckpt_events = np.zeros(S, dtype=np.int64)
        self.cur_steps = np.zeros(S, dtype=np.int64)
        # handler-only per-seed scalars: plain python lists (no vector
        # step reads them, and list access is several times cheaper than
        # numpy scalar indexing in the per-event handlers)
        self.prep_fails = [False] * S
        self.struct_until = [-1.0] * S
        self.down_since = [float("nan")] * S
        self.down_auto = [True] * S
        self.last_hw = [False] * S
        self.version = [0] * S
        self.fail_ptr = fails.offsets[:-1].astype(np.int64).copy()
        self.next_fail = np.full(S, inf)       # first failure time per seed
        has = fails.offsets[1:] > fails.offsets[:-1]
        if has.any():
            self.next_fail[has] = fails.times[fails.offsets[:-1][has]]

        # (S, n) pool state.  There is no separate "allocated" plane: the
        # single campaign job means allocated == (session live & in gang).
        self.healthy = np.ones((S, n), dtype=bool)
        self.excl = np.zeros((S, n), dtype=bool)
        self.in_gang = np.zeros((S, n), dtype=bool)
        self.repair = np.full((S, n), inf)
        self.rep_min = np.full(S, inf)    # row min, kept in sync by writers

        # per-seed python structures; the main stream consumes only
        # ``random()`` uniforms — exponentials live on dedicated
        # [seed, salt] streams exactly as in _CampaignState
        self.rngs = [np.random.default_rng(s) for s in seeds]
        self.rngs_manual = [
            np.random.default_rng([s, RNG_STREAM_MANUAL]) for s in seeds]
        self.rngs_struct = [
            np.random.default_rng([s, RNG_STREAM_STRUCT]) for s in seeds]
        self.isolated: List[Dict[int, str]] = [{} for _ in range(S)]
        self.cur_nodes_idx: List[Optional[List[int]]] = [None] * S
        self.npart_idx: List[Optional[List[int]]] = [None] * S
        self.downtimes: List[List[dict]] = [[] for _ in range(S)]
        self.lost: List[List[float]] = [[] for _ in range(S)]
        self.down_kind: List[str] = ["failure"] * S

        # findings accumulators — scalar mirrors of chain_stats /
        # ExclusionTracker.summary / Session.elapsed_running_h, updated in
        # event order so every float fold matches the scalar path
        self.n_att = [0] * S                   # attempts in the open chain
        self.first_reached = [False] * S
        self.retry_reached = [False] * S
        self.prev_end: List[Optional[float]] = [None] * S
        self.f4 = [[0, 0, 0] for _ in range(S)]  # retry chains/attempts/succ
        self.gaps: List[List[float]] = [[] for _ in range(S)]
        self.cur_started = [float("nan")] * S
        self.cur_created = [0.0] * S
        self.run_sum = [0.0] * S
        self.n_sessions = [0] * S
        # handler-side views of the stacked failure schedule
        self.ftimes = fails.times.tolist()
        self.fnodes = fails.nodes.tolist()
        self.fkind = fails.kind.tolist()
        self.fxid = fails.xid.tolist()
        self.fhw = fails.hardware.tolist()
        self.npart_all: List[List[int]] = [[] for _ in range(S)]
        self.n_intervals = [0] * S
        self.n_delib = [0] * S
        self.reason_counts: List[Dict[str, int]] = [{} for _ in range(S)]

        # object materialization (parity mode only)
        self.chains: List[List[Chain]] = \
            [[Chain(task_name="b200_v0")] if materialize else []
             for _ in range(S)]
        self.cur_log: List[Optional[list]] = [None] * S
        self.session_log: List[List[list]] = [[] for _ in range(S)]
        self.record_log: List[list] = [[] for _ in range(S)]

        # infra fault band (PR 6): degradation windows, escalation timers
        # and blind-window wake-ups, derived deterministically from the
        # stacked schedule by the same helpers the scalar engine uses.
        # All structures stay empty (and the (S,) next-* clocks inf) for
        # schedules without infra kinds, so legacy batches skip every new
        # wavefront step.
        self.has_infra = bool((fails.kind >= 3).any())
        self.deg_windows: List[list] = [[] for _ in range(S)]
        self.degraded: List[List[float]] = [[] for _ in range(S)]
        self.esc_list: List[list] = [[] for _ in range(S)]
        self.esc_ptr = [0] * S
        self.next_esc = np.full(S, inf)
        self.blind_list: List[list] = [[] for _ in range(S)]
        self.blind_ptr = [0] * S
        self.next_blind = np.full(S, inf)
        if self.has_infra:
            for i in range(S):
                evs = fails.events(i)
                self.deg_windows[i] = degradation_windows(evs)
                es = escalation_events(evs)
                self.esc_list[i] = es
                if es:
                    self.next_esc[i] = es[0][0]
                if self.has_control:
                    # blind ends only wake the loop when a control plane
                    # exists to replay queued decisions (scalar candidate
                    # list adds them under the same condition)
                    be = [b1 for _, b1 in blind_windows(evs)]
                    self.blind_list[i] = be
                    if be:
                        self.next_blind[i] = be[0]

        # telemetry / control (populated by the engine when enabled)
        self.planes: List[Optional[ControlPlane]] = [None] * S
        self.views: List[Optional[_SeedView]] = [None] * S
        self.exporters: List[Optional[ExporterSuite]] = [None] * S
        self.stores: List[Optional[TimeSeriesStore]] = [None] * S
        self.next_k = np.zeros(S, dtype=np.int64)
        self.pending_sigs: List[list] = [[] for _ in range(S)]
        self.tel_seeds: List[int] = []
        self.max_chunk = _MAX_SPAN_TICKS
        self.n_ticks_total = int(np.ceil(cfg.duration_h / TICK_H - 1e-9))


class BatchedCampaignEngine:
    """S seeds of one `CampaignConfig`, one stacked pass.

    ``run(seeds)`` materializes full per-seed `CampaignResult` objects
    (the parity surface); ``run_findings(seeds)`` skips object
    materialization and returns the per-seed findings dicts the sweep
    runner aggregates — same numbers, a fraction of the allocation work.
    Only the (default) event engine semantics are supported.
    """

    def __init__(self, config: CampaignConfig,
                 wavefront_backend: str = "auto"):
        if config.engine != "event":
            raise ValueError(
                "BatchedCampaignEngine batches the event engine; "
                f"got engine={config.engine!r}")
        if wavefront_backend not in ("auto", "numpy", "xla", "pallas"):
            raise ValueError(
                f"unknown wavefront backend {wavefront_backend!r}; "
                "expected 'auto', 'numpy', 'xla' or 'pallas'")
        self.wavefront_backend = wavefront_backend
        base = ClusterSim(config)         # resolves the storage fabric
        self.cfg = base.cfg
        self.fabric = base.fabric
        self.retry_engine = RetryEngine(self.cfg.retry)
        c = self.cfg
        self._notice_p = (c.retry.delay_min / 60.0) \
            / max(c.operator_notice_mean_h, 1e-6) * 0.5
        self._fixed_delay = c.retry.delay_min + c.retry.teardown_min \
            if c.retry.policy is RetryPolicy.FIXED else None

    # -- public API ---------------------------------------------------------

    def run(self, seeds: Sequence[int]) -> List[CampaignResult]:
        B = self._simulate(seeds, materialize=True)
        return [self._materialize(B, i) for i in range(B.S)]

    def run_findings(self, seeds: Sequence[int]) -> List[dict]:
        # findings-only campaigns are the compiled wavefront's parity
        # surface: route eligible batches through the device core (the
        # object-materializing `run` path stays numpy by construction)
        if self.wavefront_backend != "numpy":
            try:
                from repro.kernels.wavefront import (
                    resolve_wavefront_backend, run_findings_compiled)
            except ImportError:          # no jax: auto degrades to numpy
                if self.wavefront_backend != "auto":
                    raise
            else:
                backend = resolve_wavefront_backend(
                    self.wavefront_backend, self.cfg, len(seeds))
                if backend != "numpy":
                    return run_findings_compiled(self.cfg, seeds,
                                                 backend=backend)
        B = self._simulate(seeds, materialize=False)
        return [self._findings(B, i) for i in range(B.S)]

    # -- setup --------------------------------------------------------------

    def _setup_telemetry(self, B: _Batch):
        cfg = self.cfg
        if not cfg.telemetry and cfg.control is None:
            return
        n_pad = N_PAD_METRICS if cfg.telemetry_pad_metrics is None \
            else cfg.telemetry_pad_metrics
        fabric = self.fabric if self.fabric is not None else StorageFabric()
        levels = fabric.telemetry_levels(cfg.job_nodes)
        retain = cfg.telemetry and cfg.telemetry_store
        if cfg.control is not None and cfg.control.drain:
            B.max_chunk = min(_MAX_SPAN_TICKS, cfg.control.reaction_ticks)
        for i, seed in enumerate(B.seeds):
            exp = ExporterSuite(cfg.n_nodes, seed=seed, n_pad=n_pad,
                                storage_levels=levels)
            evs = B.fails.events(i)
            for ev in evs:
                if ev.precursor_lead_h > 0:
                    exp.begin_gradual_precursor(
                        ev.node, ev.time_h - ev.precursor_lead_h,
                        until_h=ev.time_h + 0.05)
                if ev.kind in DEGRADE_KINDS and ev.window_h > 0:
                    exp.begin_degradation(
                        ev.node, ev.time_h, ev.time_h + ev.window_h,
                        ev.slow_factor, ev.kind, ev.onset)
                elif ev.kind == "ctrl_blind" and ev.window_h > 0:
                    exp.begin_outage(ev.time_h, ev.time_h + ev.window_h)
                elif ev.kind in CORRELATED_KINDS and ev.window_h > 0:
                    # correlated band: co-degrade the whole blast radius
                    # (mirrors the scalar `_make_telemetry` registration)
                    exp.begin_link_degradation(
                        sorted(set(ev.members) | set(ev.peers)),
                        ev.time_h, ev.time_h + ev.window_h, ev.slow_factor)
            B.exporters[i] = exp
            if retain:
                B.stores[i] = TimeSeriesStore(cfg.n_nodes)
            if cfg.control is not None:
                plane = ControlPlane(
                    cfg.control, urgent_save_s=cfg.checkpoint_save_s,
                    n_nodes=cfg.n_nodes, seed=seed)
                plane.infra_active = B.has_infra and bool(
                    (B.fails.kind[B.fails.offsets[i]:
                                  B.fails.offsets[i + 1]] >= 3).any())
                for b0, b1 in blind_windows(evs):
                    plane.begin_blind(b0, b1)
                plane.register_failures(evs)
                B.planes[i] = plane
                B.views[i] = _SeedView(self, B, i)
            B.tel_seeds.append(i)

    # -- per-seed transition handlers (exact scalar-RNG discipline) ---------

    def _process_starts(self, B: _Batch, idx: np.ndarray,
                        t: List[float]):
        """Attempt starts for every due seed of this wavefront iteration.

        The deterministic pool scan is one stacked pass — free masks,
        gang-feasibility counts and first-``job_nodes`` selection via a
        row cumsum for all D seeds at once; only the sampled decisions
        (pressure readmits, transient-retry rolls, load-duration draws)
        and the per-seed logs run in python, each on its own rng stream.
        Seeds with an alarm-informed ``avoid`` preference (control plane)
        fall back to the scalar ordering — the soft sort is per-seed by
        nature and rare.
        """
        cfg = self.cfg
        job = cfg.job_nodes
        free = B.healthy[idx] & ~B.excl[idx]      # due seeds have no session
        counts = free.sum(axis=1)
        ok = counts >= job
        chosen_mask = free & (np.cumsum(free, axis=1) <= job)
        ok_rows = ok.nonzero()[0]
        # per-seed node lists for all gang-feasible seeds, in two calls
        nodes_flat = chosen_mask[ok_rows].nonzero()[1].reshape(-1, job)
        npart_flat = (~chosen_mask[ok_rows]).nonzero()[1].reshape(
            -1, B.n - job)

        nodes_all = nodes_flat.tolist()
        npart_all = npart_flat.tolist()
        p_readmit = cfg.p_pressure_readmit
        p_transient = cfg.p_transient_retry_fail
        load_cold, load_warm = cfg.loading_cold_h, cfg.loading_time_h
        mat = B.mat
        # locals for everything the per-seed body touches (attribute
        # loads in a 100k-invocation loop are real wall-clock)
        struct_until, last_hw = B.struct_until, B.last_hw
        rngs, planes, isolated = B.rngs, B.planes, B.isolated
        n_att_l, prev_end, gaps = B.n_att, B.prev_end, B.gaps
        cur_created, cur_started = B.cur_created, B.cur_started
        n_sessions = B.n_sessions
        cur_nodes_idx, npart_idx = B.cur_nodes_idx, B.npart_idx
        prep_fails = B.prep_fails
        sched_next = self._schedule_next
        # bit-exact fast forms of the scalar draws:
        #   uniform(a, b) == a + (b-a) * random()   (same C arithmetic)
        w_load = 0.3 - (-0.08)
        w_fail = 0.15 - 0.05
        started_seeds: List[int] = []
        started_until: List[float] = []
        ok_l = ok.tolist()
        no_ctl = not B.has_control
        if no_ctl and len(ok_rows):
            # reactive batch: no avoid preference anywhere — land every
            # gang row in one stacked write instead of 60-bool row copies
            B.in_gang[idx[ok]] = chosen_mask[ok]
        ok_i = 0
        for pos, s in enumerate(idx.tolist()):
            ts_ = t[s]
            rng = rngs[s]
            if no_ctl:
                avoid = None
            else:
                plane = planes[s]
                avoid = plane.avoid_nodes(ts_) \
                    if plane is not None else None
            if not ok_l[pos]:
                iso = isolated[s]
                hrow = B.healthy[s]
                cand = [i for i in iso if hrow[i]]
                if cand and rng.random() < p_readmit:
                    i0 = cand[0]
                    B.excl[s, i0] = False
                    hrow[i0] = True
                    iso.pop(i0, None)
                    B.repair[s, i0] = np.inf
                    B.rep_min[s] = B.repair[s].min()
                n_att_l[s] += 1
                pe = prev_end[s]
                if pe is not None:
                    gaps[s].append((ts_ - pe) * 60.0)
                prev_end[s] = ts_                 # alloc_fail ends at start
                if mat:
                    B.chains[s][-1].attempts.append(
                        Attempt(start_h=ts_, end_h=ts_,
                                failure_kind="alloc_fail"))
                sched_next(B, s, ts_, structural=True)
                continue
            if avoid:
                free_idx = free[pos].nonzero()[0]
                order = RetryEngine.placement_order(free_idx.tolist(),
                                                    avoid)
                nodes = order[:job]
                row = B.in_gang[s]
                row[:] = False
                row[nodes] = True
                npart = (~row).nonzero()[0].tolist()
                ok_i += 1
            else:
                nodes = nodes_all[ok_i]
                if not no_ctl:
                    B.in_gang[s] = chosen_mask[pos]
                npart = npart_all[ok_i]
                ok_i += 1
            cur_nodes_idx[s] = nodes
            npart_idx[s] = npart
            cur_created[s] = ts_
            cur_started[s] = _NAN
            n_sessions[s] += 1
            n_att = n_att_l[s] + 1
            n_att_l[s] = n_att
            pe = prev_end[s]
            if pe is not None:
                gaps[s].append((ts_ - pe) * 60.0)
            prev_end[s] = None                    # open until it ends
            if mat:
                chain = B.chains[s][-1]
                chain.attempts.append(Attempt(start_h=ts_))
                # session record: [created, nodes, started, ended,
                #                  end_is_error, error, steps, task_name]
                log = [ts_, nodes, None, None, False, None, 0,
                       chain.task_name]
                B.cur_log[s] = log
                B.session_log[s].append(log)
            fails = ts_ < struct_until[s]
            if not fails and n_att in (2, 3) \
                    and rng.random() < p_transient:
                fails = True
            prep_fails[s] = fails
            if fails:
                dur = 0.05 + w_fail * rng.random()
            else:
                warm = load_cold if last_hw[s] else load_warm
                dur = warm + (-0.08 + w_load * rng.random())
            started_seeds.append(s)
            started_until.append(ts_ + dur)

        if started_seeds:
            arr = np.array(started_seeds)
            B.cur_on[arr] = True
            B.cur_run[arr] = False
            B.cur_steps[arr] = 0
            B.pend[arr] = np.nan
            B.prep_until[arr] = started_until

    def _record_session(self, B: _Batch, s: int, t0: float, t1: float):
        """Exclusion bookkeeping for a finished session (the tracker's
        ``record_session`` in accumulator form + a replay log).  Mirrors
        `_CampaignState.exclusion_reasons`: the isolation ledger first,
        then the control plane's switch indictments (same setdefault
        order, so the replayed tracker matches the scalar one)."""
        iso = B.isolated[s]
        plane = B.planes[s]
        if plane is not None:
            sw = plane.switch_reasons(t0, t1)
            if sw:
                merged = dict(iso)
                for node, why in sw.items():
                    merged.setdefault(node, why)
                iso = merged
        npart = B.npart_idx[s]
        B.npart_all[s].extend(npart)
        B.n_intervals[s] += len(npart)
        if iso:
            in_gang = B.in_gang[s]
            delib = 0
            rc = B.reason_counts[s]
            for node in iso:
                if not in_gang[node]:
                    delib += 1
                    reason = iso[node]
                    rc[reason] = rc.get(reason, 0) + 1
            B.n_delib[s] += delib
        if B.mat:
            B.record_log[s].append((t0, t1, B.cur_nodes_idx[s],
                                    tuple(iso.items()) if iso else ()))

    def _account_degradation(self, B: _Batch, s: int, t1: float):
        """Close the degradation ledger for seed ``s``'s RUNNING span
        ending at ``t1`` (mirrors `_CampaignState.account_degradation`:
        called wherever the span closes — failure, drain, campaign end)."""
        if not B.deg_windows[s]:
            return
        started = B.cur_started[s]
        if started != started:          # NaN: never reached RUNNING
            return
        d = degraded_overlap_h(B.deg_windows[s], started, t1,
                               B.cur_nodes_idx[s])
        if d:
            B.degraded[s].append(d)

    def _fail_session(self, B: _Batch, s: int, t: float, kind: str, xid):
        self._account_degradation(B, s, t)
        B.last_hw[s] = kind == "unreachable" or (
            xid is not None and _XID_HW[xid])
        B.prev_end[s] = t
        started = B.cur_started[s]
        if started == started:          # session reached RUNNING
            B.run_sum[s] += max(0.0, t - started)
        if B.mat:
            att = B.chains[s][-1].attempts[-1]
            att.end_h = t
            att.failure_kind = kind
            att.xid = xid
            log = B.cur_log[s]
            log[3] = t                  # ended
            log[4] = True               # ERROR
            log[5] = f"{kind}:{xid}"
            log[6] = int(B.cur_steps[s])
            B.cur_log[s] = None
        self._record_session(B, s, B.cur_created[s], t)
        B.cur_on[s] = False
        ds = B.down_since[s]
        if ds != ds:                    # NaN: no open downtime window yet
            B.down_since[s] = t

    def _close_chain(self, B: _Batch, s: int):
        """Fold the open chain into the per-seed F4 aggregates (the
        `chain_stats` retry-chain filter and classification, inline)."""
        n_att = B.n_att[s]
        if n_att > 1:
            f4 = B.f4[s]
            f4[0] += 1
            f4[1] += n_att
            if B.retry_reached[s]:
                f4[2] += 1
        B.n_att[s] = 0
        B.first_reached[s] = False
        B.retry_reached[s] = False
        B.prev_end[s] = None

    def _schedule_next(self, B: _Batch, s: int, t: float, xid=None,
                       structural: bool = False):
        cfg = self.cfg
        rng = B.rngs[s]
        n_attempt = B.n_att[s]
        retry_on = cfg.retry.enabled
        max_r = cfg.retry.max_retries
        if self._fixed_delay is not None:       # FIXED policy ignores xid
            delay_min = self._fixed_delay \
                if retry_on and n_attempt <= max_r else None
        else:
            delay_min = self.retry_engine.next_delay_min(n_attempt, xid=xid)
        noticed = n_attempt >= 3 and rng.random() < self._notice_p
        if structural and cfg.retry.structural_stop:
            noticed = True
        if retry_on and delay_min is not None \
                and n_attempt < max_r and not noticed:
            B.pend[s] = t + delay_min / 60.0
        else:
            if B.mat:
                chain = B.chains[s][-1]
                if n_attempt >= cfg.retry.max_retries:
                    chain.stopped_reason = "max retries"
                B.version[s] += 1
                B.chains[s].append(
                    Chain(task_name=f"b200_v{B.version[s]}"))
            self._close_chain(B, s)
            B.pend[s] = t + self._manual_delay(B.rngs_manual[s], t)
            B.down_auto[s] = False
            if rng.random() < cfg.p_manual_misfix:
                B.struct_until[s] = max(
                    B.struct_until[s],
                    B.pend[s] + (cfg.structural_fix_mean_h / 2)
                    * B.rngs_struct[s].standard_exponential())
            else:
                B.struct_until[s] = min(B.struct_until[s], B.pend[s])

    def _manual_delay(self, rng_manual, t_h: float) -> float:
        cfg = self.cfg
        hour_of_day = (t_h % 24.0)
        day = int(t_h // 24.0) % 7
        if day >= 5 or hour_of_day < 8 or hour_of_day > 20:
            return float(cfg.manual_response_h_night
                         * rng_manual.standard_exponential())
        return float(cfg.manual_response_h_day
                     * rng_manual.standard_exponential())

    def _process_prepare_done(self, B: _Batch, s: int, t: float):
        if B.prep_fails[s]:
            self._fail_session(B, s, t, "software", None)
            self._schedule_next(B, s, t)
            return
        B.cur_run[s] = True
        B.cur_started[s] = t
        if B.n_att[s] == 1:
            B.first_reached[s] = True
        else:
            B.retry_reached[s] = True
        if B.mat:
            B.cur_log[s][2] = t                 # started (RUNNING)
            B.chains[s][-1].attempts[-1].reached_training = True
        B.last_ckpt[s] = t
        B.last_save[s] = t
        ds = B.down_since[s]
        if ds == ds:                            # not NaN: close the window
            B.downtimes[s].append({"t": t,
                                   "hours": t - ds,
                                   "auto": bool(B.down_auto[s]),
                                   "kind": B.down_kind[s]})
            B.down_since[s] = np.nan
            B.down_auto[s] = True
            B.down_kind[s] = "failure"

    def _process_failure(self, B: _Batch, s: int, t: float, j: int):
        """Failure row ``j`` of the stacked schedule lands on seed ``s``."""
        cfg = self.cfg
        node = B.fnodes[j]
        kcode = B.fkind[j]
        if kcode >= 3:
            # infra band (net_degrade / resource_exhaust / ctrl_blind):
            # degrade-don't-kill — the event acts via telemetry overlays,
            # the degradation ledger and (escalating pressure) a separate
            # crash timer; no immediate state change, no RNG draws
            return
        if kcode == 2:                              # fail_slow
            B.isolated[s][node] = "performance degradation"
            B.excl[s, node] = True
            B.repair[s, node] = t + cfg.slow_isolation_h
            return
        plane = B.planes[s]
        if plane is not None \
                and B.isolated[s].get(node) == "predictive drain":
            plane.stats.failures_on_drained_node += 1
        if B.fhw[j]:
            B.healthy[s, node] = False
            B.repair[s, node] = t + cfg.repair_time_h
            B.isolated[s].setdefault(node, "hardware failure")
        if B.cur_on[s] and B.in_gang[s, node]:
            rng = B.rngs[s]
            if B.cur_run[s]:
                lost = min(t - float(B.last_save[s]),
                           cfg.checkpoint_interval_h)
                B.lost[s].append(lost)
                if plane is not None:
                    baseline = min(t - float(B.last_ckpt[s]),
                                   cfg.checkpoint_interval_h)
                    plane.stats.lost_work_avoided_h += \
                        max(baseline - lost, 0.0)
            if rng.random() < cfg.p_software_failure:
                B.struct_until[s] = max(
                    B.struct_until[s],
                    t + cfg.structural_fix_mean_h
                    * B.rngs_struct[s].standard_exponential())
            xid = B.fxid[j]
            xid = xid if xid >= 0 else None
            self._fail_session(B, s, t, KIND_NAMES[kcode], xid)
            self._schedule_next(B, s, t, xid=xid)

    def _process_escalation(self, B: _Batch, s: int, t: float, node: int):
        """Escalating resource-exhaustion crash for seed ``s`` (mirrors
        `_CampaignState.process_escalation` draw for draw)."""
        cfg = self.cfg
        plane = B.planes[s]
        if plane is not None \
                and B.isolated[s].get(node) == "predictive drain":
            plane.stats.failures_on_drained_node += 1
        if B.cur_on[s] and B.in_gang[s, node]:
            rng = B.rngs[s]
            if B.cur_run[s]:
                lost = min(t - float(B.last_save[s]),
                           cfg.checkpoint_interval_h)
                B.lost[s].append(lost)
                if plane is not None:
                    baseline = min(t - float(B.last_ckpt[s]),
                                   cfg.checkpoint_interval_h)
                    plane.stats.lost_work_avoided_h += \
                        max(baseline - lost, 0.0)
            if rng.random() < cfg.p_software_failure:
                B.struct_until[s] = max(
                    B.struct_until[s],
                    t + cfg.structural_fix_mean_h
                    * B.rngs_struct[s].standard_exponential())
            self._fail_session(B, s, t, "resource_exhaust", None)
            self._schedule_next(B, s, t)

    def _drain_session(self, B: _Batch, s: int, t: float, node: int, *,
                       redeploy_h: float, recheck_h: float):
        self._account_degradation(B, s, t)
        B.prev_end[s] = t
        started = B.cur_started[s]
        if started == started:
            B.run_sum[s] += max(0.0, t - started)
        if B.mat:
            chain = B.chains[s][-1]
            att = chain.attempts[-1]
            att.end_h = t
            att.failure_kind = "drain"
            log = B.cur_log[s]
            log[3] = t
            log[4] = False                      # TERMINATED (graceful)
            log[6] = int(B.cur_steps[s])
            B.cur_log[s] = None
            chain.stopped_reason = "predictive drain"
            B.version[s] += 1
            B.chains[s].append(Chain(task_name=f"b200_v{B.version[s]}"))
        self._record_session(B, s, B.cur_created[s], t)
        B.cur_on[s] = False
        self._close_chain(B, s)
        B.isolated[s][node] = "predictive drain"
        B.excl[s, node] = True
        B.repair[s, node] = t + recheck_h
        B.rep_min[s] = min(B.rep_min[s], t + recheck_h)
        B.pend[s] = t + redeploy_h
        B.last_hw[s] = False
        B.down_since[s] = t
        B.down_kind[s] = "drain"

    # -- telemetry emission (per-seed chunks, group-scanned detector) -------

    def _emit(self, B: _Batch, t_next: np.ndarray):
        """Emit every telemetry seed's constant-state span up to its own
        ``t_next``, mirroring `_TelemetryBatcher.emit` chunk for chunk.
        Chunks are generated per seed (each exporter owns its rng stream)
        but scanned through the streaming detector in same-shape groups —
        one stacked pass per group.  A drain-grade alarm truncates that
        seed's span at the chunk boundary (returned in ``t_stop``)."""
        cfg = self.cfg
        k_end = np.minimum(
            np.ceil(t_next / TICK_H - 1e-9).astype(np.int64),
            B.n_ticks_total)
        emitting = [s for s in B.tel_seeds
                    if B.alive[s] and k_end[s] > B.next_k[s]]
        t_stop: Dict[int, float] = {}
        rows_cache: Dict[int, tuple] = {}
        for s in emitting:
            down_row = (~B.healthy[s]).astype(float)
            training = np.zeros(B.n)
            loading = np.zeros(B.n)
            running = False
            if B.cur_on[s]:
                if B.cur_run[s]:
                    training[B.cur_nodes_idx[s]] = 1.0
                    running = True
                else:
                    loading[B.cur_nodes_idx[s]] = 1.0
            rows_cache[s] = (training, loading, down_row, running)

        while emitting:
            chunk: Dict[int, tuple] = {}
            for s in emitting:
                k0 = int(B.next_k[s])
                k1 = min(k0 + B.max_chunk, int(k_end[s]))
                ts = np.arange(k0, k1) * TICK_H
                training, loading, down_row, running = rows_cache[s]
                if running:
                    phase = np.mod(ts - B.last_ckpt[s],
                                   cfg.checkpoint_interval_h)
                    ckpt_mask = (phase < cfg.checkpoint_save_s / 3600.0)
                    ckpt = ckpt_mask[:, None] * training[None, :]
                else:
                    ckpt = None
                batch = NodeStateBatch.constant(
                    len(ts), B.n, training=training, loading=loading,
                    checkpointing=ckpt, down=down_row)
                sigs = B.pending_sigs[s]
                rows = [(k - k0, ev) for k, ev in sigs if k0 <= k < k1]
                B.pending_sigs[s] = [(k, ev) for k, ev in sigs if k >= k1]
                snap = B.exporters[s].tick_batch(ts, batch, rows)
                if B.stores[s] is not None:
                    B.stores[s].append_batch(ts, snap)
                B.next_k[s] = k1
                chunk[s] = (ts, snap)

            # group-scan control seeds by chunk length; apply per seed
            ctl = [s for s in emitting if B.planes[s] is not None]
            halted = set()
            by_T: Dict[int, List[int]] = {}
            for s in ctl:
                by_T.setdefault(len(chunk[s][0]), []).append(s)
            for group in by_T.values():
                alarm_lists = StreamingDetector.push_group(
                    [B.planes[s].detector for s in group],
                    [chunk[s][0] for s in group],
                    [chunk[s][1] for s in group])
                for s, alarms in zip(group, alarm_lists):
                    plane = B.planes[s]
                    if plane.log is not None:
                        # log channel: same per-chunk fusion point as the
                        # scalar `ControlPlane.on_chunk` — chunk windows
                        # are mirrored, so the emitter's draws line up
                        alarms = plane.fuse_alarms(
                            alarms, plane.scan_logs(chunk[s][0],
                                                    B.views[s]))
                    if plane.apply_alarms(alarms, B.views[s]):
                        t_stop[s] = float(B.next_k[s]) * TICK_H
                        halted.add(s)
            emitting = [s for s in emitting
                        if s not in halted and B.next_k[s] < k_end[s]]
        return t_stop

    # -- the wavefront loop -------------------------------------------------

    def _simulate(self, seeds: Sequence[int],
                  materialize: bool) -> _Batch:
        cfg = self.cfg
        injector = FailureInjector(
            n_nodes=cfg.n_nodes, mtbf_h=cfg.mtbf_h,
            hot_fraction=cfg.hot_fraction, hot_weight=cfg.hot_weight,
            kind_weights=cfg.kind_weights,
            topology_fanout=cfg.topology_fanout, seed=cfg.seed)
        fails = injector.sample_batch(cfg.duration_h, seeds)
        B = _Batch(cfg, seeds, fails, materialize)
        self._setup_telemetry(B)
        telemetry = bool(B.tel_seeds)
        duration = cfg.duration_h
        interval = cfg.checkpoint_interval_h
        ftimes, foffs = B.ftimes, fails.offsets
        cand = np.empty((7, B.S))
        cand[0] = duration
        cand[5] = np.inf        # escalation crashes (infra band)
        cand[6] = np.inf        # blind-window wake-ups (control only)
        rep_min = B.rep_min

        # NaN pending-times flow through the candidate comparisons by
        # design; silence the FPE flag once for the whole run
        err_state = np.seterr(invalid="ignore")
        try:
            self._wavefront(B, cand, rep_min, ftimes, foffs, duration,
                            interval, telemetry)
        finally:
            np.seterr(**err_state)
        return B

    def _wavefront(self, B: _Batch, cand, rep_min, ftimes, foffs,
                   duration, interval, telemetry):
        fails = B.fails
        while B.alive.any():
            alive = B.alive
            t = B.t

            # 1. repairs due (t >= repair time)
            t_list = t.tolist()      # python floats for the event handlers

            due_rep = (alive & (rep_min <= t)).nonzero()[0]
            for s in due_rep.tolist():
                row = B.repair[s]
                iso = B.isolated[s]
                for i in (row <= t_list[s]).nonzero()[0]:
                    B.healthy[s, i] = True
                    B.excl[s, i] = False
                    row[i] = np.inf
                    iso.pop(int(i), None)
            if len(due_rep):
                rep_min[due_rep] = B.repair[due_rep].min(axis=1)

            # 2. control plane: execute pending drains at chunk boundaries
            # and replay decisions queued during blind windows (the scalar
            # loop calls ``ctl.process`` unconditionally; both paths are
            # no-ops without a pending drain or a due blind queue)
            if telemetry:
                for s in B.tel_seeds:
                    plane = B.planes[s]
                    if plane is not None and alive[s] \
                            and (plane.pending_drain is not None
                                 or plane.blind_ready(t_list[s])):
                        plane.process(t_list[s], B.views[s])

            # 3. pending attempt starts (stacked pool scan + per-seed rng)
            due_start = (alive & ~B.cur_on & (B.pend <= t)).nonzero()[0]
            if len(due_start):
                self._process_starts(B, due_start, t_list)

            # 4. PREPARING completions
            due_prep = alive & B.cur_on & ~B.cur_run & (t >= B.prep_until)
            for s in due_prep.nonzero()[0].tolist():
                self._process_prepare_done(B, s, t_list[s])

            # 5. failures due at t (possibly several per seed)
            due_fail = (alive & (B.next_fail <= t + 1e-12)).nonzero()[0]
            for s in due_fail.tolist():
                ptr, end = int(B.fail_ptr[s]), int(foffs[s + 1])
                ts_ = t_list[s]
                while ptr < end and ftimes[ptr] <= ts_ + 1e-12:
                    if telemetry and B.exporters[s] is not None:
                        k = int(np.ceil(ftimes[ptr] / TICK_H - 1e-9))
                        if k < B.n_ticks_total:
                            B.pending_sigs[s].append(
                                (k, B.fails.events(s)[ptr - int(foffs[s])]))
                    self._process_failure(B, s, ts_, ptr)
                    ptr += 1
                B.fail_ptr[s] = ptr
                B.next_fail[s] = ftimes[ptr] if ptr < end else np.inf
            if len(due_fail):        # failures schedule repairs/isolations
                rep_min[due_fail] = B.repair[due_fail].min(axis=1)

            # 5b. escalation crashes from resource-exhaustion windows
            # (processed after the failures due at t, like the scalar loop)
            due_esc = (alive & (B.next_esc <= t + 1e-12)).nonzero()[0]
            for s in due_esc.tolist():
                es, p = B.esc_list[s], B.esc_ptr[s]
                ts_ = t_list[s]
                while p < len(es) and es[p][0] <= ts_ + 1e-12:
                    self._process_escalation(B, s, ts_, es[p][1])
                    p += 1
                B.esc_ptr[s] = p
                B.next_esc[s] = es[p][0] if p < len(es) else np.inf

            # 6. next event horizon, per seed.  NaN pending (= no queued
            # attempt) propagates into the min and is rinsed by the
            # isfinite fallback, exactly like the scalar candidate filter.
            preparing = B.cur_on & ~B.cur_run
            cand[1] = rep_min
            cand[2] = np.where(B.cur_on, np.inf, B.pend)
            cand[3] = np.where(preparing, B.prep_until, np.inf)
            cand[4] = B.next_fail
            cand[5] = B.next_esc
            if B.has_infra and B.has_control:
                # wake at blind-window ends so queued decisions replay
                # (span boundaries must break there exactly like the
                # scalar candidate list — emission chunking feeds the
                # exporter rng, so the horizons must match bit for bit)
                due_bl = (alive & (B.next_blind <= t + 1e-12)).nonzero()[0]
                for s in due_bl.tolist():
                    bl, p = B.blind_list[s], B.blind_ptr[s]
                    ts_ = t_list[s]
                    while p < len(bl) and bl[p] <= ts_ + 1e-12:
                        p += 1
                    B.blind_ptr[s] = p
                    B.next_blind[s] = bl[p] if p < len(bl) else np.inf
                cand[6] = B.next_blind
            masked = np.where(cand <= t[None, :] + 1e-12, np.inf, cand)
            t_next = np.nanmin(masked, axis=0)
            t_next = np.where(np.isfinite(t_next), t_next, duration)
            np.minimum(t_next, duration, out=t_next)

            # 7. telemetry span emission (may truncate at a drain alarm)
            if telemetry:
                for s, ts_stop in self._emit(B, t_next).items():
                    if ts_stop < t_next[s]:
                        t_next[s] = ts_stop

            # 8. checkpoint catch-up over the span, vectorized
            run_mask = alive & B.cur_on & B.cur_run
            if run_mask.any():
                k = np.floor((t_next - B.last_ckpt + 1e-12)
                             / interval).astype(np.int64)
                k = np.where(run_mask, np.maximum(k, 0), 0)
                B.ckpt_events += k
                B.cur_steps += k
                B.last_ckpt += k * interval
                np.maximum(B.last_save, B.last_ckpt, out=B.last_save)

            # 9. advance / finish
            finishing = alive & (t_next >= duration)
            fin_idx = finishing.nonzero()[0]
            for s in fin_idx.tolist():
                self._finalize_seed(B, s)
            if len(fin_idx):
                B.alive = alive & ~finishing
            B.t = np.where(B.alive, t_next, B.t)

    def _finalize_seed(self, B: _Batch, s: int):
        duration = self.cfg.duration_h
        if B.cur_on[s]:
            self._account_degradation(B, s, duration)
            self._record_session(B, s, B.cur_created[s], duration)
            started = B.cur_started[s]
            if started == started:
                B.run_sum[s] += max(0.0, duration - started)
            if B.mat:
                log = B.cur_log[s]
                log[3] = duration
                log[4] = False                  # TERMINATED
                log[6] = int(B.cur_steps[s])
                B.cur_log[s] = None
            B.cur_on[s] = False
        self._close_chain(B, s)                 # the last (open) chain

    # -- result assembly ----------------------------------------------------

    def _materialize(self, B: _Batch, i: int) -> CampaignResult:
        cfg = self.cfg
        sessions = []
        for created, nodes, started, ended, is_err, error, steps, _tn \
                in B.session_log[i]:
            s = Session(task_name=_tn, n_nodes=cfg.job_nodes,
                        created_h=created)
            s.nodes = list(nodes)
            s.history = [(created, SessionState.SCHEDULED),
                         (created, SessionState.PREPARING)]
            if started is not None:
                s.started_h = started
                s.history.append((started, SessionState.RUNNING))
            if is_err:
                s.state = SessionState.ERROR
                s.history.append((ended, SessionState.ERROR))
                s.error = error
            else:
                s.state = SessionState.TERMINATED
                s.history.append((ended, SessionState.TERMINATING))
                s.history.append((ended, SessionState.TERMINATED))
            s.ended_h = ended
            s.checkpoint_step = steps
            sessions.append(s)

        tracker = ExclusionTracker(cfg.n_nodes)
        for t0, t1, part, iso_items in B.record_log[i]:
            iso = dict(iso_items)
            part_set = set(part)
            for node in range(cfg.n_nodes):
                if node in part_set:
                    continue
                tracker.intervals.append(ExclusionInterval(
                    node=node, t0_h=t0, t1_h=t1,
                    deliberate=node in iso,
                    reason=iso.get(node, "not selected")))

        plane = B.planes[i]
        return CampaignResult(
            sessions=sessions, chains=B.chains[i],
            failures=B.fails.events(i), exclusions=tracker,
            store=B.stores[i], downtimes=B.downtimes[i],
            checkpoint_events=int(B.ckpt_events[i]),
            lost_hours=B.lost[i], duration_h=cfg.duration_h,
            checkpoint_save_s=cfg.checkpoint_save_s,
            control=plane.stats if plane is not None else None,
            degraded_hours=B.degraded[i])

    def _findings(self, B: _Batch, i: int) -> dict:
        """`repro.ops.sweep.compute_findings` without the object graph —
        identical formulas over the run-time accumulators (the F4 fold of
        `chain_stats`, the tracker's count/top-3 arithmetic, the session
        running-hour sum), so the values match the scalar path bit for
        bit."""
        cfg = self.cfg
        duration = cfg.duration_h
        n_chains, n_attempts, succ = B.f4[i]
        gaps = B.gaps[i]
        counts = np.bincount(B.npart_all[i],
                             minlength=cfg.n_nodes).astype(float) \
            if B.npart_all[i] else np.zeros(cfg.n_nodes)
        total = counts.sum()
        top3 = float(np.sort(counts)[::-1][:3].sum() / total) \
            if total else 0.0
        delib_frac = float(B.n_delib[i] / max(B.n_intervals[i], 1))
        autos = [d["hours"] for d in B.downtimes[i]
                 if d["auto"] and d.get("kind") != "drain"]
        mans = [d["hours"] for d in B.downtimes[i]
                if not d["auto"] and d.get("kind") != "drain"]
        run = B.run_sum[i] if cfg.job_nodes > 1 else 0.0
        lost = B.lost[i]
        ckpt_h = int(B.ckpt_events[i]) * cfg.checkpoint_save_s / 3600.0
        plane = B.planes[i]
        urgent_h = plane.stats.urgent_save_h if plane is not None else 0.0
        # degraded hours are subtracted LAST, matching
        # `CampaignResult.goodput_h`'s float fold order exactly
        deg_h = float(np.sum(B.degraded[i]))
        goodput_h = run - float(np.sum(lost)) - ckpt_h - urgent_h - deg_h
        o0, o1 = int(B.fails.offsets[i]), int(B.fails.offsets[i + 1])
        kslice = B.fails.kind[o0:o1]
        infra_n = int((kslice >= 3).sum())
        # correlated band: event count and switch concentration (share of
        # switch_degrade events landing on the busiest switch — the F3
        # analogue at rack granularity)
        corr_n = int((kslice >= 6).sum())
        sw_ids = B.fails.switch[o0:o1][kslice == 6]
        corr_top = float(np.bincount(sw_ids).max() / len(sw_ids)) \
            if len(sw_ids) else 0.0
        out = {
            "occupancy": min(run / duration, 1.0),
            "goodput": max(goodput_h, 0.0) / duration,
            "n_failures": float(B.fails.count(i)),
            "n_sessions": float(B.n_sessions[i]),
            "ckpt_events": float(B.ckpt_events[i]),
            "mean_lost_h": float(np.mean(lost)) if lost else 0.0,
            "f3_top3_share": top3,
            "f3_deliberate_fraction": delib_frac,
            "f4_n_chains": float(n_chains),
            "f4_n_attempts": float(n_attempts),
            "f4_success_rate": succ / n_chains if n_chains else 0.0,
            "f4_gap_median_min": float(np.median(gaps)) if gaps else None,
            "f4_auto_downtime_h": float(np.median(autos)) if autos else None,
            "f4_manual_downtime_h": float(np.median(mans)) if mans else None,
            "infra_n_events": float(infra_n),
            "infra_degraded_h": deg_h,
            "corr_n_events": float(corr_n),
            "corr_top_switch_share": corr_top,
        }
        if plane is not None:
            ctl = plane.stats.summarize(B.fails.events(i), duration)
            out.update({f"ctrl_{k}": v for k, v in ctl.items()})
            drains = B.reason_counts[i].get("predictive drain")
            out["ctrl_drain_excl_events"] = float(drains) if drains else 0.0
        return out

# ---------------------------------------------------------------------------
# heterogeneous stacked dispatch (the what-if service's engine entry)
# ---------------------------------------------------------------------------

def run_findings_stacked(configs: Sequence[CampaignConfig],
                         seeds: Sequence[int], *,
                         wavefront_backend: str = "auto"
                         ) -> List[Dict[int, List[dict]]]:
    """Findings for every (config, seed) lane of a heterogeneous batch.

    The engine's lane axis is homogeneous per pass — every lane shares
    one ``CampaignConfig`` (numpy wavefront) or one node count
    (compiled grid, where gang masks share the node axis).  Callers
    holding a *mixed* bag of configs (the request coalescer) therefore
    get the documented grouping discipline instead of a free-form lane
    stack:

    * compiled-eligible configs (control-free, telemetry off, no
      correlated band) are grouped **by node count** and each group runs
      as ONE `run_findings_grid` device pass when the combined lane
      count clears the compiled floor;
    * every other config runs its own `BatchedCampaignEngine` pass
      (S seeds, one stacked-numpy wavefront).

    Per-seed findings are bitwise identical to running each config alone
    — lanes never interact (the parity contract both engines carry), so
    stacking is free coalescing, not approximation.  Returns
    ``out[i][seed]`` wrapped as per-config ``{seed: findings}`` dicts
    aligned with ``configs``; the number of underlying engine passes is
    ``len(configs)`` at most (fewer when grid groups form).
    """
    if wavefront_backend not in ("auto", "numpy", "xla", "pallas"):
        raise ValueError(
            f"unknown wavefront backend {wavefront_backend!r}")
    seeds = list(seeds)
    covered: Dict[int, List[dict]] = {}
    if wavefront_backend != "numpy":
        try:
            from repro.kernels.common import WAVEFRONT_MIN_SEEDS
            from repro.kernels.wavefront import compiled_eligible
            from repro.kernels.wavefront.ops import run_findings_grid
        except ImportError:              # no jax: auto degrades to numpy
            if wavefront_backend != "auto":
                raise
        else:
            groups: Dict[int, List[int]] = {}
            for i, cfg in enumerate(configs):
                if compiled_eligible(ClusterSim(cfg).cfg):
                    groups.setdefault(cfg.n_nodes, []).append(i)
            dev = "xla" if wavefront_backend == "auto" \
                else wavefront_backend
            for idxs in groups.values():
                if wavefront_backend == "auto" \
                        and len(idxs) * len(seeds) < WAVEFRONT_MIN_SEEDS:
                    continue             # too few lanes to beat numpy
                per_cfg = run_findings_grid([configs[i] for i in idxs],
                                            seeds, backend=dev)
                for j, i in enumerate(idxs):
                    covered[i] = per_cfg[j]
    out: List[Dict[int, List[dict]]] = []
    for i, cfg in enumerate(configs):
        findings = covered.get(i)
        if findings is None:
            findings = BatchedCampaignEngine(
                cfg, wavefront_backend="numpy").run_findings(seeds)
        out.append(dict(zip(seeds, findings)))
    return out
