"""Cluster network topology: the rack / leaf-switch tree behind the nodes.

The paper's 63-node campaign runs behind a leaf-spine fabric; failures
that live in the *fabric* (a leaf switch degrading, a service-discovery
flap) hit every node attached to the same switch at once — the blast
radius the per-node fault model structurally cannot express.  This
module is the single source of truth for the node → switch mapping, so
the injector (sampling a switch event's member set), the telemetry
overlays (co-degrading gang members), the control plane (attributing a
gang-wide alarm burst to the shared switch) and the sweep columns all
agree on who sits behind what.

The mapping is deterministic and draw-free: node ``n`` sits behind leaf
switch ``n // fanout``.  The paper-shaped default (63 nodes, fanout 8)
yields 8 leaf switches — seven full racks of 8 and one of 7 — matching
the repo's hot-node skew granularity without consuming any randomness
(docs/PARITY.md rule 1: deterministic lookups cannot perturb rng
streams).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

#: paper-shaped default: 63 nodes in racks of 8 behind one leaf each
DEFAULT_FANOUT = 8


@dataclass(frozen=True)
class ClusterTopology:
    """Leaf-switch tree over ``n_nodes`` with configurable ``fanout``."""
    n_nodes: int = 63
    fanout: int = DEFAULT_FANOUT

    def __post_init__(self):
        if self.n_nodes < 1:
            raise ValueError("topology needs at least one node")
        if self.fanout < 1:
            raise ValueError(f"fanout must be >= 1, got {self.fanout}")

    @property
    def n_switches(self) -> int:
        return -(-self.n_nodes // self.fanout)

    def switch_of(self, node: int) -> int:
        """Leaf switch the node hangs off (deterministic, no draws)."""
        if not 0 <= node < self.n_nodes:
            raise ValueError(f"node {node} outside [0, {self.n_nodes})")
        return node // self.fanout

    def members(self, switch: int) -> Tuple[int, ...]:
        """All nodes attached to ``switch`` — the blast radius of a
        switch-level event."""
        if not 0 <= switch < self.n_switches:
            raise ValueError(
                f"switch {switch} outside [0, {self.n_switches})")
        lo = switch * self.fanout
        return tuple(range(lo, min(lo + self.fanout, self.n_nodes)))

    def switch_map(self) -> np.ndarray:
        """(n_nodes,) int64 node → switch lookup (vectorized callers)."""
        return np.arange(self.n_nodes, dtype=np.int64) // self.fanout
