"""Sokovan-style GPU-first gang scheduler — paper §3.3.

Two-level scheduling: cluster level (pending sessions vs resource pool) and
node level (NUMA-aware placement).  The property that matters for the
failure analyses is GANG (all-or-nothing) allocation: a 60-node job either
gets all 60 slots at once or the whole request queues — partial allocation
would deadlock NCCL init and fragment the pool.  This constraint is the
structural cause of auto-retry failures when the healthy pool drops below
the job size (paper §4.3.5).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.retry import RetryEngine
from repro.core.session import Session, SessionState


@dataclass
class Node:
    idx: int
    healthy: bool = True
    excluded: bool = False            # operator isolation (single-node occupancy)
    allocated_to: Optional[int] = None  # session id
    numa_nodes: int = 2
    gpus: int = 8

    @property
    def free(self) -> bool:
        return self.healthy and not self.excluded and self.allocated_to is None


@dataclass
class NumaPlacement:
    """Node-level placement decision (paper Fig 1)."""
    node: int
    policy: str                       # prefer-single-node | interleaving
    numa_map: Dict[int, int] = field(default_factory=dict)  # gpu -> numa node


class GangScheduler:
    def __init__(self, n_nodes: int = 63, spares: int = 3):
        self.nodes = [Node(i) for i in range(n_nodes)]
        self.n_spares = spares
        self.queue: List[Session] = []
        self.log: List[dict] = []

    # -- pool state ---------------------------------------------------------

    def free_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.free]

    def exclude(self, idx: int, t_h: float, reason: str):
        self.nodes[idx].excluded = True
        self.log.append({"t": t_h, "event": "exclude", "node": idx,
                         "reason": reason})

    def readmit(self, idx: int, t_h: float):
        self.nodes[idx].excluded = False
        self.nodes[idx].healthy = True
        self.log.append({"t": t_h, "event": "readmit", "node": idx})

    def mark_down(self, idx: int, t_h: float, reason: str):
        self.nodes[idx].healthy = False
        self.log.append({"t": t_h, "event": "down", "node": idx,
                         "reason": reason})

    # -- gang allocation ----------------------------------------------------

    def try_allocate(self, session: Session, t_h: float,
                     avoid: Optional[Set[int]] = None) -> bool:
        """All-or-nothing: allocate session.n_nodes nodes or nothing.

        ``avoid``: soft preference (alarm-informed retry placement) —
        those nodes are picked last but still used when the gang cannot be
        met without them."""
        free = self.free_nodes()
        if len(free) < session.n_nodes:
            self.log.append({"t": t_h, "event": "alloc_fail",
                             "session": session.session_id,
                             "want": session.n_nodes, "free": len(free)})
            return False
        if avoid:
            order = RetryEngine.placement_order([n.idx for n in free], avoid)
            rank = {idx: pos for pos, idx in enumerate(order)}
            free = sorted(free, key=lambda n: rank[n.idx])
        chosen = free[:session.n_nodes]
        for n in chosen:
            n.allocated_to = session.session_id
        session.nodes = [n.idx for n in chosen]
        session.transition(SessionState.SCHEDULED, t_h)
        self.log.append({"t": t_h, "event": "alloc",
                         "session": session.session_id,
                         "nodes": session.nodes})
        return True

    def release(self, session: Session, t_h: float):
        for idx in session.nodes:
            if self.nodes[idx].allocated_to == session.session_id:
                self.nodes[idx].allocated_to = None
        self.log.append({"t": t_h, "event": "release",
                         "session": session.session_id})

    # -- NUMA placement (node level) ----------------------------------------

    @staticmethod
    def numa_place(gpus_requested: int, policy: str = "prefer-single-node",
                   numa_nodes: int = 2, gpus_per_node: int = 8) -> NumaPlacement:
        """Paper Fig 1: prefer-single-node packs one NUMA domain; interleaving
        spreads.  Co-location avoids cross-NUMA access (up to 1.30x)."""
        per_numa = gpus_per_node // numa_nodes
        numa_map: Dict[int, int] = {}
        if policy == "prefer-single-node" and gpus_requested <= per_numa:
            for g in range(gpus_requested):
                numa_map[g] = 0
        else:
            for g in range(gpus_requested):
                numa_map[g] = g % numa_nodes
        return NumaPlacement(node=-1, policy=policy, numa_map=numa_map)

    # -- elastic allocation (beyond-paper: 1000+-node operation) -------------

    def try_allocate_elastic(self, session: Session, t_h: float,
                             min_nodes: int) -> bool:
        """Gang-allocate up to session.n_nodes but accept >= min_nodes.

        The paper's cluster hard-required 60/60 (structural retry failures
        when the pool dipped below — §4.3.5).  At 1000+-node scale the DP
        group must instead re-form at n-k: HSDP makes this cheap (drop a
        replica), so the scheduler offers a degraded-width allocation."""
        free = self.free_nodes()
        if len(free) < min_nodes:
            self.log.append({"t": t_h, "event": "alloc_fail",
                             "session": session.session_id,
                             "want": session.n_nodes, "min": min_nodes,
                             "free": len(free)})
            return False
        width = min(len(free), session.n_nodes)
        chosen = free[:width]
        for n in chosen:
            n.allocated_to = session.session_id
        session.nodes = [n.idx for n in chosen]
        session.n_nodes = width
        session.transition(SessionState.SCHEDULED, t_h)
        self.log.append({"t": t_h, "event": "alloc_elastic",
                         "session": session.session_id, "width": width})
        return True

    # -- priority preemption (paper §4.3.5 improvement) ----------------------

    def preempt_single_node_sessions(self, needed: int, t_h: float,
                                     single_sessions: List[Session]) -> int:
        """Free nodes held by lower-priority single-node sessions so a gang
        job can meet its requirement.  Returns number of nodes freed."""
        freed = 0
        for s in sorted(single_sessions, key=lambda s: s.created_h,
                        reverse=True):
            if freed >= needed:
                break
            if s.state in (SessionState.RUNNING, SessionState.SCHEDULED) \
                    and len(s.nodes) == 1:
                idx = s.nodes[0]
                node = self.nodes[idx]
                if node.healthy:
                    s.transition(SessionState.TERMINATING, t_h)
                    s.transition(SessionState.TERMINATED, t_h)
                    node.allocated_to = None
                    node.excluded = False
                    freed += 1
                    self.log.append({"t": t_h, "event": "preempt",
                                     "session": s.session_id, "node": idx})
        return freed
