"""Session abstraction — paper §3.2 (Table 6).

A session is the stateful unit of training lifecycle management: it bundles
nodes, storage, and checkpoint progress.  Containers are stateless; sessions
resume from the last checkpoint.  The FSM mirrors Backend.AI's states with
the hang-timeout semantics of Appendix A.1 (PREPARING <= 1 h,
TERMINATING <= 30 min).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import List, Optional


class SessionState(Enum):
    PENDING = "PENDING"
    SCHEDULED = "SCHEDULED"
    PREPARING = "PREPARING"      # image pull / NCCL init / data+ckpt load
    RUNNING = "RUNNING"
    TERMINATING = "TERMINATING"
    TERMINATED = "TERMINATED"
    ERROR = "ERROR"
    CANCELLED = "CANCELLED"


# legal transitions (anything -> ERROR is implicit on failure)
_TRANSITIONS = {
    SessionState.PENDING: {SessionState.SCHEDULED, SessionState.CANCELLED},
    SessionState.SCHEDULED: {SessionState.PREPARING, SessionState.CANCELLED},
    SessionState.PREPARING: {SessionState.RUNNING, SessionState.ERROR,
                             SessionState.TERMINATING},
    SessionState.RUNNING: {SessionState.TERMINATING, SessionState.ERROR},
    SessionState.TERMINATING: {SessionState.TERMINATED, SessionState.ERROR},
    SessionState.TERMINATED: set(),
    SessionState.ERROR: set(),
    SessionState.CANCELLED: set(),
}

HANG_TIMEOUTS_H = {SessionState.PREPARING: 1.0, SessionState.TERMINATING: 0.5}

_session_counter = itertools.count()


@dataclass
class Session:
    task_name: str                     # retry chains group by task name
    n_nodes: int
    session_id: int = field(default_factory=lambda: next(_session_counter))
    state: SessionState = SessionState.PENDING
    nodes: List[int] = field(default_factory=list)
    created_h: float = 0.0
    started_h: Optional[float] = None          # entered RUNNING
    ended_h: Optional[float] = None
    checkpoint_step: int = 0                   # resume point
    error: Optional[str] = None
    history: List[tuple] = field(default_factory=list)  # (time_h, state)

    def transition(self, new: SessionState, t_h: float, error: str = None):
        if new is SessionState.ERROR:
            pass                                    # always legal
        elif new not in _TRANSITIONS[self.state]:
            raise ValueError(f"illegal transition {self.state} -> {new}")
        self.state = new
        self.history.append((t_h, new))
        if new is SessionState.RUNNING and self.started_h is None:
            self.started_h = t_h
        if new in (SessionState.TERMINATED, SessionState.ERROR,
                   SessionState.CANCELLED):
            self.ended_h = t_h
        if error:
            self.error = error

    @property
    def reached_training(self) -> bool:
        return any(s is SessionState.RUNNING for _, s in self.history)

    @property
    def is_terminal(self) -> bool:
        return self.state in (SessionState.TERMINATED, SessionState.ERROR,
                              SessionState.CANCELLED)

    def hang_check(self, t_h: float) -> bool:
        """True if the session exceeded its per-state allowed time."""
        limit = HANG_TIMEOUTS_H.get(self.state)
        if limit is None or not self.history:
            return False
        entered = self.history[-1][0]
        return (t_h - entered) > limit

    def elapsed_running_h(self, t_h: float = None) -> float:
        if self.started_h is None:
            return 0.0
        end = self.ended_h if self.ended_h is not None else t_h
        return max(0.0, (end if end is not None else self.started_h)
                   - self.started_h)
