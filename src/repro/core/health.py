"""Multi-layer health checks — paper Appendix A.1 (Table 15).

Each layer has its own probe mechanism and timeout; the health monitor
aggregates them into a per-node verdict that feeds the scheduler's
isolation decisions.  ``lspci``-based GPU probing has no TPU analogue — the
device layer uses a generic liveness probe instead (DESIGN.md §2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List


class HealthLayer(Enum):
    INFRA_KV = "infra_etcd"            # 5.0 s liveness
    INFRA_CACHE = "infra_valkey"       # 2.0 s per component / 5.0 s total
    INFRA_DB = "infra_postgres"        # 2-5 s
    AGENT_RPC = "agent_rpc"            # 5.0 s manager->agent ping
    AGENT_LIVENESS = "agent_liveness"  # 300 s heartbeat, 600 s sweep
    SESSION_HANG = "session_hang"      # PREPARING 1 h / TERMINATING 30 min
    DEVICE = "device"                  # accelerator liveness probe
    DEVICE_METRICS = "device_metrics"  # exporter thresholds


TIMEOUTS_S = {
    HealthLayer.INFRA_KV: 5.0,
    HealthLayer.INFRA_CACHE: 5.0,
    HealthLayer.INFRA_DB: 5.0,
    HealthLayer.AGENT_RPC: 5.0,
    HealthLayer.AGENT_LIVENESS: 300.0,
    HealthLayer.SESSION_HANG: 3600.0,
    HealthLayer.DEVICE: 10.0,
    HealthLayer.DEVICE_METRICS: 30.0,
}


@dataclass
class Probe:
    layer: HealthLayer
    fn: Callable[[], bool]
    timeout_s: float = 0.0

    def __post_init__(self):
        if not self.timeout_s:
            self.timeout_s = TIMEOUTS_S[self.layer]


@dataclass
class HealthReport:
    node: int
    healthy: bool
    failing_layers: List[HealthLayer] = field(default_factory=list)
    latencies_s: Dict[HealthLayer, float] = field(default_factory=dict)


class HealthMonitor:
    """Aggregates per-layer probes into per-node verdicts."""

    def __init__(self):
        self.probes: Dict[int, List[Probe]] = {}

    def register(self, node: int, probe: Probe):
        self.probes.setdefault(node, []).append(probe)

    def check(self, node: int) -> HealthReport:
        failing: List[HealthLayer] = []
        lats: Dict[HealthLayer, float] = {}
        for probe in self.probes.get(node, []):
            t0 = time.perf_counter()
            try:
                ok = probe.fn()
            except Exception:
                ok = False
            dt = time.perf_counter() - t0
            lats[probe.layer] = dt
            if not ok or dt > probe.timeout_s:
                failing.append(probe.layer)
        return HealthReport(node=node, healthy=not failing,
                            failing_layers=failing, latencies_s=lats)

    def sweep(self) -> List[HealthReport]:
        return [self.check(n) for n in sorted(self.probes)]


def device_liveness_probe() -> bool:
    """Generic accelerator liveness: can we enumerate devices and run a
    trivial computation?  (The lspci rev-ff check's portable analogue.)"""
    import jax
    import jax.numpy as jnp
    try:
        devs = jax.devices()
        if not devs:
            return False
        x = jnp.ones((8,))
        return bool(jnp.sum(x) == 8.0)
    except Exception:
        return False
