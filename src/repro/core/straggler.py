"""Fail-slow (straggler) detection — paper §2.2 + §7.2.

The paper's cluster lacked per-iteration throughput instrumentation, so
operators found slow nodes "only after noticing speed differences across
sessions" (reactive).  This module is the §7.2 fix: per-node per-step wall
times are reported by the training loop (tokens/s is derivable), and
stragglers are flagged online by peer deviation — same statistical frame as
the precursor detector, but on the *throughput* plane.

Evidence this matters at scale: 59% of 512-1024-GPU jobs hit fail-slow
stragglers with a mean 34.6% completion delay [Wu et al.]; 42.5% of jobs
affected, 10.4% of GPU-hours wasted [Lin et al.] (paper §2.2).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional

import numpy as np


@dataclass(frozen=True)
class StragglerConfig:
    window: int = 32              # trailing steps kept per node
    rel_threshold: float = 1.15   # sustained step-time ratio vs peer median
    min_steps: int = 8            # warm-up before judging
    sustain: int = 6              # consecutive slow steps before flagging


@dataclass
class StragglerReport:
    node: int
    step: int
    ratio: float                  # node step time / peer median
    sustained_steps: int


class StragglerDetector:
    """Online per-step detector over per-node step durations.

    In synchronous data-parallel training every node's *visible* step time
    equals the slowest node's — so the inputs here are the per-node compute
    segment times (fwd+bwd before the gradient sync), which the runtime can
    measure around the collective.
    """

    def __init__(self, n_nodes: int,
                 config: Optional[StragglerConfig] = None):
        # per-instance default, not a shared default-argument instance
        config = config if config is not None else StragglerConfig()
        self.n = n_nodes
        self.cfg = config
        self.hist: List[Deque[float]] = [deque(maxlen=config.window)
                                         for _ in range(n_nodes)]
        self.slow_streak = np.zeros(n_nodes, dtype=int)
        self.step = 0

    def observe(self, step_times: np.ndarray) -> List[StragglerReport]:
        """step_times: (n_nodes,) compute-segment seconds for this step."""
        self.step += 1
        for i, t in enumerate(step_times):
            self.hist[i].append(float(t))
        if self.step < self.cfg.min_steps:
            return []
        med = float(np.median(step_times))
        if med <= 0:
            return []
        ratios = step_times / med
        slow = ratios > self.cfg.rel_threshold
        self.slow_streak = np.where(slow, self.slow_streak + 1, 0)
        out = []
        for node in np.nonzero(self.slow_streak == self.cfg.sustain)[0]:
            out.append(StragglerReport(node=int(node), step=self.step,
                                       ratio=float(ratios[node]),
                                       sustained_steps=int(self.cfg.sustain)))
        return out

    def job_slowdown(self) -> float:
        """Current whole-job slowdown: max node median / peer median (the
        synchronous-training amplification the paper describes)."""
        if self.step < self.cfg.min_steps:
            return 1.0
        medians = np.array([np.median(h) if h else 0.0 for h in self.hist])
        peer = np.median(medians[medians > 0])
        return float(medians.max() / peer) if peer > 0 else 1.0
