"""XID error taxonomy and resolution actions (paper Table 3).

XID codes are the paper's failure-classification language (NVIDIA driver
codes); the taxonomy transfers unchanged to any accelerator fleet — we keep
the codes verbatim so the recovery-policy analysis reads identically
(DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import Enum


class Resolution(Enum):
    RESTART_APP = "RESTART_APP"        # process/session restart sufficient
    RESET_GPU = "RESET_GPU"            # device reset required
    RESTART_BM = "RESTART_BM"          # node (bare-metal) reboot required
    CONTACT_SUPPORT = "CONTACT_SUPPORT"  # hardware replacement path


@dataclass(frozen=True)
class XidInfo:
    code: int
    description: str
    resolution: Resolution
    action: str
    hardware: bool                     # True -> node isolation + migration


# paper Table 3 (+ §4.3.5 CONTACT_SUPPORT branch for XID 79)
XID_TABLE = {
    79: XidInfo(79, "GPU fell off the bus", Resolution.RESTART_BM,
                "Node reboot", True),
    119: XidInfo(119, "GSP RPC timeout", Resolution.RESET_GPU,
                 "GPU reset", True),
    145: XidInfo(145, "NVLink RLW error", Resolution.RESET_GPU,
                 "GPU reset", True),
    149: XidInfo(149, "NVLink NETIR error", Resolution.RESET_GPU,
                 "GPU reset", True),
    31: XidInfo(31, "GPU memory page fault", Resolution.RESTART_APP,
                "Session restart", False),
    43: XidInfo(43, "GPU processing halted", Resolution.RESTART_APP,
                "Session restart", False),
    94: XidInfo(94, "Contained ECC error", Resolution.RESTART_APP,
                "Auto-corrected", False),
}

# Minder-category mapping used by the failure-taxonomy benchmark (Table 2)
MINDER_CATEGORY = {
    145: "NVLink errors", 149: "NVLink errors",
    94: "ECC errors",
    79: "GPU card dropout",
    119: "GPU execution errors",
    31: "GPU execution errors", 43: "GPU execution errors",
}


def classify(code: int) -> XidInfo:
    return XID_TABLE[code]


def requires_isolation(code: int) -> bool:
    """Hardware-action XIDs (79/119/145/149) trigger node isolation +
    session migration; application-level XIDs retry in place (paper §2.3)."""
    return XID_TABLE[code].hardware
