"""Dispatch for the fused robust-stats detection pass.

``detect_block`` is the one entry point the streaming detector calls: it
takes a stacked ``(S, B, T, n)`` metric block (S seeds x B metrics x T
ticks x n nodes), the ``(S, T, n)`` peer-cohort mask and the ``(S, n)``
carried streaks, and returns the per-tick vote counts plus the
persistence streaks — the detector's whole pass 1 on device (Pallas TPU
kernel or the jitted-XLA reference; ``ckpt_pack``-style layout).  The
numpy implementation in ``repro.control.streaming`` stays the parity
oracle: both compiled backends must produce the identical alarm set, and
the tier-1 backend tests plus the ``detector_backend`` benchmark assert
exactly that.

Shape discipline — the part that makes the compiled path deployable:
the campaign engines emit spans whose (seed-group, tick) shapes vary
run to run (groups shrink as seeds halt; boundary chunks are short; a
drain-less span can be 2048 ticks).  Compiling per exact shape would
swamp a Monte Carlo run with recompiles (~1 s per shape for the unrolled
sorting network), so:

* the seed axis is padded to a power of two and the tick axis to a
  64-multiple, tiled at ``TILE_T`` — a handful of *cheap* jit entries
  per campaign (the pre/post stages compile in ~50 ms);
* the expensive sorting network is jitted on flattened ``(rows, n_pow2)``
  2-D input only, with rows padded to eighth-octave buckets (grain
  ``next_pow2(rows) / 8`` — <= 12.5% pad waste, at most 8 entries per
  octave and far fewer in practice), shared by every campaign, span
  shape and metric chunk;
* metric axes larger than ``BLOCK_ELEMS`` are fed in chunks (votes
  accumulate; the streak scan runs once), bounding the transient device
  buffer exactly like the numpy path's block budget.

Padded seeds/ticks/rows arrive inactive (or as +inf sort rows) and are
sliced away — they never join a cohort, a vote, or a streak.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import (BACKENDS, BLOCK_ELEMS,  # noqa: F401
                                  COMPILED_MIN_ELEMS, TILE_T)
from repro.kernels.common import next_pow2 as _next_pow2
from repro.kernels.common import on_tpu as _on_tpu
from repro.kernels.common import row_bucket as _row_bucket
from repro.kernels.common import tick_layout as _tick_layout
from repro.kernels.common import validate_backend as _validate_backend
from repro.kernels.robust_stats.kernel import (N_LANES, T_TILE,
                                               robust_hit_blocks)
from repro.kernels.robust_stats.ref import (bitonic_sort_rows,
                                            bitonic_sort_rows_loop,
                                            filled_rows_ref,
                                            hit_from_sorted_ref,
                                            streak_scan_ref)


def validate_backend(backend: str) -> str:
    return _validate_backend(backend, what="detector backend")


# -- jit stages --------------------------------------------------------------

_filled = jax.jit(filled_rows_ref)
_post = jax.jit(hit_from_sorted_ref)
# sort inputs are always freshly-built temporaries — donate them so XLA
# reuses the buffer instead of allocating another rows x 64 f32 block
_sort_net = jax.jit(bitonic_sort_rows,       # fast runtime, ~1 s compile
                    donate_argnums=0)
_sort_loop = jax.jit(bitonic_sort_rows_loop,  # ~25% slower, ~0.3 s compile
                     donate_argnums=0)
_streak = jax.jit(streak_scan_ref)

# row counts below this sort via the fori-loop network: at small shapes
# the runtime difference is milliseconds while the compile difference is
# ~0.7 s per bucket — and small long-tail shapes are the many ones
_SORT_NET_MIN_ROWS = 1 << 16


def _hit_xla(block, active, z_threshold):
    """One (tile, metric-chunk) vote pass: cheap pre/post jits around the
    row-bucketed sort, so only the 2-D sort carries a heavy compile —
    and only at the few large buckets where its runtime edge matters."""
    S, Bc, W, n = block.shape
    filled = _filled(block, active)                   # (S, Bc, W, n_pow2)
    npad = filled.shape[-1]
    rows = S * Bc * W
    rb = _row_bucket(rows)
    v = filled.reshape(rows, npad)
    if rb != rows:
        v = jnp.concatenate(
            [v, jnp.full((rb - rows, npad), jnp.inf, v.dtype)])
    sort = _sort_net if rb >= _SORT_NET_MIN_ROWS else _sort_loop
    s = sort(v)[:rows].reshape(S, Bc, W, npad)
    return _post(s, block, active, jnp.float32(z_threshold))


@functools.partial(jax.jit, static_argnames=("z_threshold", "interpret"))
def _hit_pallas(block, active, *, z_threshold, interpret):
    """Pad to the kernel's (T_TILE, N_LANES) tiles, run, slice back."""
    S, B, T, n = block.shape
    pt = (-T) % T_TILE
    pn = (-n) % N_LANES
    if pt or pn:
        block = jnp.pad(block, ((0, 0), (0, 0), (0, pt), (0, pn)))
        active = jnp.pad(active, ((0, 0), (0, pt), (0, pn)))
    hit = robust_hit_blocks(block, active, z_threshold=z_threshold,
                            interpret=interpret)
    return hit[:, :T, :n]


# -- the public entry points -------------------------------------------------

def bucket_layout(S: int, T: int):
    """(padded seeds, tick-tile widths) for a (S, …, T, n) span — callers
    that build host blocks can allocate the bucketed buffer directly and
    pass ``prepadded`` to :func:`hit_block`, skipping a copy."""
    return _next_pow2(S), _tick_layout(T)


def hit_block(block: np.ndarray, active: np.ndarray, *, z_threshold: float,
              backend: str = "xla", interpret: bool = None,
              prepadded: Tuple[int, int] = None) -> np.ndarray:
    """Multi-signal vote counts for one stacked metric chunk.

    ``block``: (S, B, T, n) metric values (cast to float32 on the way
    in); ``active``: (S, T, n) bool cohort mask.  Returns (S, T, n)
    int32.  Callers with more metrics than ``BLOCK_ELEMS`` permits (or
    with per-chunk host buffers, like the streaming detector) call this
    per chunk and sum — vote counts are additive across metrics.

    ``prepadded=(S, T)`` declares that ``block``/``active`` already have
    the :func:`bucket_layout` shape with real data in the leading
    ``[:S, …, :T]`` corner and zeros elsewhere.
    """
    validate_backend(backend)
    if backend == "numpy":
        raise ValueError("hit_block is the compiled path; the numpy "
                         "oracle lives in repro.control.streaming")
    if backend == "pallas" and interpret is None:
        interpret = not _on_tpu()
    if prepadded is not None:
        S, T = prepadded
        Sp, B, Tp, n = block.shape
        layout = _tick_layout(T)
        if (Sp, Tp) != (_next_pow2(S), sum(layout)):
            raise ValueError(f"prepadded block {block.shape} does not "
                             f"match bucket_layout({S}, {T})")
        padded, act = np.asarray(block, dtype=np.float32), active
    else:
        S, B, T, n = block.shape
        Sp = _next_pow2(S)
        layout = _tick_layout(T)
        Tp = sum(layout)
        padded = np.zeros((Sp, B, Tp, n), dtype=np.float32)
        padded[:S, :, :T] = block
        act = np.zeros((Sp, Tp, n), dtype=bool)
        act[:S, :T] = active
    act_j = jnp.asarray(act)

    chunk_b = max(BLOCK_ELEMS // max(Sp * max(layout) * n, 1), 1)
    hit = np.empty((Sp, Tp, n), dtype=np.int32)
    t0 = 0
    for width in layout:
        a_tile = act_j[:, t0:t0 + width]
        parts = []
        for i in range(0, B, chunk_b):
            x = jnp.asarray(padded[:, i:i + chunk_b, t0:t0 + width])
            if backend == "pallas":
                parts.append(_hit_pallas(
                    x, a_tile, z_threshold=float(z_threshold),
                    interpret=interpret))
            else:
                parts.append(_hit_xla(x, a_tile, z_threshold))
        tile_hit = parts[0]
        for p in parts[1:]:
            tile_hit = tile_hit + p
        hit[:, t0:t0 + width] = np.asarray(tile_hit)
        t0 += width
    return hit[:S, :T]


def streak_scan(hit: np.ndarray, carry: np.ndarray,
                min_signals: int) -> np.ndarray:
    """Compiled persistence-streak scan over accumulated vote counts.

    ``hit``: (S, T, n) int32; ``carry``: (S, n) pre-span streaks.
    Bucketed like the vote pass (padded rows never vote, so their
    streaks are 0 and slice away).
    """
    S, T, n = hit.shape
    Sp, Tp = _next_pow2(S), sum(_tick_layout(T))
    over = np.zeros((Sp, Tp, n), dtype=bool)
    over[:S, :T] = hit >= min_signals
    car = np.zeros((Sp, n), dtype=np.int32)
    car[:S] = carry
    streak = _streak(jnp.asarray(over), jnp.asarray(car))
    return np.asarray(streak)[:S, :T]


def detect_block(block: np.ndarray, active: np.ndarray, carry: np.ndarray,
                 *, z_threshold: float, min_signals: int,
                 backend: str = "xla",
                 interpret: bool = None) -> Tuple[np.ndarray, np.ndarray]:
    """Fused pass-1 of the streaming detector on a stacked span group.

    Returns numpy ``(hit, streak)``, both (S, T, n) int32: the
    multi-signal vote counts and the consecutive-hit streaks (alarms are
    ``streak == persistence``, which the caller resolves — attribution
    stays host side).
    """
    hit = hit_block(block, active, z_threshold=z_threshold,
                    backend=backend, interpret=interpret)
    return hit, streak_scan(hit, carry, min_signals)
