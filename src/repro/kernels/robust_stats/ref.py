"""Jitted-XLA reference for the fused robust-stats detection pass.

This is the compiled mirror of the numpy hot loop in
``repro.control.streaming``: masked peer median/MAD over the node axis,
robust z-scores, the multi-signal vote reduction, and the consecutive-hit
streak scan — one fused XLA computation over stacked ``(S, B, T, n)``
metric blocks (S seeds x B metrics x T ticks x n nodes) instead of the
~10 numpy passes (and their transient ``(S, B, T, n)`` temporaries) the
reference pays per span.

Structure mirrors the numpy path operation-for-operation so the alarm
sets agree:

* inactive peers are filled with ``+inf`` so they land past every valid
  entry; the median of the ``m`` active values is the midpoint pair of
  order statistics of the filled row (``jnp.sort`` here selects exactly
  the order statistics ``np.partition`` selects);
* all-inactive rows produce median 0 after the ``nan_to_num`` step, as
  the numpy path does;
* the streak scan is the identical cummax formulation:
  ``streak[t] = (t+1) - last_reset[t]`` plus the carried-in streak while
  no reset has occurred.

The one deliberate difference is precision: telemetry reaches this path
as float32 (``jax_enable_x64`` is off), while numpy computes in the
metric's own dtype (mostly float64).  Robust z-scores sit far from the
vote threshold on both sides (healthy peers at z ~ O(1), anomalies at
z ~ O(10^2) against a MAD floor), so the alarm sets agree exactly on
every tested seed — and the parity is *asserted*, not assumed, by the
tier-1 backend tests and the ``detector_backend`` benchmark gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bitonic_sort_rows(v):
    """Ascending bitonic sort over the last axis (power-of-two width).

    XLA's variadic ``sort`` lowers to a scalar comparator loop on CPU —
    ~6x slower than ``np.partition`` on these row widths — so the
    reference sorts with an explicit bitonic network instead: ``log2(w)``
    phases of reshape + min/max + select, every stage a full-width
    vectorized pass.  ~3x faster than ``jnp.sort`` on CPU and it lowers
    to pure VPU ops on TPU.  Comparison-exchange networks permute values
    only, so the sorted multiset (hence every order statistic) is
    identical to any other correct sort's.
    """
    m = v.shape[-1]
    assert m & (m - 1) == 0, f"bitonic width must be a power of 2: {m}"
    rows = v.shape[:-1]
    k = 2
    while k <= m:
        j = k // 2
        while j >= 1:
            g = m // (2 * j)
            w = v.reshape(rows + (g, 2, j))
            a, b = w[..., 0, :], w[..., 1, :]
            mn, mx = jnp.minimum(a, b), jnp.maximum(a, b)
            gi = jnp.arange(g)
            asc = (((gi * 2 * j) // k) % 2 == 0)[:, None]
            first = jnp.where(asc, mn, mx)
            second = jnp.where(asc, mx, mn)
            v = jnp.stack([first, second], axis=-2).reshape(rows + (m,))
            j //= 2
        k *= 2
    return v


def bitonic_sort_rows_loop(v):
    """The same bitonic network as a ``fori_loop`` over gather-based
    compare-exchange stages (partner ``i ^ j``, direction from
    ``i & k``).  ~25% slower at runtime than the unrolled reshape form
    (the gather beats the reshape's materialization only on compile
    time), but it compiles in ~0.3 s instead of ~1 s — the right trade
    for the small row counts the campaign engines emit in long-tail
    shapes.  ``ops.py`` picks per row count."""
    m = v.shape[-1]
    assert m & (m - 1) == 0, f"bitonic width must be a power of 2: {m}"
    stages = []
    k = 2
    while k <= m:
        j = k // 2
        while j >= 1:
            stages.append((k, j))
            j //= 2
        k *= 2
    ks = jnp.array([k for k, _ in stages], jnp.int32)
    js = jnp.array([j for _, j in stages], jnp.int32)
    idx = jnp.arange(m, dtype=jnp.int32)

    def body(i, v):
        j, k = js[i], ks[i]
        p = idx ^ j
        b = jnp.take(v, p, axis=-1)
        asc = (idx & k) == 0
        keep_min = (idx < p) == asc
        return jnp.where(keep_min, jnp.minimum(v, b), jnp.maximum(v, b))

    return jax.lax.fori_loop(0, len(stages), body, v)


def order_stat_pair(s, k_lo, k_hi):
    """(s[k_lo] + s[k_hi]) / 2 per row of an ascending-sorted ``s``."""
    lo = jnp.take_along_axis(s, k_lo, axis=-1)
    hi = jnp.take_along_axis(s, k_hi, axis=-1)
    return (lo + hi) * 0.5


def _vshape_order_stat(s, med, k, m):
    """k-th smallest of ``|s - med|`` over the first ``m`` entries of an
    ascending-sorted row, without a second sort.

    ``|s - med|`` over a sorted row is V-shaped, so its k+1 smallest
    values occupy a contiguous window ``s[lo : lo+k]`` and the k-th order
    statistic is the window's larger endpoint deviation, minimized over
    placements::

        d_(k) = min_lo max(|s[lo] - med|, |s[lo + k] - med|)

    (the k-closest-elements identity).  One gather + a max + a row min —
    O(n) per row instead of the O(n log^2 n) sorting network.  Window
    placements that would leave the active prefix (``lo + k >= m``) are
    masked to +inf.  Exact: every candidate is the true deviation of a
    real element, and the optimal window realizes the k-th order
    statistic precisely (ties share values, so any optimal window
    agrees).
    """
    n = s.shape[-1]
    idx = jnp.arange(n, dtype=jnp.int32)
    lo_dev = jnp.abs(s - med)                            # |s[lo] - med|
    hi_idx = jnp.minimum(idx + k, n - 1)
    hi_dev = jnp.abs(jnp.take_along_axis(s, hi_idx, axis=-1) - med)
    e = jnp.maximum(lo_dev, hi_dev)
    valid = (idx + k) < m                                # window inside cohort
    return jnp.min(jnp.where(valid, e, jnp.inf), axis=-1, keepdims=True)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def filled_rows_ref(block, active):
    """Sort input: +inf-filled rows, node axis padded to a power of two.

    The cohort of a row is its active AND finite entries — per metric,
    exactly as the numpy path's masked-NaN fill resolves it.  Split out
    as its own (cheap-to-compile) stage so the expensive sorting network
    can be jitted on flattened 2-D rows only — see ``ops.py``.
    """
    mask = active[:, None] & ~jnp.isnan(block)          # (S, B, T, n)
    n = block.shape[-1]
    pad = max(_next_pow2(n), 2) - n
    filled = jnp.where(mask, block, jnp.inf)
    if pad:
        filled = jnp.pad(filled, ((0, 0),) * (block.ndim - 1) + ((0, pad),),
                         constant_values=jnp.inf)
    return filled


def hit_from_sorted_ref(s, block, active, z_threshold):
    """Vote counts given the sorted rows: med/MAD selection, robust-z
    compare, multi-signal reduction.

    ``s``: (S, B, T, n_pow2) ascending-sorted filled rows; ``block`` /
    ``active`` as in :func:`robust_hit_block_ref`.  Returns (S, T, n)
    int32 vote counts.
    """
    mask = active[:, None] & ~jnp.isnan(block)          # (S, B, T, n)
    m = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1).astype(jnp.int32)
    k_lo, k_hi = (m - 1) // 2, m // 2

    med = order_stat_pair(s, k_lo, k_hi)
    any_active = mask.any(axis=-1, keepdims=True)
    med = jnp.where(any_active, med, 0.0)               # nan_to_num step

    # MAD from the same sorted row: the V-shape window identity replaces
    # the second sort entirely
    mad = (_vshape_order_stat(s, med, k_lo, m)
           + _vshape_order_stat(s, med, k_hi, m)) * 0.5
    mad = jnp.where(any_active, mad, 0.0)

    scale = 1.4826 * mad
    floor = jnp.maximum(1e-12, 1e-6 * jnp.maximum(jnp.abs(med), 1.0))
    scale = jnp.where(scale < 1e-12, floor, scale)
    # |x - med| > thr * scale  <=>  |z| > thr (scale > 0 by the floor):
    # comparing un-divided deviations saves a full-block divide pass
    over = jnp.abs(block - med) > z_threshold * scale
    return (over & mask).sum(axis=1, dtype=jnp.int32)


def robust_hit_block_ref(block, active, z_threshold):
    """Per-(seed, tick, node) multi-signal vote counts, fused end to end.

    ``block``: (S, B, T, n) float32 metric values; ``active``: (S, T, n)
    bool peer-cohort mask; returns ``hit``: (S, T, n) int32 — how many of
    the B metrics exceed ``z_threshold`` on an active node at that tick.
    (``ops.py`` runs the same three stages with the sort jitted on
    flattened rows; this single-graph form is the spec.)
    """
    filled = filled_rows_ref(block, active)
    s = bitonic_sort_rows(filled)
    return hit_from_sorted_ref(s, block, active, z_threshold)


def streak_scan_ref(over, carry):
    """Consecutive-hit streaks with cross-span carry, vectorized.

    ``over``: (S, T, n) bool vote outcomes; ``carry``: (S, n) int32 streaks
    carried in from the previous span.  ``streak[t] = (streak[t-1]+1) *
    over[t]`` == distance to the last reset row, plus the carried streak
    while no reset has occurred — the cummax formulation of the numpy path.
    """
    S, T, n = over.shape
    idx = jnp.arange(1, T + 1, dtype=jnp.int32)[None, :, None]
    last_reset = jax.lax.cummax(jnp.where(over, 0, idx), axis=1)
    streak = jnp.where(over, idx - last_reset, 0)
    return streak + jnp.where(over & (last_reset == 0),
                              carry[:, None, :], 0)


def fused_detect_ref(block, active, carry, z_threshold, min_signals):
    """The full fused pass: (hit, streak) for one stacked span group."""
    hit = robust_hit_block_ref(block, active, z_threshold)
    streak = streak_scan_ref(hit >= min_signals, carry)
    return hit, streak
