"""Fused robust-stats detection Pallas TPU kernel (the F1 hot loop).

One VMEM pass per (seed, tick-tile, metric) grid cell fuses the four
per-tick operations of the streaming detector's dominant pass:

  1. masked peer median over the node lane (inactive peers at +inf),
  2. MAD of the active cohort (second masked median on |x - med|),
  3. robust z-scores with the MAD floor, and
  4. the multi-signal vote accumulation across metrics.

The node axis is small (63 on the paper's cluster; padded to the 128-lane
tile), so the median is computed by *rank counting* instead of a sort:
for each candidate value, count how many row entries are <= it, then take
the minimum candidate whose count reaches the target rank.  That is an
O(n^2) lane-parallel reduction — three VPU ops per order statistic —
which selects exactly the same order statistics as the reference's sort
(duplicates resolve to equal values), so the Pallas and XLA backends are
bit-identical on the same float32 inputs.

Grid = (S, T_tiles, B) with the metric axis innermost: each (seed, tile)
output block is revisited B times and the vote counts accumulate in
place — the whole multi-signal reduction never leaves VMEM.  The streak
scan runs on the kernel's (S, T, n) vote output in plain XLA (see
``ops.fused_detect``): it is O(S*T*n) int work, negligible next to the
O(S*B*T*n) pass fused here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

T_TILE = 8          # float32 sublane tile
N_LANES = 128       # node axis padded to one lane tile


def _rank_select(filled, rank):
    """k-th smallest per row by rank counting.

    ``filled``: (T, n) with masked-out entries at +inf; ``rank``: (T, 1)
    int32, the 0-based order statistic to select.  ``cnt[t, j]`` = how
    many entries of row t are <= filled[t, j]; the k-th smallest is the
    minimum value whose count reaches k+1.  Exact for duplicates: any
    candidate tied with the true order statistic has the same value.
    """
    le = (filled[:, None, :] <= filled[:, :, None])      # (T, cand, n)
    cnt = le.sum(axis=-1, dtype=jnp.int32)               # (T, cand)
    ok = cnt >= rank + 1
    return jnp.min(jnp.where(ok, filled, jnp.inf), axis=-1, keepdims=True)


def _kernel(x_ref, act_ref, hit_ref, *, z_threshold):
    """One (seed, tick-tile, metric) cell: z-scores -> vote accumulation."""
    b = pl.program_id(2)
    x = x_ref[0, 0]                                      # (T_TILE, n) f32
    active = act_ref[0]                                  # (T_TILE, n) bool
    mask = active & ~jnp.isnan(x)
    m = jnp.maximum(mask.sum(axis=-1, keepdims=True), 1).astype(jnp.int32)
    k_lo, k_hi = (m - 1) // 2, m // 2

    filled = jnp.where(mask, x, jnp.inf)
    med = (_rank_select(filled, k_lo) + _rank_select(filled, k_hi)) * 0.5
    any_active = mask.any(axis=-1, keepdims=True)
    med = jnp.where(any_active, med, 0.0)                # nan_to_num step
    dev = jnp.where(mask, jnp.abs(x - med), jnp.inf)
    mad = (_rank_select(dev, k_lo) + _rank_select(dev, k_hi)) * 0.5
    mad = jnp.where(any_active, mad, 0.0)

    scale = 1.4826 * mad
    floor = jnp.maximum(1e-12, 1e-6 * jnp.maximum(jnp.abs(med), 1.0))
    scale = jnp.where(scale < 1e-12, floor, scale)
    z = jnp.abs((x - med) / scale)
    contrib = ((z > z_threshold) & mask).astype(jnp.int32)

    @pl.when(b == 0)
    def _init():
        hit_ref[0] = contrib

    @pl.when(b > 0)
    def _accum():
        hit_ref[0] += contrib


def robust_hit_blocks(x, active, *, z_threshold: float,
                      interpret: bool = False):
    """Vote counts over padded blocks: (S, B, T, n) f32 -> (S, T, n) i32.

    ``T`` must be a multiple of ``T_TILE`` and ``n`` of ``N_LANES``
    (``ops.py`` pads; padded nodes/ticks arrive inactive, so they never
    join a cohort or a vote).
    """
    S, B, T, n = x.shape
    kern = functools.partial(_kernel, z_threshold=float(z_threshold))
    return pl.pallas_call(
        kern,
        grid=(S, T // T_TILE, B),
        in_specs=[
            pl.BlockSpec((1, 1, T_TILE, n), lambda s, t, b: (s, b, t, 0)),
            pl.BlockSpec((1, T_TILE, n), lambda s, t, b: (s, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, T_TILE, n), lambda s, t, b: (s, t, 0)),
        out_shape=jax.ShapeDtypeStruct((S, T, n), jnp.int32),
        interpret=interpret,
    )(x, active)
