"""Flash attention Pallas TPU kernel.

Blocked causal GQA attention with optional sliding window and logit softcap,
in the canonical TPU grid layout: grid = (batch, q_heads, nq, nk) with the
kv axis innermost (sequential), online-softmax state (m, l, acc) carried in
VMEM scratch across kv steps, output written on the last kv block.

BlockSpecs tile (B, H, S, D) operands into (1, 1, block_q|block_k, D) VMEM
tiles; D (head_dim) is MXU-lane aligned (128 for every assigned arch; the
wrapper pads if not).  GQA is expressed in the K/V index_maps (q head h
reads kv head h // group) — no repeated KV materialisation in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, nk: int,
            seq_len: int, window: int, softcap: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = q_pos >= k_pos                                # causal
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-37)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, window: int = 0, softcap: float = 0.0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). Causal. Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(d)

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, nk=nk,
        seq_len=s, window=window, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
