"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_bhsd(q, k, v, *, window: int = 0, softcap: float = 0.0):
    """Reference causal GQA attention. q: (B,H,S,D); k,v: (B,Hkv,S,D)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, h, s, d).astype(q.dtype)
