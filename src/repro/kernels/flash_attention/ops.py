"""jit'd public wrapper: (B, S, H, D) layout adapter + dispatch.

On TPU backends the Pallas kernel runs compiled; everywhere else
``interpret=True`` executes the kernel body in Python for validation
(CPU CI) — same numerics, no Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "attn_softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, window: int = 0, attn_softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    """q: (B, S, H, D); k, v: (B, S, Hkv, D) — the model-layer layout."""
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, window=window,
                               softcap=attn_softcap, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
