"""Shared shape-bucketing / jit-cache discipline for the compiled packages.

Both compiled hot paths — the robust-stats detection pass
(``kernels/robust_stats``) and the whole-campaign wavefront
(``kernels/wavefront``) — face the same deployment problem: callers hand
them shapes that vary run to run (seed groups shrink as seeds halt, span
chunks have ragged tails, Monte Carlo sweeps pick arbitrary seed counts),
while jit compiles per exact shape.  The discipline that keeps the jit
cache small lives here so the two packages cannot drift:

* **pow2 seed bucketing** — the leading seed/lane axis pads to the next
  power of two (`next_pow2`); padded lanes arrive inactive and are
  sliced away.
* **eighth-octave row buckets** (`row_bucket`) for expensively-compiled
  2-D stages: <= 12.5% pad waste, at most 8 jit entries per octave.
* **tick-axis tiling** (`tick_layout`) at ``TILE_T`` with a 64-multiple
  tail, so long spans share a canonical slab width.
* **numpy dispatch floors** — problems smaller than
  ``COMPILED_MIN_ELEMS`` stacked elements (or, for the wavefront, fewer
  than ``WAVEFRONT_MIN_SEEDS`` lanes) are cheaper on the numpy oracle
  than on a device round trip and dispatch back to it.  Bit-exact either
  way; this is pure dispatch, like any size-gated BLAS offload.
"""
from __future__ import annotations

import jax

#: backends the compiled packages accept ("numpy" is always the parity
#: oracle path; "xla" the jitted reference; "pallas" the TPU kernel)
BACKENDS = ("numpy", "xla", "pallas")

# metric-axis chunk budget (elements of one stacked device chunk)
BLOCK_ELEMS = 1 << 26

# spans smaller than this (stacked elements) route back to numpy
COMPILED_MIN_ELEMS = 1 << 21

# seed floor for the compiled wavefront: below this lane count the
# while-loop dispatch overhead dominates and the numpy wavefront wins
WAVEFRONT_MIN_SEEDS = 64

# tick-axis tile: long spans are cut into TILE_T slabs so the jit cache
# sees one canonical width instead of every emitted span length
TILE_T = 256


def validate_backend(backend: str, *, what: str = "backend") -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown {what} {backend!r}; "
                         f"expected one of {BACKENDS}")
    return backend


def next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p


def row_bucket(r: int, *, floor: int = 4096) -> int:
    """Eighth-octave row bucket: <= 12.5% pad waste on the shapes where
    the compiled stage's time matters, a handful of cache entries per
    octave (the floor keeps tiny problems from paying a big-bucket
    stage)."""
    grain = max(floor, next_pow2(r) // 8)
    return -(-r // grain) * grain


def tick_layout(T: int):
    """Tile widths covering T: full TILE_T slabs + a 64-multiple tail."""
    tiles = [TILE_T] * (T // TILE_T)
    tail = T % TILE_T
    if tail:
        tiles.append(-(-tail // 64) * 64)
    return tiles or [64]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"
