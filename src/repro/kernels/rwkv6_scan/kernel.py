"""Chunked WKV6 (RWKV-6 linear attention) Pallas TPU kernel.

Grid = (B, H, n_chunks) with the chunk axis innermost (sequential); the
matrix-valued state S (D, D) is carried in VMEM scratch across chunks.
Within a chunk the recurrence becomes three MXU matmuls (see
``ref.wkv6_chunked`` for the derivation): inflow (r~ @ S), intra-chunk
(masked (r~ @ k~^T) @ v), and the state update (k_tail^T @ v) — this is the
TPU-native re-blocking of the GPU kernel's register-resident recurrence
(DESIGN.md §2: hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
            s_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (L, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)               # (1, D) -> broadcast

    logw = jnp.log(jnp.maximum(w, 1e-30))
    cum = jnp.cumsum(logw, axis=0)                 # (L, D)
    cum_prev = cum - logw
    r_scaled = r * jnp.exp(cum_prev)
    k_scaled = k * jnp.exp(-cum)

    state = s_scr[...]
    y_in = jax.lax.dot_general(r_scaled, state, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    att = jax.lax.dot_general(r_scaled, k_scaled, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (L, L)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(row > col, att, 0.0)           # strictly causal
    y_intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_diag = jnp.sum(r * u * k, axis=1, keepdims=True) * v
    o_ref[0, 0] = (y_in + y_intra + y_diag).astype(o_ref.dtype)

    decay_all = jnp.exp(cum[-1:])                  # (1, D)
    k_tail = k * jnp.exp(cum[-1:] - cum)
    s_scr[...] = decay_all.T * state + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _final():
        sout_ref[0, 0] = s_scr[...]


def wkv6_bhsd(r, k, v, w, u, s0, *, chunk: int = 64,
              interpret: bool = False):
    """r,k,v,w: (B, H, S, D); u: (H, D); s0: (B, H, D, D).

    Returns (y (B,H,S,D) in r.dtype, s_final (B,H,D,D) f32).
    """
    b, h, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    seq_spec = pl.BlockSpec((1, 1, chunk, d),
                            lambda bi, hi, ci: (bi, hi, ci, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
