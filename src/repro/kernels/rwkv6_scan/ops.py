"""jit'd wrapper for the WKV6 kernel: (B, S, H, D) layout adapter."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan.kernel import wkv6_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = None):
    """r,k,v,w: (B, S, H, D); u: (H, D); s0: (B, H, D, D) — model layout."""
    if interpret is None:
        interpret = not _on_tpu()
    args = [jnp.swapaxes(t, 1, 2) for t in (r, k, v, w)]
    y, s_fin = wkv6_bhsd(*args, u, s0, chunk=chunk, interpret=interpret)
    return jnp.swapaxes(y, 1, 2), s_fin
