"""Pure-jnp oracle for the chunked WKV6 recurrence (and the chunked algorithm
itself, shared with the model's "chunked" backend).

Recurrence (per batch b, head h):
    y_t = r_t . (diag(u) k_t v_t^T + S_{t-1})
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t in (0,1), data-dependent

Chunked (block-parallel, matmul) form over chunks of length L:
with cum_t = sum_{s<=t} log w_s (within-chunk cumulative log decay):

  inflow_t  = (r_t * exp(cum_{t-1})) . S_0
  intra[t,s]= (r_t * exp(cum_{t-1} - cum_s)) . k_s        (s < t)
  diag[t]   = (r_t * u) . k_t
  S_L       = exp(cum_L) * S_0 + sum_s exp(cum_L - cum_s) k_s v_s^T

All pairwise terms are two scaled matmuls (MXU-friendly) — this is the block
decomposition the Pallas kernel implements with VMEM tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_sequential(r, k, v, w, u, s0):
    """Reference sequential recurrence. r,k,v,w: (B,S,H,D); u: (H,D); s0: (B,H,D,D)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y
    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))
    s, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), s


def wkv6_chunked(r, k, v, w, u, s0, chunk_size: int = 64):
    """Block-parallel WKV6. Same contract/result as ``wkv6_sequential``."""
    b, s, h, d = r.shape
    l = min(chunk_size, s)
    if s % l:
        # fall back for ragged tails (decode path uses sequential anyway)
        return wkv6_sequential(r, k, v, w, u, s0)
    nc = s // l

    rc = r.reshape(b, nc, l, h, d).swapaxes(0, 1).astype(jnp.float32)
    kc = k.reshape(b, nc, l, h, d).swapaxes(0, 1).astype(jnp.float32)
    vc = v.reshape(b, nc, l, h, d).swapaxes(0, 1).astype(jnp.float32)
    wc = w.reshape(b, nc, l, h, d).swapaxes(0, 1).astype(jnp.float32)

    causal_mask = jnp.tril(jnp.ones((l, l), bool), k=-1)  # strictly lower

    def chunk(s_state, inp):
        rb, kb, vb, wb = inp                       # (B,L,H,D)
        logw = jnp.log(jnp.maximum(wb, 1e-30))
        cum = jnp.cumsum(logw, axis=1)             # (B,L,H,D) = cum_t
        cum_prev = cum - logw                      # cum_{t-1}
        r_scaled = rb * jnp.exp(cum_prev)
        k_scaled = kb * jnp.exp(-cum)
        # inflow from carried state
        y_in = jnp.einsum("blhk,bhkv->blhv", r_scaled, s_state)
        # intra-chunk pairwise (strictly causal)
        att = jnp.einsum("blhk,bmhk->bhlm", r_scaled, k_scaled)
        att = att * causal_mask[None, None]
        y_intra = jnp.einsum("bhlm,bmhv->blhv", att, vb)
        # diagonal bonus
        y_diag = jnp.einsum("blhk,blhk->blh", rb * u[None, None], kb)[..., None] * vb
        y = y_in + y_intra + y_diag
        # state update
        decay_all = jnp.exp(cum[:, -1])            # (B,H,D) total chunk decay
        k_tail = kb * jnp.exp(cum[:, -1][:, None] - cum)   # exp(cum_L - cum_s)
        s_new = decay_all[..., None] * s_state + \
            jnp.einsum("blhk,blhv->bhkv", k_tail, vb)
        return s_new, y

    s_fin, ys = jax.lax.scan(chunk, s0.astype(jnp.float32), (rc, kc, vc, wc))
    return ys.swapaxes(0, 1).reshape(b, s, h, d), s_fin
