"""Pallas kernels for the wavefront's contended inner passes.

Two kernels, both integer/compare-exact so they are drop-in on any
backend (TPU Mosaic, or ``interpret=True`` on CPU for parity tests):

* **gang selection** — the allocation row scan ``free & (rowcumsum(free)
  <= job)`` that picks the first ``job`` free nodes of every lane.  The
  cumsum is computed as a matmul against an upper-triangular ones matrix
  (MXU-friendly; counts are small integers, exact in f32), then compared
  against the per-lane gang size.
* **storage-fabric slot-table query** — the analytic
  ``expected_duration_s`` of the shared-NFS slot-table model evaluated
  over a stacked batch of (op params, fanin, bytes) rows, for dense
  sweep surfaces that probe the fabric at every grid point.  The float
  formula has genuine mul-add chains, so *this* kernel is allclose-level
  (1-ulp class), not bitwise: the numpy ``StorageFabric`` stays the
  resolution oracle wherever parity matters (campaign setup), and the
  compiled paths serve the wide analytic surfaces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["gang_select_pallas", "fabric_query_ref", "fabric_query_pallas",
           "GANG_ROWS", "N_LANES"]

GANG_ROWS = 8        # lanes per gang-select block
N_LANES = 128        # node-axis pad (TPU lane width)


# -- gang selection ----------------------------------------------------------

def _gang_kernel(free_ref, job_ref, out_ref):
    free = free_ref[...]                                   # (R, npad) f32
    npad = free.shape[-1]
    row = lax.broadcasted_iota(jnp.int32, (npad, npad), 0)
    col = lax.broadcasted_iota(jnp.int32, (npad, npad), 1)
    tri = (row <= col).astype(jnp.float32)                 # inclusive scan
    csum = jnp.dot(free, tri, preferred_element_type=jnp.float32)
    sel = (free > 0.5) & (csum <= job_ref[...])
    out_ref[...] = sel.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gang_blocks(free_f32, job_f32, *, interpret):
    L, npad = free_f32.shape
    return pl.pallas_call(
        _gang_kernel,
        grid=(L // GANG_ROWS,),
        in_specs=[pl.BlockSpec((GANG_ROWS, npad), lambda i: (i, 0)),
                  pl.BlockSpec((GANG_ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((GANG_ROWS, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, npad), jnp.float32),
        interpret=interpret,
    )(free_f32, job_f32)


def gang_select_pallas(free, job, *, interpret: bool = False):
    """``free`` (L, n) bool, ``job`` (L,) int -> chosen (L, n) bool.
    Bit-identical to the cumsum reference: the arithmetic is exact
    small-integer work carried in f32."""
    L, n = free.shape
    npad = max(N_LANES, n)
    f = jnp.zeros((L, npad), dtype=jnp.float32)
    f = f.at[:, :n].set(free.astype(jnp.float32))
    j = job.astype(jnp.float32)[:, None]
    out = _gang_blocks(f, j, interpret=interpret)
    return out[:, :n] > 0.5


# -- storage-fabric slot-table query -----------------------------------------

def fabric_query_ref(t_base, size, inflight, server_bw, t_queue, ctx,
                     slots, link_bw, degradation, n_waves, jmean):
    """Vector form of ``StorageFabric.expected_duration_s`` over stacked
    query rows (all args broadcastable arrays; ``n_waves`` is the
    pre-divided ``max(n_rpcs / slots, 1)`` and ``jmean`` the lognormal
    mean factor, both host-computed)."""
    t = t_base + size * inflight / server_bw \
        + t_queue * jnp.maximum(inflight - ctx, 0.0) / ctx
    t_svc = jnp.maximum(t * degradation, slots * size / link_bw)
    return n_waves * t_svc * jmean


_fabric_ref_jit = jax.jit(fabric_query_ref)


def _fabric_kernel(tb, size, infl, sbw, tq, ctx, slots, lbw, deg, nw,
                   jm, out_ref):
    t = tb[...] + size[...] * infl[...] / sbw[...] \
        + tq[...] * jnp.maximum(infl[...] - ctx[...], 0.0) / ctx[...]
    t_svc = jnp.maximum(t * deg[...], slots[...] * size[...] / lbw[...])
    out_ref[...] = nw[...] * t_svc * jm[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fabric_blocks(args2d, *, interpret):
    R, C = args2d[0].shape
    spec = pl.BlockSpec((GANG_ROWS, C), lambda i: (i, 0))
    return pl.pallas_call(
        _fabric_kernel,
        grid=(R // GANG_ROWS,),
        in_specs=[spec] * len(args2d),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((R, C), args2d[0].dtype),
        interpret=interpret,
    )(*args2d)


def fabric_query_pallas(*args, interpret: bool = False):
    """Pallas evaluation of :func:`fabric_query_ref` over (Q,) rows."""
    q = args[0].shape[0]
    rows = -(-q // N_LANES)
    rpad = -(-rows // GANG_ROWS) * GANG_ROWS
    total = rpad * N_LANES
    padded = []
    for a in args:
        f = jnp.zeros(total, dtype=jnp.float32)
        f = f.at[:q].set(a.astype(jnp.float32))
        padded.append(f.reshape(rpad, N_LANES))
    out = _fabric_blocks(tuple(padded), interpret=interpret)
    return out.reshape(-1)[:q]
