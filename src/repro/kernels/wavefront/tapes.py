"""Host-side draw tapes and event tables for the compiled wavefront.

The compiled core cannot call ``default_rng`` mid-loop, so every sampled
decision a campaign can take is materialized up front, extending
``sample_batch``'s draw-order discipline to the remaining streams:

* the **main uniform tape** ``u`` — ``default_rng(seed).random(U)`` is
  positionally identical to U sequential ``rng.random()`` calls, and
  after the rng stream refactor (``RNG_STREAM_MANUAL`` /
  ``RNG_STREAM_STRUCT`` in ``repro.core.cluster``) the main stream
  consumes *only* ``random()`` uniforms, so one pointer walks it;
* the **manual-delay tapes** — one ``standard_exponential`` sequence on
  the dedicated ``[seed, RNG_STREAM_MANUAL]`` stream, pre-scaled by both
  the day and the night response means (the consumer picks one, the
  pointer advances once — exactly the scalar call pattern);
* the **structural-fix tapes** — the ``[seed, RNG_STREAM_STRUCT]``
  sequence pre-scaled by ``mean/2`` (manual-misfix horizon) and ``mean``
  (software follow-on), one pointer, scaling chosen per consumption site.

Why the tapes carry *transformed* values rather than raw draws: XLA CPU
contracts ``a + b*c`` into an FMA inside a jitted computation, which
breaks bitwise parity with the numpy engines by 1 ulp on ~12% of
elements (and ``lax.optimization_barrier`` does not prevent it).  Every
multiply-add that feeds a parity-critical float therefore happens here,
in numpy elementwise ufuncs (separate C loops, never fused): the device
only gathers, compares, and performs lone adds.  The same reasoning
produces the **retry delay tables** (``dna`` per attempt count, per-event
``fdelay`` for the XID branch, both pre-divided by 60) so the device
computes ``pend = t + delay`` as a single fadd.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import (RNG_STREAM_MANUAL, RNG_STREAM_STRUCT,
                                CampaignConfig)
from repro.core.failures import (FailureBatch, degradation_windows,
                                 escalation_events)
from repro.core.retry import RetryEngine, RetryPolicy

__all__ = ["WavefrontCaps", "LaneTables", "build_lane_tables",
           "concat_lane_tables", "pad_lanes_pow2"]

# load-duration uniform widths (bit-exact fast forms of the scalar
# draws, shared with the numpy engines: uniform(a, b) == a + (b-a)*u)
_W_LOAD = 0.3 - (-0.08)
_W_FAIL = 0.15 - 0.05


@dataclass(frozen=True)
class WavefrontCaps:
    """Static device-array capacities (all jit-cache keys).

    Each cap carries slack beyond the expected consumption; the device
    flags any lane that comes within one iteration's worth of a cap and
    the driver re-runs with that cap doubled (see ``ops.py``).
    """
    n_uniform: int = 2048        # main-stream uniforms per lane
    n_manual: int = 512          # manual-delay draws per lane
    n_struct: int = 512          # structural-fix draws per lane
    n_sessions: int = 512        # session records per lane
    n_iters: int = 4096          # wavefront iterations

    def doubled(self, which: Sequence[str]) -> "WavefrontCaps":
        return replace(self, **{k: 2 * getattr(self, k) for k in which})


@dataclass
class LaneTables:
    """Device inputs + host-side replay context for a block of lanes.

    ``device`` maps names to stacked ``(L, ...)`` numpy arrays (tapes,
    event tables, per-lane parameters); everything else is host-only
    context the replay/findings pass needs (degradation windows, the
    original per-lane failure slices, checkpoint constants).
    """
    device: Dict[str, np.ndarray]
    n_nodes: int
    caps: WavefrontCaps
    # host-side per-lane context
    seeds: List[int]
    interval: np.ndarray         # (L,) checkpoint_interval_h
    duration: np.ndarray         # (L,) duration_h
    save_s: np.ndarray           # (L,) checkpoint_save_s
    job_gt1: np.ndarray          # (L,) bool: job_nodes > 1 (occupancy gate)
    deg_windows: List[list]      # per-lane degradation windows
    n_failures: np.ndarray       # (L,) failure-event counts
    infra_n: np.ndarray          # (L,) infra-band event counts

    @property
    def n_lanes(self) -> int:
        return len(self.seeds)


def _delay_table(cfg: CampaignConfig, engine: RetryEngine,
                 n_rows: int) -> np.ndarray:
    """``dna[k]`` = automatic-retry delay (hours) after attempt count
    ``k`` with no XID resolution, NaN where the scalar path yields None.
    Mirrors ``BatchedCampaignEngine._schedule_next``'s FIXED shortcut and
    ``RetryEngine.next_delay_min`` for the other policies."""
    r = cfg.retry
    fixed = r.delay_min + r.teardown_min \
        if r.policy is RetryPolicy.FIXED else None
    out = np.full(n_rows, np.nan)
    for k in range(n_rows):
        if fixed is not None:
            d = fixed if r.enabled and k <= r.max_retries else None
        else:
            d = engine.next_delay_min(k, xid=None)
        if d is not None:
            out[k] = d / 60.0
    return out


def build_lane_tables(cfg: CampaignConfig, fails: FailureBatch,
                      seeds: Sequence[int],
                      caps: Optional[WavefrontCaps] = None) -> LaneTables:
    """Materialize one config's S seed lanes (config must be resolved —
    i.e. ``ClusterSim(cfg).cfg`` — so storage-derived checkpoint params
    are final)."""
    caps = caps if caps is not None else WavefrontCaps()
    S, n = len(seeds), cfg.n_nodes
    U, M, X = caps.n_uniform, caps.n_manual, caps.n_struct
    engine = RetryEngine(cfg.retry)

    u = np.empty((S, U))
    man_day = np.empty((S, M))
    man_night = np.empty((S, M))
    x_half = np.empty((S, X))
    x_full = np.empty((S, X))
    half_mean = cfg.structural_fix_mean_h / 2
    for i, seed in enumerate(seeds):
        u[i] = np.random.default_rng(seed).random(U)
        std_m = np.random.default_rng(
            [seed, RNG_STREAM_MANUAL]).standard_exponential(M)
        man_day[i] = cfg.manual_response_h_day * std_m
        man_night[i] = cfg.manual_response_h_night * std_m
        std_x = np.random.default_rng(
            [seed, RNG_STREAM_STRUCT]).standard_exponential(X)
        x_half[i] = half_mean * std_x
        x_full[i] = cfg.structural_fix_mean_h * std_x
    # pre-transformed load durations (numpy ufuncs are separate C loops —
    # bitwise equal to the scalar chain, and no fmul feeds an fadd on
    # device).  The inner term is shared exactly like the scalar form.
    inner = -0.08 + _W_LOAD * u
    dur_fail = 0.05 + _W_FAIL * u
    dur_warm = cfg.loading_time_h + inner
    dur_cold = cfg.loading_cold_h + inner

    # failure tables, padded (S, F); +inf times never come due.  The +1
    # guarantees a trailing +inf sentinel on EVERY lane: the device gather
    # clips the pointer, so without it the widest lane would re-read its
    # last real event after draining the queue and never leave "pending"
    offs = fails.offsets
    F = max(int((offs[1:] - offs[:-1]).max()), 0) + 1
    ft = np.full((S, F), np.inf)
    fnode = np.zeros((S, F), dtype=np.int32)
    fkcode = np.full((S, F), 3, dtype=np.int32)   # pad rows are inert
    fhw = np.zeros((S, F), dtype=bool)
    fdelay = np.full((S, F), np.nan)
    fhas_xid = np.zeros((S, F), dtype=bool)
    is_xid_policy = cfg.retry.policy is RetryPolicy.XID_BRANCH
    E = 1
    esc_rows: List[list] = []
    deg_windows: List[list] = []
    for i in range(S):
        o0, o1 = int(offs[i]), int(offs[i + 1])
        k = o1 - o0
        ft[i, :k] = fails.times[o0:o1]
        fnode[i, :k] = fails.nodes[o0:o1]
        fkcode[i, :k] = fails.kind[o0:o1]
        fhw[i, :k] = fails.hardware[o0:o1]
        if is_xid_policy:
            for j in range(k):
                xid = int(fails.xid[o0 + j])
                if fails.kind[o0 + j] <= 1 and xid >= 0:
                    fhas_xid[i, j] = True
                    # the attempt-count guard lives on device (n < max_r
                    # subsumes it), so the table only resolves the action
                    d = engine.next_delay_min(1, xid=xid)
                    if d is not None:
                        fdelay[i, j] = d / 60.0
        evs = fails.events(i)
        deg_windows.append(degradation_windows(evs))
        es = escalation_events(evs)
        esc_rows.append(es)
        E = max(E, len(es))
    et = np.full((S, E + 1), np.inf)      # same +inf sentinel discipline
    enode = np.zeros((S, E + 1), dtype=np.int32)
    for i, es in enumerate(esc_rows):
        for j, (t_crash, node) in enumerate(es):
            et[i, j] = t_crash
            enode[i, j] = node

    dna = np.tile(_delay_table(cfg, engine, cfg.retry.max_retries + 2),
                  (S, 1))
    notice_p = (cfg.retry.delay_min / 60.0) \
        / max(cfg.operator_notice_mean_h, 1e-6) * 0.5

    def const(v, dtype=np.float64):
        return np.full(S, v, dtype=dtype)

    device = {
        "u": u, "dur_fail": dur_fail, "dur_warm": dur_warm,
        "dur_cold": dur_cold, "man_day": man_day, "man_night": man_night,
        "x_half": x_half, "x_full": x_full,
        "ft": ft, "fnode": fnode, "fkcode": fkcode, "fhw": fhw,
        "fdelay": fdelay, "fhas_xid": fhas_xid, "et": et, "enode": enode,
        "dna": dna,
        "duration": const(cfg.duration_h),
        "job": const(cfg.job_nodes, np.int32),
        "p_readmit": const(cfg.p_pressure_readmit),
        "p_transient": const(cfg.p_transient_retry_fail),
        "p_soft": const(cfg.p_software_failure),
        "p_misfix": const(cfg.p_manual_misfix),
        "notice_p": const(notice_p),
        "repair_h": const(cfg.repair_time_h),
        "slow_iso_h": const(cfg.slow_isolation_h),
        "retry_on": const(cfg.retry.enabled, bool),
        "max_r": const(cfg.retry.max_retries, np.int32),
        "policy_xid": const(is_xid_policy, bool),
        "struct_stop": const(cfg.retry.structural_stop, bool),
        "lane_on": np.ones(S, dtype=bool),
    }
    kinds = fails.kind
    infra_n = np.array([int((kinds[int(offs[i]):int(offs[i + 1])] >= 3)
                            .sum()) for i in range(S)])
    return LaneTables(
        device=device, n_nodes=n, caps=caps, seeds=list(seeds),
        interval=const(cfg.checkpoint_interval_h),
        duration=const(cfg.duration_h),
        save_s=const(cfg.checkpoint_save_s),
        job_gt1=const(cfg.job_nodes > 1, bool),
        deg_windows=deg_windows,
        n_failures=(offs[1:] - offs[:-1]).astype(np.int64),
        infra_n=infra_n)


def _pad_cols(a: np.ndarray, width: int, fill) -> np.ndarray:
    if a.shape[1] == width:
        return a
    out = np.full((a.shape[0], width), fill, dtype=a.dtype)
    out[:, :a.shape[1]] = a
    return out


def concat_lane_tables(blocks: Sequence[LaneTables]) -> LaneTables:
    """Stack per-config lane blocks into one dense grid batch.  Ragged
    event-table widths (failure count, escalations, retry-table rows)
    pad to the grid maximum with inert rows; every other array simply
    concatenates along the lane axis."""
    if len(blocks) == 1:
        return blocks[0]
    n = blocks[0].n_nodes
    caps = blocks[0].caps
    for b in blocks[1:]:
        if b.n_nodes != n:
            raise ValueError("dense grid requires a uniform n_nodes; got "
                             f"{b.n_nodes} vs {n}")
        if b.caps != caps:
            raise ValueError("lane blocks built with different caps")
    pad_fill = {"ft": np.inf, "fkcode": 3, "fdelay": np.nan,
                "et": np.inf, "dna": np.nan}
    ragged = ("ft", "fnode", "fkcode", "fhw", "fdelay", "fhas_xid",
              "et", "enode", "dna")
    device: Dict[str, np.ndarray] = {}
    for key in blocks[0].device:
        parts = [b.device[key] for b in blocks]
        if key in ragged:
            width = max(p.shape[1] for p in parts)
            parts = [_pad_cols(p, width, pad_fill.get(key, 0))
                     for p in parts]
        device[key] = np.concatenate(parts, axis=0)
    return LaneTables(
        device=device, n_nodes=n, caps=caps,
        seeds=sum((b.seeds for b in blocks), []),
        interval=np.concatenate([b.interval for b in blocks]),
        duration=np.concatenate([b.duration for b in blocks]),
        save_s=np.concatenate([b.save_s for b in blocks]),
        job_gt1=np.concatenate([b.job_gt1 for b in blocks]),
        deg_windows=sum((b.deg_windows for b in blocks), []),
        n_failures=np.concatenate([b.n_failures for b in blocks]),
        infra_n=np.concatenate([b.infra_n for b in blocks]))


def pad_lanes_pow2(tables: LaneTables, min_lanes: int = 64) -> LaneTables:
    """Pad the lane axis to a power of two (the shared seed-bucketing
    discipline, ``kernels.common.next_pow2``).  Padded lanes arrive with
    ``lane_on=False`` — the device loop never wakes them and the findings
    pass slices them away."""
    from repro.kernels.common import next_pow2
    L = tables.n_lanes
    Lp = max(next_pow2(L), min_lanes)
    if Lp == L:
        return tables
    pad = Lp - L
    fill = {"ft": np.inf, "et": np.inf, "fdelay": np.nan, "dna": np.nan,
            "fkcode": 3, "duration": 1.0, "job": 1, "max_r": 0}
    device = {}
    for key, a in tables.device.items():
        out = np.full((Lp,) + a.shape[1:], fill.get(key, 0),
                      dtype=a.dtype)
        out[:L] = a
        device[key] = out
    device["lane_on"][L:] = False
    ones = np.ones(pad)
    return LaneTables(
        device=device, n_nodes=tables.n_nodes, caps=tables.caps,
        seeds=tables.seeds + [-1] * pad,
        interval=np.concatenate([tables.interval, ones]),
        duration=np.concatenate([tables.duration, ones]),
        save_s=np.concatenate([tables.save_s, ones]),
        job_gt1=np.concatenate(
            [tables.job_gt1, np.zeros(pad, dtype=bool)]),
        deg_windows=tables.deg_windows + [[] for _ in range(pad)],
        n_failures=np.concatenate(
            [tables.n_failures, np.zeros(pad, dtype=np.int64)]),
        infra_n=np.concatenate(
            [tables.infra_n, np.zeros(pad, dtype=np.int64)]))
