"""Dispatch + host glue for the compiled whole-campaign wavefront.

``run_findings_compiled(cfg, seeds)`` (and the dense-grid form
``run_findings_grid``) produce per-seed findings dicts **bitwise
identical** to ``BatchedCampaignEngine.run_findings`` / the scalar
``ClusterSim``, in three phases:

1. **materialize** (``tapes.py``) — every rng draw a campaign can
   consume becomes a pre-transformed tape; failure/escalation schedules
   and retry-delay tables become padded per-lane arrays;
2. **device pass** (``ref.py``) — one jitted ``lax.while_loop`` advances
   all lanes event by event, emitting a per-iteration record stream,
   integer accumulators and per-session gang bitmasks;
3. **host replay** (here) — the float accounting folds (checkpoint
   catch-up, lost work, run-hours, downtime windows, retry-gap lists,
   degradation overlaps) rerun in numpy along the iteration axis, where
   C-double arithmetic matches the scalar engine bit for bit; findings
   assemble with the exact ``_findings`` formulas.

Dispatch rules: the compiled core covers the control-free scope —
``cfg.telemetry`` off and ``cfg.control is None`` (reactive presets, all
retry policies, and the full infra fault band without a control plane).
Telemetry/control campaigns route to the numpy wavefront: the detector
feedback loop is already compiled elsewhere (``kernels/robust_stats``)
and the drain path is control-plane-coupled, so an honest backend split
beats a speculative one (same precedent as the detector's numpy floor).
``backend="auto"`` also floors at ``WAVEFRONT_MIN_SEEDS`` lanes, below
which the device round trip costs more than the numpy pass.

Cap discipline: device arrays are fixed-size (tape lengths, session
slots, iteration budget).  The core flags any lane that approaches a
cap; the driver doubles the flagged capacities and reruns — results are
only ever read from a clean pass.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import CampaignConfig, ClusterSim
from repro.core.failures import (FailureInjector, degraded_overlap_h,
                                 has_correlated_band)
from repro.kernels.common import (WAVEFRONT_MIN_SEEDS, next_pow2, on_tpu,
                                  validate_backend)
from repro.kernels.wavefront.ref import (F_ADVANCE, F_ALLOCFAIL,
                                         F_CHAIN_CLOSE, F_FINALIZE,
                                         F_LOST, F_PREP_OK, F_RUNNING,
                                         F_SESS_FAIL, F_START, F_VALID,
                                         wavefront_core)
from repro.kernels.wavefront.tapes import (LaneTables, WavefrontCaps,
                                           build_lane_tables,
                                           concat_lane_tables,
                                           pad_lanes_pow2)

__all__ = ["compiled_eligible", "resolve_wavefront_backend",
           "run_findings_compiled", "run_findings_grid",
           "fabric_query_batch"]

_MAX_CAP_RETRIES = 6


def compiled_eligible(cfg: CampaignConfig) -> bool:
    """True when the campaign is in the compiled wavefront's scope.

    The correlated fault band (switch_degrade / dns_flap) is host-only:
    its variable-size blast-radius sets don't fit the fixed-lane tape
    layout, so configs carrying those kinds route to the numpy engines."""
    return (cfg.engine == "event" and not cfg.telemetry
            and cfg.control is None
            and not has_correlated_band(cfg.kind_weights))


def resolve_wavefront_backend(backend: str, cfg: CampaignConfig,
                              n_seeds: int) -> str:
    """Map a requested wavefront backend to the one that will run.

    ``auto`` picks the compiled path only when the config is eligible
    AND the batch clears the ``WAVEFRONT_MIN_SEEDS`` floor; explicit
    ``xla``/``pallas`` on an ineligible config is an error (silent
    fallback would misreport what ran)."""
    if backend == "auto":
        if compiled_eligible(cfg) and n_seeds >= WAVEFRONT_MIN_SEEDS:
            return "xla"
        return "numpy"
    validate_backend(backend, what="wavefront backend")
    if backend != "numpy" and not compiled_eligible(cfg):
        raise ValueError(
            f"wavefront backend {backend!r} requires a control-free "
            "campaign (telemetry off, control None, no correlated fault "
            "band); use backend='auto' or 'numpy' for telemetry/control/"
            "correlated configs")
    return backend


# -- device pass + cap-doubling driver ---------------------------------------

def _run_core(tables: LaneTables, backend: str, interpret: bool):
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    with enable_x64():
        P = {k: jnp.asarray(v) for k, v in tables.device.items()}
        out = wavefront_core(
            P, n_nodes=tables.n_nodes,
            n_sessions=tables.caps.n_sessions,
            n_iters=tables.caps.n_iters,
            backend=backend, interpret=interpret)
        return {k: np.asarray(v) for k, v in out.items()}


def _run_with_caps(build, backend: str, interpret: bool):
    """build(caps) -> LaneTables; rerun with doubled caps until no lane
    overflows (results are never read from an overflowed pass)."""
    caps = None
    for _ in range(_MAX_CAP_RETRIES):
        tables = build(caps)
        caps = tables.caps
        host = _run_core(tables, backend, interpret)
        if not host["overflow"][tables.device["lane_on"]].any():
            return tables, host
        caps = caps.doubled(("n_uniform", "n_manual", "n_struct",
                             "n_sessions", "n_iters"))
    raise RuntimeError(
        f"wavefront caps still overflow after {_MAX_CAP_RETRIES} "
        f"doublings (last: {caps})")


# -- host replay of the float accounting folds -------------------------------

class _Replay:
    """Per-lane accounting state driven by the device record stream."""

    def __init__(self, L: int):
        self.cur_t = np.zeros(L)
        self.last_ckpt = np.zeros(L)
        self.last_save = np.zeros(L)
        self.ckpt_events = np.zeros(L, dtype=np.int64)
        self.started = np.full(L, np.nan)
        self.open_sess = np.zeros(L, dtype=bool)
        self.prev_end = np.full(L, np.nan)
        self.down_since = np.full(L, np.nan)
        self.down_auto = np.ones(L, dtype=bool)
        self.n_att = np.zeros(L, dtype=np.int64)
        self.retry_reached = np.zeros(L, dtype=bool)
        self.run_sum = np.zeros(L)
        self.f4 = np.zeros((L, 3), dtype=np.int64)
        self.gaps: List[List[float]] = [[] for _ in range(L)]
        self.lost: List[List[float]] = [[] for _ in range(L)]
        self.downtimes: List[List[tuple]] = [[] for _ in range(L)]
        self.sess: List[List[tuple]] = [[] for _ in range(L)]


def _replay(tables: LaneTables, host: Dict[str, np.ndarray]) -> _Replay:
    """Rerun the float folds along the iteration axis.  Application
    order within an iteration mirrors the numpy wavefront's step order
    (starts -> prep-done -> session fail/lost -> chain close -> finalize
    -> checkpoint catch-up), so every sequential float accumulation sees
    the same operand sequence as the scalar engine."""
    L = host["rec_t"].shape[1]
    R = _Replay(L)
    interval = tables.interval
    duration = tables.duration
    it_count = int(host["it"])
    rec_t, rec_fl = host["rec_t"], host["rec_flags"]
    isnan = np.isnan
    for it in range(it_count):
        fl = rec_fl[it]
        if not fl.any():
            continue
        tn = rec_t[it]
        t = R.cur_t

        m_start = (fl & F_START) != 0
        m_af = (fl & F_ALLOCFAIL) != 0
        m_att = m_start | m_af
        if m_att.any():
            gm = m_att & ~isnan(R.prev_end)
            if gm.any():
                gv = (t - R.prev_end) * 60.0
                for s in np.nonzero(gm)[0]:
                    R.gaps[s].append(float(gv[s]))
            R.n_att[m_att] += 1
            R.prev_end[m_af] = t[m_af]
            R.prev_end[m_start] = np.nan
            R.started[m_start] = np.nan
            R.open_sess[m_start] = True

        m_pok = (fl & F_PREP_OK) != 0
        if m_pok.any():
            R.started[m_pok] = t[m_pok]
            R.retry_reached[m_pok & (R.n_att != 1)] = True
            R.last_ckpt[m_pok] = t[m_pok]
            R.last_save[m_pok] = t[m_pok]
            dc = m_pok & ~isnan(R.down_since)
            for s in np.nonzero(dc)[0]:
                R.downtimes[s].append(
                    (float(t[s] - R.down_since[s]), bool(R.down_auto[s])))
            R.down_since[dc] = np.nan
            R.down_auto[dc] = True

        m_fail = (fl & F_SESS_FAIL) != 0
        m_lost = (fl & F_LOST) != 0
        if m_fail.any():
            if m_lost.any():            # lost precedes the teardown fold
                lv = np.minimum(t - R.last_save, interval)
                for s in np.nonzero(m_lost)[0]:
                    R.lost[s].append(float(lv[s]))
            rs = m_fail & ~isnan(R.started)
            R.run_sum[rs] += np.maximum(0.0, t[rs] - R.started[rs])
            for s in np.nonzero(m_fail)[0]:
                R.sess[s].append((float(R.started[s]), float(t[s])))
            R.started[m_fail] = np.nan
            R.open_sess[m_fail] = False
            R.prev_end[m_fail] = t[m_fail]
            dn = m_fail & isnan(R.down_since)
            R.down_since[dn] = t[dn]

        m_cc = (fl & F_CHAIN_CLOSE) != 0
        if m_cc.any():
            g = m_cc & (R.n_att > 1)
            R.f4[g, 0] += 1
            R.f4[g, 1] += R.n_att[g]
            R.f4[g & R.retry_reached, 2] += 1
            R.n_att[m_cc] = 0
            R.retry_reached[m_cc] = False
            R.prev_end[m_cc] = np.nan
            R.down_auto[m_cc] = False

        m_fin = (fl & F_FINALIZE) != 0
        if m_fin.any():
            fo = m_fin & R.open_sess
            rs = fo & ~isnan(R.started)
            R.run_sum[rs] += np.maximum(0.0, duration[rs] - R.started[rs])
            for s in np.nonzero(fo)[0]:
                R.sess[s].append((float(R.started[s]), float(duration[s])))
            R.open_sess[fo] = False
            R.started[fo] = np.nan
            g = m_fin & (R.n_att > 1)
            R.f4[g, 0] += 1
            R.f4[g, 1] += R.n_att[g]
            R.f4[g & R.retry_reached, 2] += 1
            R.n_att[m_fin] = 0
            R.retry_reached[m_fin] = False

        m_run = ((fl & F_ADVANCE) != 0) & ((fl & F_RUNNING) != 0)
        if m_run.any():
            k = np.floor((tn - R.last_ckpt + 1e-12)
                         / interval).astype(np.int64)
            k = np.where(m_run, np.maximum(k, 0), 0)
            R.ckpt_events += k
            R.last_ckpt += k * interval
            np.maximum(R.last_save, R.last_ckpt, out=R.last_save)

        m_adv = (fl & F_ADVANCE) != 0
        R.cur_t = np.where(m_adv, tn, R.cur_t)
    return R


def _degraded(tables: LaneTables, host, R: _Replay,
              lane: int) -> List[float]:
    windows = tables.deg_windows[lane]
    if not windows:
        return []
    gang = host["se_gang"][lane]
    out: List[float] = []
    for k, (t0, t1) in enumerate(R.sess[lane]):
        if t0 != t0:                    # never reached RUNNING
            continue
        nodes = np.nonzero(gang[k])[0].tolist()
        d = degraded_overlap_h(windows, t0, t1, nodes)
        if d:
            out.append(d)
    return out


def _lane_findings(tables: LaneTables, host, R: _Replay,
                   lane: int) -> dict:
    duration = float(tables.duration[lane])
    n_chains, n_attempts, succ = (int(v) for v in R.f4[lane])
    gaps = R.gaps[lane]
    counts = host["npart_counts"][lane].astype(float)
    total = counts.sum()
    top3 = float(np.sort(counts)[::-1][:3].sum() / total) \
        if total else 0.0
    delib_frac = float(int(host["n_delib"][lane])
                       / max(int(host["n_intervals"][lane]), 1))
    autos = [h for h, auto in R.downtimes[lane] if auto]
    mans = [h for h, auto in R.downtimes[lane] if not auto]
    run = float(R.run_sum[lane]) if tables.job_gt1[lane] else 0.0
    lost = R.lost[lane]
    ckpt_h = int(R.ckpt_events[lane]) \
        * float(tables.save_s[lane]) / 3600.0
    degraded = _degraded(tables, host, R, lane)
    deg_h = float(np.sum(degraded))
    goodput_h = run - float(np.sum(lost)) - ckpt_h - 0.0 - deg_h
    return {
        "occupancy": min(run / duration, 1.0),
        "goodput": max(goodput_h, 0.0) / duration,
        "n_failures": float(tables.n_failures[lane]),
        "n_sessions": float(host["n_sessions"][lane]),
        "ckpt_events": float(R.ckpt_events[lane]),
        "mean_lost_h": float(np.mean(lost)) if lost else 0.0,
        "f3_top3_share": top3,
        "f3_deliberate_fraction": delib_frac,
        "f4_n_chains": float(n_chains),
        "f4_n_attempts": float(n_attempts),
        "f4_success_rate": succ / n_chains if n_chains else 0.0,
        "f4_gap_median_min": float(np.median(gaps)) if gaps else None,
        "f4_auto_downtime_h": float(np.median(autos)) if autos else None,
        "f4_manual_downtime_h": float(np.median(mans)) if mans else None,
        "infra_n_events": float(tables.infra_n[lane]),
        "infra_degraded_h": deg_h,
        # eligibility excludes the correlated band, so these lanes carry
        # no switch_degrade / dns_flap events by construction
        "corr_n_events": 0.0,
        "corr_top_switch_share": 0.0,
    }


# -- public entry points -----------------------------------------------------

def run_findings_grid(configs: Sequence[CampaignConfig],
                      seeds: Sequence[int], *, backend: str = "xla",
                      interpret: Optional[bool] = None,
                      caps: Optional[WavefrontCaps] = None
                      ) -> List[List[dict]]:
    """Findings for every (config, seed) lane of a dense scenario grid
    in ONE stacked device pass.  Returns ``out[g][s]`` aligned with the
    inputs; every dict is bitwise identical to the numpy engines'."""
    if not configs:
        return []
    if interpret is None:
        interpret = not on_tpu()
    resolved = []
    for cfg in configs:
        base = ClusterSim(cfg)
        rcfg = base.cfg
        if not compiled_eligible(rcfg):
            raise ValueError(
                "run_findings_grid covers control-free campaigns only "
                "(telemetry off, control None, no correlated fault band)")
        injector = FailureInjector(
            n_nodes=rcfg.n_nodes, mtbf_h=rcfg.mtbf_h,
            hot_fraction=rcfg.hot_fraction, hot_weight=rcfg.hot_weight,
            kind_weights=rcfg.kind_weights,
            topology_fanout=rcfg.topology_fanout, seed=rcfg.seed)
        fails = injector.sample_batch(rcfg.duration_h, seeds)
        resolved.append((rcfg, fails))

    def build(caps_in):
        blocks = [build_lane_tables(rcfg, fails, seeds, caps=caps_in)
                  for rcfg, fails in resolved]
        return pad_lanes_pow2(concat_lane_tables(blocks))

    first = build(caps)
    tables, host = _run_with_caps(
        lambda c: first if c is None else build(c), backend, interpret)
    R = _replay(tables, host)
    S = len(seeds)
    out: List[List[dict]] = []
    for g in range(len(configs)):
        out.append([_lane_findings(tables, host, R, g * S + s)
                    for s in range(S)])
    return out


def run_findings_compiled(config: CampaignConfig, seeds: Sequence[int],
                          *, backend: str = "xla",
                          interpret: Optional[bool] = None,
                          caps: Optional[WavefrontCaps] = None
                          ) -> List[dict]:
    """Single-config form of :func:`run_findings_grid`."""
    return run_findings_grid([config], seeds, backend=backend,
                             interpret=interpret, caps=caps)[0]


def fabric_query_batch(fabric, op, fanins, bytes_per_client, *,
                       slots_per_client=None, rpc_bytes=None,
                       backend: str = "numpy",
                       interpret: Optional[bool] = None) -> np.ndarray:
    """Batched ``StorageFabric.expected_duration_s`` over stacked query
    rows (``fanins``/``bytes_per_client`` broadcast together).

    ``backend='numpy'`` evaluates through the fabric itself (the bitwise
    resolution oracle); ``'xla'`` evaluates the same analytic formula on
    device in f64 (1-ulp class; the mul-add chains may contract to FMA)
    and ``'pallas'`` in f32 lane tiles (~1e-7 relative) — both for wide
    sweep surfaces, never for campaign setup."""
    from repro.storage.fabric import _std_rpc_bytes, _std_slots
    validate_backend(backend, what="fabric query backend")
    fanins = np.atleast_1d(np.asarray(fanins))
    byts = np.broadcast_to(np.atleast_1d(np.asarray(bytes_per_client)),
                           fanins.shape)
    slots = _std_slots(op) if slots_per_client is None else slots_per_client
    size = _std_rpc_bytes(op) if rpc_bytes is None else rpc_bytes
    if backend == "numpy":
        return np.array([fabric.expected_duration_s(
            op, int(f), int(b), slots_per_client=slots, rpc_bytes=size)
            for f, b in zip(fanins, byts)])
    cfg = fabric.config
    server_bw, ctx, t_base, t_queue = cfg.op_params(op)
    inflight = np.maximum(fanins.astype(np.int64), 1) * slots
    n_rpcs = np.maximum(np.ceil(byts / size), 1.0)
    n_waves = np.maximum(n_rpcs / slots, 1.0)
    jmean = float(np.exp(cfg.service_jitter ** 2 / 2.0))
    args = (np.full_like(n_waves, t_base), np.full_like(n_waves, size),
            inflight.astype(float), np.full_like(n_waves, server_bw),
            np.full_like(n_waves, t_queue), np.full_like(n_waves, ctx),
            np.full_like(n_waves, slots),
            np.full_like(n_waves, cfg.client_link_bw),
            np.full_like(n_waves, cfg.degradation), n_waves,
            np.full_like(n_waves, jmean))
    import jax.numpy as jnp
    if backend == "pallas":
        from repro.kernels.wavefront.kernel import fabric_query_pallas
        if interpret is None:
            interpret = not on_tpu()
        out = fabric_query_pallas(*(jnp.asarray(a) for a in args),
                                  interpret=interpret)
        return np.asarray(out, dtype=float)
    from jax.experimental import enable_x64

    from repro.kernels.wavefront.kernel import _fabric_ref_jit
    with enable_x64():
        out = _fabric_ref_jit(*(jnp.asarray(a) for a in args))
        return np.asarray(out, dtype=float)
