"""Jitted XLA core of the whole-campaign wavefront.

One ``lax.while_loop`` advances every lane (seed x scenario config) of a
campaign batch to its own next event per iteration, over fixed-size
struct-of-arrays state: per-lane clocks, pool/repair masks, gang
assignments, and the tape pointers into the pre-materialized draw tapes
(``tapes.py``).  The loop mirrors the numpy wavefront's step order
(repairs, attempt starts, PREPARING completions, failures, escalation
crashes, horizon) with one deliberate difference: the numpy engine
drains *all* same-time failures per seed in an inner python loop, the
device processes **at most one kill event per lane per iteration** and
holds the lane's clock (a "pending" iteration) until the queue at that
instant drains — same event order, one extra iteration per queued event.

Bitwise discipline (the parity contract with ``ClusterSim``): the loop
body contains *no* fmul-feeding-fadd chain on parity-critical floats —
XLA CPU would contract it into an FMA and drift 1 ulp from numpy.  All
multiply-adds live in the host tapes/tables; the device only gathers,
compares, selects, and performs lone adds (``pend = t + delay``).  Float
accounting folds (checkpoint catch-up, lost work, run-hours, downtime)
do not happen here at all: the device emits a per-iteration record
stream — ``(rec_t, rec_flags)`` with the event bits below — plus integer
accumulators and per-session gang bitmasks, and the host *replay*
(``ops.py``) reruns the folds in numpy, where double arithmetic matches
the scalar engine exactly.

The checkpoint catch-up in particular cannot be split across device
iterations (``c + k1*i`` then ``+ k2*i`` differs bitwise from
``c + (k1+k2)*i``), which is why pending iterations clear ``F_ADVANCE``:
the replay folds once per *visited* time, exactly like the numpy pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["wavefront_core", "F_VALID", "F_ADVANCE", "F_RUNNING",
           "F_START", "F_ALLOCFAIL", "F_PREP_OK", "F_SESS_FAIL",
           "F_LOST", "F_CHAIN_CLOSE", "F_FINALIZE"]

# rec_flags bits (replayed host-side in this order within an iteration)
F_VALID = 1          # lane alive this iteration
F_ADVANCE = 2        # clock advanced to rec_t (catch-up folds once)
F_RUNNING = 4        # session RUNNING at span end (catch-up applies)
F_START = 8          # attempt started (session opened)
F_ALLOCFAIL = 16     # attempt could not allocate a gang
F_PREP_OK = 32       # PREPARING completed -> RUNNING
F_SESS_FAIL = 64     # open session failed at this time
F_LOST = 128         # lost-work event (RUNNING session was killed)
F_CHAIN_CLOSE = 256  # retry chain closed (manual-intervention branch)
F_FINALIZE = 512     # campaign end reached

_EPS = 1e-12
_ORD_MAX = jnp.iinfo(jnp.int32).max


def _row(tab, ptr):
    """tab[(l, ptr[l])] with a clipped (overflow-safe) gather."""
    idx = jnp.clip(ptr, 0, tab.shape[1] - 1)
    return jnp.take_along_axis(tab, idx[:, None], axis=1)[:, 0]


def _gang_select_xla(free, job):
    csum = jnp.cumsum(free.astype(jnp.int32), axis=1)
    return free & (csum <= job[:, None])


def _gang_select(free, job, backend: str, interpret: bool):
    if backend == "pallas":
        from repro.kernels.wavefront.kernel import gang_select_pallas
        return gang_select_pallas(free, job, interpret=interpret)
    return _gang_select_xla(free, job)


def _record_close(st, P, mask):
    """Exclusion-tracker accounting for sessions closing now (integer
    only: non-participant counts, interval counts, deliberate counts)."""
    n = st["in_gang"].shape[1]
    out = ~st["in_gang"] & mask[:, None]
    st["npart_counts"] = st["npart_counts"] + out.astype(jnp.int32)
    st["n_intervals"] = st["n_intervals"] + jnp.where(
        mask, n - P["job"], 0)
    delib = jnp.sum((st["iso_reason"] > 0) & ~st["in_gang"], axis=1,
                    dtype=jnp.int32)
    st["n_delib"] = st["n_delib"] + jnp.where(mask, delib, 0)
    return st


def _fail_session(st, flags, P, mask, hw_new):
    st["last_hw"] = jnp.where(mask, hw_new, st["last_hw"])
    st = _record_close(st, P, mask)
    flags = flags | jnp.where(mask, F_SESS_FAIL, 0)
    st["cur_on"] = st["cur_on"] & ~mask
    return st, flags


def _sched_next(st, flags, P, mask, t, evt_delay_h, evt_has_xid,
                structural: bool):
    """Vector form of ``_schedule_next``: retry-vs-manual decision and
    the next pending-start time, with the exact scalar draw discipline
    (noticed roll consumed iff attempt count >= 3; misfix roll always
    consumed on the manual branch; delays pre-divided so the device adds
    once)."""
    n_att = st["n_att"]
    roll = mask & (n_att >= 3)
    u_not = _row(P["u"], st["u_ptr"])
    noticed = roll & (u_not < P["notice_p"])
    st["u_ptr"] = st["u_ptr"] + roll
    if structural:
        noticed = noticed | (mask & P["struct_stop"])
    dna_d = _row(P["dna"], n_att)
    delay = jnp.where(P["policy_xid"] & evt_has_xid, evt_delay_h, dna_d)
    retry = mask & P["retry_on"] & jnp.isfinite(delay) \
        & (n_att < P["max_r"]) & ~noticed
    st["pend"] = jnp.where(retry, t + delay, st["pend"])

    man = mask & ~retry
    # manual-intervention branch: chain closes, operator responds with a
    # day/night exponential delay, and a misfixed root cause may extend
    # the structural-failure horizon
    hour = lax.rem(t, 24.0)
    day = lax.rem((t - hour) / 24.0, 7.0)
    night = (day >= 5.0) | (hour < 8.0) | (hour > 20.0)
    md = jnp.where(night, _row(P["man_night"], st["m_ptr"]),
                   _row(P["man_day"], st["m_ptr"]))
    st["m_ptr"] = st["m_ptr"] + man
    pend_man = t + md
    st["pend"] = jnp.where(man, pend_man, st["pend"])
    u_mis = _row(P["u"], st["u_ptr"])
    mis = man & (u_mis < P["p_misfix"])
    st["u_ptr"] = st["u_ptr"] + man
    xh = _row(P["x_half"], st["x_ptr"])
    st["x_ptr"] = st["x_ptr"] + mis
    su = st["struct_until"]
    st["struct_until"] = jnp.where(
        mis, jnp.maximum(su, pend_man + xh),
        jnp.where(man, jnp.minimum(su, pend_man), su))
    st["n_att"] = jnp.where(man, 0, st["n_att"])
    flags = flags | jnp.where(man, F_CHAIN_CLOSE, 0)
    return st, flags


def _iteration(st, P, backend: str, interpret: bool):
    t = st["t"]
    alive = st["alive"]
    L, n = st["healthy"].shape
    iota_n = lax.broadcasted_iota(jnp.int32, (L, n), 1)
    rows = jnp.arange(L)
    zero_b = jnp.zeros(L, dtype=bool)
    nan_v = jnp.full(L, jnp.nan)
    flags = jnp.zeros(L, dtype=jnp.int32)

    # 1. repairs due (node returns, isolation entry cleared)
    rep_act = (st["repair"] <= t[:, None]) & alive[:, None]
    st["healthy"] = st["healthy"] | rep_act
    st["excl"] = st["excl"] & ~rep_act
    st["iso_reason"] = jnp.where(rep_act, 0, st["iso_reason"])
    st["iso_order"] = jnp.where(rep_act, _ORD_MAX, st["iso_order"])
    st["repair"] = jnp.where(rep_act, jnp.inf, st["repair"])

    # 3. pending attempt starts
    free = st["healthy"] & ~st["excl"]
    counts = jnp.sum(free, axis=1, dtype=jnp.int32)
    due_start = alive & ~st["cur_on"] & (st["pend"] <= t)
    feasible = counts >= P["job"]
    okm = due_start & feasible
    afail = due_start & ~feasible
    chosen = _gang_select(free, P["job"], backend, interpret)

    # alloc-fail: pressure-readmit roll over the isolation list (dict
    # insertion order == smallest iso_order among still-unhealthy-free
    # candidates), then attempt bookkeeping and structural reschedule
    cand = (st["iso_reason"] > 0) & st["healthy"]
    has_cand = afail & jnp.any(cand, axis=1)
    u_adm = _row(P["u"], st["u_ptr"])
    readmit = has_cand & (u_adm < P["p_readmit"])
    st["u_ptr"] = st["u_ptr"] + has_cand
    ordm = jnp.where(cand, st["iso_order"], _ORD_MAX)
    rm_node = jnp.argmin(ordm, axis=1).astype(jnp.int32)
    rm = readmit[:, None] & (iota_n == rm_node[:, None])
    st["excl"] = st["excl"] & ~rm
    st["healthy"] = st["healthy"] | rm
    st["repair"] = jnp.where(rm, jnp.inf, st["repair"])
    st["iso_reason"] = jnp.where(rm, 0, st["iso_reason"])
    st["iso_order"] = jnp.where(rm, _ORD_MAX, st["iso_order"])

    st["n_att"] = st["n_att"] + due_start.astype(jnp.int32)
    flags = flags | jnp.where(afail, F_ALLOCFAIL, 0)
    st, flags = _sched_next(st, flags, P, afail, t, nan_v, zero_b, True)

    # gang-feasible: open the session, record the gang bitmask
    st["in_gang"] = jnp.where(okm[:, None], chosen, st["in_gang"])
    NS = st["se_gang"].shape[1]
    sidx = jnp.clip(st["sess_ctr"], 0, NS - 1)
    prev_gang = st["se_gang"][rows, sidx]
    st["se_gang"] = st["se_gang"].at[rows, sidx].set(
        jnp.where(okm[:, None], chosen, prev_gang))
    st["sess_ctr"] = st["sess_ctr"] + okm
    st["n_sessions"] = st["n_sessions"] + okm.astype(jnp.int32)
    flags = flags | jnp.where(okm, F_START, 0)
    # transient-retry roll + pre-transformed load-duration draw
    pf_pre = t < st["struct_until"]
    roll_tr = okm & ~pf_pre & ((st["n_att"] == 2) | (st["n_att"] == 3))
    u_tr = _row(P["u"], st["u_ptr"])
    trans = roll_tr & (u_tr < P["p_transient"])
    st["u_ptr"] = st["u_ptr"] + roll_tr
    pf = pf_pre | trans
    dur = jnp.where(pf, _row(P["dur_fail"], st["u_ptr"]),
                    jnp.where(st["last_hw"],
                              _row(P["dur_cold"], st["u_ptr"]),
                              _row(P["dur_warm"], st["u_ptr"])))
    st["u_ptr"] = st["u_ptr"] + okm
    st["prep_until"] = jnp.where(okm, t + dur, st["prep_until"])
    st["prep_fails"] = jnp.where(okm, pf, st["prep_fails"])
    st["cur_on"] = st["cur_on"] | okm
    st["cur_run"] = st["cur_run"] & ~okm
    st["pend"] = jnp.where(okm, jnp.inf, st["pend"])

    # 4. PREPARING completions (incl. sessions opened this iteration
    # whose load duration underruns — the numpy step order does the same)
    due_prep = alive & st["cur_on"] & ~st["cur_run"] \
        & (t >= st["prep_until"])
    pok = due_prep & ~st["prep_fails"]
    pfail = due_prep & st["prep_fails"]
    st["cur_run"] = st["cur_run"] | pok
    flags = flags | jnp.where(pok, F_PREP_OK, 0)
    st, flags = _fail_session(st, flags, P, pfail, zero_b)
    st, flags = _sched_next(st, flags, P, pfail, t, nan_v, zero_b, False)

    # 5. at most one failure event per lane per iteration
    nf = _row(P["ft"], st["fail_ptr"])
    fdue = alive & (nf <= t + _EPS)
    fnode = _row(P["fnode"], st["fail_ptr"])
    fk = _row(P["fkcode"], st["fail_ptr"])
    fhw = _row(P["fhw"], st["fail_ptr"])
    fdel = _row(P["fdelay"], st["fail_ptr"])
    fhx = _row(P["fhas_xid"], st["fail_ptr"])
    node_m = iota_n == fnode[:, None]
    # fail_slow: deliberate perf-degradation isolation (overwrite keeps
    # dict insertion order; a fresh key takes the next order counter)
    sm = (fdue & (fk == 2))[:, None] & node_m
    newly = sm & (st["iso_reason"] == 0)
    st["iso_order"] = jnp.where(newly, st["iso_ctr"][:, None],
                                st["iso_order"])
    st["iso_ctr"] = st["iso_ctr"] + jnp.any(newly, axis=1)
    st["iso_reason"] = jnp.where(sm, 1, st["iso_reason"])
    st["excl"] = st["excl"] | sm
    st["repair"] = jnp.where(
        sm, t[:, None] + P["slow_iso_h"][:, None], st["repair"])
    # hardware kills: node down + repair timer + setdefault isolation
    m_kill = fdue & (fk <= 1)
    hm = (m_kill & fhw)[:, None] & node_m
    st["healthy"] = st["healthy"] & ~hm
    st["repair"] = jnp.where(
        hm, t[:, None] + P["repair_h"][:, None], st["repair"])
    newly2 = hm & (st["iso_reason"] == 0)
    st["iso_order"] = jnp.where(newly2, st["iso_ctr"][:, None],
                                st["iso_order"])
    st["iso_ctr"] = st["iso_ctr"] + jnp.any(newly2, axis=1)
    st["iso_reason"] = jnp.where(newly2, 2, st["iso_reason"])
    # gang hit: lost work (if RUNNING), software roll, session teardown
    hit = jnp.take_along_axis(st["in_gang"],
                              jnp.clip(fnode, 0, n - 1)[:, None],
                              axis=1)[:, 0]
    ghit = m_kill & st["cur_on"] & hit
    flags = flags | jnp.where(ghit & st["cur_run"], F_LOST, 0)
    u_sw = _row(P["u"], st["u_ptr"])
    soft = ghit & (u_sw < P["p_soft"])
    st["u_ptr"] = st["u_ptr"] + ghit
    xf = _row(P["x_full"], st["x_ptr"])
    st["struct_until"] = jnp.where(
        soft, jnp.maximum(st["struct_until"], t + xf),
        st["struct_until"])
    st["x_ptr"] = st["x_ptr"] + soft
    st, flags = _fail_session(st, flags, P, ghit, fhw)
    st, flags = _sched_next(st, flags, P, ghit, t, fdel, fhx, False)
    st["fail_ptr"] = st["fail_ptr"] + fdue

    # 5b. escalation crash, only once the failure queue at t has drained
    # (the numpy loop processes failures then escalations per iteration)
    nf2 = _row(P["ft"], st["fail_ptr"])
    ne = _row(P["et"], st["esc_ptr"])
    edue = alive & (ne <= t + _EPS) & ~(nf2 <= t + _EPS)
    en = _row(P["enode"], st["esc_ptr"])
    ehit_node = jnp.take_along_axis(st["in_gang"],
                                    jnp.clip(en, 0, n - 1)[:, None],
                                    axis=1)[:, 0]
    ehit = edue & st["cur_on"] & ehit_node
    flags = flags | jnp.where(ehit & st["cur_run"], F_LOST, 0)
    u_sw2 = _row(P["u"], st["u_ptr"])
    soft2 = ehit & (u_sw2 < P["p_soft"])
    st["u_ptr"] = st["u_ptr"] + ehit
    xf2 = _row(P["x_full"], st["x_ptr"])
    st["struct_until"] = jnp.where(
        soft2, jnp.maximum(st["struct_until"], t + xf2),
        st["struct_until"])
    st["x_ptr"] = st["x_ptr"] + soft2
    st, flags = _fail_session(st, flags, P, ehit, zero_b)
    st, flags = _sched_next(st, flags, P, ehit, t, nan_v, zero_b, False)
    st["esc_ptr"] = st["esc_ptr"] + edue
    ne2 = _row(P["et"], st["esc_ptr"])

    # 6. next-event horizon (same-time candidates mask to +inf; the
    # duration term keeps the min finite, exactly the numpy fallback)
    c_pend = jnp.where(st["cur_on"], jnp.inf, st["pend"])
    c_prep = jnp.where(st["cur_on"] & ~st["cur_run"], st["prep_until"],
                       jnp.inf)
    t_next = P["duration"]
    for c in (jnp.min(st["repair"], axis=1), c_pend, c_prep, nf2, ne2):
        t_next = jnp.minimum(t_next, jnp.where(c <= t + _EPS, jnp.inf, c))
    pending = (nf2 <= t + _EPS) | (ne2 <= t + _EPS)
    t_next = jnp.where(pending, t, t_next)

    # record + finalize
    flags = flags | jnp.where(alive, F_VALID, 0)
    adv = alive & ~pending
    flags = flags | jnp.where(adv, F_ADVANCE, 0)
    flags = flags | jnp.where(alive & st["cur_on"] & st["cur_run"],
                              F_RUNNING, 0)
    finishing = adv & (t_next >= P["duration"])
    flags = flags | jnp.where(finishing, F_FINALIZE, 0)
    st = _record_close(st, P, finishing & st["cur_on"])
    st["cur_on"] = st["cur_on"] & ~finishing

    it = st["it"]
    st["rec_t"] = st["rec_t"].at[it].set(t_next)
    st["rec_flags"] = st["rec_flags"].at[it].set(flags)

    st["alive"] = alive & ~finishing
    st["t"] = jnp.where(st["alive"], t_next, st["t"])

    # cap sentries: a lane within one iteration's worth of consumption of
    # any cap is flagged and halted before a clipped read can corrupt it
    U, M, X = P["u"].shape[1], P["man_day"].shape[1], P["x_half"].shape[1]
    NS = st["se_gang"].shape[1]
    lane_over = (st["u_ptr"] > U - 8) | (st["m_ptr"] > M - 4) \
        | (st["x_ptr"] > X - 4) | (st["sess_ctr"] > NS - 2)
    st["overflow"] = st["overflow"] | (st["alive"] & lane_over)
    st["alive"] = st["alive"] & ~lane_over
    st["it"] = it + 1
    return st


@functools.partial(jax.jit, static_argnames=(
    "n_nodes", "n_sessions", "n_iters", "backend", "interpret"))
def wavefront_core(P, *, n_nodes: int, n_sessions: int, n_iters: int,
                   backend: str = "xla", interpret: bool = False):
    """Run the compiled wavefront over the lane tables ``P`` (the
    ``LaneTables.device`` dict as jnp arrays, f64 floats).  Returns the
    record stream, session gang bitmasks, integer accumulators, overflow
    flags and the iteration count — everything the host replay needs."""
    L = P["u"].shape[0]
    n, NS, I = n_nodes, n_sessions, n_iters
    inf = jnp.inf
    st = {
        "t": jnp.zeros(L),
        "alive": P["lane_on"],
        "pend": jnp.zeros(L),          # first attempt queued at t=0
        "prep_until": jnp.zeros(L),
        "struct_until": jnp.full(L, -1.0),
        "cur_on": jnp.zeros(L, dtype=bool),
        "cur_run": jnp.zeros(L, dtype=bool),
        "prep_fails": jnp.zeros(L, dtype=bool),
        "last_hw": jnp.zeros(L, dtype=bool),
        "n_att": jnp.zeros(L, dtype=jnp.int32),
        "u_ptr": jnp.zeros(L, dtype=jnp.int32),
        "m_ptr": jnp.zeros(L, dtype=jnp.int32),
        "x_ptr": jnp.zeros(L, dtype=jnp.int32),
        "fail_ptr": jnp.zeros(L, dtype=jnp.int32),
        "esc_ptr": jnp.zeros(L, dtype=jnp.int32),
        "iso_ctr": jnp.zeros(L, dtype=jnp.int32),
        "sess_ctr": jnp.zeros(L, dtype=jnp.int32),
        "healthy": jnp.ones((L, n), dtype=bool),
        "excl": jnp.zeros((L, n), dtype=bool),
        "in_gang": jnp.zeros((L, n), dtype=bool),
        "repair": jnp.full((L, n), inf),
        "iso_reason": jnp.zeros((L, n), dtype=jnp.int8),
        "iso_order": jnp.full((L, n), _ORD_MAX, dtype=jnp.int32),
        "npart_counts": jnp.zeros((L, n), dtype=jnp.int32),
        "n_intervals": jnp.zeros(L, dtype=jnp.int32),
        "n_delib": jnp.zeros(L, dtype=jnp.int32),
        "n_sessions": jnp.zeros(L, dtype=jnp.int32),
        "se_gang": jnp.zeros((L, NS, n), dtype=bool),
        "rec_t": jnp.zeros((I, L)),
        "rec_flags": jnp.zeros((I, L), dtype=jnp.int32),
        "overflow": jnp.zeros(L, dtype=bool),
        "it": jnp.int32(0),
    }

    def cond(st):
        return jnp.any(st["alive"]) & (st["it"] < I)

    def body(st):
        return _iteration(st, P, backend, interpret)

    st = lax.while_loop(cond, body, st)
    # lanes still alive at the iteration cap are cap overflows too
    st["overflow"] = st["overflow"] | st["alive"]
    return {k: st[k] for k in (
        "rec_t", "rec_flags", "se_gang", "npart_counts", "n_intervals",
        "n_delib", "n_sessions", "overflow", "it")}
