"""Compiled whole-campaign wavefront (jitted XLA / Pallas).

``run_findings_compiled`` advances every Monte Carlo lane (seed x scenario
config) of a campaign batch to its own next event inside one jitted
``lax.while_loop`` and returns findings dicts bitwise identical to the
numpy ``BatchedCampaignEngine`` / scalar ``ClusterSim`` path.  See
``ops.py`` for the dispatch rules and ``tapes.py`` for the draw-tape
discipline that makes the rng streams materializable up front.
"""
from repro.kernels.wavefront.ops import (compiled_eligible,  # noqa: F401
                                         resolve_wavefront_backend,
                                         run_findings_compiled)
