"""Pure-numpy/jnp oracle for ckpt_pack."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ckpt_pack_blocks_ref(x):
    """x: (n_blocks, block) float32 -> (bf16, uint32 (n_blocks, 1))."""
    y = x.astype(jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    chk = jnp.sum(bits, axis=1, keepdims=True, dtype=jnp.uint32)
    return y, chk


def block_checksums_np(arr: np.ndarray, block: int = 2048) -> np.ndarray:
    """Vectorized host-side block checksums over an fp32 array's bits.

    Matches the kernel's layout: flatten, zero-pad to a block multiple,
    wrapping-uint32 sum per block.  Used by the checkpoint restore path to
    verify payloads against the checksums the save-path kernel produced.
    """
    flat = np.ascontiguousarray(arr, dtype=np.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    bits = flat.view(np.uint32).reshape(-1, block)
    return (bits.astype(np.uint64).sum(axis=1) & 0xFFFFFFFF).astype(np.uint32)


def ckpt_pack_numpy(x: np.ndarray):
    """Host-side oracle (numpy, wrapping uint32 arithmetic)."""
    bits = x.view(np.uint32).reshape(x.shape)
    chk = np.zeros((x.shape[0], 1), np.uint32)
    for i in range(x.shape[0]):
        acc = np.uint32(0)
        with np.errstate(over="ignore"):
            for wrd in bits[i]:
                acc = np.uint32((int(acc) + int(wrd)) & 0xFFFFFFFF)
        chk[i, 0] = acc
    import ml_dtypes  # shipped with jax
    y = x.astype(ml_dtypes.bfloat16)
    return y, chk
