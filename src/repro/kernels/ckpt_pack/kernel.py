"""Checkpoint-shard packing Pallas TPU kernel (serves the F2 save path).

Fuses the two per-shard operations of checkpoint phase 2 in one VMEM pass:
  1. dtype cast fp32 -> bf16 (halves the RPC-constrained NFS write volume —
     the single biggest lever on the paper's 128-slot bottleneck), and
  2. a per-block additive uint32 checksum over the ORIGINAL fp32 bits
     (integrity verification at restore; bitcast + modular sum).

Input is reshaped by ops.py to (n_blocks, block); grid = (n_blocks,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, chk_ref):
    x = x_ref[0]                                     # (block,) f32
    y_ref[0] = x.astype(jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    chk_ref[0, 0] = jnp.sum(bits, dtype=jnp.uint32)  # modular (wrapping) sum


def ckpt_pack_blocks(x, *, interpret: bool = False):
    """x: (n_blocks, block) float32 -> (bf16 same shape, uint32 (n_blocks,1))."""
    nb, blk = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, blk), jnp.bfloat16),
            jax.ShapeDtypeStruct((nb, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(x)
