"""jit'd wrapper: flat-tensor pad/reshape + dispatch for ckpt_pack."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_pack.kernel import ckpt_pack_blocks
from repro.kernels.ckpt_pack.ref import ckpt_pack_blocks_ref

BLOCK = 2048


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_blocks(x, block: int):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    return jnp.pad(flat, (0, pad)).reshape(-1, block)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ckpt_pack(x, *, block: int = BLOCK, interpret: bool = None):
    """Pack a flat fp32 tensor for the checkpoint write path.

    Returns (bf16 payload (n,), checksums (n_blocks,)); ``n`` is padded up
    to a block multiple (zero pad — checksum covers the padded layout).
    """
    if interpret is None:
        interpret = not _on_tpu()
    blocks = _pad_blocks(x, block)
    y, chk = ckpt_pack_blocks(blocks, interpret=interpret)
    return y.reshape(-1), chk.reshape(-1)


@functools.partial(jax.jit, static_argnames=("block",))
def _ckpt_pack_xla(x, *, block: int = BLOCK):
    blocks = _pad_blocks(x, block)
    y, chk = ckpt_pack_blocks_ref(blocks)
    return y.reshape(-1), chk.reshape(-1)


def ckpt_pack_host(x, *, block: int = BLOCK):
    """ckpt_pack for the production save path: the compiled Pallas kernel
    on TPU, the jitted XLA reference (bit-identical outputs) elsewhere —
    interpret-mode Pallas is far too slow for checkpoint-sized tensors."""
    if _on_tpu():
        return ckpt_pack(x, block=block)
    return _ckpt_pack_xla(x, block=block)
