"""jit'd wrapper: flat-tensor pad/reshape + dispatch for ckpt_pack."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_pack.kernel import ckpt_pack_blocks

BLOCK = 2048


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ckpt_pack(x, *, block: int = BLOCK, interpret: bool = None):
    """Pack a flat fp32 tensor for the checkpoint write path.

    Returns (bf16 payload (n,), checksums (n_blocks,)); ``n`` is padded up
    to a block multiple (zero pad — checksum covers the padded layout).
    """
    if interpret is None:
        interpret = not _on_tpu()
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    y, chk = ckpt_pack_blocks(blocks, interpret=interpret)
    return y.reshape(-1), chk.reshape(-1)
