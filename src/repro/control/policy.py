"""Detection→recovery policy engine — closing the paper's title arc.

The reactive baseline (`ClusterSim` without a control plane) only reacts
to XID failures after they fire; the F1 detector's alarms change nothing.
`ControlPlane` embeds the streaming detector in the event engine and maps
its alarms to recovery actions, in the proactive-operations direction of
Kokolis et al. (2024) and the L4 diagnosis→mitigation pipeline:

* **urgent checkpoint** — an alarm on a node inside the running gang
  triggers an immediate save, priced at the gang's fanin through the same
  `checkpoint_save_s` the shared-NFS `StorageFabric` resolves for regular
  saves.  True positives shrink the lost-work window at the next failure;
  false positives burn save time.  Both sides are accounted.
* **predictive drain** — a *confirmed* alarm gracefully terminates the
  session behind a final checkpoint and isolates the suspect node before
  the failure lands, so the gang re-forms from spares instead of crashing
  into a retry chain.  Confirmation is alarm clustering, not vote size:
  real precursors flap (tens of alarms on one node inside half an hour as
  the degradation ramps) while false positives arrive as isolated shots —
  requiring ``drain_confirm_alarms`` same-node alarms inside
  ``drain_confirm_window_h`` separates them cleanly where a per-alarm
  signal count cannot (TP and FP alarms both carry ~4-5 votes).  Drains
  need a spare in the pool (a degraded-pool drain would starve the gang)
  and feed the `ExclusionTracker` with a ``"predictive drain"`` reason —
  F3 concentration then *emerges from detector behaviour* instead of
  being injected.  A false-positive drain is re-checked healthy and
  readmitted after ``drain_recheck_h``.
* **alarm-informed retry placement** — gang allocations for retries avoid
  recently-alarmed nodes (`RetryEngine.placement_order`), while the
  all-or-nothing gang requirement still wins when the pool is tight.

Counterfactual accounting: the campaign keeps two checkpoint clocks — the
scheduled cadence (`last_ckpt`) and the effective latest save
(`last_save`, advanced by urgent saves) — so every failure records both
the actual lost work and what the reactive baseline would have lost.
`ControlStats.summarize` turns that into the goodput ledger the sweep
report prints: lost-work hours avoided per true positive, urgent-save
hours wasted per false positive.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.failures import CORRELATED_KINDS, DEGRADE_KINDS
from repro.core.precursor import Alarm, DetectorConfig, evaluate
from repro.core.session import SessionState
from repro.core.topology import ClusterTopology
from repro.control.streaming import StreamingDetector
from repro.logs.analysis import LogAnalyzer, LogChannelConfig
from repro.logs.emitter import LogEmitter, _TICK_H

# alarm classification for the infra fault band: a network-degradation
# signature concentrates its top z-scores in transport/RPC metrics, a
# resource-exhaustion signature in host-pressure metrics.  The >= 3 rule
# separates them from existing alarm families (XID kills, fail-slow,
# unreachable, gradual precursors), but exponential-tailed noise can
# coincidentally meet it on a false positive — so the net-throttle policy
# only engages when the campaign's schedule carries infra-band events
# (``ControlPlane.infra_active``); pre-band campaigns stay bit-identical.
NET_ALARM_METRICS = frozenset({
    "node_mountstats_nfs_rpc_queue_depth",
    "node_netstat_Tcp_transport_backlog_bytes",
    "backendai_rpc_latency_ms",
    "node_sockstat_TCP_alloc",
    "node_mountstats_nfs_operations_response_time_seconds_total:GETATTR",
})
RESOURCE_ALARM_METRICS = frozenset({
    "node_memory_MemAvailable_bytes",
    "all_smi_sys_memory_used_bytes",
    "node_vmstat_pgpgout",
    "node_context_switches_total",
    "DCGM_FI_DEV_GPU_UTIL",
})


# metric name -> class code for the batched form (0 node, 1 net, 2 res)
_METRIC_CLASS = {m: 1 for m in NET_ALARM_METRICS}
_METRIC_CLASS.update({m: 2 for m in RESOURCE_ALARM_METRICS})
_CLASS_NAMES = ("node", "net", "resource")


def _metric_class(m: str) -> int:
    """Class code for one attributed metric.  Log-channel templates carry
    their class in the name (``log:net:*`` / ``log:res:*``) — names that
    never existed before the log channel, so pre-existing campaigns see
    the exact same codes as the plain dict lookup."""
    code = _METRIC_CLASS.get(m)
    if code is not None:
        return code
    if m.startswith("log:net:"):
        return 1
    if m.startswith("log:res:"):
        return 2
    return 0


def classify_alarm(alarm: Alarm) -> str:
    """``"net"`` | ``"resource"`` | ``"node"`` from the alarm's top-4
    attributed metrics (>= 3 votes in one class set)."""
    codes = [_metric_class(m) for m, _ in alarm.top_metrics[:4]]
    if sum(c == 1 for c in codes) >= 3:
        return "net"
    if sum(c == 2 for c in codes) >= 3:
        return "resource"
    return "node"


def classify_alarms(alarms) -> List[str]:
    """Batched :func:`classify_alarm` over one chunk's alarm list.

    The top-4 metric attributions map to small class codes and the
    >= 3-votes rule evaluates as one ``(A, 4)`` array pass instead of A
    per-alarm scans — same answers, one call per chunk (the shape the
    batched campaign engine's ``push_group`` hands the policy)."""
    if not alarms:
        return []
    codes = np.zeros((len(alarms), 4), dtype=np.int8)
    for i, a in enumerate(alarms):
        for j, (m, _) in enumerate(a.top_metrics[:4]):
            codes[i, j] = _metric_class(m)
    net = np.sum(codes == 1, axis=1) >= 3
    res = np.sum(codes == 2, axis=1) >= 3
    kinds = np.where(net, 1, np.where(res, 2, 0))
    return [_CLASS_NAMES[k] for k in kinds]


@dataclass(frozen=True)
class ControlConfig:
    """Policy knobs for the online detection→recovery loop."""
    # default_factory: a class-level shared instance would alias every
    # control plane's detector config (DetectorConfig is frozen today,
    # but the aliasing is a trap for any future mutable field)
    detector: DetectorConfig = field(default_factory=DetectorConfig)
    # pass-1 implementation for the streaming detector: "numpy" (the
    # parity oracle), "xla" (fused jitted XLA), "pallas" (TPU kernel) —
    # all three produce the identical alarm set on tested telemetry
    detector_backend: str = "numpy"
    # urgent checkpoint on any in-gang alarm
    urgent_checkpoint: bool = True
    urgent_cooldown_h: float = 0.5        # min spacing between urgent saves
    # predictive drain on confirmed (clustered) alarms
    drain: bool = False
    drain_confirm_alarms: int = 3         # same-node alarms that confirm
    drain_confirm_window_h: float = 0.5   # ...inside this window
    drain_redeploy_h: float = 5.0 / 60.0  # graceful handoff before restart
    drain_recheck_h: float = 4.0          # FP drains readmitted after this
    # alarm-informed retry placement
    retry_avoid_alarmed: bool = True
    alarm_memory_h: float = 4.0           # how long an alarm taints a node
    # log channel (L4-style diagnosis): fuse synthetic-log verdicts with
    # the metric vote.  Off by default — when off, neither the emitter nor
    # the analyzer is even constructed, so every pre-existing campaign is
    # bit-identical (see docs/LOG_CHANNEL.md)
    log_channel: bool = False
    log: LogChannelConfig = field(default_factory=LogChannelConfig)
    # blast-radius-aware recovery (correlated fault band): attribute a
    # gang-wide alarm burst to the shared leaf switch (Mycroft-style:
    # indict the root cause, not the symptomatic members), suppress
    # member drains while the switch is indicted, and avoid re-placing
    # the gang under a degraded switch.  Off by default — the topology
    # is then never constructed, so pre-band campaigns stay bit-identical
    blast_radius_aware: bool = False
    topology_fanout: int = 8              # leaf-switch fanout (topology.py)
    switch_confirm_members: int = 3       # distinct members that indict...
    switch_window_h: float = 0.5          # ...inside this window
    switch_avoid_h: float = 2.0           # indictment / placement-avoid span
    # control interval: max scrape ticks the engine may emit before the
    # detector sees them (bounds alarm->action latency; 120 ticks = 1 h)
    reaction_ticks: int = 120


@dataclass
class UrgentSave:
    time_h: float
    node: int
    alarm_idx: int                        # index into ControlStats.alarms
    cost_h: float


@dataclass
class DrainAction:
    time_h: float
    node: int
    alarm_idx: int
    executed: bool                        # False: state changed before drain
    evacuate: bool = False                # blast-radius evacuation: the gang
                                          #   moves off an indicted switch's
                                          #   rack, not off a sick node


@dataclass
class ControlStats:
    """Everything the control plane did, plus the counterfactual ledger."""
    alarms: List[Alarm] = field(default_factory=list)
    urgent_saves: List[UrgentSave] = field(default_factory=list)
    drains: List[DrainAction] = field(default_factory=list)
    urgent_save_h: float = 0.0            # total save time spent on alarms
    lost_work_avoided_h: float = 0.0      # vs the scheduled-cadence clock
    failures_on_drained_node: int = 0     # disruptions a drain dodged
    # infra fault band responses
    throttles: List[tuple] = field(default_factory=list)
                                          # (time_h, node, alarm_idx): net
                                          #   alarms waited out, not drained
    alarms_deferred: int = 0              # alarms queued in blind windows
    # correlated fault band responses
    topology_events: List[tuple] = field(default_factory=list)
                                          # (time_h, switch, n_members):
                                          #   gang-wide burst attributed to
                                          #   the shared leaf switch
    misattributed_drains: int = 0         # executed drains on a member of
                                          #   an actively-indicted switch
    switch_avoid_h: float = 2.0           # indictment span per topology
                                          #   event (set from ControlConfig;
                                          #   summarize scores attribution
                                          #   over the whole span)

    @property
    def n_drains(self) -> int:
        return sum(1 for d in self.drains if d.executed)

    def summarize(self, failures, duration_h: float) -> Dict[str, float]:
        """Score the campaign's alarms against its ground-truth failure
        schedule and split the spend/savings by true vs false positive."""
        xid_fails = [f for f in failures if f.kind == "xid"]
        ev = evaluate(self.alarms, xid_fails, duration_h)
        wasted_h = sum(s.cost_h for s in self.urgent_saves
                       if s.alarm_idx not in ev.matched_alarm_ids)
        tp = ev.detected
        fp = ev.false_positives
        # degradation-aware columns: detection of degrade-band windows
        # (alarm on the affected node inside the window, small latency
        # slack for chunked emission + persistence)
        deg = [f for f in failures if f.kind in DEGRADE_KINDS]
        deg_detected = sum(
            1 for f in deg
            if any(a.node == f.node
                   and f.time_h <= a.time_h <= f.time_h + f.window_h + 0.25
                   for a in self.alarms))
        blind = [f for f in failures if f.kind == "ctrl_blind"]
        # time-to-detection: per detectable fault, first alarm on the
        # fault's node inside its activity span, measured from *onset*
        # (precursor start for gradual XIDs, window open for degrade
        # faults) — the log channel's whole value proposition is moving
        # this left without adding false drains
        ttds = []
        for f in failures:
            if f.kind == "ctrl_blind":
                continue
            lead = max(getattr(f, "precursor_lead_h", 0.0), 0.0)
            window = max(getattr(f, "window_h", 0.0), 0.0)
            onset = f.time_h - lead
            horizon = f.time_h + window + 0.25
            hits = [a.time_h for a in self.alarms
                    if a.node == f.node
                    and onset - 1e-9 <= a.time_h <= horizon]
            if hits:
                ttds.append(min(hits) - onset)
        # false drains: executed drains on a node with no fault activity
        # anywhere near the drain time
        false_drains = 0
        for d in self.drains:
            if not d.executed or d.evacuate:
                # evacuations are deliberate fabric-cause moves, not
                # per-node failure predictions — they score separately
                continue
            justified = any(
                f.kind != "ctrl_blind" and f.node == d.node
                and (f.time_h
                     - max(getattr(f, "precursor_lead_h", 0.0), 0.5) - 1e-9
                     <= d.time_h
                     <= f.time_h + max(getattr(f, "window_h", 0.0), 0.0)
                     + 0.5)
                for f in failures)
            false_drains += 0 if justified else 1
        n_log_alarms = sum(
            1 for a in self.alarms
            if a.top_metrics and a.top_metrics[0][0].startswith("log:"))
        # correlated-band attribution: a switch event counts as attributed
        # when a topology event's indictment span overlaps the event's
        # activity window (small slack for chunked emission + persistence)
        # — back-to-back events on a still-indicted switch are attributed
        # by the standing indictment, not a second topology event
        corr = [f for f in failures if f.kind in CORRELATED_KINDS]
        sw_fails = [f for f in corr if f.kind == "switch_degrade"]
        sw_attr = sum(
            1 for f in sw_fails
            if any(e[1] == f.switch
                   and e[0] <= f.time_h + f.window_h + 0.25
                   and e[0] + self.switch_avoid_h > f.time_h - 1e-9
                   for e in self.topology_events))
        return {
            "n_alarms": float(len(self.alarms)),
            "tp": float(tp),
            "fp": float(fp),
            "fp_per_day": ev.fp_per_day,
            "n_urgent_saves": float(len(self.urgent_saves)),
            "urgent_save_h": self.urgent_save_h,
            "urgent_wasted_h": wasted_h,
            "wasted_per_fp_h": wasted_h / max(fp, 1),
            "lost_work_avoided_h": self.lost_work_avoided_h,
            "avoided_per_tp_h": self.lost_work_avoided_h / max(tp, 1),
            "n_drains": float(self.n_drains),
            "failures_avoided": float(self.failures_on_drained_node),
            "n_throttles": float(len(self.throttles)),
            "alarms_deferred": float(self.alarms_deferred),
            "deg_windows": float(len(deg)),
            "deg_detected": float(deg_detected),
            "deg_detect_rate": deg_detected / max(len(deg), 1),
            "n_blind_windows": float(len(blind)),
            "blind_h": float(sum(f.window_h for f in blind)),
            "n_log_alarms": float(n_log_alarms),
            "ttd_h": float(np.median(ttds)) if ttds else None,
            "ttd_n": float(len(ttds)),
            "false_drains": float(false_drains),
            "corr_events": float(len(corr)),
            "switch_events": float(len(sw_fails)),
            "switch_attributed": float(sw_attr),
            "switch_attr_rate": sw_attr / max(len(sw_fails), 1),
            "n_topology_events": float(len(self.topology_events)),
            "misattributed_drains": float(self.misattributed_drains),
            "evacuations": float(sum(1 for d in self.drains
                                     if d.executed and d.evacuate)),
        }


class ControlPlane:
    """Online controller embedded in the event engine.

    The telemetry batcher feeds every emitted span chunk to
    :meth:`on_chunk`; alarms are applied as follows:

    * urgent checkpoints are pure accounting at the alarm's own timestamp
      (the save would have completed well inside the span; it does not
      change the span's constant-state evolution), so they apply
      retroactively within the chunk;
    * drains DO change cluster state, so the chunk that raised a
      drain-grade alarm halts further emission and the drain becomes a
      first-class event the main loop processes at the chunk boundary —
      reaction latency is bounded by ``reaction_ticks``.
    """

    def __init__(self, config: ControlConfig, urgent_save_s: float,
                 n_nodes: int = 0, seed: int = 0):
        self.cfg = config
        self.urgent_save_s = urgent_save_s
        self.detector = StreamingDetector(config.detector,
                                          backend=config.detector_backend)
        # log channel: constructed only when the gate is on — the off path
        # never touches the log subsystem (the bit-identity guarantee)
        if config.log_channel:
            self.log: Optional[LogAnalyzer] = LogAnalyzer(config.log)
            self._log_emitter: Optional[LogEmitter] = LogEmitter(
                n_nodes, seed,
                noise_per_node_h=config.log.noise_per_node_h)
        else:
            self.log = None
            self._log_emitter = None
        self.stats = ControlStats(switch_avoid_h=config.switch_avoid_h)
        self.last_alarm_h: Dict[int, float] = {}
        self.pending_drain: Optional[DrainAction] = None
        self._last_urgent_h = -1e18
        self._node_alarms: Dict[int, List[float]] = {}   # confirmation ring
        # control-plane blind windows (scheduler outages): alarms raised
        # inside one cannot trigger actions — they queue and replay when
        # visibility returns at the window's end
        self._blind: List[tuple] = []                    # (t0, t1)
        self._blind_queue: List[tuple] = []              # (alarm, idx)
        self._blind_release = float("inf")
        # the net-throttle policy only engages when the campaign schedule
        # carries infra-band events (set by the engines at setup); noise
        # alarms in pre-band campaigns keep the legacy urgent-save path
        self.infra_active = False
        # blast-radius-aware recovery: the topology is constructed only
        # when the gate is on — the off path never touches the topology
        # layer (the bit-identity guarantee, same shape as the log channel)
        if config.blast_radius_aware:
            self.topology: Optional[ClusterTopology] = ClusterTopology(
                max(n_nodes, 1), config.topology_fanout)
        else:
            self.topology = None
        self._switch_alarms: Dict[int, List[tuple]] = {}  # sw -> (t, node)
        self._switch_until: Dict[int, float] = {}         # sw -> indicted til

    def begin_blind(self, t0_h: float, t1_h: float):
        """Register a scheduler-outage window [t0, t1) (campaign setup)."""
        self._blind.append((t0_h, t1_h))

    def register_failures(self, failures) -> None:
        """Hand the failure schedule to the log emitter (campaign setup,
        schedule order).  No-op when the log channel is off."""
        if self._log_emitter is None:
            return
        for ev in failures:
            self._log_emitter.register_failure(ev)

    def _blind_at(self, t: float) -> Optional[float]:
        """End of the blind window containing ``t``, if any."""
        for b0, b1 in self._blind:
            if b0 <= t < b1:
                return b1
        return None

    def blind_ready(self, t: float) -> bool:
        """True when queued blind-window decisions are due for replay."""
        return bool(self._blind_queue) and t >= self._blind_release - 1e-12

    # -- telemetry-side hook (called by _TelemetryBatcher) -------------------

    def on_chunk(self, ts, snap, state) -> bool:
        """Scan one emitted span chunk; apply in-span actions.

        Returns True when emission must halt so a pending drain can run as
        an event at the chunk boundary.
        """
        alarms = self.detector.push(ts, snap)
        if self.log is not None:
            alarms = self.fuse_alarms(alarms, self.scan_logs(ts, state))
        return self.apply_alarms(alarms, state)

    def scan_logs(self, ts, state) -> List[Alarm]:
        """Run the log channel over one chunk's time window: emit the
        synthetic lines for [ts[0], ts[-1] + tick), score every window the
        chunk completes, and convert verdicts to :class:`Alarm` records
        whose ``top_metrics`` carry ``log:<class>:<template>`` names.
        Called at the same point by both engines (the scalar batcher's
        chunk and the batched engine's per-seed group scan), so the
        emitter's per-chunk draws line up bit-for-bit."""
        if self.log is None:
            return []
        t0 = float(ts[0])
        step = float(ts[1] - ts[0]) if len(ts) > 1 else _TICK_H
        t1 = float(ts[-1]) + step
        cur = state.current
        gang = list(cur.nodes) \
            if cur is not None and cur.state is SessionState.RUNNING else []
        lines = self._log_emitter.emit_window(t0, t1, gang)
        return [
            Alarm(tick=int(v.time_h / _TICK_H + 1e-9), time_h=v.time_h,
                  node=v.node, n_signals=len(v.top),
                  top_metrics=list(v.top))
            for v in self.log.ingest(lines, t1)]

    @staticmethod
    def fuse_alarms(metric_alarms: List[Alarm],
                    log_alarms: List[Alarm]) -> List[Alarm]:
        """Merge the two channels' alarms into one time-ordered stream.
        Stable on ties (metric first) so the policy loop — cooldowns,
        confirmation rings — sees a deterministic order."""
        if not log_alarms:
            return metric_alarms
        return sorted(metric_alarms + log_alarms, key=lambda a: a.time_h)

    def apply_alarms(self, alarms, state) -> bool:
        """Map one chunk's alarms to in-span actions (urgent saves, drain
        confirmation, placement memory).  Split from :meth:`on_chunk` so
        the batched campaign engine can scan a whole seed group through
        ``StreamingDetector.push_group`` and then apply each seed's alarms
        against its own state view — the policy arithmetic is identical
        either way.  Returns True when emission must halt for a drain.
        """
        cfg = self.cfg
        halt = False
        kinds = classify_alarms(alarms) if self.infra_active \
            else [None] * len(alarms)
        for alarm, kind in zip(alarms, kinds):
            idx = len(self.stats.alarms)
            self.stats.alarms.append(alarm)
            blind_until = self._blind_at(alarm.time_h)
            if blind_until is not None:
                # scheduler outage: the alarm is recorded but cannot act —
                # queue the decision for replay when visibility returns
                self.stats.alarms_deferred += 1
                self._blind_queue.append((alarm, idx))
                self._blind_release = blind_until
                continue
            if kind == "net":
                # network degradation: throttle and wait the window out —
                # no urgent save (the gang still runs), no drain (the
                # fabric, not the node, is the bottleneck), no placement
                # taint (the node is healthy).  Blast-radius attribution
                # feeds on exactly these alarms: a burst of them across one
                # switch's members indicts the switch, not the nodes
                if self._note_topology(alarm, idx, state):
                    halt = True
                self.stats.throttles.append((alarm.time_h, alarm.node, idx))
                continue
            self.last_alarm_h[alarm.node] = alarm.time_h
            cur = state.current
            in_gang = (cur is not None
                       and cur.state is SessionState.RUNNING
                       and alarm.node in cur.nodes)
            if not in_gang:
                continue
            if cfg.urgent_checkpoint and alarm.time_h - self._last_urgent_h \
                    >= cfg.urgent_cooldown_h:
                self._urgent_save(alarm.time_h, alarm.node, idx, state)
            if cfg.drain and self.pending_drain is None \
                    and self._confirmed(alarm) \
                    and not self._switch_indicted(alarm.node, alarm.time_h):
                self.pending_drain = DrainAction(alarm.time_h, alarm.node,
                                                 idx, executed=False)
                halt = True
        return halt

    # -- blast-radius attribution (correlated fault band) --------------------

    def _note_topology(self, alarm: Alarm, idx: int = -1,
                       state=None) -> bool:
        """Mycroft-style cross-node correlation: record a net-class alarm
        against the emitting node's leaf switch; once
        ``switch_confirm_members`` *distinct* members alarm inside
        ``switch_window_h``, the burst is attributed to the shared switch
        (one topology event) and the switch is indicted for
        ``switch_avoid_h`` — member drains are suppressed, retry placement
        avoids the whole rack, and (when a gang is running on the rack) an
        evacuation drain is proposed.  Returns True when the caller must
        halt emission for that evacuation."""
        if self.topology is None \
                or not 0 <= alarm.node < self.topology.n_nodes:
            return False
        sw = self.topology.switch_of(alarm.node)
        ring = self._switch_alarms.setdefault(sw, [])
        ring.append((alarm.time_h, alarm.node))
        cutoff = alarm.time_h - self.cfg.switch_window_h
        ring[:] = [(t, n) for t, n in ring if t >= cutoff]
        distinct = {n for _, n in ring}
        if len(distinct) >= self.cfg.switch_confirm_members \
                and alarm.time_h >= self._switch_until.get(sw, -1e18):
            self.stats.topology_events.append(
                (alarm.time_h, sw, len(distinct)))
            self._switch_until[sw] = alarm.time_h + self.cfg.switch_avoid_h
            return self._propose_evacuation(alarm, sw, idx, state)
        return False

    def _propose_evacuation(self, alarm: Alarm, sw: int, idx: int,
                            state) -> bool:
        """Blast-radius-aware recovery: the moment a burst is attributed
        to a switch, evacuate the running gang off its rack behind a final
        checkpoint — the redeploy's placement (:meth:`avoid_nodes`) keeps
        the new gang clear of the indicted switch, so the whole blast
        radius stops charging degraded hours.  Rides the ordinary drain
        machinery (pending action, chunk halt, execution at the boundary)
        so both campaign engines stay bit-identical."""
        if state is None or not self.cfg.drain \
                or self.pending_drain is not None:
            return False
        cur = state.current
        if cur is None or cur.state is not SessionState.RUNNING:
            return False
        in_gang = sorted(set(self.topology.members(sw)) & set(cur.nodes))
        if not in_gang:
            return False
        node = alarm.node if alarm.node in cur.nodes else in_gang[0]
        self.pending_drain = DrainAction(alarm.time_h, node, idx,
                                         executed=False, evacuate=True)
        return True

    def _switch_indicted(self, node: int, t: float) -> bool:
        """True while ``node``'s leaf switch is under an active indictment
        — the root cause is the fabric, so the member must not be drained."""
        if self.topology is None \
                or not 0 <= node < self.topology.n_nodes:
            return False
        return t < self._switch_until.get(self.topology.switch_of(node),
                                          -1e18)

    def switch_reasons(self, t0: float, t1: float) -> Dict[int, str]:
        """Exclusion attribution for the tracker: every member of a switch
        whose indictment overlaps [t0, t1) carries reason ``"switch"`` —
        the correlated band's contribution to the F3 concentration ledger.
        Empty when the blast-radius gate is off (pre-band bit-identity)."""
        if self.topology is None or not self.stats.topology_events:
            return {}
        out: Dict[int, str] = {}
        for tev, sw, _n in self.stats.topology_events:
            if tev < t1 and tev + self.cfg.switch_avoid_h > t0:
                for node in self.topology.members(sw):
                    out.setdefault(node, "switch")
        return out

    def _confirmed(self, alarm: Alarm) -> bool:
        """Alarm-clustering confirmation: real precursors flap (many alarms
        on one node as the degradation ramps); false positives do not."""
        cfg = self.cfg
        ring = self._node_alarms.setdefault(alarm.node, [])
        ring.append(alarm.time_h)
        cutoff = alarm.time_h - cfg.drain_confirm_window_h
        ring[:] = [t for t in ring if t >= cutoff]
        return len(ring) >= cfg.drain_confirm_alarms

    def _urgent_save(self, t: float, node: int, alarm_idx: int, state):
        cost_h = self.urgent_save_s / 3600.0
        state.last_save = max(state.last_save, t)
        self.stats.urgent_saves.append(UrgentSave(t, node, alarm_idx, cost_h))
        self.stats.urgent_save_h += cost_h
        self._last_urgent_h = t

    # -- event-side hooks (called by the main loop) --------------------------

    def process(self, t: float, state):
        """Execute a pending drain at the chunk boundary that raised it,
        and replay decisions queued during a blind window once visibility
        returns (actions land at ``t``, the window's end — the outage cost
        is exactly that latency)."""
        if self.blind_ready(t):
            queued, self._blind_queue = self._blind_queue, []
            self._blind_release = float("inf")
            cfg = self.cfg
            kinds = classify_alarms([a for a, _ in queued]) \
                if self.infra_active else [None] * len(queued)
            for (alarm, idx), kind in zip(queued, kinds):
                if kind == "net":
                    self._note_topology(alarm, idx, state)
                    self.stats.throttles.append((alarm.time_h, alarm.node,
                                                 idx))
                    continue
                self.last_alarm_h[alarm.node] = alarm.time_h
                cur = state.current
                in_gang = (cur is not None
                           and cur.state is SessionState.RUNNING
                           and alarm.node in cur.nodes)
                if not in_gang:
                    continue
                if cfg.urgent_checkpoint and t - self._last_urgent_h \
                        >= cfg.urgent_cooldown_h:
                    self._urgent_save(t, alarm.node, idx, state)
                if cfg.drain and self.pending_drain is None \
                        and self._confirmed(alarm) \
                        and not self._switch_indicted(alarm.node, t):
                    self.pending_drain = DrainAction(t, alarm.node, idx,
                                                     executed=False)
        if self.pending_drain is None:
            return
        act = self.pending_drain
        self.pending_drain = None
        if not act.evacuate and self._switch_indicted(act.node, t):
            # the indictment landed after this drain was confirmed: the
            # burst belongs to the node's leaf switch, so draining the
            # member would misattribute a fabric fault to a healthy node —
            # record the near-miss and stand down
            self.stats.misattributed_drains += 1
            self.stats.drains.append(act)
            return
        cur = state.current
        spares = sum(1 for nd in state.sched.nodes if nd.free)
        if (cur is None or cur.state is not SessionState.RUNNING
                or act.node not in cur.nodes
                or not state.sched.nodes[act.node].healthy
                or spares < 1):
            # stale (state moved on) or unsafe (no spare: draining would
            # starve the gang and stall the campaign on the re-allocation)
            self.stats.drains.append(act)
            return
        # final save behind the drain (the handoff is checkpointed)
        if state.last_save < t:
            self._urgent_save(t, act.node, act.alarm_idx, state)
        state.drain_session(t, act.node,
                            redeploy_h=self.cfg.drain_redeploy_h,
                            recheck_h=self.cfg.drain_recheck_h)
        self.stats.drains.append(DrainAction(t, act.node, act.alarm_idx,
                                             executed=True,
                                             evacuate=act.evacuate))

    def avoid_nodes(self, t: float) -> Optional[Set[int]]:
        """Nodes a retry allocation should place last (recent alarms)."""
        if not self.cfg.retry_avoid_alarmed:
            return None
        cutoff = t - self.cfg.alarm_memory_h
        avoid = {n for n, th in self.last_alarm_h.items() if th >= cutoff}
        if self.topology is not None:
            # blast-radius-aware placement: while a switch is indicted,
            # every node behind it places last — a retry gang re-formed
            # under a degraded switch inherits the whole blast radius
            for sw, until in self._switch_until.items():
                if t < until:
                    avoid.update(self.topology.members(sw))
        return avoid or None
