"""Online detection→recovery control plane.

`StreamingDetector` (the incremental F1 detector consuming span-batched
telemetry) + `ControlPlane` (the policy engine mapping alarms to urgent
checkpoints, predictive drains, and alarm-informed retry placement inside
the event-driven `ClusterSim`).
"""
from repro.control.policy import (ControlConfig, ControlPlane, ControlStats,
                                  DrainAction, UrgentSave)
from repro.control.streaming import StreamingDetector, robust_peer_z_block

__all__ = [
    "ControlConfig", "ControlPlane", "ControlStats", "DrainAction",
    "UrgentSave", "StreamingDetector", "robust_peer_z_block",
]
