"""Streaming precursor detection — the control plane's sensor.

``StreamingDetector`` is the incremental reformulation of
``PrecursorDetector.scan`` (paper F1 / §4.1): it consumes span-batched
telemetry *as the event engine emits it* and returns the alarms raised by
each span.  The per-tick math is unchanged — robust peer z-scores
(median/MAD across the active cohort), a multi-signal vote, and a
persistence streak — but the formulation is online:

* one vectorized numpy pass per pushed span (no full-store rescan), so the
  amortized cost of online detection equals one offline scan of the same
  window — the ``control_plane`` benchmark measures >=10x over rescanning
  the growing store at each span;
* O(n_nodes) carry state between spans: the previous tick's activity row
  (the peer cohort is "was running the SPMD workload at the previous
  scrape") and the per-node consecutive-hit streak.  Nothing else crosses
  span boundaries, which is what makes the reformulation exact;
* alarm attribution (``top_metrics``) runs as a second pass restricted to
  the alarming ticks, so the per-(tick, node) bookkeeping that dominated
  the offline scan is only paid where an alarm actually fired.

``PrecursorDetector.scan`` delegates to this class (one push of the whole
store), so the offline and online paths share one implementation and one
set of tests; the parity test asserts chunked pushes reproduce ``scan``'s
alarm list exactly.

Backends: the numpy pass above is the *parity oracle*; ``backend="xla"``
(jitted XLA) and ``backend="pallas"`` (TPU kernel) route pass 1 through
the fused `repro.kernels.robust_stats` implementation — masked peer
median/MAD, robust z, the multi-signal vote and the streak scan in one
compiled call over the stacked block.  The compiled backends must
produce the identical alarm set (same (tick, node) pairs, same streak
counts and vote totals) on all tested seeds — asserted by the backend
tier-1 tests and the ``detector_backend`` benchmark gate — so every
parity contract built on the numpy path survives a backend switch.
Attribution (pass 2) always runs host-side: it touches only the alarming
ticks.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.precursor import Alarm, DetectorConfig


def _nanmedian_rows(a: np.ndarray) -> np.ndarray:
    """Median over the last axis, ignoring NaNs; keepdims.

    NaNs (inactive peers) are mapped to +inf so they land past every valid
    entry; the median of the ``m`` valid values is then the midpoint pair
    of order statistics.  The cohort size ``m`` takes only a handful of
    distinct values per span (gang width, minus the occasional down node),
    so ``np.partition`` at that small ``kth`` set replaces a full sort.
    Unlike ``np.nanmedian`` (which drops into a per-row python path when
    NaNs are present) this stays fully vectorized, and it is the ONE
    median both the offline scan and the online detector evaluate — their
    parity is structural.  Partition and the sort fallback select the same
    order statistics, so results are identical either way.  All-NaN rows
    return NaN, as ``np.nanmedian`` would.
    """
    finite = ~np.isnan(a)
    m = np.maximum(finite.sum(axis=-1, keepdims=True), 1)
    k_lo, k_hi = (m - 1) // 2, m // 2
    filled = np.where(finite, a, np.inf)
    ks = np.unique(np.concatenate([k_lo.ravel(), k_hi.ravel()]))
    if len(ks) > 8:                      # pathological cohort variety
        s = np.sort(filled, axis=-1)
    else:
        s = np.partition(filled, list(ks), axis=-1)
    med = (np.take_along_axis(s, k_lo, axis=-1)
           + np.take_along_axis(s, k_hi, axis=-1)) / 2
    return np.where(finite.any(axis=-1, keepdims=True), med, np.nan)


def robust_peer_z_block(series: np.ndarray,
                        active: np.ndarray) -> np.ndarray:
    """|z| of every node vs its active peer cohort, per tick row.

    ``series``: (..., T, n_nodes) — a single metric or a stacked block of
    metrics sharing one dtype; ``active``: (T, n_nodes), broadcast over
    leading axes.  Median/MAD are computed over the active nodes of each
    row (the faulty node is <=1/N of the sample, so both are stable).
    Row-wise selection is independent of the stacking, so blocked and
    per-metric evaluation are bit-identical for a given dtype.
    """
    masked = np.where(active, series, np.nan)
    with np.errstate(all="ignore"), warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        med = _nanmedian_rows(masked)
        mad = _nanmedian_rows(np.abs(masked - med))
    med = np.nan_to_num(med)
    mad = np.nan_to_num(mad)
    scale = 1.4826 * mad
    floor = np.maximum(1e-12, 1e-6 * np.maximum(np.abs(med), 1.0))
    scale = np.where(scale < 1e-12, floor, scale)
    return np.abs((series - med) / scale)


# stacked-block budget for pass 1: bounds the transient (B, T, n) buffer
_BLOCK_ELEMS = 1 << 24


def _by_dtype(values: Dict[str, np.ndarray],
              names: Sequence[str]) -> Dict[np.dtype, List[str]]:
    """Group metric names by array dtype (stacking mixed dtypes would
    upcast and change the per-metric math bit-for-bit)."""
    groups: Dict[np.dtype, List[str]] = {}
    for name in names:
        groups.setdefault(np.asarray(values[name]).dtype, []).append(name)
    return groups


def _worth_compiling(S: int, B: int, T: int, n: int) -> bool:
    """Small spans are cheaper on the numpy pass than on a device round
    trip; route them back regardless of the configured backend (the
    outputs are identical either way — this is pure size dispatch)."""
    from repro.kernels.robust_stats.ops import COMPILED_MIN_ELEMS
    return S * B * T * n >= COMPILED_MIN_ELEMS


class StreamingDetector:
    """Online multi-signal detector over span-batched telemetry.

    Feed scrape spans in order via :meth:`push`; each call returns the
    alarms whose persistence streak completed inside that span.  Pushing a
    whole store in one call is exactly the offline scan.

    ``backend`` selects the pass-1 implementation: ``"numpy"`` (the
    reference and parity oracle), ``"xla"`` (jitted XLA, fused), or
    ``"pallas"`` (TPU kernel; interpreted off-TPU, so only useful there).
    All three produce the same alarms on tested telemetry.
    """

    def __init__(self, config: Optional[DetectorConfig] = None,
                 backend: str = "numpy"):
        # NOTE: config default is constructed per instance — a shared
        # default-argument instance would alias every detector's config
        self.config = config if config is not None else DetectorConfig()
        if backend != "numpy":
            from repro.kernels.robust_stats.ops import validate_backend
            validate_backend(backend)
        self.backend = backend
        self._streak: Optional[np.ndarray] = None     # (n,) consecutive hits
        self._prev_act: Optional[np.ndarray] = None   # (1, n) last activity row
        self._tick_offset = 0                         # global tick index
        self.n_alarms = 0

    # -- state helpers ------------------------------------------------------

    def _activity(self, values: Dict[str, np.ndarray],
                  shape) -> np.ndarray:
        """Active cohort per tick: node ran the workload at the *previous*
        scrape (so the failure tick itself stays eligible).  The previous
        span's last row carries across the boundary."""
        cfg = self.config
        if cfg.activity_metric in values:
            act_now = np.asarray(values[cfg.activity_metric]) \
                > cfg.activity_threshold
            prev = self._prev_act if self._prev_act is not None \
                else act_now[:1]
            active = np.vstack([prev, act_now[:-1]])
            self._prev_act = act_now[-1:].copy()
        else:
            active = np.ones(shape, dtype=bool)
            self._prev_act = active[-1:].copy()
        return active

    # -- the one-pass-per-span core -----------------------------------------

    def _hit_pass_numpy(self, values, names, active, T, n) -> np.ndarray:
        """Pass 1, numpy oracle: multi-signal vote counts (T, n) int32.

        Metrics are stacked into (B, T, n) blocks — grouped by dtype so
        the stacked math stays bit-identical to per-metric evaluation —
        which collapses the ~300 per-metric numpy calls of a fine-grained
        online chunk into a handful.
        """
        cfg = self.config
        hit = np.zeros((T, n), dtype=np.int32)
        block_n = max(_BLOCK_ELEMS // max(T * n, 1), 1)
        for group in _by_dtype(values, names).values():
            for i in range(0, len(group), block_n):
                block = np.stack([np.asarray(values[name])
                                  for name in group[i:i + block_n]])
                z = robust_peer_z_block(block, active)
                hit += ((z > cfg.z_threshold) & active).sum(
                    axis=0, dtype=np.int32)
        return hit

    @staticmethod
    def _detect_compiled(values_list, names, active, carry, cfg, backend):
        """Pass 1 + streak scan via the fused robust_stats backend.

        ``active``: (S, T, n); ``carry``: (S, n) pre-span streaks.
        Returns (hit, streak), both (S, T, n) int32.  Metric chunks are
        stacked float32 directly (half the host footprint of a float64
        stack) under the same block budget as the numpy path — votes are
        additive across chunks, so a 300-metric offline scan never holds
        more than one chunk's block on the host — and the streak scan
        runs once on the accumulated counts.
        """
        from repro.kernels.robust_stats.ops import (BLOCK_ELEMS,
                                                    bucket_layout, hit_block,
                                                    streak_scan)
        S, T, n = active.shape
        Sp, layout = bucket_layout(S, T)
        Tp = sum(layout)
        act = np.zeros((Sp, Tp, n), dtype=bool)
        act[:S, :T] = active
        hit = np.zeros((S, T, n), dtype=np.int32)
        block_n = max(BLOCK_ELEMS // max(Sp * Tp * n, 1), 1)
        for i in range(0, len(names), block_n):
            chunk = names[i:i + block_n]
            # build straight into the bucketed buffer (see bucket_layout)
            # so the kernel layer pays no second pad copy
            block = np.zeros((Sp, len(chunk), Tp, n), dtype=np.float32)
            for s, values in enumerate(values_list):
                for b, name in enumerate(chunk):
                    block[s, b, :T] = values[name]
            hit += hit_block(block, act, z_threshold=cfg.z_threshold,
                             backend=backend, prepadded=(S, T))
        return hit, streak_scan(hit, carry, cfg.min_signals)

    def _span_streak(self, hit: np.ndarray, T: int, n: int) -> np.ndarray:
        """Persistence streak with cross-span carry, vectorized:
        streak[t] = (streak[t-1] + 1) * over[t]  ==  distance to the last
        reset row, plus the carried-in streak while no reset has occurred.
        """
        over = hit >= self.config.min_signals
        carry = self._streak if self._streak is not None \
            else np.zeros(n, dtype=np.int64)
        idx = np.arange(1, T + 1, dtype=np.int64)[:, None]
        last_reset = np.maximum.accumulate(np.where(over, 0, idx), axis=0)
        streak = np.where(over, idx - last_reset, 0)
        streak += np.where(over & (last_reset == 0), carry[None, :], 0)
        return streak

    def push(self, ts: np.ndarray,
             values: Dict[str, np.ndarray]) -> List[Alarm]:
        """Consume one telemetry span; return the alarms it raised.

        ``ts``: (T,) scrape times in hours; ``values``: metric -> (T, n)
        arrays (a ``TimeSeriesStore`` snapshot slice or an
        ``ExporterSuite.tick_batch`` output).
        """
        cfg = self.config
        ts = np.asarray(ts, dtype=float)
        names = [n for n in values if n not in cfg.exclude_metrics]
        if len(ts) == 0 or not names:
            return []
        T, n = np.asarray(values[names[0]]).shape
        active = self._activity(values, (T, n))

        if self.backend == "numpy" or not _worth_compiling(
                1, len(names), T, n):
            hit = self._hit_pass_numpy(values, names, active, T, n)
            streak = self._span_streak(hit, T, n)
        else:
            # fused compiled pass; the pre-span carry feeds the scan
            carry = np.zeros((1, n), dtype=np.int32) \
                if self._streak is None \
                else self._streak[None].astype(np.int32)
            hit, streak = self._detect_compiled(
                [values], names, active[None], carry, cfg, self.backend)
            hit, streak = hit[0], streak[0]
        self._streak = streak[-1].copy()

        rows, nodes = np.nonzero(streak == cfg.persistence)
        if len(rows) == 0:
            self._tick_offset += T
            return []

        alarms = self._attribute(ts, values, names, active, hit, rows, nodes)
        self._tick_offset += T
        self.n_alarms += len(alarms)
        return alarms

    def _attribute(self, ts, values, names, active, hit,
                   rows, nodes) -> List[Alarm]:
        """Pass 2: per-alarm metric attribution, restricted to the alarming
        ticks — recompute z on just those rows (row-sliced median/MAD is
        bit-identical).

        All alarming ticks are scored at once: metrics stack into
        (B, U, n) blocks (dtype-grouped, like pass 1) so one
        `robust_peer_z_block` call covers a whole group instead of one
        call per metric.  Candidate lists are still assembled in ``names``
        order, so the stable sort ties break exactly as the per-metric
        loop broke them.
        """
        cfg = self.config
        urows = np.unique(rows)
        pos = {int(r): i for i, r in enumerate(urows)}
        sub_active = active[urows]
        U, n = sub_active.shape

        # stacked z for every metric on just the alarming ticks, gathered
        # down to one (B, n_alarms) column matrix in metric-name order
        zcols = np.empty((len(names), len(rows)))
        arows = np.array([pos[int(r)] for r in rows])
        order = {name: b for b, name in enumerate(names)}
        block_n = max(_BLOCK_ELEMS // max(U * n, 1), 1)
        for group in _by_dtype(values, names).values():
            for i in range(0, len(group), block_n):
                chunk = group[i:i + block_n]
                block = np.stack([np.asarray(values[name])[urows]
                                  for name in chunk])
                z = robust_peer_z_block(block, sub_active)
                rows_idx = [order[name] for name in chunk]
                zcols[rows_idx] = z[:, arows, nodes]

        exceed = zcols > cfg.z_threshold
        exceed &= sub_active[arows, nodes][None, :]
        alarms = []
        for j, (r, node) in enumerate(zip(rows, nodes)):
            cand = np.nonzero(exceed[:, j])[0]
            # stable argsort on -z ties in metric-name order, exactly as
            # the per-metric append + stable sort resolved them
            best = cand[np.argsort(-zcols[cand, j], kind="stable")[:5]]
            metrics = [(names[b], float(zcols[b, j])) for b in best]
            alarms.append(Alarm(tick=self._tick_offset + int(r),
                                time_h=float(ts[r]), node=int(node),
                                n_signals=int(hit[r, node]),
                                top_metrics=metrics))
        return alarms

    # -- leading-seed-axis form (the batched campaign engine's path) ---------

    @classmethod
    def push_group(cls, detectors: "Sequence[StreamingDetector]",
                   ts_list: Sequence[np.ndarray],
                   values_list: Sequence[Dict[str, np.ndarray]],
                   ) -> List[List[Alarm]]:
        """Push S same-shape spans through S detectors in one stacked pass.

        ``values_list[i]`` is detector ``i``'s span (metric -> (T, n)); all
        spans must share (T, n) and the metric vocabulary — their tick
        *times* may differ (the z math never reads ``ts``; per-seed times
        only label the alarms).  Metrics are stacked to (S, B, T, n) blocks
        for pass 1, so a group of seeds costs one set of numpy calls
        instead of S.  Every per-element operation is independent of the
        stacking (`robust_peer_z_block` broadcasts over leading axes and
        selects medians row-wise), so each detector's alarms, carry state
        (activity row, streak) and tick offset advance bit-identically to
        S scalar ``push`` calls — the batched campaign engine's parity
        contract leans on exactly this.
        """
        S = len(detectors)
        if S == 1:
            return [detectors[0].push(ts_list[0], values_list[0])]
        cfg = detectors[0].config
        if any(d.config is not cfg and d.config != cfg for d in detectors):
            raise ValueError("push_group requires a shared DetectorConfig")
        backend = detectors[0].backend
        if any(d.backend != backend for d in detectors):
            raise ValueError("push_group requires a shared backend")
        names = [n for n in values_list[0] if n not in cfg.exclude_metrics]
        if len(ts_list[0]) == 0 or not names:
            return [d.push(t, v) for d, t, v in
                    zip(detectors, ts_list, values_list)]
        T, n = np.asarray(values_list[0][names[0]]).shape

        # activity with per-detector carry, stacked to (S, T, n)
        if cfg.activity_metric in values_list[0]:
            act_now = np.stack(
                [np.asarray(v[cfg.activity_metric]) > cfg.activity_threshold
                 for v in values_list])
            prev = np.stack(
                [d._prev_act if d._prev_act is not None else act_now[i, :1]
                 for i, d in enumerate(detectors)])
            active = np.concatenate([prev, act_now[:, :-1]], axis=1)
            for i, d in enumerate(detectors):
                d._prev_act = act_now[i, -1:].copy()
        else:
            active = np.ones((S, T, n), dtype=bool)
            for d in detectors:
                d._prev_act = active[0, -1:].copy()

        if backend == "numpy" or not _worth_compiling(S, len(names), T, n):
            # pass 1 on (S, B, T, n) blocks; same per-seed dtype grouping
            # and block budget as the scalar path (the grouping never
            # changes the per-metric math, only how many numpy calls)
            hit = np.zeros((S, T, n), dtype=np.int32)
            block_n = max(_BLOCK_ELEMS // max(T * n, 1), 1)
            act_b = active[:, None]               # (S, 1, T, n)
            for group in _by_dtype(values_list[0], names).values():
                for i in range(0, len(group), block_n):
                    block = np.stack(
                        [[np.asarray(v[name])
                          for name in group[i:i + block_n]]
                         for v in values_list])   # (S, B, T, n)
                    z = robust_peer_z_block(block, act_b)
                    hit += ((z > cfg.z_threshold) & act_b).sum(
                        axis=1, dtype=np.int32)

            # streak with per-detector carry, vectorized over the seed axis
            over = hit >= cfg.min_signals
            carry = np.stack(
                [d._streak if d._streak is not None
                 else np.zeros(n, dtype=np.int64) for d in detectors])
            idx = np.arange(1, T + 1, dtype=np.int64)[None, :, None]
            last_reset = np.maximum.accumulate(np.where(over, 0, idx),
                                               axis=1)
            streak = np.where(over, idx - last_reset, 0)
            streak += np.where(over & (last_reset == 0),
                               carry[:, None, :], 0)
        else:
            carry = np.stack(
                [d._streak.astype(np.int32) if d._streak is not None
                 else np.zeros(n, dtype=np.int32) for d in detectors])
            hit, streak = cls._detect_compiled(
                values_list, names, active, carry, cfg, backend)

        out: List[List[Alarm]] = []
        for i, d in enumerate(detectors):
            d._streak = streak[i, -1].copy()
            rows, nodes = np.nonzero(streak[i] == cfg.persistence)
            alarms = [] if len(rows) == 0 else d._attribute(
                ts_list[i], values_list[i], names, active[i], hit[i],
                rows, nodes)
            d._tick_offset += T
            d.n_alarms += len(alarms)
            out.append(alarms)
        return out
