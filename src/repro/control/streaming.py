"""Streaming precursor detection — the control plane's sensor.

``StreamingDetector`` is the incremental reformulation of
``PrecursorDetector.scan`` (paper F1 / §4.1): it consumes span-batched
telemetry *as the event engine emits it* and returns the alarms raised by
each span.  The per-tick math is unchanged — robust peer z-scores
(median/MAD across the active cohort), a multi-signal vote, and a
persistence streak — but the formulation is online:

* one vectorized numpy pass per pushed span (no full-store rescan), so the
  amortized cost of online detection equals one offline scan of the same
  window — the ``control_plane`` benchmark measures >=10x over rescanning
  the growing store at each span;
* O(n_nodes) carry state between spans: the previous tick's activity row
  (the peer cohort is "was running the SPMD workload at the previous
  scrape") and the per-node consecutive-hit streak.  Nothing else crosses
  span boundaries, which is what makes the reformulation exact;
* alarm attribution (``top_metrics``) runs as a second pass restricted to
  the alarming ticks, so the per-(tick, node) bookkeeping that dominated
  the offline scan is only paid where an alarm actually fired.

``PrecursorDetector.scan`` delegates to this class (one push of the whole
store), so the offline and online paths share one implementation and one
set of tests; the parity test asserts chunked pushes reproduce ``scan``'s
alarm list exactly.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.precursor import Alarm, DetectorConfig


def _nanmedian_rows(a: np.ndarray) -> np.ndarray:
    """Median over the last axis, ignoring NaNs; keepdims.

    NaNs (inactive peers) are mapped to +inf so they land past every valid
    entry; the median of the ``m`` valid values is then the midpoint pair
    of order statistics.  The cohort size ``m`` takes only a handful of
    distinct values per span (gang width, minus the occasional down node),
    so ``np.partition`` at that small ``kth`` set replaces a full sort.
    Unlike ``np.nanmedian`` (which drops into a per-row python path when
    NaNs are present) this stays fully vectorized, and it is the ONE
    median both the offline scan and the online detector evaluate — their
    parity is structural.  Partition and the sort fallback select the same
    order statistics, so results are identical either way.  All-NaN rows
    return NaN, as ``np.nanmedian`` would.
    """
    finite = ~np.isnan(a)
    m = np.maximum(finite.sum(axis=-1, keepdims=True), 1)
    k_lo, k_hi = (m - 1) // 2, m // 2
    filled = np.where(finite, a, np.inf)
    ks = np.unique(np.concatenate([k_lo.ravel(), k_hi.ravel()]))
    if len(ks) > 8:                      # pathological cohort variety
        s = np.sort(filled, axis=-1)
    else:
        s = np.partition(filled, list(ks), axis=-1)
    med = (np.take_along_axis(s, k_lo, axis=-1)
           + np.take_along_axis(s, k_hi, axis=-1)) / 2
    return np.where(finite.any(axis=-1, keepdims=True), med, np.nan)


def robust_peer_z_block(series: np.ndarray,
                        active: np.ndarray) -> np.ndarray:
    """|z| of every node vs its active peer cohort, per tick row.

    ``series``: (..., T, n_nodes) — a single metric or a stacked block of
    metrics sharing one dtype; ``active``: (T, n_nodes), broadcast over
    leading axes.  Median/MAD are computed over the active nodes of each
    row (the faulty node is <=1/N of the sample, so both are stable).
    Row-wise selection is independent of the stacking, so blocked and
    per-metric evaluation are bit-identical for a given dtype.
    """
    masked = np.where(active, series, np.nan)
    with np.errstate(all="ignore"), warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        med = _nanmedian_rows(masked)
        mad = _nanmedian_rows(np.abs(masked - med))
    med = np.nan_to_num(med)
    mad = np.nan_to_num(mad)
    scale = 1.4826 * mad
    floor = np.maximum(1e-12, 1e-6 * np.maximum(np.abs(med), 1.0))
    scale = np.where(scale < 1e-12, floor, scale)
    return np.abs((series - med) / scale)


# stacked-block budget for pass 1: bounds the transient (B, T, n) buffer
_BLOCK_ELEMS = 1 << 24


class StreamingDetector:
    """Online multi-signal detector over span-batched telemetry.

    Feed scrape spans in order via :meth:`push`; each call returns the
    alarms whose persistence streak completed inside that span.  Pushing a
    whole store in one call is exactly the offline scan.
    """

    def __init__(self, config: DetectorConfig = DetectorConfig()):
        self.config = config
        self._streak: Optional[np.ndarray] = None     # (n,) consecutive hits
        self._prev_act: Optional[np.ndarray] = None   # (1, n) last activity row
        self._tick_offset = 0                         # global tick index
        self.n_alarms = 0

    # -- state helpers ------------------------------------------------------

    def _activity(self, values: Dict[str, np.ndarray],
                  shape) -> np.ndarray:
        """Active cohort per tick: node ran the workload at the *previous*
        scrape (so the failure tick itself stays eligible).  The previous
        span's last row carries across the boundary."""
        cfg = self.config
        if cfg.activity_metric in values:
            act_now = np.asarray(values[cfg.activity_metric]) \
                > cfg.activity_threshold
            prev = self._prev_act if self._prev_act is not None \
                else act_now[:1]
            active = np.vstack([prev, act_now[:-1]])
            self._prev_act = act_now[-1:].copy()
        else:
            active = np.ones(shape, dtype=bool)
            self._prev_act = active[-1:].copy()
        return active

    # -- the one-pass-per-span core -----------------------------------------

    def push(self, ts: np.ndarray,
             values: Dict[str, np.ndarray]) -> List[Alarm]:
        """Consume one telemetry span; return the alarms it raised.

        ``ts``: (T,) scrape times in hours; ``values``: metric -> (T, n)
        arrays (a ``TimeSeriesStore`` snapshot slice or an
        ``ExporterSuite.tick_batch`` output).
        """
        cfg = self.config
        ts = np.asarray(ts, dtype=float)
        names = [n for n in values if n not in cfg.exclude_metrics]
        if len(ts) == 0 or not names:
            return []
        T, n = np.asarray(values[names[0]]).shape
        active = self._activity(values, (T, n))

        # pass 1: multi-signal vote.  Metrics are stacked into (B, T, n)
        # blocks — grouped by dtype so the stacked math stays bit-identical
        # to per-metric evaluation — which collapses the ~300 per-metric
        # numpy calls of a fine-grained online chunk into a handful
        hit = np.zeros((T, n), dtype=np.int32)
        by_dtype: Dict[np.dtype, List[str]] = {}
        for name in names:
            by_dtype.setdefault(np.asarray(values[name]).dtype,
                                []).append(name)
        block_n = max(_BLOCK_ELEMS // max(T * n, 1), 1)
        for group in by_dtype.values():
            for i in range(0, len(group), block_n):
                block = np.stack([np.asarray(values[name])
                                  for name in group[i:i + block_n]])
                z = robust_peer_z_block(block, active)
                hit += ((z > cfg.z_threshold) & active).sum(
                    axis=0, dtype=np.int32)

        # persistence streak with cross-span carry, vectorized:
        # streak[t] = (streak[t-1] + 1) * over[t]  ==  distance to the last
        # reset row, plus the carried-in streak while no reset has occurred
        over = hit >= cfg.min_signals
        carry = self._streak if self._streak is not None \
            else np.zeros(n, dtype=np.int64)
        idx = np.arange(1, T + 1, dtype=np.int64)[:, None]
        last_reset = np.maximum.accumulate(np.where(over, 0, idx), axis=0)
        streak = np.where(over, idx - last_reset, 0)
        streak += np.where(over & (last_reset == 0), carry[None, :], 0)
        self._streak = streak[-1].copy()

        rows, nodes = np.nonzero(streak == cfg.persistence)
        if len(rows) == 0:
            self._tick_offset += T
            return []

        alarms = self._attribute(ts, values, names, active, hit, rows, nodes)
        self._tick_offset += T
        self.n_alarms += len(alarms)
        return alarms

    def _attribute(self, ts, values, names, active, hit,
                   rows, nodes) -> List[Alarm]:
        """Pass 2: per-alarm metric attribution, restricted to the alarming
        ticks — recompute z on just those rows (row-sliced median/MAD is
        bit-identical)."""
        cfg = self.config
        urows = np.unique(rows)
        pos = {int(r): i for i, r in enumerate(urows)}
        sub_active = active[urows]
        top: Dict[int, List] = {j: [] for j in range(len(rows))}
        for name in names:
            series = np.asarray(values[name])[urows]
            z = robust_peer_z_block(series, sub_active)
            ex = (z > cfg.z_threshold) & sub_active
            for j, (r, node) in enumerate(zip(rows, nodes)):
                if ex[pos[int(r)], node]:
                    top[j].append((name, float(z[pos[int(r)], node])))

        alarms = []
        for j, (r, node) in enumerate(zip(rows, nodes)):
            metrics = sorted(top[j], key=lambda kv: -kv[1])[:5]
            alarms.append(Alarm(tick=self._tick_offset + int(r),
                                time_h=float(ts[r]), node=int(node),
                                n_signals=int(hit[r, node]),
                                top_metrics=metrics))
        return alarms

    # -- leading-seed-axis form (the batched campaign engine's path) ---------

    @classmethod
    def push_group(cls, detectors: "Sequence[StreamingDetector]",
                   ts_list: Sequence[np.ndarray],
                   values_list: Sequence[Dict[str, np.ndarray]],
                   ) -> List[List[Alarm]]:
        """Push S same-shape spans through S detectors in one stacked pass.

        ``values_list[i]`` is detector ``i``'s span (metric -> (T, n)); all
        spans must share (T, n) and the metric vocabulary — their tick
        *times* may differ (the z math never reads ``ts``; per-seed times
        only label the alarms).  Metrics are stacked to (S, B, T, n) blocks
        for pass 1, so a group of seeds costs one set of numpy calls
        instead of S.  Every per-element operation is independent of the
        stacking (`robust_peer_z_block` broadcasts over leading axes and
        selects medians row-wise), so each detector's alarms, carry state
        (activity row, streak) and tick offset advance bit-identically to
        S scalar ``push`` calls — the batched campaign engine's parity
        contract leans on exactly this.
        """
        S = len(detectors)
        if S == 1:
            return [detectors[0].push(ts_list[0], values_list[0])]
        cfg = detectors[0].config
        if any(d.config is not cfg and d.config != cfg for d in detectors):
            raise ValueError("push_group requires a shared DetectorConfig")
        names = [n for n in values_list[0] if n not in cfg.exclude_metrics]
        if len(ts_list[0]) == 0 or not names:
            return [d.push(t, v) for d, t, v in
                    zip(detectors, ts_list, values_list)]
        T, n = np.asarray(values_list[0][names[0]]).shape

        # activity with per-detector carry, stacked to (S, T, n)
        if cfg.activity_metric in values_list[0]:
            act_now = np.stack(
                [np.asarray(v[cfg.activity_metric]) > cfg.activity_threshold
                 for v in values_list])
            prev = np.stack(
                [d._prev_act if d._prev_act is not None else act_now[i, :1]
                 for i, d in enumerate(detectors)])
            active = np.concatenate([prev, act_now[:, :-1]], axis=1)
            for i, d in enumerate(detectors):
                d._prev_act = act_now[i, -1:].copy()
        else:
            active = np.ones((S, T, n), dtype=bool)
            for d in detectors:
                d._prev_act = active[0, -1:].copy()

        # pass 1 on (S, B, T, n) blocks; same per-seed dtype grouping and
        # block budget as the scalar path (the grouping never changes the
        # per-metric math, only how many numpy calls it takes)
        hit = np.zeros((S, T, n), dtype=np.int32)
        by_dtype: Dict[np.dtype, List[str]] = {}
        for name in names:
            by_dtype.setdefault(np.asarray(values_list[0][name]).dtype,
                                []).append(name)
        block_n = max(_BLOCK_ELEMS // max(T * n, 1), 1)
        act_b = active[:, None]                   # (S, 1, T, n)
        for group in by_dtype.values():
            for i in range(0, len(group), block_n):
                block = np.stack(
                    [[np.asarray(v[name]) for name in group[i:i + block_n]]
                     for v in values_list])       # (S, B, T, n)
                z = robust_peer_z_block(block, act_b)
                hit += ((z > cfg.z_threshold) & act_b).sum(
                    axis=1, dtype=np.int32)

        # streak with per-detector carry, vectorized over the seed axis
        over = hit >= cfg.min_signals
        carry = np.stack(
            [d._streak if d._streak is not None
             else np.zeros(n, dtype=np.int64) for d in detectors])
        idx = np.arange(1, T + 1, dtype=np.int64)[None, :, None]
        last_reset = np.maximum.accumulate(np.where(over, 0, idx), axis=1)
        streak = np.where(over, idx - last_reset, 0)
        streak += np.where(over & (last_reset == 0), carry[:, None, :], 0)

        out: List[List[Alarm]] = []
        for i, d in enumerate(detectors):
            d._streak = streak[i, -1].copy()
            rows, nodes = np.nonzero(streak[i] == cfg.persistence)
            alarms = [] if len(rows) == 0 else d._attribute(
                ts_list[i], values_list[i], names, active[i], hit[i],
                rows, nodes)
            d._tick_offset += T
            d.n_alarms += len(alarms)
            out.append(alarms)
        return out
