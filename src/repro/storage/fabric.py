"""Shared-NFS storage fabric — the cluster-scale side of paper F2 / §4.2.5.

The paper's headline cross-organizational result is a storage bottleneck
that is *absent in 2-4-node tests and only emerges at 60-node scale*:
restart loads reach 21.5% of the 700 GB/s aggregate read maximum, save
bursts 16.0% of the 250 GB/s write maximum, with NFS/RPC queueing and
transport backlog rising together.  A per-client slot-table model with
fixed service times cannot reproduce this — aggregate bandwidth would
scale linearly with node count — so this module models the *server* side:

N client RPC slot tables contend for one shared NFS server with

1. **finite service capacity** — all in-flight RPCs share the server's
   aggregate read/write bandwidth (processor sharing: an RPC of size S
   with C total in-flight takes ``S * C / server_bw`` to move its payload);
2. **fanin-dependent service inflation** — the server has a finite pool of
   RPC service contexts per op class; once total in-flight exceeds it,
   per-RPC queueing delay grows linearly with the excess (the paper's
   NFS/RPC queueing signal); and
3. **client transport floor** — a client draining ``slots`` concurrent
   RPCs can never exceed its own link, so per-RPC effective service is
   floored at ``slots * S / link_bw`` (the transport backlog regime).

The per-RPC *effective service time at fanin N* is therefore

    t_svc(N) = max(t_base + S*C/server_bw + t_q * max(0, C - ctx)/ctx,
                   slots * S / link_bw),          C = N * slots_per_client

and the scale-emergent collapse is *derived*: at 2-4 clients the model is
client-link-bound (near-linear aggregate scaling, high utilization of the
achievable ceiling); at 60+ clients the contention terms dominate and
aggregate bandwidth collapses to the paper's fractions.  The constants
below are calibrated so the paper's Table 13 per-RPC service times
*emerge* from the model (READ 27.3 ms at the 60-node restart-load fanin,
WRITE 126 ms at the ~39-node effective writeback fanin) and the 63-client
scenarios land on 21.5% / 16.0% aggregate utilization.

Two multi-client simulation engines share the service model:

* ``engine="vectorized"`` (default) — numpy wave schedule over ALL
  clients at once: each wave assigns the next ``slots`` jittered service
  draws to the least-loaded slots of every client ((n_clients, slots)
  array ops per wave instead of one Python heap op per RPC), tracking
  the greedy discrete-event schedule's makespan to within one service
  time per slot stream.
* ``engine="event"`` — the discrete-event reference (per-client min-heap
  over slot free times, one pop/push per RPC), kept for the parity check
  and the speedup benchmark.

``expected_duration_s`` / ``utilization`` are the deterministic analytic
queries the campaign simulation and scenario resolution use (no RNG).
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass
from typing import Dict, List, Literal, Optional, Sequence

import numpy as np

LINK_BW_BYTES = 25e9              # 200 Gbps RoCE per node

# fleet-standard client slot tables (paper: 128-slot RPC table; restart
# loads run over nconnect=2 mounts -> two tables)
STD_WRITE_SLOTS = 128
STD_READ_SLOTS = 256
STD_WSIZE = 1 << 20               # 1 MiB write RPCs
STD_RSIZE = 256 << 10             # 256 KiB effective read RPCs

Op = Literal["write", "read"]


@dataclass(frozen=True)
class FabricConfig:
    """Shared NFS server + transport parameters.

    The defaults are calibrated against the paper's published F2 numbers
    (see module docstring); ``degradation`` multiplies every service-time
    term (an overloaded/misbehaving backend), leaving the nominal
    aggregate maxima — the utilization denominators — untouched.
    """
    server_read_bw: float = 700e9        # aggregate read max (paper F2)
    server_write_bw: float = 250e9       # aggregate write max (paper F2)
    read_contexts: int = 2048            # server RPC service contexts, READ
    write_contexts: int = 512            # ... WRITE (stable-storage slots)
    t_base_read_s: float = 1.5e-3        # unloaded per-RPC server+net time
    t_base_write_s: float = 2.0e-3
    t_queue_read_s: float = 3.0e-3       # queueing delay per unit excess
    t_queue_write_s: float = 11.9e-3
    client_link_bw: float = LINK_BW_BYTES
    service_jitter: float = 0.15         # lognormal sigma (sim engines)
    degradation: float = 1.0             # service-time multiplier

    def op_params(self, op: Op):
        """(server_bw, contexts, t_base, t_queue) for one op class."""
        if op == "write":
            return (self.server_write_bw, self.write_contexts,
                    self.t_base_write_s, self.t_queue_write_s)
        if op == "read":
            return (self.server_read_bw, self.read_contexts,
                    self.t_base_read_s, self.t_queue_read_s)
        raise ValueError(f"unknown op {op!r}")


def _std_slots(op: Op) -> int:
    return STD_WRITE_SLOTS if op == "write" else STD_READ_SLOTS


def _std_rpc_bytes(op: Op) -> int:
    return STD_WSIZE if op == "write" else STD_RSIZE


@dataclass
class FabricTransferResult:
    """One multi-client transfer through the shared server."""
    op: str
    n_clients: int
    bytes_per_client: int
    n_rpcs_per_client: int
    engine: str
    duration_s: float                     # makespan across clients
    per_client_duration_s: np.ndarray
    mean_slot_wait_s: float
    mean_service_s: float
    ceiling_bytes_s: float                # min(n*link, server max)

    @property
    def total_bytes(self) -> int:
        return self.n_clients * self.bytes_per_client

    @property
    def aggregate_bandwidth_bytes_s(self) -> float:
        return self.total_bytes / self.duration_s if self.duration_s > 0 \
            else 0.0

    @property
    def utilization(self) -> float:
        """Achieved aggregate bandwidth over the achievable ceiling.

        The ceiling is ``min(n_clients * link_bw, server_max)`` — at 63
        clients that is the server's published maximum (the paper's 700 /
        250 GB/s denominators); at 2-4 clients it is the clients' own
        links, so near-linear small-scale runs score high and the
        60-node collapse scores the paper's fractions.
        """
        return self.aggregate_bandwidth_bytes_s / self.ceiling_bytes_s \
            if self.ceiling_bytes_s > 0 else 0.0


class StorageFabric:
    """N client slot tables contending for one shared NFS server."""

    def __init__(self, config: Optional[FabricConfig] = None):
        # per-instance default, not a shared default-argument instance
        self.config = config if config is not None else FabricConfig()

    # ------------------------------------------------------------------
    # analytic service model (deterministic; used by sim + campaign)
    # ------------------------------------------------------------------

    def service_time_s(self, op: Op, fanin: int,
                       slots_per_client: Optional[int] = None,
                       rpc_bytes: Optional[int] = None) -> float:
        """Effective per-RPC service time with ``fanin`` concurrent clients."""
        cfg = self.config
        slots = slots_per_client if slots_per_client is not None \
            else _std_slots(op)
        size = rpc_bytes if rpc_bytes is not None else _std_rpc_bytes(op)
        server_bw, ctx, t_base, t_queue = cfg.op_params(op)
        inflight = max(int(fanin), 1) * slots
        t = t_base + size * inflight / server_bw \
            + t_queue * max(0, inflight - ctx) / ctx
        t *= cfg.degradation
        # transport floor: `slots` in flight cannot drain faster than the
        # client link (backlog accumulates in the TCP transmit queue)
        return max(t, slots * size / cfg.client_link_bw)

    def per_client_bandwidth_bytes_s(self, op: Op, fanin: int,
                                     slots_per_client: Optional[int] = None,
                                     rpc_bytes: Optional[int] = None) -> float:
        slots = slots_per_client if slots_per_client is not None \
            else _std_slots(op)
        size = rpc_bytes if rpc_bytes is not None else _std_rpc_bytes(op)
        return slots * size / self.service_time_s(op, fanin, slots, size)

    def ceiling_bytes_s(self, op: Op, n_clients: int) -> float:
        server_bw, _, _, _ = self.config.op_params(op)
        return min(n_clients * self.config.client_link_bw, server_bw)

    def utilization(self, op: Op, n_clients: int,
                    slots_per_client: Optional[int] = None,
                    rpc_bytes: Optional[int] = None) -> float:
        """Aggregate achieved bandwidth over the achievable ceiling."""
        agg = n_clients * self.per_client_bandwidth_bytes_s(
            op, n_clients, slots_per_client, rpc_bytes)
        return agg / self.ceiling_bytes_s(op, n_clients)

    def expected_duration_s(self, op: Op, n_clients: int,
                            bytes_per_client: int,
                            slots_per_client: Optional[int] = None,
                            rpc_bytes: Optional[int] = None) -> float:
        """Deterministic transfer duration (mean over service jitter)."""
        slots = slots_per_client if slots_per_client is not None \
            else _std_slots(op)
        size = rpc_bytes if rpc_bytes is not None else _std_rpc_bytes(op)
        n_rpcs = max(int(np.ceil(bytes_per_client / size)), 1)
        t_svc = self.service_time_s(op, n_clients, slots, size)
        jmean = float(np.exp(self.config.service_jitter ** 2 / 2.0))
        # a transfer can never beat one RPC service time: a final partial
        # wave (n_rpcs < slots) still costs a full service round
        return max(n_rpcs / slots, 1.0) * t_svc * jmean

    def scaling_curve(self, op: Op, node_counts: Sequence[int] = (
            2, 4, 8, 16, 32, 63)) -> List[Dict[str, float]]:
        """The F2 deliverable: aggregate bandwidth vs node count."""
        rows = []
        for n in node_counts:
            bw = n * self.per_client_bandwidth_bytes_s(op, n)
            rows.append({
                "nodes": int(n),
                "service_ms": self.service_time_s(op, n) * 1e3,
                "aggregate_gbs": bw / 1e9,
                "utilization": bw / self.ceiling_bytes_s(op, n),
            })
        return rows

    # ------------------------------------------------------------------
    # telemetry levels (exported by the registry during save/load spans)
    # ------------------------------------------------------------------

    def telemetry_levels(self, fanin: int) -> Dict[str, float]:
        """Characteristic per-client RPC queue depth / transport backlog
        while a save or load is in flight at ``fanin`` (steady state:
        every slot busy plus this client's share of the server queue;
        degraded service holds requests in queue proportionally longer,
        so the detector sees degraded campaigns deviate)."""
        cfg = self.config
        out: Dict[str, float] = {}
        for op, tag in (("write", "save"), ("read", "load")):
            slots = _std_slots(op)
            _, ctx, _, _ = cfg.op_params(op)
            inflight = max(int(fanin), 1) * slots
            depth = slots + cfg.degradation * max(0, inflight - ctx) \
                / max(int(fanin), 1)
            out[f"{tag}_queue_depth"] = float(depth)
            out[f"{tag}_backlog_bytes"] = float(depth * _std_rpc_bytes(op))
        # network-degradation windows: a latency/loss window multiplies a
        # client's RPC service times the way ``cfg.degradation`` does, so
        # its ambient (non-burst) traffic queues proportionally deeper.
        # These are the per-unit-severity telemetry deltas the exporter
        # overlays on an affected node (~25% of the burst-level queue:
        # background NFS traffic vs a full checkpoint load)
        amb = 0.25 * out["load_queue_depth"]
        out["degrade_queue_depth"] = float(amb)
        out["degrade_backlog_bytes"] = float(amb * _std_rpc_bytes("read"))
        return out

    # ------------------------------------------------------------------
    # multi-client simulation
    # ------------------------------------------------------------------

    def simulate(self, op: Op, n_clients: int, bytes_per_client: int, *,
                 slots_per_client: Optional[int] = None,
                 rpc_bytes: Optional[int] = None,
                 engine: str = "vectorized",
                 seed: int = 0) -> FabricTransferResult:
        """Simulate all ``n_clients`` bursting ``bytes_per_client`` at t=0.

        Both engines draw per-RPC lognormal jitter around the shared
        effective service time at fanin ``n_clients``; they differ only in
        the slot schedule (numpy wave balancing vs greedy min-heap), which
        agree on duration to within the jitter noise floor.
        """
        if engine not in ("vectorized", "event"):
            raise ValueError(f"unknown engine {engine!r}")
        slots = slots_per_client if slots_per_client is not None \
            else _std_slots(op)
        size = rpc_bytes if rpc_bytes is not None else _std_rpc_bytes(op)
        n_rpcs = max(int(np.ceil(bytes_per_client / size)), 1)
        t_svc = self.service_time_s(op, n_clients, slots, size)
        sigma = self.config.service_jitter

        if engine == "vectorized":
            rng = np.random.default_rng(seed)
            durations, mean_wait, mean_service = _clients_vectorized(
                rng, n_clients, n_rpcs, slots, t_svc, sigma)
        else:
            durations = np.empty(n_clients)
            waits = np.empty(n_clients)
            services = np.empty(n_clients)
            for c in range(n_clients):
                rng = np.random.default_rng((seed, c))
                d, w, s = _client_event(rng, n_rpcs, slots, t_svc, sigma)
                durations[c], waits[c], services[c] = d, w, s
            mean_wait = float(waits.mean())
            mean_service = float(services.mean())

        return FabricTransferResult(
            op=op, n_clients=n_clients, bytes_per_client=bytes_per_client,
            n_rpcs_per_client=n_rpcs, engine=engine,
            duration_s=float(durations.max()),
            per_client_duration_s=durations,
            mean_slot_wait_s=mean_wait,
            mean_service_s=mean_service,
            ceiling_bytes_s=self.ceiling_bytes_s(op, n_clients))

    # convenience views -------------------------------------------------

    def replace(self, **kw) -> "StorageFabric":
        return StorageFabric(dataclasses.replace(self.config, **kw))


def _draw_services(rng, n_rpcs: int, t_svc: float, sigma: float) -> np.ndarray:
    if sigma <= 0:
        return np.full(n_rpcs, t_svc)
    return t_svc * rng.lognormal(mean=0.0, sigma=sigma, size=n_rpcs)


def _clients_vectorized(rng, n_clients, n_rpcs, slots, t_svc, sigma):
    """Wave-balanced slot schedule for ALL clients as array ops.

    Per wave, the next ``slots`` RPCs of every client go to that client's
    least-loaded slots ((n_clients, slots) argsort + take, one numpy pass
    per wave instead of one Python heap op per RPC).  Greedy min-heap
    scheduling hands each RPC to the globally least-loaded slot; pairing
    a whole wave against the load-sorted slot vector keeps the per-slot
    load spread bounded by a single service time, so the makespan matches
    the event reference to O(t_svc) — a ~1/waves relative error.
    """
    loads = np.zeros((n_clients, slots))
    wait_sum = np.zeros(n_clients)
    svc_sum = 0.0
    remaining = n_rpcs
    while remaining > 0:
        k = min(slots, remaining)
        remaining -= k
        svc = _draw_services(rng, n_clients * k, t_svc, sigma) \
            .reshape(n_clients, k)
        # LPT pairing: largest service onto the least-loaded slot keeps the
        # per-slot load spread compressed to <= one service time, matching
        # the greedy heap's continuously-rebalanced schedule
        svc = -np.sort(-svc, axis=1)
        order = np.argsort(loads, axis=1)[:, :k]     # least-loaded slots
        starts = np.take_along_axis(loads, order, axis=1)
        wait_sum += starts.sum(axis=1)               # arrival t=0: wait=start
        np.put_along_axis(loads, order, starts + svc, axis=1)
        svc_sum += float(svc.sum())
    durations = loads.max(axis=1)
    return durations, float(wait_sum.mean() / n_rpcs), \
        svc_sum / (n_clients * n_rpcs)


def _client_event(rng, n_rpcs, slots, t_svc, sigma):
    """Discrete-event reference: greedy min-heap over slot free times."""
    services = _draw_services(rng, n_rpcs, t_svc, sigma)
    heap = [0.0] * slots
    heapq.heapify(heap)
    end = 0.0
    wait_sum = 0.0
    for i in range(n_rpcs):
        t_slot = heapq.heappop(heap)
        wait_sum += t_slot                  # arrival t=0
        fin = t_slot + services[i]
        heapq.heappush(heap, fin)
        end = max(end, fin)
    return float(end), wait_sum / n_rpcs, float(services.mean())
