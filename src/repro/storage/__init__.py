"""Cluster-scale storage fabric: N NFS clients sharing one server.

``StorageFabric`` derives the paper's scale-emergent F2 bottleneck
(near-linear aggregate bandwidth at 2-4 nodes, collapse to 21.5% read /
16.0% write utilization at 60-node scale) from finite server service
capacity, fanin-dependent service inflation, and transport backlog.  The
per-client checkpoint view (`repro.checkpoint.storage`), the campaign
simulation (`repro.core.cluster`), and the scenario engine
(`repro.ops`) all consume it.
"""
from repro.storage.fabric import (LINK_BW_BYTES, STD_READ_SLOTS, STD_RSIZE,
                                  STD_WRITE_SLOTS, STD_WSIZE, FabricConfig,
                                  FabricTransferResult, StorageFabric)

__all__ = [
    "FabricConfig", "StorageFabric", "FabricTransferResult",
    "LINK_BW_BYTES", "STD_WRITE_SLOTS", "STD_READ_SLOTS",
    "STD_WSIZE", "STD_RSIZE",
]
