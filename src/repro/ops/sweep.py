"""Vectorized campaign sweeps: N seeds x M scenarios -> F1-F4 comparison.

`SweepRunner` fans campaigns out over a `concurrent.futures` executor
(process pool by default — each campaign is an independent, seeded
simulation), computes the paper's four findings per campaign, aggregates
across seeds, and renders a markdown comparison report next to the paper's
published numbers.

The per-campaign worker is a module-level function (`run_campaign`) taking
plain dicts, so specs pickle across process boundaries and results are
deterministic for fixed (scenario, seed) regardless of executor choice.

Monte Carlo mode: ``SweepRunner(scenarios, mc_seeds=256)`` replaces the
one-process-per-seed fan-out with one `BatchedCampaignEngine` pass per
scenario — hundreds of seeds in a single stacked-numpy simulation, with
per-seed findings identical to the pool path (the engine's parity
contract).  At >=8 seeds the report grows distributional columns
(median / IQR / 95% CI of the mean) for the F1-F4 findings and the
proactive-vs-reactive goodput delta, which is the point: headline numbers
from one 73-day trajectory are point estimates; the Monte Carlo layer
reports how wide they actually are.
"""
from __future__ import annotations

import concurrent.futures
import os
import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core.cluster import ClusterSim
from repro.core.failures import CORRELATED_KINDS, INFRA_KINDS
from repro.core.retry import chain_stats
from repro.ops.scenario import Scenario, get_scenario

# distributional statistics (median/IQR/CI columns, paired goodput
# deltas, what-if service answers) render from this many seeds up —
# below it, quartiles of a handful of campaigns would be noise dressed
# as rigor.  Shared by the report sections and `repro.serve`.
MIN_DIST_SEEDS = 8

# paper headline values, shown as the reference row of every report
PAPER_REFERENCE = {
    "occupancy": 0.966,            # §3 training occupancy
    "f1_detection_rate": 1.0,      # 10/10 at-XID detection
    "f1_pre_xid_rate": 0.2,        # 2/10 pre-XID
    "f1_fp_per_day": 0.84,
    "f2_load_util": 0.215,         # restart-load share of 700 GB/s read max
    "f2_save_util": 0.160,         # save-burst share of 250 GB/s write max
    "f3_top3_share": 0.50,         # >50% of exclusions on 3 nodes
    "f4_success_rate": 0.333,      # auto-retry chain success
    "f4_gap_median_min": 11.0,     # inter-session gap
    "f4_auto_downtime_h": 1.9,
    "f4_manual_downtime_h": 3.3,
}


# ---------------------------------------------------------------------------
# per-campaign worker (module-level: must pickle for ProcessPoolExecutor)
# ---------------------------------------------------------------------------

def _top_switch_share(failures) -> float:
    """Share of switch_degrade events landing on the busiest switch (same
    bincount arithmetic as the batched engine's `_findings`)."""
    sw = [f.switch for f in failures if f.kind == "switch_degrade"]
    if not sw:
        return 0.0
    return float(np.bincount(np.asarray(sw)).max() / len(sw))


def compute_findings(res) -> Dict[str, Optional[float]]:
    """F2-F4 metrics (plus campaign health) from one CampaignResult."""
    st = chain_stats(res.retry_chains())
    excl = res.exclusions.summary()
    # drain episodes are controlled handoffs, not recovery downtime — keep
    # the F4 medians comparable with the paper's reactive measurements
    autos = [d["hours"] for d in res.downtimes
             if d["auto"] and d.get("kind") != "drain"]
    mans = [d["hours"] for d in res.downtimes
            if not d["auto"] and d.get("kind") != "drain"]
    out = {
        "occupancy": res.training_occupancy(),
        "goodput": res.goodput(),
        "n_failures": float(len(res.failures)),
        "n_sessions": float(len(res.sessions)),
        "ckpt_events": float(res.checkpoint_events),
        "mean_lost_h": float(np.mean(res.lost_hours))
        if res.lost_hours else 0.0,
        "f3_top3_share": excl["top3_share"],
        "f3_deliberate_fraction": excl["deliberate_fraction"],
        "f4_n_chains": float(st["n_chains"]),
        "f4_n_attempts": float(st["n_attempts"]),
        "f4_success_rate": st["chain_success_rate"],
        "f4_gap_median_min": st["gap_median_min"],
        "f4_auto_downtime_h": float(np.median(autos)) if autos else None,
        "f4_manual_downtime_h": float(np.median(mans)) if mans else None,
        # infra fault band: degrade-don't-kill events and the effective
        # hours their windows ate (always present, 0.0 without the band)
        "infra_n_events": float(sum(1 for f in res.failures
                                    if f.kind in INFRA_KINDS)),
        "infra_degraded_h": float(np.sum(res.degraded_hours)),
        # correlated fault band: event count and switch concentration (the
        # share of switch_degrade events on the busiest leaf switch — F3 at
        # rack granularity; 0.0 without the band)
        "corr_n_events": float(sum(1 for f in res.failures
                                   if f.kind in CORRELATED_KINDS)),
        "corr_top_switch_share": _top_switch_share(res.failures),
    }
    if res.control is not None:
        ctl = res.control.summarize(res.failures, res.duration_h)
        out.update({f"ctrl_{k}": v for k, v in ctl.items()})
        drain_excl = res.exclusions.by_reason().get("predictive drain")
        out["ctrl_drain_excl_events"] = \
            float(drain_excl["count"]) if drain_excl else 0.0
    return out


def _f1_findings(scenario: Scenario, seed: int) -> Dict[str, float]:
    """F1 precursor metrics from a telemetry-on sub-campaign.

    Full-length telemetry at 30 s x ~300 metrics x n_nodes does not fit in
    memory for 73-day sweeps, so F1 runs on a shorter window
    (``scenario.telemetry_days``); detection and FP rates are per-day
    quantities, so the window length only affects their variance.  The
    full ~305-metric registry is scraped by default (~0.5 GB per 2-day
    campaign, one campaign in flight per pool worker) — set
    ``scenario.telemetry_pad_metrics`` to shrink it for wide sweeps, at
    the cost of FP-rate fidelity.
    """
    from repro.core.precursor import (DetectorConfig, PrecursorDetector,
                                      evaluate)
    # the F1 sub-campaign is an offline scan over a retained store; the
    # online control plane (which discards spans) is disabled for it
    sub = scenario.replace(duration_days=scenario.telemetry_days,
                           telemetry=True, control_plane=False)
    res = ClusterSim(sub.to_campaign_config(seed)).run()
    xid_fails = [f for f in res.failures if f.kind == "xid"]
    # the offline scan is the same pass-1 hot loop the fast path serves:
    # the scenario's backend switch covers it too (alarms identical)
    alarms = PrecursorDetector(
        DetectorConfig(), backend=scenario.detector_backend).scan(res.store)
    ev = evaluate(alarms, xid_fails, res.duration_h)
    # windows with no XID event cannot score detection (None -> skipped in
    # aggregation); the FP rate is meaningful either way
    has_events = ev.n_failures > 0
    return {
        "f1_n_failures": float(ev.n_failures),
        "f1_detection_rate": ev.detection_rate if has_events else None,
        "f1_pre_xid_rate": ev.pre_xid_rate if has_events else None,
        "f1_fp_per_day": ev.fp_per_day,
    }


def _f2_findings(scenario: Scenario) -> Dict[str, float]:
    """F2 storage metrics: aggregate utilization at the gang fanin plus the
    fabric-derived save/restart-read durations (deterministic queries)."""
    fab = scenario.fabric()
    n = scenario.job_nodes
    wslots = scenario.storage_slots
    rslots = 2 * scenario.storage_slots        # nconnect=2 load path
    wire = int((scenario.ckpt_bytes_per_node or 20 << 30)
               * scenario.ckpt_wire_ratio)
    return {
        "f2_load_util": fab.utilization("read", n, rslots),
        "f2_save_util": fab.utilization("write", n, wslots),
        "f2_load_agg_gbs": n * fab.per_client_bandwidth_bytes_s(
            "read", n, rslots) / 1e9,
        "f2_save_agg_gbs": n * fab.per_client_bandwidth_bytes_s(
            "write", n, wslots) / 1e9,
        "f2_save_s": fab.expected_duration_s(
            "write", n, wire, slots_per_client=wslots),
        "f2_restart_read_s": fab.expected_duration_s(
            "read", n, scenario.restore_bytes_per_node,
            slots_per_client=rslots),
    }


def run_campaign(scenario_dict: dict, seed: int) -> dict:
    """Run one (scenario, seed) campaign and return its findings dict."""
    scenario = Scenario.from_dict(scenario_dict)
    t0 = time.perf_counter()
    res = ClusterSim(scenario.to_campaign_config(seed)).run()
    findings = compute_findings(res)
    if scenario.storage_fabric:
        findings.update(_f2_findings(scenario))
    if scenario.telemetry_days > 0:
        findings.update(_f1_findings(scenario, seed))
    findings["wall_s"] = time.perf_counter() - t0
    return {"scenario": scenario.name, "seed": seed, "findings": findings}


# ---------------------------------------------------------------------------
# distribution extraction (shared by the report and the what-if service)
# ---------------------------------------------------------------------------

def findings_distribution(per_seed: Sequence[Dict[str, Optional[float]]]
                          ) -> Dict[str, dict]:
    """metric -> distribution stats over one stack of per-seed findings.

    Each entry carries ``n``, ``mean``, ``median``, ``q25``/``q75`` (the
    IQR) and a normal-approximation 95% CI of the mean (``ci_lo``/
    ``ci_hi``; degenerate at n=1).  ``None`` values (metric not
    applicable for that seed) are skipped; non-numeric metrics are
    dropped.  This is the single extraction both `SweepResult.
    distribution()` (per scenario) and the what-if service (per stacked
    engine pass) run, so a served answer and a report cell computed from
    the same findings are the same numbers.
    """
    keys = sorted({k for f in per_seed for k in f})
    stats: Dict[str, dict] = {}
    for k in keys:
        vals = [f[k] for f in per_seed if f.get(k) is not None]
        if not vals or not all(
                isinstance(v, (int, float)) for v in vals):
            continue
        a = np.asarray(vals, dtype=float)
        mean = float(a.mean())
        if len(a) > 1:
            half = 1.96 * float(a.std(ddof=1)) / np.sqrt(len(a))
        else:
            half = 0.0
        stats[k] = {
            "n": len(a),
            "mean": mean,
            "median": float(np.median(a)),
            "q25": float(np.percentile(a, 25)),
            "q75": float(np.percentile(a, 75)),
            "ci_lo": mean - half,
            "ci_hi": mean + half,
        }
    return stats


# ---------------------------------------------------------------------------
# sweep runner
# ---------------------------------------------------------------------------

@dataclass
class SweepOutcome:
    scenario: str
    seed: int
    findings: Dict[str, Optional[float]]


@dataclass
class SweepResult:
    scenarios: List[Scenario]
    seeds: List[int]
    outcomes: List[SweepOutcome]
    wall_s: float = 0.0

    def aggregate(self) -> Dict[str, Dict[str, float]]:
        """scenario -> metric -> mean over seeds (None values skipped)."""
        out: Dict[str, Dict[str, float]] = {}
        for sc in self.scenarios:
            per = [o.findings for o in self.outcomes if o.scenario == sc.name]
            keys = sorted({k for f in per for k in f})
            agg = {}
            for k in keys:
                vals = [f[k] for f in per if f.get(k) is not None]
                agg[k] = float(np.mean(vals)) if vals else None
            out[sc.name] = agg
        return out

    def distribution(self) -> Dict[str, Dict[str, dict]]:
        """scenario -> metric -> distribution stats over seeds
        (see :func:`findings_distribution` for the per-metric entries)."""
        out: Dict[str, Dict[str, dict]] = {}
        for sc in self.scenarios:
            per = [o.findings for o in self.outcomes if o.scenario == sc.name]
            out[sc.name] = findings_distribution(per)
        return out

    # -- rendering ----------------------------------------------------------

    _COLUMNS = [
        ("occupancy", "occ %", lambda v: f"{v*100:.1f}"),
        ("goodput", "goodput %", lambda v: f"{v*100:.1f}"),
        ("n_failures", "fails", lambda v: f"{v:.0f}"),
        ("f1_detection_rate", "F1 det %", lambda v: f"{v*100:.0f}"),
        ("f1_fp_per_day", "F1 fp/d", lambda v: f"{v:.2f}"),
        ("f2_load_util", "F2 load %", lambda v: f"{v*100:.1f}"),
        ("f2_save_util", "F2 save %", lambda v: f"{v*100:.1f}"),
        ("f3_top3_share", "F3 top3 %", lambda v: f"{v*100:.0f}"),
        ("f4_n_chains", "F4 chains", lambda v: f"{v:.1f}"),
        ("f4_success_rate", "F4 succ %", lambda v: f"{v*100:.0f}"),
        ("f4_gap_median_min", "gap min", lambda v: f"{v:.0f}"),
        ("f4_auto_downtime_h", "auto dt h", lambda v: f"{v:.1f}"),
        ("f4_manual_downtime_h", "manual dt h", lambda v: f"{v:.1f}"),
        ("infra_degraded_h", "deg h", lambda v: f"{v:.1f}"),
        ("corr_top_switch_share", "corr sw %", lambda v: f"{v*100:.0f}"),
    ]

    def comparison_rows(self) -> List[List[str]]:
        agg = self.aggregate()
        header = ["scenario"] + [label for _, label, _ in self._COLUMNS]
        rows = [header]
        for sc in self.scenarios:
            row = [sc.name]
            for key, _, fmt in self._COLUMNS:
                v = agg[sc.name].get(key)
                row.append(fmt(v) if v is not None else "—")
            rows.append(row)
        ref = ["paper"]
        for key, _, fmt in self._COLUMNS:
            v = PAPER_REFERENCE.get(key)
            ref.append(fmt(v) if v is not None else "—")
        rows.append(ref)
        return rows

    def comparison_table(self) -> str:
        """Plain-text table (also valid GitHub markdown)."""
        rows = self.comparison_rows()
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        def line(r):
            return "| " + " | ".join(c.ljust(w) for c, w in zip(r, widths)) \
                + " |"
        sep = "|" + "|".join("-" * (w + 2) for w in widths) + "|"
        return "\n".join([line(rows[0]), sep] + [line(r) for r in rows[1:]])

    def to_markdown(self) -> str:
        n_campaigns = len(self.outcomes)
        parts = [
            "# Scenario sweep report",
            "",
            f"{len(self.scenarios)} scenarios x {len(self.seeds)} seeds = "
            f"{n_campaigns} campaigns, wall time {self.wall_s:.1f} s "
            f"({self.wall_s / max(n_campaigns, 1):.2f} s/campaign).",
            "",
            "## F1-F4 comparison (mean over seeds)",
            "",
            self.comparison_table(),
            "",
            "`—` = not applicable (F1 columns need `telemetry_days > 0`; "
            "F2 columns need `storage_fabric=True`; downtime columns need "
            "at least one episode of that kind).",
            "",
        ]
        parts += self._distribution_section()
        parts += self._f2_section()
        parts += self._control_section()
        parts += [
            "## Scenarios",
            "",
        ]
        for sc in self.scenarios:
            parts.append(f"- **{sc.name}** ({sc.duration_days:.0f} d, "
                         f"{sc.n_nodes} nodes): {sc.description}")
        parts += [
            "",
            "## Paper reference",
            "",
            "F1: 10/10 detection, 2/10 pre-XID, 0.84 FP/day (Table 9). "
            "F3: >50% of exclusions on 3 nodes (Figs 11-13). "
            "F4: 33.3% auto-retry chain success vs 12.5% manual, 11 min "
            "median gap, 1.9 h vs 3.3 h median downtime (Table 14, "
            "Figs 16-17).",
            "",
        ]
        return "\n".join(parts)

    # findings that get distributional columns (metric, label, scale, fmt);
    # F2 columns are deterministic fabric queries — identical across seeds
    _DIST_COLUMNS = [
        ("occupancy", "occ %", 100.0, "{:.1f}"),
        ("goodput", "goodput %", 100.0, "{:.1f}"),
        ("f1_detection_rate", "F1 det %", 100.0, "{:.0f}"),
        ("f1_fp_per_day", "F1 fp/d", 1.0, "{:.2f}"),
        ("f3_top3_share", "F3 top3 %", 100.0, "{:.0f}"),
        ("f4_success_rate", "F4 succ %", 100.0, "{:.0f}"),
        ("f4_gap_median_min", "F4 gap min", 1.0, "{:.1f}"),
        ("f4_auto_downtime_h", "auto dt h", 1.0, "{:.2f}"),
        ("f4_manual_downtime_h", "manual dt h", 1.0, "{:.2f}"),
        ("infra_degraded_h", "deg h", 1.0, "{:.2f}"),
        ("corr_top_switch_share", "corr sw %", 100.0, "{:.0f}"),
        ("ctrl_ttd_h", "TTD h", 1.0, "{:.2f}"),
        ("ctrl_false_drains", "false drains", 1.0, "{:.1f}"),
        ("ctrl_switch_attr_rate", "sw attr %", 100.0, "{:.0f}"),
    ]

    # distributional columns render from this many seeds up — the shared
    # module-level cutoff (kept as a class attribute for back-compat)
    MIN_SEEDS_FOR_DISTRIBUTION = MIN_DIST_SEEDS

    @staticmethod
    def _dist_cell(st: Optional[dict], scale: float, fmt: str) -> str:
        if st is None:
            return "—"
        med = fmt.format(st["median"] * scale)
        q25 = fmt.format(st["q25"] * scale)
        q75 = fmt.format(st["q75"] * scale)
        half = fmt.format((st["ci_hi"] - st["ci_lo"]) / 2 * scale)
        return f"{med} [{q25}, {q75}] ±{half}"

    def _distribution_section(self) -> List[str]:
        """Median / IQR / 95%-CI columns over the seed axis — the
        distributional form of the F1-F4 findings that the Monte Carlo
        mode exists to produce."""
        if len(self.seeds) < self.MIN_SEEDS_FOR_DISTRIBUTION:
            return []
        dist = self.distribution()
        cols = [c for c in self._DIST_COLUMNS
                if any(c[0] in dist[sc.name] for sc in self.scenarios)]
        if not cols:
            return []
        parts = [
            f"## Distributional findings ({len(self.seeds)} seeds)",
            "",
            "Cells are `median [q25, q75] ±half-width` of the normal-"
            "approximation 95% CI of the mean.  The paper's headline "
            "numbers are single-trajectory point estimates; these columns "
            "say how wide each one actually is across seeds.",
            "",
            "| scenario | " + " | ".join(label for _, label, _, _ in cols)
            + " |",
            "|---" * (len(cols) + 1) + "|",
        ]
        for sc in self.scenarios:
            row = [sc.name]
            for key, _, scale, fmt in cols:
                row.append(self._dist_cell(dist[sc.name].get(key),
                                           scale, fmt))
            parts.append("| " + " | ".join(row) + " |")
        parts.append("")
        return parts

    def _f2_section(self) -> List[str]:
        """Bandwidth-vs-node-count curves for fabric-backed scenarios: the
        paper's scale-emergent F2 phenomenon, derived — near-linear at 2-4
        nodes, collapsed to 21.5% read / 16.0% write at 60-node scale."""
        fab_scenarios = [sc for sc in self.scenarios if sc.storage_fabric]
        if not fab_scenarios:
            return []
        parts = ["## F2 storage fabric: aggregate bandwidth vs node count",
                 ""]
        for sc in fab_scenarios:
            fab = sc.fabric()
            parts.append(f"**{sc.name}** (server max "
                         f"{sc.storage_server_read_gbs:.0f}/"
                         f"{sc.storage_server_write_gbs:.0f} GB/s r/w):")
            parts.append("")
            parts.append("| nodes | read GB/s | read util | write GB/s | "
                         "write util |")
            parts.append("|---|---|---|---|---|")
            reads = fab.scaling_curve("read")
            writes = fab.scaling_curve("write")
            for r, w in zip(reads, writes):
                parts.append(
                    f"| {r['nodes']} | {r['aggregate_gbs']:.0f} | "
                    f"{r['utilization']*100:.1f}% | "
                    f"{w['aggregate_gbs']:.0f} | "
                    f"{w['utilization']*100:.1f}% |")
            parts.append("")
        parts.append("Paper F2: restart loads 21.5% of the 700 GB/s read "
                     "max, save bursts 16.0% of the 250 GB/s write max at "
                     "60-node scale; 2-4-node tests show none of this.")
        parts.append("")
        return parts

    # Scenario fields that a control preset legitimately differs from its
    # reactive twin on — everything else must match for a goodput delta to
    # be attributable to the control plane rather than config drift
    _CONTROL_ONLY_FIELDS = frozenset({
        "name", "description", "control_plane", "control_urgent_checkpoint",
        "control_drain", "control_drain_confirm_alarms",
        "control_alarm_memory_h", "log_channel", "blast_radius_aware",
        "telemetry", "telemetry_store", "telemetry_pad_metrics",
    })

    def _reactive_twin(self, ctl_sc: Scenario) -> Optional[Scenario]:
        """The non-control scenario in this sweep whose config matches
        ``ctl_sc`` on every axis the control plane doesn't own — the only
        baseline whose goodput delta isolates the control plane."""
        want = {k: v for k, v in ctl_sc.to_dict().items()
                if k not in self._CONTROL_ONLY_FIELDS}
        for sc in self.scenarios:
            if sc.control_plane:
                continue
            have = {k: v for k, v in sc.to_dict().items()
                    if k not in self._CONTROL_ONLY_FIELDS}
            if have == want:
                return sc
        return None

    def _control_section(self) -> List[str]:
        """Detection->recovery ledger for control-plane scenarios: goodput
        vs the config-matched reactive baseline on identical failure
        schedules, plus the counterfactual accounting (lost-work hours
        avoided per true positive, urgent-save hours wasted per false
        positive)."""
        agg = self.aggregate()
        ctl_scenarios = [sc for sc in self.scenarios
                         if agg[sc.name].get("ctrl_n_alarms") is not None]
        if not ctl_scenarios:
            return []
        parts = ["## Detection -> recovery (control plane)", ""]
        parts.append("Δ goodput is shown only against a config-matched "
                     "non-control scenario in this sweep (identical "
                     "failure schedules, same seeds); `—` means no such "
                     "baseline was swept.  At >= "
                     f"{self.MIN_SEEDS_FOR_DISTRIBUTION} seeds the Δ is "
                     "the paired per-seed distribution: `mean±CI95 "
                     "[q25, q75]`.")
        parts.append("")
        per_seed = {(o.scenario, o.seed): o.findings
                    for o in self.outcomes}
        parts.append("| scenario | goodput % | Δ goodput h (vs) | alarms | "
                      "TP | FP/day | urgent saves | saved h/TP | "
                      "wasted h/FP | drains | crashes dodged | "
                      "log alarms | TTD h | false drains |")
        parts.append("|---|---|---|---|---|---|---|---|---|---|---|"
                     "---|---|---|")

        def cell(a, key, fmt):
            v = a.get(key)
            return fmt.format(v) if v is not None else "—"

        for sc in ctl_scenarios:
            a = agg[sc.name]
            baseline = self._reactive_twin(sc)
            deltas = []
            if baseline is not None:
                hours = sc.duration_days * 24.0
                for seed in self.seeds:
                    g_ctl = per_seed.get((sc.name, seed), {}).get("goodput")
                    g_rea = per_seed.get((baseline.name, seed),
                                         {}).get("goodput")
                    if g_ctl is not None and g_rea is not None:
                        deltas.append((g_ctl - g_rea) * hours)
            if deltas:
                mean = float(np.mean(deltas))
                if len(deltas) >= self.MIN_SEEDS_FOR_DISTRIBUTION:
                    half = 1.96 * float(np.std(deltas, ddof=1)) \
                        / np.sqrt(len(deltas))
                    q25, q75 = (q + 0.0 for q          # -0.0 -> 0.0
                                in np.percentile(deltas, [25, 75]))
                    delta_s = (f"{mean:+.1f}±{half:.1f} "
                               f"[{q25:+.1f}, {q75:+.1f}] "
                               f"({baseline.name})")
                else:
                    delta_s = f"{mean:+.1f} ({baseline.name})"
            else:
                delta_s = "—"
            parts.append(
                f"| {sc.name} | {cell(a, 'goodput', '{:.1%}')} | {delta_s} | "
                f"{cell(a, 'ctrl_n_alarms', '{:.0f}')} | "
                f"{cell(a, 'ctrl_tp', '{:.1f}')} | "
                f"{cell(a, 'ctrl_fp_per_day', '{:.2f}')} | "
                f"{cell(a, 'ctrl_n_urgent_saves', '{:.0f}')} | "
                f"{cell(a, 'ctrl_avoided_per_tp_h', '{:.2f}')} | "
                f"{cell(a, 'ctrl_wasted_per_fp_h', '{:.3f}')} | "
                f"{cell(a, 'ctrl_n_drains', '{:.1f}')} | "
                f"{cell(a, 'ctrl_failures_avoided', '{:.1f}')} | "
                f"{cell(a, 'ctrl_n_log_alarms', '{:.0f}')} | "
                f"{cell(a, 'ctrl_ttd_h', '{:.2f}')} | "
                f"{cell(a, 'ctrl_false_drains', '{:.1f}')} |")
        parts += [
            "",
            "Urgent checkpoints are trajectory-preserving (accounting at "
            "the alarm time, priced like a regular gang-fanin save), so "
            "their goodput delta is exactly `lost-work avoided − save time "
            "spent`.  Predictive drains change the trajectory: a true "
            "positive dodges the crash (and its retry chain) for the price "
            "of a controlled restart; a false positive burns the restart "
            "and a spare for the recheck window.",
            "",
            "`log alarms` counts alarms originating from the log channel "
            "(L4 template/burst verdicts; zero unless `log_channel` is "
            "on).  `TTD h` is the median time-to-detection from fault "
            "onset (precursor start / window open) to the first alarm on "
            "the fault's node; `false drains` counts executed drains with "
            "no fault activity near the drained node.  Compare "
            "`log-fusion` against `log-fusion-off` for the log channel's "
            "deltas.",
            "",
        ]
        return parts

    def write(self, path) -> str:
        md = self.to_markdown()
        with open(path, "w") as f:
            f.write(md)
        return md


class SweepRunner:
    """Runs M scenarios x N seeds and aggregates findings.

    ``executor``: "process" (default — campaigns are CPU-bound pure Python/
    numpy), "thread", or "serial" (in-process, deterministic ordering, used
    by tests).

    ``mc_seeds``: Monte Carlo mode.  ``mc_seeds=N`` overrides ``seeds``
    with ``range(N)`` and routes every scenario through one
    `BatchedCampaignEngine` pass instead of one executor task per seed —
    the per-seed findings are identical (the engine's parity contract),
    the wall clock is a fraction, and the report's distributional columns
    light up.  The F1 telemetry sub-campaigns (``telemetry_days > 0``)
    stay per-seed — a retained 30 s x ~300-metric store per seed is
    memory-bound, not compute-bound — so Monte Carlo sweeps are designed
    for the F2-F4 + goodput findings first.

    ``wavefront_backend``: how Monte Carlo campaigns simulate.  "auto"
    (default) stacks every control-free scenario with the same node count
    into ONE compiled device pass (`run_findings_grid`) when the lane
    count clears the compiled floor, and falls back to the numpy engine
    otherwise; "numpy" forces the stacked-numpy wavefront everywhere;
    "xla"/"pallas" force the compiled core for every eligible scenario
    (control-plane scenarios still run numpy — the sweep mixes presets,
    so an eligibility error would make the flag unusable).  Findings are
    bitwise identical across all of these.
    """

    def __init__(self, scenarios: Sequence[Union[Scenario, str]],
                 seeds: Iterable[int] = (0, 1, 2),
                 max_workers: Optional[int] = None,
                 executor: str = "process",
                 mc_seeds: Optional[int] = None,
                 wavefront_backend: str = "auto"):
        self.scenarios = [get_scenario(s) if isinstance(s, str) else s
                          for s in scenarios]
        names = [s.name for s in self.scenarios]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate scenario names: {names}")
        self.seeds = list(range(mc_seeds)) if mc_seeds is not None \
            else list(seeds)
        self.mc_seeds = mc_seeds
        self.max_workers = max_workers
        if executor not in ("process", "thread", "serial"):
            raise ValueError(f"unknown executor {executor!r}")
        self.executor = executor
        if wavefront_backend not in ("auto", "numpy", "xla", "pallas"):
            raise ValueError(
                f"unknown wavefront backend {wavefront_backend!r}")
        self.wavefront_backend = wavefront_backend

    def run(self) -> SweepResult:
        if self.mc_seeds is not None:
            return self._run_mc()
        tasks = [(sc.to_dict(), seed)
                 for sc in self.scenarios for seed in self.seeds]
        t0 = time.perf_counter()
        if self.executor == "serial":
            raw = [run_campaign(d, s) for d, s in tasks]
        else:
            pool_cls = concurrent.futures.ProcessPoolExecutor \
                if self.executor == "process" \
                else concurrent.futures.ThreadPoolExecutor
            workers = self.max_workers or min(len(tasks),
                                              os.cpu_count() or 1)
            with pool_cls(max_workers=workers) as pool:
                futs = [pool.submit(run_campaign, d, s) for d, s in tasks]
                raw = [f.result() for f in futs]
        wall = time.perf_counter() - t0
        order = {sc.name: i for i, sc in enumerate(self.scenarios)}
        outcomes = sorted(
            (SweepOutcome(r["scenario"], r["seed"], r["findings"])
             for r in raw),
            key=lambda o: (order[o.scenario], o.seed))
        return SweepResult(scenarios=self.scenarios, seeds=self.seeds,
                           outcomes=outcomes, wall_s=wall)

    def _grid_pass(self) -> Dict[int, List[dict]]:
        """Whole-sweep wavefront: stack every eligible (scenario, seed)
        lane of the Monte Carlo sweep into single compiled device passes
        (one per node count — gang masks share the node axis) and return
        ``scenario_index -> per-seed findings`` for the covered subset."""
        backend = self.wavefront_backend
        if backend == "numpy":
            return {}
        try:
            from repro.kernels.common import WAVEFRONT_MIN_SEEDS
            from repro.kernels.wavefront import compiled_eligible
            from repro.kernels.wavefront.ops import run_findings_grid
        except ImportError:              # no jax: auto degrades to numpy
            if backend != "auto":
                raise
            return {}
        cfgs = [sc.to_campaign_config(0) for sc in self.scenarios]
        groups: Dict[int, List[int]] = {}
        for i, cfg in enumerate(cfgs):
            if compiled_eligible(cfg):
                groups.setdefault(cfg.n_nodes, []).append(i)
        dev = "xla" if backend == "auto" else backend
        out: Dict[int, List[dict]] = {}
        t_g = time.perf_counter()
        for idxs in groups.values():
            if backend == "auto" \
                    and len(idxs) * len(self.seeds) < WAVEFRONT_MIN_SEEDS:
                continue                 # too few lanes to beat numpy
            per_cfg = run_findings_grid([cfgs[i] for i in idxs],
                                        self.seeds, backend=dev)
            for j, i in enumerate(idxs):
                out[i] = per_cfg[j]
        self._grid_per_campaign = (time.perf_counter() - t_g) \
            / max(len(out) * len(self.seeds), 1)
        return out

    def _run_mc(self) -> SweepResult:
        """Monte Carlo path: one stacked pass per scenario — through the
        whole-sweep compiled grid where eligible, the batched numpy
        engine otherwise (identical findings either way)."""
        from repro.core.batch import BatchedCampaignEngine
        t0 = time.perf_counter()
        grid = self._grid_pass()
        eng_backend = "numpy" if self.wavefront_backend == "numpy" \
            else "auto"
        outcomes: List[SweepOutcome] = []
        for si, sc in enumerate(self.scenarios):
            t_sc = time.perf_counter()
            if si in grid:
                findings_list = grid[si]
            else:
                engine = BatchedCampaignEngine(
                    sc.to_campaign_config(0),
                    wavefront_backend=eng_backend)
                findings_list = engine.run_findings(self.seeds)
            f2 = _f2_findings(sc) if sc.storage_fabric else None
            for seed, findings in zip(self.seeds, findings_list):
                if f2:
                    findings.update(f2)
                if sc.telemetry_days > 0:
                    findings.update(_f1_findings(sc, seed))
                outcomes.append(SweepOutcome(sc.name, seed, findings))
            # shared average, stamped after the (possibly F1-dominated)
            # per-seed work so it matches what the pool path reports;
            # grid-covered scenarios add their share of the device pass
            per_campaign = (time.perf_counter() - t_sc) \
                / max(len(self.seeds), 1)
            if si in grid:
                per_campaign += self._grid_per_campaign
            for findings in findings_list:
                findings["wall_s"] = per_campaign
        wall = time.perf_counter() - t0
        return SweepResult(scenarios=self.scenarios, seeds=self.seeds,
                           outcomes=outcomes, wall_s=wall)
