"""Vectorized campaign sweeps: N seeds x M scenarios -> F1-F4 comparison.

`SweepRunner` fans campaigns out over a `concurrent.futures` executor
(process pool by default — each campaign is an independent, seeded
simulation), computes the paper's four findings per campaign, aggregates
across seeds, and renders a markdown comparison report next to the paper's
published numbers.

The per-campaign worker is a module-level function (`run_campaign`) taking
plain dicts, so specs pickle across process boundaries and results are
deterministic for fixed (scenario, seed) regardless of executor choice.
"""
from __future__ import annotations

import concurrent.futures
import os
import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core.cluster import ClusterSim
from repro.core.retry import chain_stats
from repro.ops.scenario import Scenario, get_scenario

# paper headline values, shown as the reference row of every report
PAPER_REFERENCE = {
    "occupancy": 0.966,            # §3 training occupancy
    "f1_detection_rate": 1.0,      # 10/10 at-XID detection
    "f1_pre_xid_rate": 0.2,        # 2/10 pre-XID
    "f1_fp_per_day": 0.84,
    "f2_load_util": 0.215,         # restart-load share of 700 GB/s read max
    "f2_save_util": 0.160,         # save-burst share of 250 GB/s write max
    "f3_top3_share": 0.50,         # >50% of exclusions on 3 nodes
    "f4_success_rate": 0.333,      # auto-retry chain success
    "f4_gap_median_min": 11.0,     # inter-session gap
    "f4_auto_downtime_h": 1.9,
    "f4_manual_downtime_h": 3.3,
}


# ---------------------------------------------------------------------------
# per-campaign worker (module-level: must pickle for ProcessPoolExecutor)
# ---------------------------------------------------------------------------

def compute_findings(res) -> Dict[str, Optional[float]]:
    """F2-F4 metrics (plus campaign health) from one CampaignResult."""
    st = chain_stats(res.retry_chains())
    excl = res.exclusions.summary()
    # drain episodes are controlled handoffs, not recovery downtime — keep
    # the F4 medians comparable with the paper's reactive measurements
    autos = [d["hours"] for d in res.downtimes
             if d["auto"] and d.get("kind") != "drain"]
    mans = [d["hours"] for d in res.downtimes
            if not d["auto"] and d.get("kind") != "drain"]
    out = {
        "occupancy": res.training_occupancy(),
        "goodput": res.goodput(),
        "n_failures": float(len(res.failures)),
        "n_sessions": float(len(res.sessions)),
        "ckpt_events": float(res.checkpoint_events),
        "mean_lost_h": float(np.mean(res.lost_hours))
        if res.lost_hours else 0.0,
        "f3_top3_share": excl["top3_share"],
        "f3_deliberate_fraction": excl["deliberate_fraction"],
        "f4_n_chains": float(st["n_chains"]),
        "f4_n_attempts": float(st["n_attempts"]),
        "f4_success_rate": st["chain_success_rate"],
        "f4_gap_median_min": st["gap_median_min"],
        "f4_auto_downtime_h": float(np.median(autos)) if autos else None,
        "f4_manual_downtime_h": float(np.median(mans)) if mans else None,
    }
    if res.control is not None:
        ctl = res.control.summarize(res.failures, res.duration_h)
        out.update({f"ctrl_{k}": v for k, v in ctl.items()})
        drain_excl = res.exclusions.by_reason().get("predictive drain")
        out["ctrl_drain_excl_events"] = \
            float(drain_excl["count"]) if drain_excl else 0.0
    return out


def _f1_findings(scenario: Scenario, seed: int) -> Dict[str, float]:
    """F1 precursor metrics from a telemetry-on sub-campaign.

    Full-length telemetry at 30 s x ~300 metrics x n_nodes does not fit in
    memory for 73-day sweeps, so F1 runs on a shorter window
    (``scenario.telemetry_days``); detection and FP rates are per-day
    quantities, so the window length only affects their variance.  The
    full ~305-metric registry is scraped by default (~0.5 GB per 2-day
    campaign, one campaign in flight per pool worker) — set
    ``scenario.telemetry_pad_metrics`` to shrink it for wide sweeps, at
    the cost of FP-rate fidelity.
    """
    from repro.core.precursor import (DetectorConfig, PrecursorDetector,
                                      evaluate)
    # the F1 sub-campaign is an offline scan over a retained store; the
    # online control plane (which discards spans) is disabled for it
    sub = scenario.replace(duration_days=scenario.telemetry_days,
                           telemetry=True, control_plane=False)
    res = ClusterSim(sub.to_campaign_config(seed)).run()
    xid_fails = [f for f in res.failures if f.kind == "xid"]
    alarms = PrecursorDetector(DetectorConfig()).scan(res.store)
    ev = evaluate(alarms, xid_fails, res.duration_h)
    # windows with no XID event cannot score detection (None -> skipped in
    # aggregation); the FP rate is meaningful either way
    has_events = ev.n_failures > 0
    return {
        "f1_n_failures": float(ev.n_failures),
        "f1_detection_rate": ev.detection_rate if has_events else None,
        "f1_pre_xid_rate": ev.pre_xid_rate if has_events else None,
        "f1_fp_per_day": ev.fp_per_day,
    }


def _f2_findings(scenario: Scenario) -> Dict[str, float]:
    """F2 storage metrics: aggregate utilization at the gang fanin plus the
    fabric-derived save/restart-read durations (deterministic queries)."""
    fab = scenario.fabric()
    n = scenario.job_nodes
    wslots = scenario.storage_slots
    rslots = 2 * scenario.storage_slots        # nconnect=2 load path
    wire = int((scenario.ckpt_bytes_per_node or 20 << 30)
               * scenario.ckpt_wire_ratio)
    return {
        "f2_load_util": fab.utilization("read", n, rslots),
        "f2_save_util": fab.utilization("write", n, wslots),
        "f2_load_agg_gbs": n * fab.per_client_bandwidth_bytes_s(
            "read", n, rslots) / 1e9,
        "f2_save_agg_gbs": n * fab.per_client_bandwidth_bytes_s(
            "write", n, wslots) / 1e9,
        "f2_save_s": fab.expected_duration_s(
            "write", n, wire, slots_per_client=wslots),
        "f2_restart_read_s": fab.expected_duration_s(
            "read", n, scenario.restore_bytes_per_node,
            slots_per_client=rslots),
    }


def run_campaign(scenario_dict: dict, seed: int) -> dict:
    """Run one (scenario, seed) campaign and return its findings dict."""
    scenario = Scenario.from_dict(scenario_dict)
    t0 = time.perf_counter()
    res = ClusterSim(scenario.to_campaign_config(seed)).run()
    findings = compute_findings(res)
    if scenario.storage_fabric:
        findings.update(_f2_findings(scenario))
    if scenario.telemetry_days > 0:
        findings.update(_f1_findings(scenario, seed))
    findings["wall_s"] = time.perf_counter() - t0
    return {"scenario": scenario.name, "seed": seed, "findings": findings}


# ---------------------------------------------------------------------------
# sweep runner
# ---------------------------------------------------------------------------

@dataclass
class SweepOutcome:
    scenario: str
    seed: int
    findings: Dict[str, Optional[float]]


@dataclass
class SweepResult:
    scenarios: List[Scenario]
    seeds: List[int]
    outcomes: List[SweepOutcome]
    wall_s: float = 0.0

    def aggregate(self) -> Dict[str, Dict[str, float]]:
        """scenario -> metric -> mean over seeds (None values skipped)."""
        out: Dict[str, Dict[str, float]] = {}
        for sc in self.scenarios:
            per = [o.findings for o in self.outcomes if o.scenario == sc.name]
            keys = sorted({k for f in per for k in f})
            agg = {}
            for k in keys:
                vals = [f[k] for f in per if f.get(k) is not None]
                agg[k] = float(np.mean(vals)) if vals else None
            out[sc.name] = agg
        return out

    # -- rendering ----------------------------------------------------------

    _COLUMNS = [
        ("occupancy", "occ %", lambda v: f"{v*100:.1f}"),
        ("goodput", "goodput %", lambda v: f"{v*100:.1f}"),
        ("n_failures", "fails", lambda v: f"{v:.0f}"),
        ("f1_detection_rate", "F1 det %", lambda v: f"{v*100:.0f}"),
        ("f1_fp_per_day", "F1 fp/d", lambda v: f"{v:.2f}"),
        ("f2_load_util", "F2 load %", lambda v: f"{v*100:.1f}"),
        ("f2_save_util", "F2 save %", lambda v: f"{v*100:.1f}"),
        ("f3_top3_share", "F3 top3 %", lambda v: f"{v*100:.0f}"),
        ("f4_n_chains", "F4 chains", lambda v: f"{v:.1f}"),
        ("f4_success_rate", "F4 succ %", lambda v: f"{v*100:.0f}"),
        ("f4_gap_median_min", "gap min", lambda v: f"{v:.0f}"),
        ("f4_auto_downtime_h", "auto dt h", lambda v: f"{v:.1f}"),
        ("f4_manual_downtime_h", "manual dt h", lambda v: f"{v:.1f}"),
    ]

    def comparison_rows(self) -> List[List[str]]:
        agg = self.aggregate()
        header = ["scenario"] + [label for _, label, _ in self._COLUMNS]
        rows = [header]
        for sc in self.scenarios:
            row = [sc.name]
            for key, _, fmt in self._COLUMNS:
                v = agg[sc.name].get(key)
                row.append(fmt(v) if v is not None else "—")
            rows.append(row)
        ref = ["paper"]
        for key, _, fmt in self._COLUMNS:
            v = PAPER_REFERENCE.get(key)
            ref.append(fmt(v) if v is not None else "—")
        rows.append(ref)
        return rows

    def comparison_table(self) -> str:
        """Plain-text table (also valid GitHub markdown)."""
        rows = self.comparison_rows()
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        def line(r):
            return "| " + " | ".join(c.ljust(w) for c, w in zip(r, widths)) \
                + " |"
        sep = "|" + "|".join("-" * (w + 2) for w in widths) + "|"
        return "\n".join([line(rows[0]), sep] + [line(r) for r in rows[1:]])

    def to_markdown(self) -> str:
        n_campaigns = len(self.outcomes)
        parts = [
            "# Scenario sweep report",
            "",
            f"{len(self.scenarios)} scenarios x {len(self.seeds)} seeds = "
            f"{n_campaigns} campaigns, wall time {self.wall_s:.1f} s "
            f"({self.wall_s / max(n_campaigns, 1):.2f} s/campaign).",
            "",
            "## F1-F4 comparison (mean over seeds)",
            "",
            self.comparison_table(),
            "",
            "`—` = not applicable (F1 columns need `telemetry_days > 0`; "
            "F2 columns need `storage_fabric=True`; downtime columns need "
            "at least one episode of that kind).",
            "",
        ]
        parts += self._f2_section()
        parts += self._control_section()
        parts += [
            "## Scenarios",
            "",
        ]
        for sc in self.scenarios:
            parts.append(f"- **{sc.name}** ({sc.duration_days:.0f} d, "
                         f"{sc.n_nodes} nodes): {sc.description}")
        parts += [
            "",
            "## Paper reference",
            "",
            "F1: 10/10 detection, 2/10 pre-XID, 0.84 FP/day (Table 9). "
            "F3: >50% of exclusions on 3 nodes (Figs 11-13). "
            "F4: 33.3% auto-retry chain success vs 12.5% manual, 11 min "
            "median gap, 1.9 h vs 3.3 h median downtime (Table 14, "
            "Figs 16-17).",
            "",
        ]
        return "\n".join(parts)

    def _f2_section(self) -> List[str]:
        """Bandwidth-vs-node-count curves for fabric-backed scenarios: the
        paper's scale-emergent F2 phenomenon, derived — near-linear at 2-4
        nodes, collapsed to 21.5% read / 16.0% write at 60-node scale."""
        fab_scenarios = [sc for sc in self.scenarios if sc.storage_fabric]
        if not fab_scenarios:
            return []
        parts = ["## F2 storage fabric: aggregate bandwidth vs node count",
                 ""]
        for sc in fab_scenarios:
            fab = sc.fabric()
            parts.append(f"**{sc.name}** (server max "
                         f"{sc.storage_server_read_gbs:.0f}/"
                         f"{sc.storage_server_write_gbs:.0f} GB/s r/w):")
            parts.append("")
            parts.append("| nodes | read GB/s | read util | write GB/s | "
                         "write util |")
            parts.append("|---|---|---|---|---|")
            reads = fab.scaling_curve("read")
            writes = fab.scaling_curve("write")
            for r, w in zip(reads, writes):
                parts.append(
                    f"| {r['nodes']} | {r['aggregate_gbs']:.0f} | "
                    f"{r['utilization']*100:.1f}% | "
                    f"{w['aggregate_gbs']:.0f} | "
                    f"{w['utilization']*100:.1f}% |")
            parts.append("")
        parts.append("Paper F2: restart loads 21.5% of the 700 GB/s read "
                     "max, save bursts 16.0% of the 250 GB/s write max at "
                     "60-node scale; 2-4-node tests show none of this.")
        parts.append("")
        return parts

    # Scenario fields that a control preset legitimately differs from its
    # reactive twin on — everything else must match for a goodput delta to
    # be attributable to the control plane rather than config drift
    _CONTROL_ONLY_FIELDS = frozenset({
        "name", "description", "control_plane", "control_urgent_checkpoint",
        "control_drain", "control_drain_confirm_alarms",
        "control_alarm_memory_h", "telemetry", "telemetry_store",
        "telemetry_pad_metrics",
    })

    def _reactive_twin(self, ctl_sc: Scenario) -> Optional[Scenario]:
        """The non-control scenario in this sweep whose config matches
        ``ctl_sc`` on every axis the control plane doesn't own — the only
        baseline whose goodput delta isolates the control plane."""
        want = {k: v for k, v in ctl_sc.to_dict().items()
                if k not in self._CONTROL_ONLY_FIELDS}
        for sc in self.scenarios:
            if sc.control_plane:
                continue
            have = {k: v for k, v in sc.to_dict().items()
                    if k not in self._CONTROL_ONLY_FIELDS}
            if have == want:
                return sc
        return None

    def _control_section(self) -> List[str]:
        """Detection->recovery ledger for control-plane scenarios: goodput
        vs the config-matched reactive baseline on identical failure
        schedules, plus the counterfactual accounting (lost-work hours
        avoided per true positive, urgent-save hours wasted per false
        positive)."""
        agg = self.aggregate()
        ctl_scenarios = [sc for sc in self.scenarios
                         if agg[sc.name].get("ctrl_n_alarms") is not None]
        if not ctl_scenarios:
            return []
        parts = ["## Detection -> recovery (control plane)", ""]
        parts.append("Δ goodput is shown only against a config-matched "
                     "non-control scenario in this sweep (identical "
                     "failure schedules, same seeds); `—` means no such "
                     "baseline was swept.")
        parts.append("")
        parts.append("| scenario | goodput % | Δ goodput h (vs) | alarms | "
                      "TP | FP/day | urgent saves | saved h/TP | "
                      "wasted h/FP | drains | crashes dodged |")
        parts.append("|---|---|---|---|---|---|---|---|---|---|---|")

        def cell(a, key, fmt):
            v = a.get(key)
            return fmt.format(v) if v is not None else "—"

        for sc in ctl_scenarios:
            a = agg[sc.name]
            baseline = self._reactive_twin(sc)
            if baseline is not None \
                    and agg[baseline.name].get("goodput") is not None \
                    and a.get("goodput") is not None:
                delta = (a["goodput"] - agg[baseline.name]["goodput"]) \
                    * sc.duration_days * 24.0
                delta_s = f"{delta:+.1f} ({baseline.name})"
            else:
                delta_s = "—"
            parts.append(
                f"| {sc.name} | {cell(a, 'goodput', '{:.1%}')} | {delta_s} | "
                f"{cell(a, 'ctrl_n_alarms', '{:.0f}')} | "
                f"{cell(a, 'ctrl_tp', '{:.1f}')} | "
                f"{cell(a, 'ctrl_fp_per_day', '{:.2f}')} | "
                f"{cell(a, 'ctrl_n_urgent_saves', '{:.0f}')} | "
                f"{cell(a, 'ctrl_avoided_per_tp_h', '{:.2f}')} | "
                f"{cell(a, 'ctrl_wasted_per_fp_h', '{:.3f}')} | "
                f"{cell(a, 'ctrl_n_drains', '{:.1f}')} | "
                f"{cell(a, 'ctrl_failures_avoided', '{:.1f}')} |")
        parts += [
            "",
            "Urgent checkpoints are trajectory-preserving (accounting at "
            "the alarm time, priced like a regular gang-fanin save), so "
            "their goodput delta is exactly `lost-work avoided − save time "
            "spent`.  Predictive drains change the trajectory: a true "
            "positive dodges the crash (and its retry chain) for the price "
            "of a controlled restart; a false positive burns the restart "
            "and a spare for the recheck window.",
            "",
        ]
        return parts

    def write(self, path) -> str:
        md = self.to_markdown()
        with open(path, "w") as f:
            f.write(md)
        return md


class SweepRunner:
    """Runs M scenarios x N seeds and aggregates findings.

    ``executor``: "process" (default — campaigns are CPU-bound pure Python/
    numpy), "thread", or "serial" (in-process, deterministic ordering, used
    by tests).
    """

    def __init__(self, scenarios: Sequence[Union[Scenario, str]],
                 seeds: Iterable[int] = (0, 1, 2),
                 max_workers: Optional[int] = None,
                 executor: str = "process"):
        self.scenarios = [get_scenario(s) if isinstance(s, str) else s
                          for s in scenarios]
        names = [s.name for s in self.scenarios]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate scenario names: {names}")
        self.seeds = list(seeds)
        self.max_workers = max_workers
        if executor not in ("process", "thread", "serial"):
            raise ValueError(f"unknown executor {executor!r}")
        self.executor = executor

    def run(self) -> SweepResult:
        tasks = [(sc.to_dict(), seed)
                 for sc in self.scenarios for seed in self.seeds]
        t0 = time.perf_counter()
        if self.executor == "serial":
            raw = [run_campaign(d, s) for d, s in tasks]
        else:
            pool_cls = concurrent.futures.ProcessPoolExecutor \
                if self.executor == "process" \
                else concurrent.futures.ThreadPoolExecutor
            workers = self.max_workers or min(len(tasks),
                                              os.cpu_count() or 1)
            with pool_cls(max_workers=workers) as pool:
                futs = [pool.submit(run_campaign, d, s) for d, s in tasks]
                raw = [f.result() for f in futs]
        wall = time.perf_counter() - t0
        order = {sc.name: i for i, sc in enumerate(self.scenarios)}
        outcomes = sorted(
            (SweepOutcome(r["scenario"], r["seed"], r["findings"])
             for r in raw),
            key=lambda o: (order[o.scenario], o.seed))
        return SweepResult(scenarios=self.scenarios, seeds=self.seeds,
                           outcomes=outcomes, wall_s=wall)
