"""Operational scenario engine: declarative campaign specs + batched sweeps.

``Scenario`` composes a failure mix, a retry policy, a checkpoint strategy,
and a storage model into a named, serializable campaign spec;
``SweepRunner`` fans N seeds x M scenarios out over worker processes and
aggregates the paper's F1-F4 findings into comparison tables.
"""
from repro.ops.scenario import (PRESETS, Scenario, get_scenario,
                                list_scenarios)
from repro.ops.sweep import (MIN_DIST_SEEDS, SweepOutcome, SweepResult,
                             SweepRunner, findings_distribution,
                             run_campaign)

__all__ = [
    "Scenario", "PRESETS", "get_scenario", "list_scenarios",
    "SweepRunner", "SweepResult", "SweepOutcome", "run_campaign",
    "MIN_DIST_SEEDS", "findings_distribution",
]
