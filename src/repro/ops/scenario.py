"""Declarative campaign scenarios.

A ``Scenario`` is the single front door for "what if the campaign had
looked different": it composes the failure mix (MTBF + category tilts +
hot-node skew), the auto-retry policy (paper-faithful FIXED, §4.3.5
EXP_BACKOFF / XID_BRANCH / structural-stop), the checkpoint strategy
(observed fixed interval vs Young-Daly optimum), and the storage model
(NFS RPC-slot simulation driving save/load times) into one named,
serializable spec that resolves to a `CampaignConfig`.

Presets cover the paper's own campaign plus the what-if corners the
ROADMAP asks for; ``Scenario.to_dict`` / ``from_dict`` round-trip so sweeps
can ship specs across process boundaries (and users can keep them in JSON).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.checkpoint.storage import NFSClientSim, NFSConfig
from repro.checkpoint.youngdaly import MTBF_H_PAPER, t_opt_s
from repro.control.policy import ControlConfig
from repro.core.cluster import CampaignConfig
from repro.core.failures import FAILURE_CATEGORIES
from repro.core.retry import RetryConfig, RetryPolicy
from repro.storage.fabric import FabricConfig, StorageFabric


@dataclass
class Scenario:
    """One named operational what-if, resolvable to a `CampaignConfig`."""

    name: str
    description: str = ""

    # -- cluster shape ------------------------------------------------------
    n_nodes: int = 63
    job_nodes: int = 60
    duration_days: float = 73.0

    # -- failure model ------------------------------------------------------
    mtbf_h: float = MTBF_H_PAPER
    hot_fraction: float = 0.05
    hot_weight: float = 0.55
    # category -> multiplicative tilt on the paper's Table 2 mix
    # (nvlink | ecc | dropout | exec | app | unreachable | fail_slow)
    kind_weights: Optional[Dict[str, float]] = None

    # -- retry policy -------------------------------------------------------
    retry_policy: str = "fixed"           # fixed | exp_backoff | xid_branch
    retry_enabled: bool = True
    max_retries: int = 30
    retry_delay_min: float = 10.0
    structural_stop: bool = False         # §4.3.5 improvement 3

    # -- checkpoint strategy ------------------------------------------------
    checkpoint_strategy: str = "fixed"    # fixed | young_daly
    checkpoint_interval_h: float = 2.23   # used when strategy == "fixed"
    checkpoint_delta_s: float = 18.0      # save duration (4K-phase paper value)
    # when set, the save duration is *derived* from the NFS RPC-slot model
    # instead of taken from ``checkpoint_delta_s``
    ckpt_bytes_per_node: Optional[int] = None
    ckpt_wire_ratio: float = 0.5          # ckpt_pack fp32->bf16 wire volume
                                          #   (1.0 models pack="xor")

    # -- storage model ------------------------------------------------------
    storage_slots: int = 128              # NFS client RPC slot table
    storage_degradation: float = 1.0      # service-time / load-time multiplier
    # shared-NFS fabric (paper F2): when True, save duration AND restart
    # loading time are derived from fabric queries at the gang fanin
    # (scale-emergent contention) instead of the per-client constants
    storage_fabric: bool = False
    storage_server_read_gbs: float = 700.0   # aggregate read max (paper)
    storage_server_write_gbs: float = 250.0  # aggregate write max (paper)
    restore_bytes_per_node: int = 200 << 30

    # -- telemetry / F1 -----------------------------------------------------
    telemetry: bool = False               # scrape during the main campaign
    telemetry_days: float = 0.0           # F1 sub-campaign window (0 = no F1)
    # None = the full paper-realistic ~305-metric registry (detector FP
    # behaviour at the true metric count); set lower to trade FP fidelity
    # for memory in wide sweeps
    telemetry_pad_metrics: Optional[int] = None

    # -- detection->recovery control plane ----------------------------------
    # when True the campaign runs the online control loop: the streaming
    # detector consumes span-batched telemetry as it is emitted
    # (stream-and-discard; nothing retained) and maps alarms to recovery
    # actions.  The reactive baseline is simply control_plane=False.
    control_plane: bool = False
    control_urgent_checkpoint: bool = True   # in-gang alarm -> urgent save
    control_drain: bool = False              # confirmed alarm -> drain node
    control_drain_confirm_alarms: int = 3    # same-node alarms that confirm
    control_alarm_memory_h: float = 4.0      # retry placement avoids alarmed
    # log channel (L4): synthetic operational logs analyzed alongside the
    # metric vote — template bursts + cross-node references attribute
    # gang-wide symptoms to a root-cause node, fused into the same alarm
    # stream.  Requires control_plane; off by default (bit-identity).
    log_channel: bool = False
    # blast-radius-aware recovery (correlated fault band): attribute
    # gang-wide alarm bursts to the shared leaf switch, suppress member
    # drains while the switch is indicted, and re-place retries away from
    # the degraded rack.  Requires control_plane; off by default.
    blast_radius_aware: bool = False
    topology_fanout: int = 8              # nodes per leaf switch (the
                                          #   switch_degrade blast radius)
    # streaming-detector pass-1 implementation: "numpy" (reference /
    # parity oracle) | "xla" (fused jitted XLA) | "pallas" (TPU kernel).
    # The compiled backends produce the identical alarm set, so campaign
    # trajectories are backend-invariant; switch for wall-clock only.
    detector_backend: str = "numpy"

    # escape hatch: raw CampaignConfig field overrides applied last
    overrides: Dict[str, float] = field(default_factory=dict)

    def __post_init__(self):
        RetryPolicy(self.retry_policy)                  # validate early
        if self.detector_backend != "numpy":
            from repro.kernels.robust_stats.ops import validate_backend
            validate_backend(self.detector_backend)
        if self.checkpoint_strategy not in ("fixed", "young_daly"):
            raise ValueError(
                f"unknown checkpoint_strategy {self.checkpoint_strategy!r}")
        unknown = set(self.kind_weights or ()) - FAILURE_CATEGORIES
        if unknown:
            raise ValueError(
                f"unknown kind_weights categories {sorted(unknown)}; "
                f"valid: {sorted(FAILURE_CATEGORIES)}")
        if self.log_channel and not self.control_plane:
            raise ValueError(
                "log_channel requires control_plane=True (the log "
                "analyzer's verdicts fuse into the control loop)")
        if self.blast_radius_aware and not self.control_plane:
            raise ValueError(
                "blast_radius_aware requires control_plane=True (switch "
                "indictment lives in the control loop)")

    # -- resolution ---------------------------------------------------------

    def fabric_config(self) -> FabricConfig:
        return FabricConfig(
            server_read_bw=self.storage_server_read_gbs * 1e9,
            server_write_bw=self.storage_server_write_gbs * 1e9,
            degradation=self.storage_degradation)

    def fabric(self) -> StorageFabric:
        """The shared-NFS server this scenario's clients contend for."""
        return StorageFabric(self.fabric_config())

    def storage_model(self, seed: int = 0) -> NFSClientSim:
        if self.storage_fabric:
            # per-client view of the shared fabric: service times derived
            # at the campaign fanins, degradation included
            return NFSClientSim(NFSConfig(n_slots=self.storage_slots),
                                seed=seed, fabric=self.fabric())
        cfg = NFSConfig(
            n_slots=self.storage_slots,
            write_service_s=0.126 * self.storage_degradation,
            read_service_s=0.0273 * self.storage_degradation)
        return NFSClientSim(cfg, seed=seed)

    def resolve_delta_s(self) -> float:
        """Checkpoint save duration under this scenario's storage model."""
        if self.storage_fabric:
            wire = int((self.ckpt_bytes_per_node or 20 << 30)
                       * self.ckpt_wire_ratio)
            return float(self.fabric().expected_duration_s(
                "write", self.job_nodes, wire,
                slots_per_client=self.storage_slots))
        if self.ckpt_bytes_per_node is not None:
            nfs = self.storage_model()
            return float(nfs.checkpoint_save(self.ckpt_bytes_per_node)
                         .duration_s)
        return self.checkpoint_delta_s * self.storage_degradation

    def resolve_interval_h(self, delta_s: Optional[float] = None) -> float:
        if delta_s is None:
            delta_s = self.resolve_delta_s()
        if self.checkpoint_strategy == "young_daly":
            return t_opt_s(delta_s, self.mtbf_h) / 3600.0
        return self.checkpoint_interval_h

    def retry_config(self) -> RetryConfig:
        return RetryConfig(enabled=self.retry_enabled,
                           max_retries=self.max_retries,
                           delay_min=self.retry_delay_min,
                           policy=RetryPolicy(self.retry_policy),
                           structural_stop=self.structural_stop)

    def control_config(self) -> Optional[ControlConfig]:
        if not self.control_plane:
            return None
        return ControlConfig(
            urgent_checkpoint=self.control_urgent_checkpoint,
            drain=self.control_drain,
            drain_confirm_alarms=self.control_drain_confirm_alarms,
            alarm_memory_h=self.control_alarm_memory_h,
            log_channel=self.log_channel,
            blast_radius_aware=self.blast_radius_aware,
            topology_fanout=self.topology_fanout,
            detector_backend=self.detector_backend)

    def to_campaign_config(self, seed: int = 0) -> CampaignConfig:
        delta_s = self.resolve_delta_s()
        cfg = CampaignConfig(
            n_nodes=self.n_nodes,
            job_nodes=self.job_nodes,
            duration_h=self.duration_days * 24.0,
            mtbf_h=self.mtbf_h,
            retry=self.retry_config(),
            checkpoint_interval_h=self.resolve_interval_h(delta_s),
            checkpoint_save_s=delta_s,
            loading_time_h=(31.0 / 60.0) * self.storage_degradation,
            loading_cold_h=(58.0 / 60.0) * self.storage_degradation,
            hot_fraction=self.hot_fraction,
            hot_weight=self.hot_weight,
            kind_weights=dict(self.kind_weights)
            if self.kind_weights else None,
            topology_fanout=self.topology_fanout,
            telemetry=self.telemetry,
            telemetry_pad_metrics=self.telemetry_pad_metrics,
            seed=seed,
        )
        if self.storage_fabric:
            # hand ClusterSim the fabric itself: save/loading times are
            # re-derived there from gang-fanin queries (identical to the
            # delta_s above), and telemetry picks up the fabric's
            # queue-depth/backlog levels
            cfg = dataclasses.replace(
                cfg,
                storage=self.fabric_config(),
                storage_slots=self.storage_slots,
                ckpt_bytes_per_node=self.ckpt_bytes_per_node or 20 << 30,
                ckpt_wire_ratio=self.ckpt_wire_ratio,
                restore_bytes_per_node=self.restore_bytes_per_node)
        if self.control_plane:
            # online loop: telemetry spans feed the streaming detector and
            # are discarded (day-scale retention is an offline-F1 concern)
            cfg = dataclasses.replace(
                cfg, control=self.control_config(),
                telemetry=True, telemetry_store=False)
        if self.overrides:
            cfg = dataclasses.replace(cfg, **self.overrides)
        return cfg

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    # labels, not semantics: two specs differing only here run the exact
    # same campaign, so the canonical key must treat them as equal
    _LABEL_FIELDS = ("name", "description")

    def canonical_dict(self) -> dict:
        """The semantics of this spec in canonical form.

        Normalization rules (what makes two specs "the same campaign"):

        * ``name``/``description`` are dropped — they label the spec, the
          simulation never reads them (preset-vs-explicit equivalence:
          a preset and a hand-built Scenario with identical fields get
          identical keys);
        * numeric values are canonicalized to ``float`` (``73`` and
          ``73.0`` resolve to the same campaign; bools stay bools);
        * ``kind_weights`` drops identity tilts (``1.0`` multiplies a
          category weight by one) and collapses empty/None to ``None``;
        * ``overrides`` collapses empty to ``{}``; nested dict key order
          never matters (ordering-insensitive by sorted-key dumping).
        """
        def norm(v):
            if isinstance(v, bool) or v is None or isinstance(v, str):
                return v
            if isinstance(v, (int, float)):
                return float(v)
            if isinstance(v, dict):
                return {k: norm(x) for k, x in sorted(v.items())}
            raise TypeError(
                f"unserializable scenario field value {v!r}")
        d = {k: norm(v) for k, v in self.to_dict().items()
             if k not in self._LABEL_FIELDS}
        kw = {k: v for k, v in (d.get("kind_weights") or {}).items()
              if v != 1.0}
        d["kind_weights"] = kw or None
        d["overrides"] = d.get("overrides") or {}
        return d

    def canonical_key(self) -> str:
        """Stable cache key for this spec's *semantics*.

        Equal for any two specs that resolve to the same campaign:
        dict-order changes, ``to_dict``/``from_dict`` round-trips, preset
        vs explicit construction, int-vs-float spelling and identity
        kind-weight tilts all collapse to one key (see
        :meth:`canonical_dict`).  The key is the sha256 of the sorted
        canonical JSON, so it is safe as a bounded-length LRU key and
        across processes.
        """
        payload = json.dumps(self.canonical_dict(), sort_keys=True,
                             separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        return cls(**d)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# named presets
# ---------------------------------------------------------------------------

PRESETS: Dict[str, Scenario] = {s.name: s for s in [
    Scenario(
        name="paper-faithful",
        description="The paper's 73-day 63-node campaign: Table 2 failure "
                    "mix, 10-min fixed auto-retry, 2.23 h checkpoint "
                    "interval (4K-phase median)."),
    Scenario(
        name="flaky-fabric",
        description="NVLink-dominated failure storm: MTBF halved, NVLink "
                    "share x2.5, hot nodes carry 70% of the hazard.",
        mtbf_h=28.0,
        hot_weight=0.70,
        kind_weights={"nvlink": 2.5}),
    Scenario(
        name="storage-degraded",
        description="Overloaded NFS backend: 4x RPC service times (save/"
                    "load stretch accordingly); Young-Daly re-optimises the "
                    "checkpoint interval for the slower saves.",
        storage_degradation=4.0,
        ckpt_bytes_per_node=20 << 30,
        checkpoint_strategy="young_daly"),
    Scenario(
        name="storage-fabric",
        description="Paper campaign with checkpoint timing DERIVED from "
                    "the shared-NFS fabric at gang fanin (F2: 21.5%/16.0% "
                    "aggregate utilization at 60-node scale, near-linear "
                    "at 2-4 nodes) instead of the observed constants.",
        storage_fabric=True),
    Scenario(
        name="storage-fabric-degraded",
        description="Shared fabric with 4x degraded server service; saves "
                    "and restart loads stretch with gang-fanin contention "
                    "and Young-Daly re-optimises the interval.",
        storage_fabric=True,
        storage_degradation=4.0,
        checkpoint_strategy="young_daly"),
    Scenario(
        name="big-cluster-252",
        description="4x the paper's scale (252 nodes, 240-node gang); fleet "
                    "MTBF shrinks proportionally at constant per-node "
                    "hazard.",
        n_nodes=252,
        job_nodes=240,
        duration_days=30.0,
        mtbf_h=MTBF_H_PAPER * 63.0 / 252.0),
    Scenario(
        name="no-auto-retry",
        description="Paper's counterfactual baseline: every failure is a "
                    "manual operator restart (12.5% chain success, 3.3 h "
                    "median downtime in the paper).",
        retry_enabled=False),
    Scenario(
        name="exp-backoff",
        description="§4.3.5 improvement 1: exponential retry backoff "
                    "(10 -> 20 -> 40 min, capped at 80).",
        retry_policy="exp_backoff"),
    Scenario(
        name="xid-branch",
        description="§4.3.5 improvement 2: XID-classified retry (RESTART_APP"
                    " immediate, RESET_GPU delayed, RESTART_BM pages the "
                    "operator).",
        retry_policy="xid_branch"),
    Scenario(
        name="smart-retry",
        description="§4.3.5 improvement 3: stop retrying when the healthy "
                    "pool cannot satisfy the gang requirement (no more "
                    "30-attempt burn-downs).",
        structural_stop=True),
    Scenario(
        name="young-daly",
        description="Checkpoint at the Young-Daly optimum for the 4K-phase "
                    "delta (44.9 min) instead of the observed 2.23 h.",
        checkpoint_strategy="young_daly"),
    Scenario(
        name="reactive",
        description="Reactive baseline for the control-plane presets: the "
                    "paper campaign where failures are handled only after "
                    "they fire — the F1 detector changes nothing."),
    Scenario(
        name="proactive",
        description="Online detection->recovery: the streaming detector "
                    "consumes telemetry as emitted; in-gang alarms trigger "
                    "urgent checkpoints (fabric-priced at gang fanin) and "
                    "retries avoid recently-alarmed nodes.  Trajectory-"
                    "preserving actions only: goodput gain is the lost-work "
                    "window shrunk by true positives minus save time burned "
                    "by false positives.",
        control_plane=True),
    Scenario(
        name="proactive-aggressive",
        description="Proactive plus predictive drains: alarms confirmed by "
                    "clustering (3 same-node alarms in 30 min) gracefully "
                    "checkpoint, drain, and replace the suspect node before "
                    "the failure lands — the gang dodges the crash entirely "
                    "at the price of a controlled restart (and the "
                    "occasional false-positive drain).",
        control_plane=True,
        control_drain=True),
    Scenario(
        name="infra-faults",
        description="Cluster-infrastructure fault band: network-degradation "
                    "windows (gang-wide collective slowdown), resource-"
                    "exhaustion windows (host pressure, sometimes escalating "
                    "to a crash) and control-plane blind windows (scheduler "
                    "outages that queue decisions), on top of the paper "
                    "mix.  The control plane classifies alarms and throttles "
                    "net windows instead of draining healthy nodes.",
        kind_weights={"net_degrade": 4.0, "resource_exhaust": 4.0,
                      "ctrl_blind": 4.0},
        control_plane=True),
    Scenario(
        name="degraded-network",
        description="Network-degradation-dominated band: latency/loss "
                    "windows inflate collective step time and StorageFabric "
                    "RPC service; the detector sees transport backlog / RPC "
                    "queue signatures and the control plane throttles "
                    "(waits the window out) instead of urgent-saving.",
        kind_weights={"net_degrade": 8.0},
        control_plane=True),
    Scenario(
        name="resource-pressure",
        description="Resource-exhaustion-dominated band: gradual or spike "
                    "host memory/disk pressure slows nodes and sometimes "
                    "escalates to a process crash; confirmed alarms drain "
                    "the pressured node behind a final checkpoint before "
                    "the escalation lands.",
        kind_weights={"resource_exhaust": 8.0},
        control_plane=True,
        control_drain=True),
    Scenario(
        name="ops-blind-spots",
        description="Scheduler-outage band: control-plane blind windows "
                    "queue alarm decisions until visibility returns (the "
                    "outage cost is exactly that latency), layered over "
                    "resource-pressure windows that keep raising alarms.",
        kind_weights={"ctrl_blind": 8.0, "resource_exhaust": 4.0},
        control_plane=True),
    Scenario(
        name="log-fusion-off",
        description="Metric-only twin of log-fusion: the identical infra-"
                    "heavy schedule, control plane and drain policy, with "
                    "the log channel off — the baseline the log channel's "
                    "time-to-detection and false-drain deltas are measured "
                    "against.",
        kind_weights={"net_degrade": 4.0, "resource_exhaust": 4.0,
                      "ctrl_blind": 4.0},
        control_plane=True,
        control_drain=True),
    Scenario(
        name="log-fusion",
        description="Log-channel diagnosis fused with the metric vote "
                    "(L4): a synthetic operational log stream — XID "
                    "bursts, gang-wide NCCL timeouts, NFS/RPC stall spam, "
                    "memory-pressure ramps — is template-mined, burst/"
                    "rarity scored, and root-cause attributed across "
                    "nodes; verdicts merge into the control loop's alarm "
                    "stream.  Compare against log-fusion-off for the "
                    "detection-latency and false-drain deltas.",
        kind_weights={"net_degrade": 4.0, "resource_exhaust": 4.0,
                      "ctrl_blind": 4.0},
        control_plane=True,
        control_drain=True,
        log_channel=True),
    Scenario(
        name="switch-blast",
        description="Correlated fault band, switch-dominated: one leaf "
                    "switch degrades and every node behind it co-degrades "
                    "for the same window (the blast radius the per-node "
                    "fault model cannot express).  Control-free: the "
                    "reactive baseline eats the full gang-wide slowdown.",
        kind_weights={"switch_degrade": 8.0}),
    Scenario(
        name="dns-flaps",
        description="Correlated fault band, flap-dominated: short partial-"
                    "gang connectivity windows where a sampled peer becomes "
                    "unreachable from a small member set (pairwise mask, "
                    "not node-down) — rpc name-resolution noise that looks "
                    "like a sick node but is not.  Control-free baseline.",
        kind_weights={"dns_flap": 8.0}),
    Scenario(
        name="correlated-recovery",
        description="Blast-radius-aware recovery over the full correlated "
                    "band: net-class alarm bursts across one switch's "
                    "members indict the shared switch (Mycroft-style cross-"
                    "node correlation, log lines fused in), member drains "
                    "are suppressed while the switch is indicted, and retry "
                    "placement avoids the degraded rack.  48-node gang in "
                    "the 63-node pool so a full rack can be placed around.",
        job_nodes=48,
        kind_weights={"switch_degrade": 6.0, "dns_flap": 4.0},
        control_plane=True,
        control_drain=True,
        log_channel=True,
        blast_radius_aware=True),
]}


def get_scenario(name: str) -> Scenario:
    """Resolve a preset by name, as a fresh deep copy.

    Presets carry mutable fields (``kind_weights``, ``overrides``); handing
    out the registry instance would let one caller's mutation leak into
    every later ``get_scenario`` of the same name.  The dict round-trip is
    the same canonical form sweeps ship across process boundaries, so the
    copy is also a per-lookup serialization check.
    """
    try:
        return Scenario.from_dict(PRESETS[name].to_dict())
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{', '.join(sorted(PRESETS))}") from None


def list_scenarios() -> List[str]:
    return sorted(PRESETS)
