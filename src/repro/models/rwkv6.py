"""RWKV-6 "Finch" block (data-dependent decay linear attention).

Time-mix: per-head matrix-valued state S (B, H, Dk, Dv) with per-channel
data-dependent decay; Channel-mix: squared-ReLU FFN with token shift.

Backends:
* ``sequential`` — lax.scan over time (O(1) memory, the decode recurrence).
* ``chunked``    — block-parallel linear attention (matmul form, MXU
  friendly); see ``repro.kernels.rwkv6_scan`` for the Pallas TPU kernel and
  its pure-jnp oracle (shared with this module).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, group_norm_heads, token_shift


def init_rwkv(rng, d_model: int, d_ff: int, head_dim: int, dtype):
    h = d_model // head_dim
    ks = jax.random.split(rng, 16)
    lora = 64
    return {
        # time mix
        "maa_x": jnp.zeros((d_model,), dtype),
        "maa_wkvrg": jnp.zeros((5, d_model), dtype),
        "maa_w1": normal_init(ks[0], (d_model, 5 * 32), dtype),
        "maa_w2": normal_init(ks[1], (5, 32, d_model), dtype),
        "decay": normal_init(ks[2], (d_model,), jnp.float32, scale=0.5),
        "decay_w1": normal_init(ks[3], (d_model, lora), dtype),
        "decay_w2": normal_init(ks[4], (lora, d_model), dtype),
        "first": normal_init(ks[5], (h, head_dim), jnp.float32),  # u bonus
        "Wr": normal_init(ks[6], (d_model, d_model), dtype),
        "Wk": normal_init(ks[7], (d_model, d_model), dtype),
        "Wv": normal_init(ks[8], (d_model, d_model), dtype),
        "Wg": normal_init(ks[9], (d_model, d_model), dtype),
        "Wo": normal_init(ks[10], (d_model, d_model), dtype),
        "ln_x_scale": jnp.ones((d_model,), jnp.float32),
        "ln_x_bias": jnp.zeros((d_model,), jnp.float32),
        # channel mix
        "cm_maa_k": jnp.zeros((d_model,), dtype),
        "cm_maa_r": jnp.zeros((d_model,), dtype),
        "cm_Wk": normal_init(ks[11], (d_model, d_ff), dtype),
        "cm_Wv": normal_init(ks[12], (d_ff, d_model), dtype),
        "cm_Wr": normal_init(ks[13], (d_model, d_model), dtype),
    }


def wkv_sequential(r, k, v, w, u, s0):
    """r,k,v: (B,S,H,D); w (decay in (0,1)): (B,S,H,D); u: (H,D); s0: (B,H,D,D).

    y_t = r_t . (diag(u) k_t v_t^T + S_{t-1});  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y (B,S,H,D), s_final).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y
    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))
    s, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), s


def wkv_chunked(r, k, v, w, u, s0, chunk_size: int = 64):
    """Block-parallel WKV6 (matmul form). Same contract as wkv_sequential."""
    from repro.kernels.rwkv6_scan import ref as wkv_ref
    return wkv_ref.wkv6_chunked(r, k, v, w, u, s0, chunk_size=chunk_size)


def time_mix(x, p, head_dim: int, *, state=None, backend="sequential",
             chunk_size: int = 64):
    """state: None or {"shift": (B,d), "wkv": (B,H,D,D)} -> (y, new_state)."""
    b, s, d = x.shape
    h = d // head_dim
    prev = None if state is None else state["shift"]
    xx = token_shift(x, prev) - x
    xxx = x + xx * p["maa_x"][None, None]
    mixed = jnp.tanh(jnp.einsum("bsd,df->bsf", xxx, p["maa_w1"]))
    mixed = mixed.reshape(b, s, 5, 32)
    maa = jnp.einsum("bsmf,mfd->bsmd", mixed, p["maa_w2"])  # (B,S,5,d)
    maa = maa + p["maa_wkvrg"][None, None]
    xw, xk, xv, xr, xg = [x + xx * maa[:, :, i] for i in range(5)]

    w_log = p["decay"][None, None].astype(jnp.float32) + \
        jnp.einsum("bsf,fd->bsd",
                   jnp.tanh(jnp.einsum("bsd,df->bsf", xw, p["decay_w1"])),
                   p["decay_w2"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))                             # (B,S,d) in (0,1)

    def heads(t):
        return t.reshape(b, s, h, head_dim)

    r = heads(jnp.einsum("bsd,de->bse", xr, p["Wr"])).astype(jnp.float32)
    k = heads(jnp.einsum("bsd,de->bse", xk, p["Wk"])).astype(jnp.float32)
    v = heads(jnp.einsum("bsd,de->bse", xv, p["Wv"])).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["Wg"]))
    wh = w.reshape(b, s, h, head_dim)

    s0 = jnp.zeros((b, h, head_dim, head_dim), jnp.float32) if state is None \
        else state["wkv"]
    if backend == "chunked" and s > 1:
        y, s_out = wkv_chunked(r, k, v, wh, p["first"], s0, chunk_size=chunk_size)
    else:
        y, s_out = wkv_sequential(r, k, v, wh, p["first"], s0)

    y = group_norm_heads(y, p["ln_x_scale"].reshape(h, head_dim),
                         p["ln_x_bias"].reshape(h, head_dim))
    y = y.reshape(b, s, d).astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", y, p["Wo"])
    new_state = {"shift": x[:, -1], "wkv": s_out}
    return out, new_state


def channel_mix(x, p, *, state=None):
    prev = None if state is None else state
    xx = token_shift(x, prev) - x
    xk = x + xx * p["cm_maa_k"][None, None]
    xr = x + xx * p["cm_maa_r"][None, None]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["cm_Wk"])))
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cm_Wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_Wr"])) * kv
    return out, x[:, -1]
