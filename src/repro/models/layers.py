"""Shared neural-net primitives (pure functional JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(rng, shape, dtype, scale=0.02):
    return (scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape)).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def group_norm_heads(x, scale, bias, eps=64e-5):
    """Per-head group norm over the last dim (RWKV ln_x). x: (..., H, D)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    out = x * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def rope(q, k, positions, theta=10_000.0):
    """Rotary embeddings. q,k: (B, S, H, D); positions: (S,) or scalar-like (B?, S)."""
    d = q.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    if angles.ndim == 1:        # scalar position (decode) -> (1, 1, 1, half)
        angles = angles[None, None, None, :]
    elif angles.ndim == 2:      # (S, half) -> (1, S, 1, half)
        angles = angles[None, :, None, :]
    elif angles.ndim == 3:      # (B, S, half) -> (B, S, 1, half)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        xr = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
        return xr.astype(x.dtype)

    return rot(q), rot(k)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def token_shift(x, prev=None):
    """RWKV token shift: x_{t-1} along the sequence axis.

    ``prev``: (B, d) carry for decode/prefill chunking (last token of the
    previous segment); defaults to zeros.
    """
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)
