"""Model composition: init / forward / prefill / decode for every arch family.

A model is a pytree of parameters plus pure functions.  The layer stack is a
``lax.scan`` over ``n_periods`` stacked period-parameter trees; the (static)
heterogeneous structure of one period is unrolled inside the scanned body
(DESIGN.md §6 "compile-size control").
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec, ATTN, CROSS_ATTN, MAMBA, RWKV
from repro.distributed import context as dist_ctx
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import normal_init, rms_norm, rope, swiglu, softcap


# ---------------------------------------------------------------------------
# Run options (performance levers — see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunOptions:
    attn_backend: str = "chunked"      # naive | chunked | pallas
    q_chunk: int = 1024
    kv_chunk: int = 1024
    mamba_chunk: int = 1               # 1 = sequential scan
    rwkv_backend: str = "sequential"   # sequential | chunked
    rwkv_chunk: int = 64
    remat: str = "none"                # none | dots | full
    loss_chunk: int = 0                # 0 = full-logit CE; >0 = seq-chunked CE
    lb_loss_weight: float = 0.01
    z_loss_weight: float = 1e-4
    # cost-extraction mode: python-loop over periods instead of lax.scan so
    # XLA cost_analysis counts every layer (scan bodies are counted ONCE
    # regardless of trip count — measured; see EXPERIMENTS.md §Roofline).
    unroll_periods: bool = False
    # pin dW shardings to the param shardings (fixes 8x replicated-gradient
    # FLOP inflation — see make_train_step / EXPERIMENTS.md §Perf)
    constrain_grads: bool = True
    # pin MoE dispatch tensors to the EP layout (collective-term fix;
    # False preserves the recorded paper-faithful baseline)
    moe_constraints: bool = False
    # bf16 attention math with fp32 MXU accumulation (memory-term lever)
    attn_bf16: bool = False
    # MoE dispatch implementation: "dense" (constraint-hinted GSPMD) or
    # "a2a" (explicit shard_map all-to-all routing - Perf iteration 9)
    moe_impl: str = "dense"


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_ffn(rng, cfg: ArchConfig, spec: LayerSpec):
    if spec.moe is not None:
        return {"moe": moe_mod.init_moe(rng, cfg.d_model, spec.moe, cfg.pdtype)}
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"ffn": {
        "w_gate": normal_init(k1, (cfg.d_model, cfg.d_ff), cfg.pdtype),
        "w_up": normal_init(k2, (cfg.d_model, cfg.d_ff), cfg.pdtype),
        "w_down": normal_init(k3, (cfg.d_ff, cfg.d_model), cfg.pdtype),
    }}


def _init_attn_layer(rng, cfg: ArchConfig, spec: LayerSpec):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(rng, 6)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "wq": normal_init(ks[0], (cfg.d_model, cfg.n_heads * hd), cfg.pdtype),
        "wk": normal_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), cfg.pdtype),
        "wv": normal_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), cfg.pdtype),
        "wo": normal_init(ks[3], (cfg.n_heads * hd, cfg.d_model), cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.pdtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.pdtype)
    if spec.kind == CROSS_ATTN:
        p["gate_attn"] = jnp.zeros((), cfg.pdtype)
        p["gate_ffn"] = jnp.zeros((), cfg.pdtype)
    p.update(_init_ffn(ks[4], cfg, spec))
    return p


def _init_mamba_layer(rng, cfg: ArchConfig, spec: LayerSpec):
    k1, k2 = jax.random.split(rng)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "mixer": mamba_mod.init_mamba(k1, cfg.d_model, spec, cfg.pdtype),
    }
    p.update(_init_ffn(k2, cfg, spec))
    return p


def _init_rwkv_layer(rng, cfg: ArchConfig, spec: LayerSpec):
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "mix": rwkv_mod.init_rwkv(rng, cfg.d_model, cfg.d_ff,
                                  cfg.rwkv_head_dim, cfg.pdtype),
    }


def init_layer(rng, cfg: ArchConfig, spec: LayerSpec):
    if spec.kind in (ATTN, CROSS_ATTN):
        return _init_attn_layer(rng, cfg, spec)
    if spec.kind == MAMBA:
        return _init_mamba_layer(rng, cfg, spec)
    if spec.kind == RWKV:
        return _init_rwkv_layer(rng, cfg, spec)
    raise ValueError(spec.kind)


def init_period(rng, cfg: ArchConfig):
    ks = jax.random.split(rng, len(cfg.period))
    return {f"pos{i}": init_layer(ks[i], cfg, spec)
            for i, spec in enumerate(cfg.period)}


def init_params(rng, cfg: ArchConfig):
    k_emb, k_head, k_pre, k_per, k_suf = jax.random.split(rng, 5)
    params: dict = {"final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype)}
    if cfg.embed_inputs:
        params["embed"] = normal_init(k_emb, (cfg.vocab_size, cfg.d_model), cfg.pdtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(k_head, (cfg.d_model, cfg.vocab_size), cfg.pdtype)
    if cfg.prefix:
        kp = jax.random.split(k_pre, len(cfg.prefix))
        params["prefix"] = tuple(init_layer(kp[i], cfg, s)
                                 for i, s in enumerate(cfg.prefix))
    if cfg.n_periods:
        params["period"] = jax.vmap(lambda r: init_period(r, cfg))(
            jax.random.split(k_per, cfg.n_periods))
    if cfg.suffix:
        ks = jax.random.split(k_suf, len(cfg.suffix))
        params["suffix"] = tuple(init_layer(ks[i], cfg, s)
                                 for i, s in enumerate(cfg.suffix))
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _ffn_apply(h, p, spec: LayerSpec, opts=None):
    if spec.moe is not None:
        if opts is not None and opts.moe_impl == "a2a":
            ctx = dist_ctx.current()
            b, s, d = h.shape
            if ctx is not None and ctx.mesh is not None \
                    and spec.moe.n_experts % ctx.model_size == 0 \
                    and (b * s) % (ctx.batch_size * ctx.model_size) == 0:
                from repro.models.moe_a2a import moe_ffn_a2a
                out, aux = moe_ffn_a2a(h, p["moe"], spec.moe, ctx.mesh,
                                       batch_axes=ctx.batch_axes,
                                       model_axis=ctx.model_axis)
                # restore the residual layout immediately: the shard_map's
                # (data x model) token sharding otherwise propagates into
                # the next attention layer and forces full rematerialization
                return dist_ctx.shard_batch(out), aux
        return moe_mod.moe_ffn(h, p["moe"], spec.moe,
                               constraints=bool(opts and opts.moe_constraints))
    return swiglu(h, **p["ffn"]), {}


def _project_qkv(h, p, cfg: ArchConfig, kv_src=None):
    hd = cfg.resolved_head_dim
    b, s, _ = h.shape
    kv_src = h if kv_src is None else kv_src
    q = jnp.einsum("bsd,de->bse", h, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,de->bse", kv_src, p["wk"]).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", kv_src, p["wv"]).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _attn_layer_full(x, p, spec: LayerSpec, cfg: ArchConfig, opts: RunOptions,
                     positions, img_embeds=None, want_cache=False):
    """Full-sequence attention layer (train / prefill). Returns (x, cache, aux)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    cache = None
    if spec.kind == CROSS_ATTN:
        q, k, v = _project_qkv(h, p, cfg, kv_src=img_embeds)
        out = attn_mod.cross_attention(q, k, v)
        out = out.reshape(*out.shape[:2], -1)
        out = jnp.einsum("bse,ed->bsd", out, p["wo"])
        x = x + jnp.tanh(p["gate_attn"]) * out
        if want_cache:
            cache = {"k": k, "v": v}
    else:
        q, k, v = _project_qkv(h, p, cfg)
        q, k = rope(q, k, positions, cfg.rope_theta)
        out = attn_mod.self_attention(
            q, k, v, window=spec.window, attn_softcap=cfg.attn_softcap,
            backend=opts.attn_backend, q_chunk=opts.q_chunk,
            kv_chunk=opts.kv_chunk, bf16_math=opts.attn_bf16)
        out = out.reshape(*out.shape[:2], -1)
        x = x + jnp.einsum("bse,ed->bsd", out, p["wo"])
        if want_cache:
            cache = {"k": k, "v": v}
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    ff, aux = _ffn_apply(h2, p, spec, opts)
    if spec.kind == CROSS_ATTN:
        x = x + jnp.tanh(p["gate_ffn"]) * ff
    else:
        x = x + ff
    return x, cache, aux


def _attn_layer_decode(x, p, spec: LayerSpec, cfg: ArchConfig, opts: RunOptions,
                       cache, pos):
    """Single-token decode. x: (B, 1, d). Returns (x, new_cache, aux)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == CROSS_ATTN:
        hd = cfg.resolved_head_dim
        b = h.shape[0]
        q = jnp.einsum("bsd,de->bse", h, p["wq"]).reshape(b, 1, cfg.n_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        out = attn_mod.cross_attention(q, cache["k"], cache["v"])
        out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p["wo"])
        x = x + jnp.tanh(p["gate_attn"]) * out
        new_cache = cache          # cross KV is static
    else:
        q, k, v = _project_qkv(h, p, cfg)
        q, k = rope(q, k, pos, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        out = attn_mod.decode_attention(q, k_cache, v_cache, pos,
                                        window=spec.window,
                                        attn_softcap=cfg.attn_softcap)
        x = x + jnp.einsum("bse,ed->bsd", out.reshape(*out.shape[:2], -1), p["wo"])
        new_cache = {"k": k_cache, "v": v_cache}
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    ff, aux = _ffn_apply(h2, p, spec, opts)
    if spec.kind == CROSS_ATTN:
        x = x + jnp.tanh(p["gate_ffn"]) * ff
    else:
        x = x + ff
    return x, new_cache, aux


def _mamba_layer(x, p, spec: LayerSpec, cfg: ArchConfig, opts: RunOptions,
                 state=None, want_cache=False):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    out, new_state = mamba_mod.mamba_mixer(h, p["mixer"], spec, state=state,
                                           chunk_size=opts.mamba_chunk)
    x = x + out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    ff, aux = _ffn_apply(h2, p, spec, opts)
    x = x + ff
    return x, (new_state if (want_cache or state is not None) else None), aux


def _rwkv_layer(x, p, cfg: ArchConfig, opts: RunOptions, state=None,
                want_cache=False):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    tm_state = None if state is None else {"shift": state["shift"], "wkv": state["wkv"]}
    out, new_tm = rwkv_mod.time_mix(h, p["mix"], cfg.rwkv_head_dim,
                                    state=tm_state, backend=opts.rwkv_backend,
                                    chunk_size=opts.rwkv_chunk)
    x = x + out
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    cm_state = None if state is None else state["cm"]
    out2, new_cm = rwkv_mod.channel_mix(h2, p["mix"], state=cm_state)
    x = x + out2
    new_state = None
    if want_cache or state is not None:
        new_state = {"shift": new_tm["shift"], "wkv": new_tm["wkv"], "cm": new_cm}
    return x, new_state, aux_zero()


def aux_zero():
    return {}


def apply_layer(x, p, spec: LayerSpec, cfg: ArchConfig, opts: RunOptions, *,
                positions=None, img_embeds=None, cache=None, pos=None,
                mode="train"):
    """Unified layer application. Returns (x, cache_out, aux)."""
    if spec.kind in (ATTN, CROSS_ATTN):
        if mode == "decode":
            return _attn_layer_decode(x, p, spec, cfg, opts, cache, pos)
        return _attn_layer_full(x, p, spec, cfg, opts, positions,
                                img_embeds=img_embeds,
                                want_cache=(mode == "prefill"))
    if spec.kind == MAMBA:
        return _mamba_layer(x, p, spec, cfg, opts, state=cache,
                            want_cache=(mode == "prefill"))
    if spec.kind == RWKV:
        return _rwkv_layer(x, p, cfg, opts, state=cache,
                           want_cache=(mode == "prefill"))
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# Cache init (decode entry point / dry-run specs)
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, seq_len: int):
    hd = cfg.resolved_head_dim
    if spec.kind == ATTN:
        shape = (batch, seq_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, cfg.pdtype), "v": jnp.zeros(shape, cfg.pdtype)}
    if spec.kind == CROSS_ATTN:
        shape = (batch, cfg.n_img_tokens, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shape, cfg.pdtype), "v": jnp.zeros(shape, cfg.pdtype)}
    if spec.kind == MAMBA:
        return mamba_mod.init_mamba_state(batch, cfg.d_model, spec, cfg.pdtype)
    if spec.kind == RWKV:
        h = cfg.d_model // cfg.rwkv_head_dim
        return {
            "shift": jnp.zeros((batch, cfg.d_model), cfg.pdtype),
            "wkv": jnp.zeros((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32),
            "cm": jnp.zeros((batch, cfg.d_model), cfg.pdtype),
        }
    raise ValueError(spec.kind)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    cache: dict = {}
    if cfg.prefix:
        cache["prefix"] = tuple(_layer_cache(cfg, s, batch, seq_len)
                                for s in cfg.prefix)
    if cfg.n_periods:
        one = {f"pos{i}": _layer_cache(cfg, s, batch, seq_len)
               for i, s in enumerate(cfg.period)}
        cache["period"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), one)
    if cfg.suffix:
        cache["suffix"] = tuple(_layer_cache(cfg, s, batch, seq_len)
                                for s in cfg.suffix)
    return cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ArchConfig, tokens_or_embeds):
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], tokens_or_embeds, axis=0)
        if cfg.tie_embeddings:           # gemma-style scaled embeddings
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return x.astype(cfg.cdtype)
    return tokens_or_embeds.astype(cfg.cdtype)


def unembed(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    return softcap(logits, cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _merge_aux(acc, aux):
    for k, v in aux.items():
        acc[k] = acc.get(k, 0.0) + v
    return acc


def _maybe_remat(fn, opts: RunOptions):
    if opts.remat == "none":
        return fn
    if opts.remat == "full":
        return jax.checkpoint(fn)
    if opts.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(opts.remat)


def forward(params, cfg: ArchConfig, opts: RunOptions, tokens,
            img_embeds=None):
    """Training forward: hidden states -> logits (fp32). Also returns aux."""
    x = embed_inputs(params, cfg, tokens)
    x = dist_ctx.shard_batch(x)
    positions = jnp.arange(x.shape[1])
    aux_acc: dict = {}

    for i, spec in enumerate(cfg.prefix):
        x, _, aux = apply_layer(x, params["prefix"][i], spec, cfg, opts,
                                positions=positions, img_embeds=img_embeds,
                                mode="train")
        aux_acc = _merge_aux(aux_acc, aux)

    if cfg.n_periods:
        def body(carry, period_p):
            h = dist_ctx.shard_batch(carry)
            auxes: dict = {}
            for i, spec in enumerate(cfg.period):
                h, _, aux = apply_layer(h, period_p[f"pos{i}"], spec, cfg, opts,
                                        positions=positions,
                                        img_embeds=img_embeds, mode="train")
                auxes = _merge_aux(auxes, aux)
            # fixed key-set for scan: always emit both aux scalars
            out = {"lb_loss": auxes.get("lb_loss", jnp.float32(0)),
                   "z_loss": auxes.get("z_loss", jnp.float32(0))}
            return h, out

        if opts.unroll_periods:
            body_fn = _maybe_remat(body, opts)
            for pi in range(cfg.n_periods):
                period_p = jax.tree.map(lambda a: a[pi], params["period"])
                x, out = body_fn(x, period_p)
                aux_acc = _merge_aux(aux_acc, out)
        else:
            x, period_aux = jax.lax.scan(_maybe_remat(body, opts), x,
                                         params["period"])
            aux_acc = _merge_aux(aux_acc, jax.tree.map(jnp.sum, period_aux))

    for i, spec in enumerate(cfg.suffix):
        x, _, aux = apply_layer(x, params["suffix"][i], spec, cfg, opts,
                                positions=positions, img_embeds=img_embeds,
                                mode="train")
        aux_acc = _merge_aux(aux_acc, aux)

    return x, aux_acc


def loss_fn(params, cfg: ArchConfig, opts: RunOptions, batch):
    """Next-token cross entropy (+ MoE aux). batch: {tokens|embeds, labels, [img_embeds]}."""
    inputs = batch.get("tokens", batch.get("embeds"))
    x, aux = forward(params, cfg, opts, inputs, img_embeds=batch.get("img_embeds"))
    labels = batch["labels"]

    if opts.loss_chunk and x.shape[1] % opts.loss_chunk == 0 and x.shape[1] > opts.loss_chunk:
        n = x.shape[1] // opts.loss_chunk
        xc = x.reshape(x.shape[0], n, opts.loss_chunk, x.shape[2]).swapaxes(0, 1)
        lc = labels.reshape(labels.shape[0], n, opts.loss_chunk).swapaxes(0, 1)

        def chunk_ce(carry, inp):
            xs, ls = inp
            logits = unembed(params, cfg, xs)
            ce = _ce(logits, ls)
            return carry + ce, None
        total, _ = jax.lax.scan(chunk_ce, jnp.float32(0), (xc, lc))
        ce = total / n
    else:
        logits = unembed(params, cfg, x)
        ce = _ce(logits, labels)

    loss = ce
    metrics = {"ce": ce}
    if "lb_loss" in aux:
        loss = loss + opts.lb_loss_weight * aux["lb_loss"] \
                    + opts.z_loss_weight * aux["z_loss"]
        metrics.update({k: v for k, v in aux.items()})
    metrics["loss"] = loss
    return loss, metrics


def _ce(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(params, cfg: ArchConfig, opts: RunOptions, tokens, img_embeds=None):
    """Process a prompt; return (last-token logits, cache)."""
    x = embed_inputs(params, cfg, tokens)
    x = dist_ctx.shard_batch(x)
    positions = jnp.arange(x.shape[1])
    caches: dict = {}

    pre = []
    for i, spec in enumerate(cfg.prefix):
        x, c, _ = apply_layer(x, params["prefix"][i], spec, cfg, opts,
                              positions=positions, img_embeds=img_embeds,
                              mode="prefill")
        pre.append(c)
    if pre:
        caches["prefix"] = tuple(pre)

    if cfg.n_periods:
        def body(h, period_p):
            h = dist_ctx.shard_batch(h)
            cs = {}
            for i, spec in enumerate(cfg.period):
                h, c, _ = apply_layer(h, period_p[f"pos{i}"], spec, cfg, opts,
                                      positions=positions,
                                      img_embeds=img_embeds, mode="prefill")
                cs[f"pos{i}"] = c
            return h, cs
        if opts.unroll_periods:
            body_fn = _maybe_remat(body, opts)
            cache_list = []
            for pi in range(cfg.n_periods):
                period_p = jax.tree.map(lambda a: a[pi], params["period"])
                x, cs = body_fn(x, period_p)
                cache_list.append(cs)
            caches["period"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *cache_list)
        else:
            x, period_caches = jax.lax.scan(_maybe_remat(body, opts), x,
                                            params["period"])
            caches["period"] = period_caches

    suf = []
    for i, spec in enumerate(cfg.suffix):
        x, c, _ = apply_layer(x, params["suffix"][i], spec, cfg, opts,
                              positions=positions, img_embeds=img_embeds,
                              mode="prefill")
        suf.append(c)
    if suf:
        caches["suffix"] = tuple(suf)

    logits = unembed(params, cfg, x[:, -1:])
    return logits, caches


def decode_step(params, cfg: ArchConfig, opts: RunOptions, tokens, cache, pos):
    """One decode step. tokens: (B, 1) ids or (B, 1, d) embeds; pos: scalar."""
    x = embed_inputs(params, cfg, tokens)
    x = dist_ctx.shard_batch(x)
    new_cache: dict = {}

    pre = []
    for i, spec in enumerate(cfg.prefix):
        x, c, _ = apply_layer(x, params["prefix"][i], spec, cfg, opts,
                              cache=cache["prefix"][i], pos=pos, mode="decode")
        pre.append(c)
    if pre:
        new_cache["prefix"] = tuple(pre)

    if cfg.n_periods:
        def body(h, xs):
            period_p, period_c = xs
            h = dist_ctx.shard_batch(h)
            cs = {}
            for i, spec in enumerate(cfg.period):
                h, c, _ = apply_layer(h, period_p[f"pos{i}"], spec, cfg, opts,
                                      cache=period_c[f"pos{i}"], pos=pos,
                                      mode="decode")
                cs[f"pos{i}"] = c
            return h, cs
        if opts.unroll_periods:
            cache_list = []
            for pi in range(cfg.n_periods):
                sl = jax.tree.map(lambda a: a[pi],
                                  (params["period"], cache["period"]))
                x, cs = body(x, sl)
                cache_list.append(cs)
            new_cache["period"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                               *cache_list)
        else:
            x, period_caches = jax.lax.scan(body, x,
                                            (params["period"], cache["period"]))
            new_cache["period"] = period_caches

    suf = []
    for i, spec in enumerate(cfg.suffix):
        x, c, _ = apply_layer(x, params["suffix"][i], spec, cfg, opts,
                              cache=cache["suffix"][i], pos=pos, mode="decode")
        suf.append(c)
    if suf:
        new_cache["suffix"] = tuple(suf)

    logits = unembed(params, cfg, x)
    return logits, new_cache
