"""Mamba (S6) selective-scan block, adapted for TPU.

The GPU reference implementation is a fused CUDA kernel holding the
recurrence in registers.  On TPU we express the recurrence two ways:

* ``chunk_size=1``  — a plain ``lax.scan`` over time carrying the (B, d_in, N)
  state; minimal memory, serial over S (baseline; honest about the
  latency-bound nature of S6 on a systolic machine).
* ``chunk_size=L``  — chunk-parallel form: the per-chunk decay products
  (B, L, d_in, N) are materialised in VMEM-sized tiles and contracted with
  matmuls (MXU-friendly), with a sequential carry across chunks only.
  This is the hardware adaptation of the paper's insight noted in
  DESIGN.md §2 (no warp-level analogue needed — the recurrence becomes a
  blocked matmul pipeline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec
from repro.models.layers import normal_init


def dt_rank_for(d_model: int) -> int:
    return max(d_model // 16, 1)


def init_mamba(rng, d_model: int, spec: LayerSpec, dtype):
    din = spec.expand * d_model
    n = spec.d_state
    r = dt_rank_for(d_model)
    ks = jax.random.split(rng, 8)
    # S4D-real initialisation for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": normal_init(ks[0], (d_model, 2 * din), dtype),
        "conv_w": normal_init(ks[1], (spec.d_conv, din), dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": normal_init(ks[2], (din, r + 2 * n), dtype),
        "dt_proj": normal_init(ks[3], (r, din), dtype),
        "dt_bias": jnp.full((din,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(a),                        # fp32
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": normal_init(ks[4], (din, d_model), dtype),
    }


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C).

    ``state``: (B, K-1, C) tail of the previous segment (decode carry).
    Returns (y, new_state).
    """
    k = w.shape[0]
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype) if state is None \
        else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                       # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y + b[None, None, :], new_state


def selective_scan(u, dt, a, b, c, h0=None, chunk_size: int = 1):
    """y_t = c_t . h_t,  h_t = exp(dt_t A) h_{t-1} + dt_t b_t u_t.

    u, dt: (B, S, din); a: (din, N); b, c: (B, S, N); h0: (B, din, N).
    Returns (y (B,S,din), h_final).
    """
    bs, s, din = u.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bs, din, n), jnp.float32)

    dt = dt.astype(jnp.float32)
    u = u.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)

    if chunk_size <= 1:
        def step(h, inp):
            dt_t, u_t, b_t, c_t = inp                 # (B,din),(B,din),(B,N),(B,N)
            da = jnp.exp(dt_t[..., None] * a[None])   # (B, din, N)
            h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y
        h, ys = jax.lax.scan(step, h0, (dt.swapaxes(0, 1), u.swapaxes(0, 1),
                                        b.swapaxes(0, 1), c.swapaxes(0, 1)))
        return ys.swapaxes(0, 1), h

    l = chunk_size
    assert s % l == 0, (s, l)
    nc = s // l

    def chunk(h, inp):
        dt_c, u_c, b_c, c_c = inp                     # (B,L,din),(B,L,din),(B,L,N)
        la = dt_c[..., None] * a[None, None]          # (B,L,din,N) log-decay (<0)
        cum = jnp.cumsum(la, axis=1)
        # h-contribution: exp(cum_t) * h0
        y_h = jnp.einsum("bldn,bdn,bln->bld", jnp.exp(cum), h, c_c)
        # within-chunk: sum_{s<=t} exp(cum_t - cum_s) (dt_s b_s u_s) c_t
        du = (dt_c * u_c)                             # (B,L,din)
        # pairwise decay via logsumexp-free masked matmul in N-space:
        # expand (t, s) pairs — L is small (<=64) so (B,L,L,din)? too big.
        # instead: scale sources by exp(-cum_s), targets by exp(cum_t):
        src = du[..., None] * b_c[:, :, None, :] * jnp.exp(-cum)  # (B,L,din,N)
        csum = jnp.cumsum(src, axis=1)
        h_all = jnp.exp(cum) * csum                   # (B,L,din,N) h_t w/o h0 term
        y_in = jnp.einsum("bldn,bln->bld", h_all, c_c)
        h_new = h * jnp.exp(cum[:, -1]) + h_all[:, -1]
        return h_new, y_h + y_in

    dtc = dt.reshape(bs, nc, l, din).swapaxes(0, 1)
    uc = u.reshape(bs, nc, l, din).swapaxes(0, 1)
    bc = b.reshape(bs, nc, l, n).swapaxes(0, 1)
    cc = c.reshape(bs, nc, l, n).swapaxes(0, 1)
    h, ys = jax.lax.scan(chunk, h0, (dtc, uc, bc, cc))
    return ys.swapaxes(0, 1).reshape(bs, s, din), h


def mamba_mixer(x, p, spec: LayerSpec, *, state=None, chunk_size: int = 1):
    """The S6 mixer (pre-norm residual handled by the caller).

    state: None (full sequence) or {"conv": (B,K-1,din), "ssm": (B,din,N)}.
    Returns (y, new_state).
    """
    bsz, s, d = x.shape
    din = spec.expand * d
    r = dt_rank_for(d)
    n = spec.d_state

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xm, z = xz[..., :din], xz[..., din:]
    conv_state = None if state is None else state["conv"]
    xc, new_conv = causal_conv1d(xm, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    bcdt = jnp.einsum("bse,ef->bsf", xc, p["x_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(bcdt[..., :r] @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    bm = bcdt[..., r:r + n]
    cm = bcdt[..., r + n:]
    a = -jnp.exp(p["A_log"])

    h0 = None if state is None else state["ssm"]
    y, h = selective_scan(xc, dt, a, bm, cm, h0=h0, chunk_size=chunk_size)
    y = y + xc.astype(jnp.float32) * p["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": h}


def init_mamba_state(bsz, d_model, spec: LayerSpec, dtype):
    din = spec.expand * d_model
    return {
        "conv": jnp.zeros((bsz, spec.d_conv - 1, din), dtype),
        "ssm": jnp.zeros((bsz, din, spec.d_state), jnp.float32),
    }
