"""Mixture-of-Experts FFN (token-choice top-k routing, capacity-truncated).

Dispatch strategy: token-choice top-k gates are computed per token; each
expert then takes its top-C tokens by gate weight (capacity truncation of the
token-choice assignment), is applied as a batched (E, C, d) einsum — which
shards cleanly over the ``model`` mesh axis (expert parallelism) — and
results are scatter-added back.  Memory is O(E*C*d) = O(top_k * cap_factor *
tokens * d), never O(tokens * E * C).

Router math runs in fp32 (paper §1.1: Solar Open hit instability from a
router dtype mismatch after sigmoid — 13.7% speedup on fix; we keep the
router numerically isolated by construction).

Aux outputs: load-balance loss (Switch-style) and router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoESpec
from repro.models.layers import swiglu


def init_moe(rng, d_model: int, spec: MoESpec, dtype):
    from repro.models.layers import normal_init
    ks = jax.random.split(rng, 8)
    p = {
        "router": normal_init(ks[0], (d_model, spec.n_experts), jnp.float32),
        "w_gate": normal_init(ks[1], (spec.n_experts, d_model, spec.d_expert), dtype),
        "w_up": normal_init(ks[2], (spec.n_experts, d_model, spec.d_expert), dtype),
        "w_down": normal_init(ks[3], (spec.n_experts, spec.d_expert, d_model), dtype),
    }
    if spec.n_shared:
        f = spec.n_shared * spec.d_expert
        p["shared"] = {
            "w_gate": normal_init(ks[4], (d_model, f), dtype),
            "w_up": normal_init(ks[5], (d_model, f), dtype),
            "w_down": normal_init(ks[6], (f, d_model), dtype),
        }
    return p


def moe_ffn(x, p, spec: MoESpec, *, capacity: int | None = None,
            constraints: bool = False):
    """x: (B, S, d) -> (B, S, d), aux dict of scalar losses.

    ``constraints=True`` pins the dispatch tensors to the EP layout
    (experts -> model axis, capacity tokens -> batch axes) — the §Perf
    collective-term fix for MoE cells."""
    from repro.distributed import context as dist_ctx
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e, k = spec.n_experts, spec.top_k

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (t, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # token-choice gate matrix (t, e): weight of token for its chosen experts
    gate_mat = jnp.sum(
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32) * gate_vals[..., None],
        axis=1)

    # capacity truncation: each expert keeps its top-C tokens by gate weight.
    # Small token counts (decode / tiny batches) use exact routing so that
    # decode(x_t) == forward(x)[t] — capacity drops are a throughput trade
    # that only makes sense at scale.
    if capacity is None:
        if t <= 256:
            capacity = t
        else:
            capacity = max(int(k * t / e * spec.capacity_factor), 1)
    capacity = min(capacity, t)
    w_ec, idx_ec = jax.lax.top_k(gate_mat.T, capacity)             # (e, C)
    if constraints:
        w_ec = dist_ctx.shard_experts(w_ec)
        idx_ec = dist_ctx.shard_experts(idx_ec)

    xe = jnp.take(xf, idx_ec.reshape(-1), axis=0).reshape(e, capacity, d)
    if constraints:
        xe = dist_ctx.shard_experts(xe)
    # batched expert FFN (shards over the expert axis -> EP)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if constraints:
        ye = dist_ctx.shard_experts(ye)
    ye = ye * w_ec[..., None].astype(ye.dtype)

    out = jnp.zeros((t, d), ye.dtype).at[idx_ec.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    if constraints:
        out = dist_ctx.shard_batch(out)

    if spec.n_shared:
        out = out + swiglu(xf, **{k_: v for k_, v in p["shared"].items()})

    # Switch load-balance loss + z-loss
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).sum(1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs) / k
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"lb_loss": lb_loss, "z_loss": z_loss}
    return out.reshape(b, s, d).astype(x.dtype), aux
