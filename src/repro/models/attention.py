"""Attention backends.

Three interchangeable implementations of causal (optionally sliding-window,
optionally logit-softcapped) grouped-query attention:

* ``naive``   — single einsum materialising the full (Sq, Sk) score matrix.
                Paper-faithful baseline; memory term scales O(S^2).
* ``chunked`` — flash-attention algorithm in pure jnp: online softmax over
                statically-unrolled (q_chunk x kv_chunk) blocks with static
                causal/window block skipping.  This is the memory-optimised
                path the dry-run can lower on any backend.
* ``pallas``  — the TPU kernel in ``repro.kernels.flash_attention`` (same
                block decomposition, explicit VMEM BlockSpecs); validated in
                interpret mode, selected on real TPU runs.

All shapes are (batch, seq, heads, head_dim); GQA is expressed by reshaping
queries to (B, S, n_kv, group, D).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _gqa_split(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _apply_softcap(scores, cap):
    if cap:
        scores = cap * jnp.tanh(scores / cap)
    return scores


# ---------------------------------------------------------------------------
# naive backend
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, window=0, attn_softcap=0.0, q_offset=None,
                    kv_len=None, causal=True):
    """Full-matrix attention.

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D).
    ``q_offset``: absolute position of q[0] (traced ok) — decode passes the
    cache write position; defaults to Sk - Sq (aligned suffix).
    ``kv_len``: number of valid cache entries (traced ok) for decode.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    qg = _gqa_split(q, hkv)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = _apply_softcap(scores, attn_softcap)

    q_pos = jnp.arange(sq) + (q_offset if q_offset is not None else sk - sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked (flash) backend — full-sequence processing (train / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, window=0, attn_softcap=0.0,
                      q_chunk=1024, kv_chunk=1024, bf16_math=False):
    """Online-softmax blocked attention with static block skipping.

    Requires Sq == Sk (self-attention over a full sequence, offset 0) and
    chunk sizes dividing the sequence.  Causal always on.  ``window`` is a
    *static* int (0 = global).
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    cq = min(q_chunk, s)
    ck = min(kv_chunk, s)
    if s % cq or s % ck:     # ragged sequence: exact fallback
        return naive_attention(q, k, v, window=window,
                               attn_softcap=attn_softcap)
    nq, nk = s // cq, s // ck
    scale = 1.0 / math.sqrt(d)
    # bf16_math: keep q/k/v in bf16 and let the MXU accumulate in fp32
    # (preferred_element_type) — halves score-path HBM traffic; softmax
    # statistics stay fp32 either way.
    in_dt = q.dtype if bf16_math else jnp.float32
    qg = (_gqa_split(q, hkv) * jnp.asarray(scale, q.dtype)).astype(in_dt)
    kf = k.astype(in_dt)
    vf = v.astype(in_dt)

    outs = []
    for i in range(nq):
        q_blk = qg[:, i * cq:(i + 1) * cq]                       # (B,cq,hkv,g,D)
        # static block range: causal upper bound, window lower bound
        j_hi = ((i + 1) * cq - 1) // ck          # last kv chunk with any valid key
        j_lo = max(0, (i * cq - window + 1) // ck) if window else 0
        m = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, cq), jnp.float32)
        acc = jnp.zeros((b, hkv, g, cq, d), jnp.float32)
        for j in range(j_lo, j_hi + 1):
            k_blk = kf[:, j * ck:(j + 1) * ck]
            v_blk = vf[:, j * ck:(j + 1) * ck]
            sc = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                            preferred_element_type=jnp.float32)
            sc = _apply_softcap(sc, attn_softcap)
            # masking needed only on blocks crossing the causal diagonal or
            # the window edge
            q_pos = jnp.arange(cq) + i * cq
            k_pos = jnp.arange(ck) + j * ck
            need_causal = j * ck + ck - 1 > i * cq          # block reaches above diag
            need_window = window and (i * cq + cq - 1) - (j * ck) >= window
            if need_causal or need_window:
                blk_mask = jnp.ones((cq, ck), bool)
                if need_causal:
                    blk_mask &= q_pos[:, None] >= k_pos[None, :]
                if need_window:
                    blk_mask &= (q_pos[:, None] - k_pos[None, :]) < window
                sc = jnp.where(blk_mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(in_dt), v_blk,
                preferred_element_type=jnp.float32)
            m = m_new
        out_blk = acc / jnp.maximum(l[..., None], 1e-37)
        outs.append(out_blk)                                    # (B,hkv,g,cq,D)
    out = jnp.concatenate(outs, axis=3)                          # (B,hkv,g,S,D)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, s, h, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def self_attention(q, k, v, *, window=0, attn_softcap=0.0, backend="chunked",
                   q_chunk=1024, kv_chunk=1024, bf16_math=False):
    """Full-sequence causal self-attention (train / prefill path)."""
    if backend == "naive":
        return naive_attention(q, k, v, window=window, attn_softcap=attn_softcap)
    if backend == "chunked":
        return chunked_attention(q, k, v, window=window, attn_softcap=attn_softcap,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 bf16_math=bf16_math)
    if backend == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, window=window, attn_softcap=attn_softcap)
    raise ValueError(f"unknown attention backend {backend!r}")


def decode_attention(q, k_cache, v_cache, pos, *, window=0, attn_softcap=0.0):
    """Single-token decode against a (B, S_max, Hkv, D) cache.

    ``pos`` (traced scalar): index of the token being decoded; cache entries
    at positions <= pos are valid.
    """
    return naive_attention(q, k_cache, v_cache, window=window,
                           attn_softcap=attn_softcap, q_offset=pos,
                           kv_len=pos + 1)


def cross_attention(q, k, v):
    """Non-causal attention over a fixed encoder sequence (VLM image tokens)."""
    return naive_attention(q, k, v, causal=False)
