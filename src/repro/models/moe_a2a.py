"""Expert-parallel MoE dispatch via explicit all-to-all (shard_map).

§Perf iteration 8 found GSPMD lowers the constraint-hinted dispatch as
"all-gather every token to every expert group" — tokens x d x data_axis
bytes per MoE layer.  This module routes each token ONCE: tokens are binned
by destination expert shard on their home device, exchanged with a single
`all_to_all` over the ``model`` axis, computed against the LOCAL expert
slice, and returned by the mirror all_to_all; gate weighting and the
combine happen back on the token's home device.

Per-layer collective volume drops from O(T·d·n_model) to O(T·d·k·slack)
(~20x at solar's shapes — napkin math in EXPERIMENTS.md §Perf iter 8).

Caveats (by design, documented):
* fixed per-(src,dst) capacity: C_send = ceil(k·T_local/n_model · slack);
  overflow tokens are dropped exactly like capacity drops in the dense
  dispatch (load-balance loss keeps this rare);
* requires n_experts % model_axis == 0 and tokens % data_size == 0 —
  callers fall back to the constraint-hinted path otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoESpec
from repro.models.layers import swiglu

# jax >= 0.6 promotes shard_map to the top level (replication checking via
# ``check_vma``); 0.4.x ships it under experimental with ``check_rep``
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _NO_CHECK = {"check_vma": False}
else:                                     # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map
    _NO_CHECK = {"check_rep": False}


def _local_expert_compute(xe, expert_ids, p, n_local, capacity):
    """Compute the local expert slice over received tokens.

    xe: (R, d) received tokens; expert_ids: (R,) LOCAL expert index (or -1
    for padding).  Gathers per-expert top-capacity rows, einsums, scatters
    back.  Returns (R, d).
    """
    r, d = xe.shape
    # one-hot priority: valid rows first
    prio = jnp.where(expert_ids[None, :] == jnp.arange(n_local)[:, None],
                     1.0, 0.0)                            # (E_l, R)
    cap = min(capacity, r)
    w, idx = jax.lax.top_k(prio, cap)                     # (E_l, cap)
    valid = w > 0.5
    rows = jnp.take(xe, idx.reshape(-1), axis=0).reshape(n_local, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", rows, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", rows, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = ye * valid[..., None].astype(ye.dtype)
    out = jnp.zeros((r, d), ye.dtype).at[idx.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    return out


def moe_ffn_a2a(x, p, spec: MoESpec, mesh, *, batch_axes=("data",),
                model_axis: str = "model", slack: float = 2.0):
    """Drop-in MoE FFN with explicit a2a dispatch.  x: (B, S, d).

    Must be traced under ``mesh``; x is assumed batch-sharded over
    ``batch_axes`` and replicated over ``model_axis``.
    """
    b, s, d = x.shape
    t = b * s
    e, k = spec.n_experts, spec.top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes[model_axis]
    n_data = 1
    for a in batch_axes:
        n_data *= sizes.get(a, 1)
    assert e % n_model == 0 and t % (n_data * n_model) == 0
    e_local = e // n_model
    # tokens are sharded over BOTH axes inside the shard_map (each device
    # owns t/(data*model) tokens and routes only those)
    t_local = t // (n_data * n_model)
    c_send = max(int(-(-k * t_local // n_model) * slack), 4)

    xf = x.reshape(t, d)
    # router (tiny): plain GSPMD
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    tok_axes = tuple(batch_axes) + (model_axis,)

    def body(xf_l, gi_l, gv_l, wg, wu, wd):
        # xf_l: (t_local, d); gi_l/gv_l: (t_local, k); w*: (e_local, ...)
        tl = xf_l.shape[0]
        flat_expert = gi_l.reshape(-1)                    # (tl*k,)
        flat_tok = jnp.repeat(jnp.arange(tl), k)
        flat_w = gv_l.reshape(-1)
        dst = flat_expert // e_local                      # (tl*k,)
        # per destination shard: pick up to c_send assignments
        prio = jnp.where(dst[None, :] == jnp.arange(n_model)[:, None],
                         flat_w[None, :] + 1e-6, 0.0)     # (n_model, tl*k)
        sel_w, sel = jax.lax.top_k(prio, min(c_send, tl * k))
        valid = sel_w > 0.0                               # (n_model, c_send)
        tok_rows = jnp.take(flat_tok, sel.reshape(-1)).reshape(n_model, -1)
        exp_ids = jnp.take(flat_expert, sel.reshape(-1)).reshape(n_model, -1)
        send = jnp.take(xf_l, tok_rows.reshape(-1), axis=0) \
            .reshape(n_model, -1, d)                      # (n_model, C, d)
        exp_local = jnp.where(valid, exp_ids % e_local, -1)

        # exchange tokens + local-expert ids across the model axis
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        recv_eid = jax.lax.all_to_all(exp_local, model_axis, split_axis=0,
                                      concat_axis=0, tiled=False)
        rr = recv.reshape(-1, d)
        # capacity = all received rows: no second-stage drops (R ~ k*tl*slack)
        ye = _local_expert_compute(
            rr, recv_eid.reshape(-1),
            {"w_gate": wg, "w_up": wu, "w_down": wd},
            e_local, capacity=rr.shape[0])
        ye = ye.reshape(n_model, -1, d)

        # mirror exchange back to the token home shards
        back = jax.lax.all_to_all(ye, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # weighted combine at home
        contrib = back * (sel_w * valid).reshape(n_model, -1, 1) \
            .astype(back.dtype)
        out = jnp.zeros((tl, d), back.dtype).at[tok_rows.reshape(-1)].add(
            contrib.reshape(-1, d), mode="drop")
        return out        # home tokens are disjoint across devices

    shard = _shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_axes, None), P(tok_axes, None), P(tok_axes, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=P(tok_axes, None),
        **_NO_CHECK)
    out = shard(xf, gate_idx, gate_vals.astype(xf.dtype),
                p["w_gate"], p["w_up"], p["w_down"])

    if spec.n_shared:
        out = out + swiglu(xf, **p["shared"])

    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).sum(1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs) / k
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out.reshape(b, s, d).astype(x.dtype), \
        {"lb_loss": lb_loss, "z_loss": z_loss}
