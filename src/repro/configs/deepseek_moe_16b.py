"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400.

Fine-grained MoE: 2 shared + 64 routed experts, top-6, per-expert d_ff=1408.
Layer 0 uses a dense FFN (d_ff = 64*1408/... the dense layer uses the full
10944 hidden in the original; we use 4*1408*2=11264-class scale via the
documented 1408*8). [arXiv:2401.06066; hf]
"""
from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

MOE = MoESpec(n_experts=64, top_k=6, d_expert=1408, n_shared=2)

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408 * 8,   # dense layer-0 FFN hidden (10944 in HF; 8*d_expert here)
    vocab_size=102400,
    prefix=(LayerSpec(kind="attn", window=0, moe=None),),
    period=(LayerSpec(kind="attn", window=0, moe=MOE),),
    n_periods=27,
    source="arXiv:2401.06066; hf",
))
