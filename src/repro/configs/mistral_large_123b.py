"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

Pure full attention. [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
The largest dense arch in the pool — checkpoint-volume stress case for the
paper's two-phase save path (F2).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="mistral-large-123b",
    family="dense",
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    period=(LayerSpec(kind="attn", window=0),),
    n_periods=88,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
))
