"""Architecture + shape configuration system.

Every assigned architecture is expressed as an ``ArchConfig`` built from a
repeating *period* of ``LayerSpec`` entries.  The period structure keeps the
lowered HLO size O(period) instead of O(depth): the layer stack is a
``lax.scan`` over ``n_periods`` stacked parameter trees, with the (static)
heterogeneous structure unrolled *inside* the scanned body.  Optional
``prefix``/``suffix`` layers are unrolled outside the scan for depths that are
not a multiple of the period (e.g. gemma3's 62 = 10*6 + 2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer specification
# ---------------------------------------------------------------------------

# layer kinds
ATTN = "attn"          # self attention (global or sliding window) + FFN
CROSS_ATTN = "cross"   # cross attention over image/frame embeddings + FFN
MAMBA = "mamba"        # S6 selective-scan block + FFN
RWKV = "rwkv"          # RWKV6 time-mix + channel-mix (its own FFN)


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # always-on shared experts (deepseek style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"  # paper §1.1: router dtype mismatch caused
                                   # instability -> keep router math in fp32


@dataclass(frozen=True)
class LayerSpec:
    kind: str = ATTN
    window: int = 0               # 0 = global attention; >0 = sliding window
    moe: Optional[MoESpec] = None  # None = dense FFN
    # mamba-specific
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | audio | vlm | ssm | hybrid
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    period: Tuple[LayerSpec, ...]
    n_periods: int
    prefix: Tuple[LayerSpec, ...] = ()
    suffix: Tuple[LayerSpec, ...] = ()
    head_dim: int = 0             # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0     # gemma2: 50.0
    logit_softcap: float = 0.0    # gemma2: 30.0
    embed_inputs: bool = True     # False -> frontend stub provides embeddings
    n_img_tokens: int = 0         # >0 for cross-attention (VLM) archs
    # rwkv
    rwkv_head_dim: int = 64
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # citation bookkeeping
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + self.n_periods * len(self.period) + len(self.suffix)

    @property
    def layers(self) -> Tuple[LayerSpec, ...]:
        return self.prefix + self.period * self.n_periods + self.suffix

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_attention_free(self) -> bool:
        return all(l.kind in (RWKV, MAMBA) for l in self.layers)

    @property
    def is_pure_full_attention(self) -> bool:
        """True when every layer is global full attention (quadratic)."""
        ks = self.layers
        return all(l.kind in (ATTN, CROSS_ATTN) for l in ks) and all(
            l.window == 0 for l in ks if l.kind == ATTN
        )

    @property
    def supports_long_context(self) -> bool:
        """long_500k cells run only for sub-quadratic architectures."""
        return not self.is_pure_full_attention

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        for l in self.layers:
            if l.kind in (ATTN, CROSS_ATTN):
                total += d * self.n_heads * hd            # wq
                total += 2 * d * self.n_kv_heads * hd     # wk, wv
                total += self.n_heads * hd * d            # wo
                total += 2 * d                            # norms
                if self.qk_norm:
                    total += 2 * hd
                total += self._ffn_params(l)
            elif l.kind == MAMBA:
                din = l.expand * d
                dt_rank = max(d // 16, 1)
                total += d * 2 * din + din * l.d_conv
                total += din * (dt_rank + 2 * l.d_state) + dt_rank * din
                total += din * l.d_state + din + din * d
                total += 2 * d
                total += self._ffn_params(l)
            elif l.kind == RWKV:
                h = d // self.rwkv_head_dim
                total += 6 * d + 2 * d * 64 + 64 * d      # mus + decay lora
                total += 5 * d * d + h * self.rwkv_head_dim  # r,k,v,g,o + u
                total += 2 * d                            # ln_x
                total += 2 * d * self.d_ff + d * d        # channel mix
                total += 2 * d                            # norms
        return total

    def _ffn_params(self, l: LayerSpec) -> int:
        d = self.d_model
        if l.moe is None:
            return 3 * d * self.d_ff
        m = l.moe
        dense = 3 * d * m.d_expert * m.n_experts
        shared = 3 * d * m.d_expert * m.n_shared
        router = d * m.n_experts
        return dense + shared + router

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top_k + shared only)."""
        total = self.n_params()
        for l in self.layers:
            if l.moe is not None:
                m = l.moe
                inactive = 3 * self.d_model * m.d_expert * (m.n_experts - m.top_k)
                total -= inactive
        return total

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        d = 64
        small = dict(
            d_model=d,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            n_periods=min(self.n_periods, 2),
            n_img_tokens=8 if self.n_img_tokens else 0,
            rwkv_head_dim=16,
            param_dtype="float32",
            compute_dtype="float32",
        )

        def shrink(l: LayerSpec) -> LayerSpec:
            moe = l.moe
            if moe is not None:
                moe = dataclasses.replace(
                    moe,
                    n_experts=min(moe.n_experts, 4),
                    top_k=min(moe.top_k, 2),
                    d_expert=32,
                    n_shared=min(moe.n_shared, 1),
                )
            return dataclasses.replace(
                l, moe=moe, window=min(l.window, 8) if l.window else 0,
                d_state=4, d_conv=4, expand=2,
            )

        small["period"] = tuple(shrink(l) for l in self.period)
        small["prefix"] = tuple(shrink(l) for l in self.prefix)
        small["suffix"] = tuple(shrink(l) for l in self.suffix[:1])
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input shapes (assigned set — identical for all 10 LM-family archs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell, else a skip reason."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode requires sub-quadratic attention (DESIGN.md §3)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all():
    # importing the modules populates the registry
    from repro.configs import (  # noqa: F401
        gemma3_27b, mistral_large_123b, gemma2_2b, stablelm_3b,
        deepseek_moe_16b, granite_moe_1b_a400m, musicgen_large,
        llama32_vision_90b, rwkv6_3b, jamba_v01_52b, paper_solar,
    )
