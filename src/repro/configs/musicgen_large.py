"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only transformer over EnCodec tokens. [arXiv:2306.05284; hf]

Per the assignment, only the transformer BACKBONE is modelled; the EnCodec
modality frontend is a STUB — ``input_specs()`` provides precomputed frame
embeddings of shape (batch, seq, d_model) (the sum of the 4 codebook
embeddings under the delay pattern), and the output head predicts the
2048-way codebook for stream 0.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    period=(LayerSpec(kind="attn", window=0),),
    n_periods=48,
    embed_inputs=False,   # frontend stub provides embeddings
    source="arXiv:2306.05284; hf",
))
