"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

local(4096)+global alternating, attention logit softcap 50, final logit
softcap 30, head_dim=256, tied embeddings. [arXiv:2408.00118; hf]
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="gemma2-2b",
    family="dense",
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    period=(LayerSpec(kind="attn", window=4096), LayerSpec(kind="attn", window=0)),
    n_periods=13,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
))
