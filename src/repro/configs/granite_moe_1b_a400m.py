"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155.

32 routed experts, top-8, per-expert hidden 512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Note: vocab 49155 = 3*5*29*113 is divisible by no mesh axis — exercises the
sharding helper's fallback path (embedding sharded on d_model instead).
"""
from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

MOE = MoESpec(n_experts=32, top_k=8, d_expert=512, n_shared=0)

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    period=(LayerSpec(kind="attn", window=0, moe=MOE),),
    n_periods=24,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
