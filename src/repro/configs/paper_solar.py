"""paper-solar-102b — the paper's own workload (Solar Open, arXiv:2601.07022).

102B-total / 12B-active bilingual MoE trained on the studied 504-GPU cluster
(paper §1.1, Table 5).  Public details: 102B MoE, 12B active.  Exact layer
geometry is not published; we use a consistent MoE geometry matching the
total/active parameter budget (verified by ``n_params()``/``n_active_params()``
in the smoke test) so that checkpoint volumes and step costs in the
operational benchmarks are representative of the paper's campaign.

Training configuration from the paper (Table 5): HSDP (sharding group x
replicas), global batch 13,440 at seq 4K -> progressive 32K -> 100K.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register, ShapeConfig

# 48L d_model=6144, 64 routed experts top-3 + 1 shared, d_expert=1664:
#   total  = 64 experts*3*d*d_e*47 + shared + attn + embed ~= 100B
#   active = (3+1)*3*d*d_e*47 + attn + embed ~= 12B
MOE = MoESpec(n_experts=64, top_k=3, d_expert=1664, n_shared=1)

CONFIG = register(ArchConfig(
    name="paper-solar-102b",
    family="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=131072,
    prefix=(LayerSpec(kind="attn", window=0, moe=None),),
    period=(LayerSpec(kind="attn", window=0, moe=MOE),),
    n_periods=47,
    rope_theta=1_000_000.0,
    source="arXiv:2601.07022 (Solar Open); geometry inferred from 102B/12B budget",
))

# The paper's own training shapes (Table 5 / §4.2.1), registered as extra
# dry-run shapes (scaled 1/4: the paper ran 480 GPUs-worth of batch per
# replica group; our single pod is 256 chips):
PAPER_SHAPES = {
    "solar_4k": ShapeConfig("solar_4k", 4_096, 13_440 // 4, "train"),
    "solar_32k": ShapeConfig("solar_32k", 32_768, 1_440 // 4, "train"),
}
