"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536.

RWKV-6 "Finch" — linear attention with data-dependent decay.
[arXiv:2404.05892; hf]

Attention-free: O(1) decode state -> runs the long_500k cell.
head_dim=64 (40 heads).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    d_model=2560,
    n_heads=40,          # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    period=(LayerSpec(kind="rwkv"),),
    n_periods=32,
    rwkv_head_dim=64,
    source="arXiv:2404.05892; hf",
))
