"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Mamba + attention 1:7 interleave, MoE 16 experts top-2 every other layer.
[arXiv:2403.19887; hf]

32 layers = 4 Jamba blocks of 8; within each block one attention layer and
seven Mamba layers; MoE replaces the dense FFN on alternate layers
(positions 1,3,5,7 of each block). The attention layer sits at position 0 of
the block here (the HF release places it mid-block; position within the
period does not change parameter count or cost — noted in DESIGN.md §8).
Sub-quadratic for decode (attention in 4/32 layers) -> runs long_500k.
Mamba: d_state=16, d_conv=4, expand=2.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register

MOE = MoESpec(n_experts=16, top_k=2, d_expert=14336, n_shared=0)

ATT_D = LayerSpec(kind="attn", window=0, moe=None)
MAM_D = LayerSpec(kind="mamba", moe=None)
MAM_E = LayerSpec(kind="mamba", moe=MOE)

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    period=(ATT_D, MAM_E, MAM_D, MAM_E, MAM_D, MAM_E, MAM_D, MAM_E),
    n_periods=4,
    source="arXiv:2403.19887; hf",
))
