"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.

5:1 local:global attention (sliding window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]

62 layers = 10 periods of (5 local + 1 global) + 2 trailing local layers.
Gemma3 uses qk-norm, tied embeddings, head_dim=128.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

LOCAL = LayerSpec(kind="attn", window=1024)
GLOBAL = LayerSpec(kind="attn", window=0)

CONFIG = register(ArchConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    period=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),
    n_periods=10,
    suffix=(LOCAL, LOCAL),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    qk_norm=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
