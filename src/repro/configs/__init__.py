from repro.configs.base import (
    ArchConfig, LayerSpec, MoESpec, ShapeConfig, SHAPES,
    all_configs, get_config, register, cell_is_runnable,
    ATTN, CROSS_ATTN, MAMBA, RWKV,
)

ASSIGNED_ARCHS = [
    "gemma3-27b",
    "mistral-large-123b",
    "gemma2-2b",
    "stablelm-3b",
    "deepseek-moe-16b",
    "granite-moe-1b-a400m",
    "musicgen-large",
    "llama-3.2-vision-90b",
    "rwkv6-3b",
    "jamba-v0.1-52b",
]
