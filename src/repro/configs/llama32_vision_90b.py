"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

Cross-attention image layers every 5th layer (20 cross + 80 self = 100).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Per the assignment, the vision frontend is a STUB: ``input_specs()`` provides
precomputed image patch embeddings (batch, n_img_tokens, d_model); only the
transformer backbone (self-attn decoder + gated cross-attn layers) is built.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

SELF = LayerSpec(kind="attn", window=0)
CROSS = LayerSpec(kind="cross", window=0)

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    period=(SELF, SELF, SELF, SELF, CROSS),
    n_periods=20,
    rope_theta=500_000.0,
    n_img_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
))
