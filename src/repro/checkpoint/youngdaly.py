"""Young/Daly checkpoint-interval optimisation — paper §4.2.2 (Tables 10-11).

T_opt = sqrt(2 * delta * M)   (Young's first-order approximation [19])

cost(T) = delta/T  (save overhead)  +  T/(2M)  (expected lost work fraction)

The paper's operational lesson: delta is small (18-31.7 s), so short
intervals are cheap — the 100K phase's 81.5-minute interval landed within
0.10 pp of the theoretical optimum.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

MTBF_H_PAPER = 56.2


@dataclass(frozen=True)
class PhaseProfile:
    """One training phase (paper Table 10/11)."""
    name: str
    delta_s: float                 # checkpoint save duration
    interval_min: float            # actual checkpoint interval
    episodes: int = 0


# paper Table 10/11 rows
PAPER_PHASES = [
    PhaseProfile("4K sequence", 18.0, 133.5, 466),
    PhaseProfile("32K sequence", 31.7, 199.0, 36),
    PhaseProfile("100K sequence", 30.0, 81.5, 21),
]


def t_opt_s(delta_s: float, mtbf_h: float = MTBF_H_PAPER) -> float:
    return math.sqrt(2.0 * delta_s * mtbf_h * 3600.0)


def cost_fraction(interval_s: float, delta_s: float,
                  mtbf_h: float = MTBF_H_PAPER) -> float:
    """Expected overhead fraction: save overhead + expected lost work."""
    m_s = mtbf_h * 3600.0
    return delta_s / interval_s + interval_s / (2.0 * m_s)


def save_overhead_fraction(interval_s: float, delta_s: float) -> float:
    return delta_s / interval_s


def phase_table(mtbf_h: float = MTBF_H_PAPER):
    """Reproduce paper Table 11."""
    rows = []
    for ph in PAPER_PHASES:
        interval_s = ph.interval_min * 60.0
        rows.append({
            "phase": ph.name,
            "delta_s": ph.delta_s,
            "actual_interval_min": ph.interval_min,
            "t_opt_min": t_opt_s(ph.delta_s, mtbf_h) / 60.0,
            "save_overhead_pct": 100 * save_overhead_fraction(interval_s, ph.delta_s),
            "total_cost_pct": 100 * cost_fraction(interval_s, ph.delta_s, mtbf_h),
            "optimal_cost_pct": 100 * cost_fraction(
                t_opt_s(ph.delta_s, mtbf_h), ph.delta_s, mtbf_h),
        })
    return rows


def estimate_delta_from_spikes(n_samples_mean: float,
                               scrape_interval_s: float = 30.0) -> float:
    """Paper Table 10 method: delta ~= (N_bar - 0.5) * scrape interval, from
    the mean number of consecutive scrape samples an NFS write spike spans.
    (N_bar samples cover between (N_bar-1) and N_bar intervals; the paper
    uses a point estimate consistent with delta = (N_bar - 1 + 0.5) * 30 s.)
    """
    return (n_samples_mean - 0.5) * scrape_interval_s


def empirical_lost_time(failure_times_h: np.ndarray,
                        interval_h: float) -> np.ndarray:
    """Lost work per failure given uniform checkpoint grid (for MC
    validation of the T/2M expectation)."""
    return failure_times_h % interval_h


def mc_cost_fraction(interval_s: float, delta_s: float, mtbf_h: float,
                     n: int = 100_000, seed: int = 0) -> float:
    """Monte-Carlo estimate of the total overhead fraction under
    exponential failures (validates the analytic model; used by the
    hypothesis tests)."""
    rng = np.random.default_rng(seed)
    m_s = mtbf_h * 3600.0
    # time between failures
    uptimes = rng.exponential(m_s, n)
    lost = uptimes % interval_s
    # overhead = (saves during uptime * delta + lost) / uptime
    saves = np.floor(uptimes / interval_s)
    return float((saves * delta_s + lost).sum() / uptimes.sum())
