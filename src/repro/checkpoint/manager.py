"""Two-phase asynchronous checkpointing — paper §4.2.4 (Fig 9 save path).

Phase 1 (BLOCKING, pauses training): device state -> host staging buffer
(the paper's pre-allocated /dev/shm region; here host RAM via
``jax.device_get`` into a reused buffer pool).

Phase 2 (ASYNC, training resumes): staging buffer -> storage through the
RPC-slot-limited NFS client view of the shared storage fabric (timing) and
a real local filesystem backend (durability).  Float32 tensors route
through the ``ckpt_pack`` path (Pallas kernel on TPU, its jitted XLA
reference elsewhere): the bf16 payload halves the RPC-constrained wire
volume that the fabric charges for the save, and the per-block wrapping
uint32 checksums replace the numpy xor-fold for integrity.  Non-f32
tensors keep the xor-fold and full-width payloads.  The on-disk bytes are
always the exact full-precision staging buffers, so restore-and-resume
reproduces the uninterrupted run bit-for-bit (paper Table 6).

Restore follows the load path: files -> host buffers (verify checksums) ->
device.  The save cascade ordering (GPU pause -> staging -> write() ->
writeback -> RPC backlog) is observable through the returned timeline,
which the checkpoint-path benchmark asserts against Fig 9.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.storage import NFSClientSim, TransferResult
from repro.storage.fabric import StorageFabric


# ---------------------------------------------------------------------------
# (de)serialization of pytrees
# ---------------------------------------------------------------------------

def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def xor_fold_checksum(buf: np.ndarray) -> int:
    """Whole-tensor xor-fold (the non-f32 / legacy checksum)."""
    raw = buf.tobytes()
    pad = (-len(raw)) % 8
    arr = np.frombuffer(raw + b"\x00" * pad, dtype=np.uint64)
    return int(np.bitwise_xor.reduce(arr)) if arr.size else 0


@dataclass
class SaveTimeline:
    """Timestamps of the save cascade (relative seconds)."""
    t_pause: float = 0.0          # training paused (phase-1 start)
    t_staged: float = 0.0         # device->host copy complete (training resumes)
    t_write_done: float = 0.0     # write() path complete (real fs)
    t_rpc_done: float = 0.0       # modeled NFS RPC drain complete
    bytes_staged: int = 0
    bytes_wire: int = 0           # RPC volume after ckpt_pack (bf16 for f32)
    rpc: Optional[TransferResult] = None

    @property
    def blocking_s(self) -> float:
        return self.t_staged - self.t_pause

    @property
    def async_s(self) -> float:
        return max(self.t_write_done, self.t_rpc_done) - self.t_staged

    def cascade_ordered(self) -> bool:
        return self.t_pause <= self.t_staged <= \
            max(self.t_write_done, self.t_rpc_done) + 1e-9


@dataclass
class CheckpointRecord:
    step: int
    path: str
    bytes: int
    timeline: SaveTimeline
    # key -> xor-fold int, or uint32 block-checksum array (ckpt_pack)
    checksums: Dict[str, Any] = field(default_factory=dict)


@dataclass
class RestoreResult:
    """Restored state + the simulated load timing.

    Iterates as ``(state, step)`` so existing ``state, step = restore()``
    call sites keep working."""
    state: Any
    step: int
    load_rpc: Optional[TransferResult] = None

    def __iter__(self):
        return iter((self.state, self.step))


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3,
                 nfs: Optional[NFSClientSim] = None,
                 fabric: Optional[StorageFabric] = None,
                 simulate_rpc: bool = True,
                 pack: str = "kernel"):
        if pack not in ("kernel", "xor"):
            raise ValueError(f"unknown pack mode {pack!r}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # the NFS client view shares the (possibly passed-in) fabric, so
        # manager timing reflects cluster-scale contention
        self.nfs = nfs or NFSClientSim(fabric=fabric)
        self.simulate_rpc = simulate_rpc
        self.pack = pack
        self.last_load_rpc: Optional[TransferResult] = None
        self._staging: Dict[str, np.ndarray] = {}   # reused buffer pool
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.records: List[CheckpointRecord] = []

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------

    def save(self, step: int, state, *, blocking: bool = False
             ) -> CheckpointRecord:
        """Two-phase save. Returns immediately after phase 1 unless
        ``blocking``; call ``wait()`` to join phase 2."""
        self.wait()                       # one in-flight save at a time
        tl = SaveTimeline(t_pause=time.perf_counter())

        # -- phase 1: device -> staging (blocking; training is paused) --
        flat = _flatten(state)
        total = 0
        for key, arr in flat.items():
            buf = self._staging.get(key)
            if buf is None or buf.shape != arr.shape or buf.dtype != arr.dtype:
                buf = np.empty_like(arr)
                self._staging[key] = buf
            np.copyto(buf, arr)
            total += buf.nbytes
        tl.bytes_staged = total
        tl.t_staged = time.perf_counter()

        record = CheckpointRecord(step=step, path=str(self._step_dir(step)),
                                  bytes=total, timeline=tl)

        # -- phase 2: staging -> storage (async; training resumes) --
        def flush():
            try:
                self._write_files(step, record)
                tl.t_write_done = time.perf_counter()
                if self.simulate_rpc:
                    tl.rpc = self.nfs.checkpoint_save(
                        bytes_per_node=tl.bytes_wire)
                tl.t_rpc_done = time.perf_counter()
                self.records.append(record)
                self._gc()
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=flush, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()
        return record

    def _write_files(self, step: int, record: CheckpointRecord):
        d = self._step_dir(step)
        tmp = d.with_suffix(".tmp")
        tmp.mkdir(parents=True, exist_ok=True)
        index = {}
        wire = 0
        with open(tmp / "data.bin", "wb") as f:
            for key, buf in self._staging.items():
                start = f.tell()
                f.write(buf.tobytes())
                entry = {"offset": start, "nbytes": buf.nbytes,
                         "shape": list(buf.shape), "dtype": str(buf.dtype)}
                if self.pack == "kernel" and buf.dtype == np.float32:
                    # ckpt_pack path: bf16 wire volume + block checksums.
                    # Only the checksums are consumed here (the packed
                    # payload models wire bytes, not on-disk bytes), so use
                    # the numpy routine the restore path verifies with —
                    # bit-identical to the kernel (asserted by the
                    # kernel-vs-xor parity test) without a tensor-sized
                    # discarded allocation or a per-shape jit compile
                    from repro.kernels.ckpt_pack.ref import \
                        block_checksums_np
                    chk = block_checksums_np(buf)
                    record.checksums[key] = chk
                    entry["checksum_kind"] = "ckpt_pack"
                    entry["checksums"] = chk.tolist()
                    # bf16 halves the fp32 volume; the kernel's zero block
                    # padding is a layout artifact, not wire payload
                    wire += buf.nbytes // 2
                else:
                    csum = xor_fold_checksum(buf)
                    record.checksums[key] = csum
                    entry["checksum"] = csum
                    wire += buf.nbytes
                index[key] = entry
        record.timeline.bytes_wire = wire
        (tmp / "index.json").write_text(json.dumps(
            {"step": step, "tensors": index}))
        if d.exists():
            import shutil
            shutil.rmtree(d)
        tmp.rename(d)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        self.wait()
        steps = [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                 if p.is_dir()]
        return max(steps) if steps else None

    def _read_index(self, d: Path, step: int) -> dict:
        try:
            meta = json.loads((d / "index.json").read_text())
            meta["tensors"]        # presence check: partial writes
            return meta
        except (json.JSONDecodeError, KeyError, FileNotFoundError) as e:
            raise IOError(
                f"corrupt or partial checkpoint index for step {step} "
                f"under {d}: {e}") from e

    @staticmethod
    def _verify_tensor(key: str, step: int, arr: np.ndarray, info: dict):
        kind = info.get("checksum_kind", "xor")
        if kind == "ckpt_pack":
            from repro.kernels.ckpt_pack.ref import block_checksums_np
            got = block_checksums_np(arr)
            want = np.asarray(info["checksums"], dtype=np.uint32)
            if got.shape != want.shape or not np.array_equal(got, want):
                raise IOError(
                    f"ckpt_pack block-checksum mismatch for {key} "
                    f"@step {step}")
        elif xor_fold_checksum(arr) != info["checksum"]:
            raise IOError(f"checksum mismatch for {key} @step {step}")

    def restore(self, step: Optional[int] = None, *, like=None,
                verify: bool = True) -> RestoreResult:
        """Load a checkpoint; if ``like`` is given, reassemble that pytree
        structure (values replaced), else the flat dict.  Returns a
        `RestoreResult` (iterates as ``(state, step)``) carrying the
        simulated load timing."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        meta = self._read_index(d, step)
        flat: Dict[str, np.ndarray] = {}
        rpc_bytes = 0
        with open(d / "data.bin", "rb") as f:
            for key, info in meta["tensors"].items():
                f.seek(info["offset"])
                raw = f.read(info["nbytes"])
                if len(raw) != info["nbytes"]:
                    raise IOError(f"truncated payload for {key} "
                                  f"@step {step}")
                arr = np.frombuffer(raw, dtype=np.dtype(info["dtype"])) \
                    .reshape(info["shape"]).copy()
                if verify:
                    self._verify_tensor(key, step, arr, info)
                flat[key] = arr
                rpc_bytes += info["nbytes"]
        load_rpc = None
        if self.simulate_rpc:
            load_rpc = self.nfs.checkpoint_load(bytes_per_node=rpc_bytes)
        self.last_load_rpc = load_rpc
        if like is None:
            return RestoreResult(state=flat, step=step, load_rpc=load_rpc)
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
        new_leaves = []
        for path, leaf in leaves_with_path[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx",
                           getattr(p, "name", p)))) for p in path)
            arr = flat[key]
            new_leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype)
                              if hasattr(leaf, "dtype") else arr)
        state = jax.tree_util.tree_unflatten(leaves_with_path[1], new_leaves)
        return RestoreResult(state=state, step=step, load_rpc=load_rpc)

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def _gc(self):
        dirs = sorted(self.dir.glob("step_*"))
        while len(dirs) > self.keep:
            import shutil
            shutil.rmtree(dirs.pop(0))
