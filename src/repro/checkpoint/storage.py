"""Per-client NFS RPC-slot view of the shared storage fabric — paper F2.

The paper's key finding: checkpoint I/O uses only 1.4-10.4% of the 200 Gbps
RoCE link because the bottleneck is the 128-slot NFS RPC layer, not the
network.  We model the client RPC lifecycle exactly as the paper decomposes
it: (1) slot wait (queueing for one of ``n_slots`` concurrent RPCs) and
(2) network+server processing (service time per RPC).  A discrete-event
simulation over request arrivals yields per-request latency decomposition,
achieved bandwidth, and therefore the bandwidth paradox — *derived*, not
assumed.

Since the cluster-scale refactor this module is a thin per-client window
onto `repro.storage.StorageFabric`: the per-RPC service times are no
longer free constants but the fabric's *effective* service at the
campaign's gang fanin — WRITE at the ~39-node effective writeback fanin
and READ at the 60-node restart-load fanin reproduce the paper's Table 13
values (126 ms / 27.3 ms) to within 2%.  Passing explicit
``write_service_s`` / ``read_service_s`` (e.g. degraded-storage
scenarios) bypasses the derivation.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass
from typing import List, Literal, Optional

import numpy as np

from repro.storage.fabric import (LINK_BW_BYTES, STD_READ_SLOTS,
                                  STD_WRITE_SLOTS, StorageFabric)

__all__ = ["LINK_BW_BYTES", "NFSConfig", "NFSClientSim", "RPCResult",
           "TransferResult"]


@dataclass(frozen=True)
class NFSConfig:
    n_slots: int = 128                 # client RPC slot table (paper)
    # None -> derived from the storage fabric at the fanins below
    # (fabric-effective Table 13: WRITE ~126 ms, READ ~27.3 ms)
    write_service_s: Optional[float] = None
    read_service_s: Optional[float] = None
    wsize: int = 1 << 20               # 1 MiB write RPCs
    rsize: int = 256 << 10             # 256 KiB effective read RPCs
    service_jitter: float = 0.15       # lognormal-ish spread
    n_connections: int = 1             # nconnect mounts (slots multiply)
    write_fanin: int = 39              # effective concurrent writers: saves
                                       #   destagger in the writeback window
    read_fanin: int = 60               # restart loads: the whole gang


@dataclass
class RPCResult:
    op: str
    arrival_s: float
    slot_wait_s: float
    service_s: float

    @property
    def latency_s(self) -> float:
        return self.slot_wait_s + self.service_s


@dataclass
class TransferResult:
    op: str
    total_bytes: int
    n_rpcs: int
    duration_s: float
    mean_slot_wait_s: float
    mean_service_s: float
    results: Optional[List[RPCResult]] = None

    @property
    def mean_latency_s(self) -> float:
        return self.mean_slot_wait_s + self.mean_service_s

    @property
    def slot_wait_fraction(self) -> float:
        m = self.mean_latency_s
        return self.mean_slot_wait_s / m if m > 0 else 0.0

    @property
    def bandwidth_bytes_s(self) -> float:
        return self.total_bytes / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def bandwidth_utilization(self) -> float:
        return self.bandwidth_bytes_s / LINK_BW_BYTES

    @property
    def request_rate_s(self) -> float:
        return self.n_rpcs / self.duration_s if self.duration_s > 0 else 0.0


class NFSClientSim:
    """Discrete-event simulation of one node's NFS client RPC slot table.

    Service times come from the shared ``StorageFabric`` (contention at the
    configured fanin baked in) unless the config pins them explicitly.
    """

    def __init__(self, config: Optional[NFSConfig] = None, seed: int = 0,
                 fabric: Optional[StorageFabric] = None):
        self.fabric = fabric or StorageFabric()
        self.config = self._resolve_config(config or NFSConfig())
        self.rng = np.random.default_rng(seed)

    def _resolve_config(self, config: NFSConfig) -> NFSConfig:
        """Fill None service times from the fabric.

        Derivation uses the fleet-standard slot tables, not this client's
        local override: the fanin inflation reflects what the REST of the
        cluster keeps in flight at the server."""
        w, r = config.write_service_s, config.read_service_s
        if w is None:
            w = self.fabric.service_time_s("write", config.write_fanin,
                                           STD_WRITE_SLOTS, config.wsize)
        if r is None:
            r = self.fabric.service_time_s("read", config.read_fanin,
                                           STD_READ_SLOTS, config.rsize)
        return dataclasses.replace(config, write_service_s=w,
                                   read_service_s=r)

    def _service_time(self, op: str, cfg: NFSConfig) -> float:
        base = cfg.write_service_s if op == "write" else cfg.read_service_s
        if cfg.service_jitter <= 0:
            return base
        return float(base * self.rng.lognormal(
            mean=0.0, sigma=cfg.service_jitter))

    def transfer(self, op: Literal["write", "read"], total_bytes: int,
                 arrival_rate_rpcs_s: Optional[float] = None,
                 burst: int = 1, keep_results: bool = False,
                 config: Optional[NFSConfig] = None) -> TransferResult:
        """Simulate moving ``total_bytes`` through the slot table.

        ``arrival_rate_rpcs_s``: request generation rate.  Checkpoint saves
        dump everything at once (writeback flush -> effectively infinite
        arrival rate -> pure slot-queueing, the paper's 92% slot-wait case);
        loads are paced by readahead (finite rate).

        ``config``: per-call override (e.g. the load path's nconnect=2
        mount) — the shared ``self.config`` is never mutated, so a load is
        safe against a concurrent save from the manager's flush thread.
        """
        cfg = self._resolve_config(config) if config is not None \
            else self.config
        rpc_size = cfg.wsize if op == "write" else cfg.rsize
        n = max(int(np.ceil(total_bytes / rpc_size)), 1)

        if arrival_rate_rpcs_s is None:
            arrivals = np.zeros(n)                      # burst: all at t=0
        else:
            arrivals = np.arange(n, dtype=np.float64) / arrival_rate_rpcs_s
            if burst > 1:
                # readahead issues window-sized burts: quantize arrivals so
                # ``burst`` requests land together (slot-queue contention)
                arrivals = (np.floor(np.arange(n) / burst) * burst
                            / arrival_rate_rpcs_s)

        # min-heap of slot free times (nconnect multiplies the slot table)
        slots = [0.0] * (cfg.n_slots * cfg.n_connections)
        heapq.heapify(slots)
        waits = np.empty(n)
        services = np.empty(n)
        end = 0.0
        results: List[RPCResult] = []
        for i in range(n):
            t_arr = arrivals[i]
            t_slot = heapq.heappop(slots)
            start = max(t_arr, t_slot)
            waits[i] = start - t_arr
            svc = self._service_time(op, cfg)
            services[i] = svc
            fin = start + svc
            heapq.heappush(slots, fin)
            end = max(end, fin)
            if keep_results:
                results.append(RPCResult(op, t_arr, waits[i], svc))

        return TransferResult(
            op=op, total_bytes=total_bytes, n_rpcs=n,
            duration_s=float(end),
            mean_slot_wait_s=float(waits.mean()),
            mean_service_s=float(services.mean()),
            results=results if keep_results else None)

    # -- paper-scenario helpers ---------------------------------------------

    def checkpoint_save(self, bytes_per_node: int = 20 << 30) -> TransferResult:
        """Burst write (writeback flush of the staging buffer)."""
        return self.transfer("write", bytes_per_node)

    def checkpoint_load(self, bytes_per_node: int = 200 << 30,
                        readahead_rpcs_s: float = 8800.0) -> TransferResult:
        """Sustained read at the paper's observed 8-9k req/s/node pace.

        Loads run over nconnect=2 mounts (two slot tables) — required to
        sustain the observed request rate; the override is a per-call
        config, never a mutation of the shared one."""
        cfg = dataclasses.replace(self.config, n_connections=2)
        return self.transfer("read", bytes_per_node,
                             arrival_rate_rpcs_s=readahead_rpcs_s,
                             burst=512, config=cfg)
