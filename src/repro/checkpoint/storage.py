"""NFS RPC-slot storage model — paper F2 / §4.2.5.

The paper's key finding: checkpoint I/O uses only 1.4-10.4% of the 200 Gbps
RoCE link because the bottleneck is the 128-slot NFS RPC layer, not the
network.  We model the client RPC lifecycle exactly as the paper decomposes
it: (1) slot wait (queueing for one of ``n_slots`` concurrent RPCs) and
(2) network+server processing (service time per RPC).  A discrete-event
simulation over request arrivals yields per-request latency decomposition,
achieved bandwidth, and therefore the bandwidth paradox — *derived*, not
assumed.

Service-time constants are taken from paper Table 13 (WRITE 126 ms,
READ 27.3 ms per-RPC network+server time).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Literal, Optional

import numpy as np

LINK_BW_BYTES = 25e9          # 200 Gbps RoCE per node


@dataclass(frozen=True)
class NFSConfig:
    n_slots: int = 128                 # client RPC slot table (paper)
    write_service_s: float = 0.126     # per-RPC server+network, WRITE
    read_service_s: float = 0.0273     # per-RPC server+network, READ
    wsize: int = 1 << 20               # 1 MiB write RPCs
    rsize: int = 256 << 10             # 256 KiB effective read RPCs
    service_jitter: float = 0.15       # lognormal-ish spread
    n_connections: int = 1             # nconnect mounts (slots multiply)


@dataclass
class RPCResult:
    op: str
    arrival_s: float
    slot_wait_s: float
    service_s: float

    @property
    def latency_s(self) -> float:
        return self.slot_wait_s + self.service_s


@dataclass
class TransferResult:
    op: str
    total_bytes: int
    n_rpcs: int
    duration_s: float
    mean_slot_wait_s: float
    mean_service_s: float
    results: Optional[List[RPCResult]] = None

    @property
    def mean_latency_s(self) -> float:
        return self.mean_slot_wait_s + self.mean_service_s

    @property
    def slot_wait_fraction(self) -> float:
        m = self.mean_latency_s
        return self.mean_slot_wait_s / m if m > 0 else 0.0

    @property
    def bandwidth_bytes_s(self) -> float:
        return self.total_bytes / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def bandwidth_utilization(self) -> float:
        return self.bandwidth_bytes_s / LINK_BW_BYTES

    @property
    def request_rate_s(self) -> float:
        return self.n_rpcs / self.duration_s if self.duration_s > 0 else 0.0


class NFSClientSim:
    """Discrete-event simulation of one node's NFS client RPC slot table."""

    def __init__(self, config: NFSConfig = NFSConfig(), seed: int = 0):
        self.config = config
        self.rng = np.random.default_rng(seed)

    def _service_time(self, op: str) -> float:
        base = self.config.write_service_s if op == "write" \
            else self.config.read_service_s
        if self.config.service_jitter <= 0:
            return base
        return float(base * self.rng.lognormal(
            mean=0.0, sigma=self.config.service_jitter))

    def transfer(self, op: Literal["write", "read"], total_bytes: int,
                 arrival_rate_rpcs_s: Optional[float] = None,
                 burst: int = 1, keep_results: bool = False) -> TransferResult:
        """Simulate moving ``total_bytes`` through the slot table.

        ``arrival_rate_rpcs_s``: request generation rate.  Checkpoint saves
        dump everything at once (writeback flush -> effectively infinite
        arrival rate -> pure slot-queueing, the paper's 92% slot-wait case);
        loads are paced by readahead (finite rate).
        """
        cfg = self.config
        rpc_size = cfg.wsize if op == "write" else cfg.rsize
        n = max(int(np.ceil(total_bytes / rpc_size)), 1)

        if arrival_rate_rpcs_s is None:
            arrivals = np.zeros(n)                      # burst: all at t=0
        else:
            arrivals = np.arange(n, dtype=np.float64) / arrival_rate_rpcs_s
            if burst > 1:
                # readahead issues window-sized burts: quantize arrivals so
                # ``burst`` requests land together (slot-queue contention)
                arrivals = (np.floor(np.arange(n) / burst) * burst
                            / arrival_rate_rpcs_s)

        # min-heap of slot free times (nconnect multiplies the slot table)
        slots = [0.0] * (cfg.n_slots * cfg.n_connections)
        heapq.heapify(slots)
        waits = np.empty(n)
        services = np.empty(n)
        end = 0.0
        results: List[RPCResult] = []
        for i in range(n):
            t_arr = arrivals[i]
            t_slot = heapq.heappop(slots)
            start = max(t_arr, t_slot)
            waits[i] = start - t_arr
            svc = self._service_time(op)
            services[i] = svc
            fin = start + svc
            heapq.heappush(slots, fin)
            end = max(end, fin)
            if keep_results:
                results.append(RPCResult(op, t_arr, waits[i], svc))

        return TransferResult(
            op=op, total_bytes=total_bytes, n_rpcs=n,
            duration_s=float(end),
            mean_slot_wait_s=float(waits.mean()),
            mean_service_s=float(services.mean()),
            results=results if keep_results else None)

    # -- paper-scenario helpers ---------------------------------------------

    def checkpoint_save(self, bytes_per_node: int = 20 << 30) -> TransferResult:
        """Burst write (writeback flush of the staging buffer)."""
        return self.transfer("write", bytes_per_node)

    def checkpoint_load(self, bytes_per_node: int = 200 << 30,
                        readahead_rpcs_s: float = 8800.0) -> TransferResult:
        """Sustained read at the paper's observed 8-9k req/s/node pace.

        Loads run over nconnect=2 mounts (two slot tables) — required to
        sustain >128/0.0273 = 4.7k req/s; documented in DESIGN.md §8."""
        import dataclasses
        prev = self.config
        self.config = dataclasses.replace(prev, n_connections=2)
        try:
            return self.transfer("read", bytes_per_node,
                                 arrival_rate_rpcs_s=readahead_rpcs_s,
                                 burst=512)
        finally:
            self.config = prev
