"""AdamW with decoupled weight decay, fp32 states, global-norm clipping.

States are plain pytrees mirroring the parameters, so the distributed layer
shards them with exactly the same PartitionSpecs as the parameters (ZeRO-style
optimizer sharding falls out of FSDP param sharding for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    mu: Any                    # fp32 pytree
    nu: Any                    # fp32 pytree


class _Upd(NamedTuple):
    p: Any
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def schedule(self, step):
        """Linear warmup + cosine decay to min_lr_ratio."""
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        frac = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return self.lr * warm * (self.min_lr_ratio + (1 - self.min_lr_ratio) * cos)

    def update(self, grads, state: AdamWState, params):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return _Upd((p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v)

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        is_upd = lambda t: isinstance(t, _Upd)
        new_params = jax.tree.map(lambda t: t.p, out, is_leaf=is_upd)
        new_mu = jax.tree.map(lambda t: t.m, out, is_leaf=is_upd)
        new_nu = jax.tree.map(lambda t: t.v, out, is_leaf=is_upd)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
