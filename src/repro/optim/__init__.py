from repro.optim.adamw import AdamW, AdamWState, global_norm
