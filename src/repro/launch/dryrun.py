import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   This flag is dry-run-only — smoke tests and benchmarks see 1 device.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import (  # noqa: E402
    ASSIGNED_ARCHS, SHAPES, MAMBA, RWKV, cell_is_runnable, get_config)
from repro.distributed.hlo_analysis import (  # noqa: E402
    Roofline, collective_bytes, count_collective_ops)
from repro.distributed.sharding import ShardingRules  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    make_prefill_step, make_serve_step, make_train_step)
from repro.models.model import RunOptions  # noqa: E402
from repro.optim import AdamW  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def _resolve_shape(name):
    # assigned shapes plus the paper Table-5 training shapes
    if name in SHAPES:
        return SHAPES[name]
    from repro.configs.paper_solar import PAPER_SHAPES
    return PAPER_SHAPES[name]


# ---------------------------------------------------------------------------
# RunOptions variants (the §Perf hillclimb ladder)
# ---------------------------------------------------------------------------

VARIANTS = {
    # paper-faithful baseline: HSDP + standard chunked attention + remat
    "baseline": RunOptions(attn_backend="chunked", q_chunk=2048, kv_chunk=2048,
                           remat="dots", mamba_chunk=1,
                           rwkv_backend="sequential"),
    # naive full-matrix attention (the memory-term ablation)
    "naive-attn": RunOptions(attn_backend="naive", remat="dots"),
    # the naive port: no grad constraints, naive attention, no remat —
    # where a straight translation of the paper's stack lands (§Perf start)
    "naive-port": RunOptions(attn_backend="naive", remat="none",
                             constrain_grads=False),
    # no remat (compute-vs-memory trade)
    "no-remat": RunOptions(attn_backend="chunked", q_chunk=2048, kv_chunk=2048,
                           remat="none"),
    # full remat: save only layer boundaries
    "full-remat": RunOptions(attn_backend="chunked", q_chunk=2048,
                             kv_chunk=2048, remat="full"),
    # chunked CE loss (never materialise (B,S,V) logits)
    "loss-chunk": RunOptions(attn_backend="chunked", q_chunk=2048,
                             kv_chunk=2048, remat="full", loss_chunk=512),
    # chunk-parallel recurrences (MXU-form mamba/rwkv)
    "chunked-scan": RunOptions(attn_backend="chunked", q_chunk=2048,
                               kv_chunk=2048, remat="full", mamba_chunk=16,
                               rwkv_backend="chunked", rwkv_chunk=64),
    # EP-pinned MoE dispatch (collective-term fix; §Perf iteration 3)
    "moe-shard": RunOptions(attn_backend="chunked", q_chunk=2048,
                            kv_chunk=2048, remat="dots",
                            moe_constraints=True),
    # everything on
    "opt": RunOptions(attn_backend="chunked", q_chunk=2048, kv_chunk=2048,
                      remat="full", loss_chunk=512, mamba_chunk=16,
                      rwkv_backend="chunked", rwkv_chunk=64,
                      moe_constraints=True),
    # iteration 5: drop remat (kills backward re-gathers) + bf16 attn math
    "opt2": RunOptions(attn_backend="chunked", q_chunk=2048, kv_chunk=2048,
                       remat="none", loss_chunk=512, mamba_chunk=16,
                       rwkv_backend="chunked", rwkv_chunk=64,
                       moe_constraints=True, attn_bf16=True),
    # bf16 attention math alone (memory-term ablation for prefill)
    "bf16-attn": RunOptions(attn_backend="chunked", q_chunk=2048,
                            kv_chunk=2048, remat="dots", attn_bf16=True),
    # iteration 9: explicit all-to-all MoE dispatch (shard_map)
    "moe-a2a": RunOptions(attn_backend="chunked", q_chunk=2048,
                          kv_chunk=2048, remat="dots", moe_impl="a2a"),
}


def _build_lowered(cfg, shape, opts, mesh, rules, optimizer):
    """jit + lower one step function for (cfg, shape) under ``mesh``."""
    from repro.distributed.context import activation_sharding
    specs = specs_mod.input_specs(cfg, shape, optimizer)
    with mesh, activation_sharding(rules):
        if shape.kind == "train":
            p_sh = rules.params_shardings(specs["params"])
            o_sh = rules.opt_shardings(specs["opt_state"], specs["params"])
            b_sh = rules.batch_shardings(specs["batch"])
            step = make_train_step(
                cfg, opts, optimizer,
                grad_shardings=p_sh if opts.constrain_grads else None)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(specs["params"], specs["opt_state"],
                                   specs["batch"])
        elif shape.kind == "prefill":
            p_sh = rules.params_shardings(specs["params"])
            b_sh = rules.batch_shardings(specs["batch"])
            step = make_prefill_step(cfg, opts)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:  # decode
            p_sh = rules.params_shardings(specs["params"])
            c_sh = rules.cache_shardings(specs["cache"])
            t_sh = rules.batch_shardings(specs["tokens"])
            step = make_serve_step(cfg, opts)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, c_sh, t_sh, rules.replicated()),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(specs["params"], specs["cache"],
                                   specs["tokens"], specs["pos"])
        compiled = lowered.compile()
    return lowered, compiled


def _cost_numbers(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    ops = count_collective_ops(hlo)
    return flops, byts, coll, ops


def _inner_scan_correction(cfg, shape):
    """Analytic per-trip correction for time-recurrence lax.scans (counted
    once by cost_analysis).  Mamba/RWKV recurrences are 1-2% of block cost;
    projections dominate — see EXPERIMENTS.md §Roofline methodology."""
    if shape.kind == "decode":
        return 0.0, 0.0          # single-token step: trip count is 1
    b, s = shape.global_batch, shape.seq_len
    extra_f = extra_b = 0.0
    for spec in cfg.layers:
        if spec.kind == MAMBA:
            din = spec.expand * cfg.d_model
            n = spec.d_state
            per_f = 10.0 * b * din * n
            per_b = 6.0 * b * din * n * 4
        elif spec.kind == RWKV:
            h = cfg.d_model // cfg.rwkv_head_dim
            dd = cfg.rwkv_head_dim
            per_f = 8.0 * b * h * dd * dd
            per_b = 3.0 * b * h * dd * dd * 4
        else:
            continue
        extra_f += (s - 1) * per_f
        extra_b += (s - 1) * per_b
    if shape.kind == "train":    # backward re-runs the recurrence
        extra_f *= 3.0
        extra_b *= 3.0
    return extra_f, extra_b


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               variant: str = "baseline", fsdp_pods: bool = False,
               skip_cost: bool = False):
    """One (arch x shape x mesh) cell.

    1. GATE: lower+compile the full config (scan layer stack) — proves the
       sharding config is coherent; memory_analysis() is the fits-check.
    2. COST: lower n_periods=1 and n_periods=2 with unrolled period loops,
       then extrapolate flops/bytes/collectives to the full depth (XLA
       cost_analysis counts scan bodies once — measured, see §Roofline).
    """
    cfg = get_config(arch)
    shape = _resolve_shape(shape_name)
    opts = VARIANTS[variant]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh, fsdp_pods=fsdp_pods)
    optimizer = AdamW()
    chips = mesh.devices.size

    out = {"chips": chips}

    # ---- gate compile (full model) ----
    t0 = time.time()
    lowered, compiled = _build_lowered(cfg, shape, opts, mesh, rules, optimizer)
    out["gate_compile_s"] = round(time.time() - t0, 1)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = int(getattr(ma, k, 0) or 0)
    except Exception as e:
        mem["error"] = str(e)
    out["memory_analysis"] = mem
    out["gate_collective_ops"] = count_collective_ops(compiled.as_text())
    del lowered, compiled

    if skip_cost:
        return out

    # ---- two-point cost extraction ----
    cost_opts = dataclasses.replace(
        opts, unroll_periods=True, loss_chunk=0,
        rwkv_backend="sequential", mamba_chunk=1)
    pts = {}
    for npd in (1, 2):
        cfg_n = dataclasses.replace(cfg, n_periods=npd)
        t0 = time.time()
        _, comp = _build_lowered(cfg_n, shape, cost_opts, mesh, rules, optimizer)
        pts[npd] = _cost_numbers(comp)
        out[f"cost_compile_{npd}p_s"] = round(time.time() - t0, 1)
        del comp

    n = cfg.n_periods
    f1, b1, c1, _ = pts[1]
    f2, b2, c2, ops2 = pts[2]
    flops_dev = f1 + (n - 1) * (f2 - f1)
    bytes_dev = b1 + (n - 1) * (b2 - b1)
    coll: dict = {}
    for kind in set(c1) | set(c2):
        v = c1.get(kind, 0) + (n - 1) * (c2.get(kind, 0) - c1.get(kind, 0))
        if v > 0:
            coll[kind] = v

    corr_f, corr_b = _inner_scan_correction(cfg, shape)
    flops_dev += corr_f / chips
    bytes_dev += corr_b / chips

    roof = Roofline(
        flops=flops_dev * chips,
        hbm_bytes=bytes_dev * chips,
        coll_bytes_per_device=float(sum(coll.values())),
        chips=chips,
        coll_breakdown=coll,
    )
    out.update({
        "per_device_flops": flops_dev,
        "per_device_bytes": bytes_dev,
        "roofline": roof.as_dict(),
        "inner_scan_correction_flops": corr_f,
    })
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             variant: str = "baseline", fsdp_pods: bool = False,
             skip_cost: bool = False, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant, "fsdp_pods": fsdp_pods,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    ok, reason = cell_is_runnable(cfg, _resolve_shape(shape_name))
    if not ok:
        record.update({"status": "SKIP", "reason": reason})
        return record
    t0 = time.time()
    try:
        record.update(lower_cell(arch, shape_name, multi_pod=multi_pod,
                                 variant=variant, fsdp_pods=fsdp_pods,
                                 skip_cost=skip_cost))
        record["status"] = "OK"
        record["total_s"] = round(time.time() - t0, 1)
        if verbose and "roofline" in record:
            r = record["roofline"]
            print(f"  memory_analysis: {record['memory_analysis']}")
            print(f"  roofline: compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                  f"dominant={r['dominant']}", flush=True)
    except Exception as e:
        record.update({"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:],
                       "total_s": round(time.time() - t0, 1)})
    return record


def _result_path(variant: str) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    return RESULTS / f"dryrun_{variant}.json"


def load_results(variant: str) -> dict:
    p = _result_path(variant)
    if p.exists():
        return json.loads(p.read_text())
    return {}


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--fsdp-pods", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="gate compile only (no roofline extraction)")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS + ["paper-solar-102b"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = load_results(args.variant)
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                key = f"{arch}|{shape}|{'2x16x16' if multi_pod else '16x16'}"
                if args.fsdp_pods:
                    key += "|fsdp_pods"
                prev = results.get(key)
                if prev and prev.get("status") in ("OK", "SKIP") and not args.force:
                    print(f"[cached] {key}: {prev['status']}")
                    continue
                print(f"[run] {key} variant={args.variant} ...", flush=True)
                rec = run_cell(arch, shape, multi_pod=multi_pod,
                               variant=args.variant, fsdp_pods=args.fsdp_pods,
                               skip_cost=args.skip_cost)
                results[key] = rec
                _result_path(args.variant).write_text(json.dumps(results, indent=1))
                status = rec["status"]
                extra = rec.get("reason") or rec.get("error") or \
                    f"total={rec.get('total_s')}s dominant={rec.get('roofline', {}).get('dominant')}"
                print(f"  -> {status} ({extra})", flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "OK")
    n_skip = sum(1 for r in results.values() if r["status"] == "SKIP")
    n_fail = sum(1 for r in results.values() if r["status"] == "FAIL")
    print(f"done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
