"""Step functions (train / prefill / serve) shared by smoke tests, the
dry-run, and the real training driver."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_mod
from repro.models.model import RunOptions
from repro.optim import AdamW


def make_train_step(cfg: ArchConfig, opts: RunOptions, optimizer: AdamW,
                    grad_shardings=None):
    """``grad_shardings``: optional pytree of NamedSharding matching params.

    Without explicit constraints XLA's sharding propagation replicates
    weight-gradient matmuls across the ``model`` axis (measured 8x FLOP
    inflation on dW contractions — EXPERIMENTS.md §Perf iteration 2), so
    production configs pin dW to the parameter sharding.
    """
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model_mod.loss_fn, has_aux=True)(params, cfg, opts, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ArchConfig, opts: RunOptions):
    def prefill_step(params, batch):
        inputs = batch.get("tokens", batch.get("embeds"))
        logits, cache = model_mod.prefill(params, cfg, opts, inputs,
                                          img_embeds=batch.get("img_embeds"))
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ArchConfig, opts: RunOptions):
    def serve_step(params, cache, tokens, pos):
        logits, cache = model_mod.decode_step(params, cfg, opts, tokens,
                                              cache, pos)
        return logits, cache
    return serve_step


# ---------------------------------------------------------------------------
# Synthetic batches (smoke tests / examples); the dry-run uses
# launch.specs.input_specs (ShapeDtypeStructs) instead.
# ---------------------------------------------------------------------------

def synthetic_batch(rng, cfg: ArchConfig, batch: int, seq: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    out = {"labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)}
    if cfg.embed_inputs:
        out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
    else:
        out["embeds"] = jax.random.normal(k1, (batch, seq, cfg.d_model),
                                          cfg.cdtype) * 0.02
    if cfg.n_img_tokens:
        out["img_embeds"] = jax.random.normal(
            k3, (batch, cfg.n_img_tokens, cfg.d_model), cfg.cdtype) * 0.02
    return out


def synthetic_decode_inputs(rng, cfg: ArchConfig, batch: int, seq: int,
                            pos: Optional[int] = None):
    cache = model_mod.init_cache(cfg, batch, seq)
    if cfg.embed_inputs:
        tokens = jax.random.randint(rng, (batch, 1), 0, cfg.vocab_size)
    else:
        tokens = jax.random.normal(rng, (batch, 1, cfg.d_model), cfg.cdtype)
    pos = jnp.asarray(seq - 1 if pos is None else pos, jnp.int32)
    return cache, tokens, pos
