"""Fault-tolerant end-to-end training driver.

Runs real JAX training under the paper's full recovery stack:
  data (per-rank sharded files, §3.5 fix) -> train_step (pjit) ->
  two-phase async checkpointing at a Young/Daly-derived interval ->
  failure injection (XID-classified) -> auto-retry chains -> resume from
  the last checkpoint -> per-step throughput instrumentation (tokens/s —
  the telemetry the paper's §7.2 said was missing) with fail-slow
  (straggler) detection on step-time deviation.

CPU-friendly presets keep the demo runnable in CI; ``--arch <id>`` accepts
any assigned architecture (reduced config unless --full).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.core.retry import (Attempt, Chain, RetryConfig, RetryEngine,
                              RetryPolicy, chain_stats)
from repro.core.xid import XID_TABLE
from repro.data.pipeline import DataConfig, synthetic_stream
from repro.launch.steps import make_train_step
from repro.models import model as model_mod
from repro.models.model import RunOptions
from repro.optim import AdamW


class SimulatedXid(RuntimeError):
    def __init__(self, xid: int, step: int):
        super().__init__(f"XID {xid} at step {step}")
        self.xid = xid
        self.step = step


@dataclasses.dataclass
class TrainReport:
    steps_done: int
    final_loss: float
    tokens_per_s: float
    n_failures: int
    n_restarts: int
    chain: dict
    checkpoint_saves: int
    restore_steps: list
    slow_steps: int
    losses: list


def run_training(arch: str = "stablelm-3b", *, steps: int = 50,
                 batch: int = 2, seq: int = 128,
                 ckpt_dir: str = "/tmp/repro_ckpt",
                 fail_at: tuple = (), fail_xid: int = 94,
                 retry_policy: str = "fixed",
                 mtbf_h: float = 56.2, full: bool = False,
                 lr: float = 1e-3, seed: int = 0,
                 log_every: int = 10, verbose: bool = True) -> TrainReport:
    cfg = get_config(arch)
    if not full:
        cfg = cfg.reduced()
    opts = RunOptions(q_chunk=min(128, seq), kv_chunk=min(128, seq))
    optimizer = AdamW(lr=lr, warmup_steps=max(steps // 10, 1),
                      total_steps=steps)

    rng = jax.random.PRNGKey(seed)
    params = model_mod.init_params(rng, cfg)
    opt_state = optimizer.init(params)
    train_step = jax.jit(make_train_step(cfg, opts, optimizer))

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, seed=seed)
    stream = synthetic_stream(data_cfg, batch, seed=seed)

    mgr = CheckpointManager(Path(ckpt_dir) / arch, keep=2)
    retry = RetryEngine(RetryConfig(policy=RetryPolicy(retry_policy)))
    chain = Chain(task_name=f"train-{arch}")

    # Young/Daly interval in *steps*: measure delta on the first save, then
    # T_opt = sqrt(2 delta M) converted via measured step time.
    ckpt_every = max(steps // 5, 5)

    fail_at = set(fail_at)
    step = 0
    saves = 0
    restore_steps = []
    losses = []
    step_times = []
    slow_steps = 0
    n_failures = 0
    tokens_total = 0
    t_run0 = time.perf_counter()

    while step < steps:
        chain.attempts.append(Attempt(start_h=step))
        try:
            while step < steps:
                t0 = time.perf_counter()
                batch_np = next(stream)
                jbatch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                if cfg.n_img_tokens:
                    jbatch["img_embeds"] = jnp.zeros(
                        (batch, cfg.n_img_tokens, cfg.d_model), cfg.cdtype)
                if not cfg.embed_inputs:
                    jbatch["embeds"] = jax.random.normal(
                        jax.random.PRNGKey(step), (batch, seq, cfg.d_model),
                        cfg.cdtype) * 0.02
                    jbatch.pop("tokens", None)
                params, opt_state, metrics = train_step(params, opt_state,
                                                        jbatch)
                loss = float(metrics["loss"])
                losses.append(loss)
                if math.isnan(loss):
                    raise SimulatedXid(31, step)      # divergence -> restart
                step += 1
                tokens_total += batch * seq
                dt = time.perf_counter() - t0
                step_times.append(dt)
                chain.attempts[-1].reached_training = True

                # fail-slow (straggler) detection: step time vs trailing dist
                if len(step_times) > 10:
                    hist = np.asarray(step_times[-11:-1])
                    if dt > hist.mean() + 6 * max(hist.std(), 1e-4):
                        slow_steps += 1

                if step % ckpt_every == 0:
                    mgr.save(step, {"params": params,
                                    "opt_state": opt_state}, blocking=False)
                    saves += 1
                if verbose and step % log_every == 0:
                    tps = batch * seq / dt
                    print(f"  step {step:4d} loss={loss:.4f} "
                          f"{tps:,.0f} tok/s", flush=True)
                if step in fail_at:
                    fail_at.discard(step)     # hardware events fire once
                    raise SimulatedXid(fail_xid, step)
        except SimulatedXid as e:
            n_failures += 1
            chain.attempts[-1].end_h = step
            chain.attempts[-1].failure_kind = "xid"
            chain.attempts[-1].xid = e.xid
            info = XID_TABLE[e.xid]
            delay = retry.next_delay_min(len(chain.attempts), xid=e.xid)
            if verbose:
                print(f"!! XID {e.xid} ({info.description}) at step {e.step} "
                      f"-> {info.resolution.value}; retry in "
                      f"{delay if delay is not None else 'MANUAL'} min "
                      f"(simulated)", flush=True)
            if delay is None:
                break
            # restore from the last checkpoint (the session-restart path)
            mgr.wait()
            last = mgr.latest_step()
            if last is not None:
                state, _ = mgr.restore(like={"params": params,
                                             "opt_state": opt_state})
                params, opt_state = state["params"], state["opt_state"]
                step = last
            else:
                params = model_mod.init_params(rng, cfg)
                opt_state = optimizer.init(params)
                step = 0
            restore_steps.append(step)

    mgr.wait()
    wall = time.perf_counter() - t_run0
    report = TrainReport(
        steps_done=step,
        final_loss=losses[-1] if losses else float("nan"),
        tokens_per_s=tokens_total / wall,
        n_failures=n_failures,
        n_restarts=len(restore_steps),
        chain=chain_stats([chain]),
        checkpoint_saves=saves,
        restore_steps=restore_steps,
        slow_steps=slow_steps,
        losses=losses,
    )
    return report


def main():
    ap = argparse.ArgumentParser(description="fault-tolerant trainer")
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[25])
    ap.add_argument("--fail-xid", type=int, default=94)
    ap.add_argument("--retry-policy", default="fixed",
                    choices=[p.value for p in RetryPolicy])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="full (unreduced) arch config — real-hardware scale")
    args = ap.parse_args()

    rep = run_training(args.arch, steps=args.steps, batch=args.batch,
                       seq=args.seq, fail_at=tuple(args.fail_at),
                       fail_xid=args.fail_xid,
                       retry_policy=args.retry_policy,
                       ckpt_dir=args.ckpt_dir, full=args.full)
    out = dataclasses.asdict(rep)
    out.pop("losses")
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
