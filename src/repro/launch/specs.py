"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

The dry-run lowers against these; nothing here touches real device memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as model_mod
from repro.optim import AdamW

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if cfg.embed_inputs:
        out["tokens"] = SDS((b, s), jnp.int32)
    else:
        out["embeds"] = SDS((b, s, cfg.d_model), cfg.cdtype)
    if shape.kind == "train":
        out["labels"] = SDS((b, s), jnp.int32)
    if cfg.n_img_tokens:
        out["img_embeds"] = SDS((b, cfg.n_img_tokens, cfg.d_model), cfg.cdtype)
    return out


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """serve_step inputs: (tokens, pos, cache). Cache spans shape.seq_len."""
    b = shape.global_batch
    if cfg.embed_inputs:
        tokens = SDS((b, 1), jnp.int32)
    else:
        tokens = SDS((b, 1, cfg.d_model), cfg.cdtype)
    pos = SDS((), jnp.int32)
    cache = jax.eval_shape(lambda: model_mod.init_cache(cfg, b, shape.seq_len))
    return tokens, pos, cache


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: model_mod.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ArchConfig, optimizer: AdamW, params_shapes=None):
    p = params_shapes if params_shapes is not None else abstract_params(cfg)
    return jax.eval_shape(optimizer.init, p)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, optimizer: AdamW = None):
    """All inputs for the step kind of ``shape`` (dry-run entry point)."""
    if shape.kind == "train":
        optimizer = optimizer or AdamW()
        p = abstract_params(cfg)
        o = abstract_opt_state(cfg, optimizer, p)
        return {"params": p, "opt_state": o,
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": abstract_params(cfg),
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "decode":
        tokens, pos, cache = decode_input_specs(cfg, shape)
        return {"params": abstract_params(cfg), "cache": cache,
                "tokens": tokens, "pos": pos}
    raise ValueError(shape.kind)
